//! Regenerate paper Figures 1, 2 and 3.
//!
//! ```text
//! cargo run --release --example compare_strategies -- --figure fig1 --out results/fig1.csv
//! cargo run --release --example compare_strategies -- --figure fig2 --out results/fig2.csv
//! cargo run --release --example compare_strategies -- --figure fig3 --out results/fig3.csv
//! ```
//!
//! * fig1 — training loss vs iterations, PerSyn vs GoSGD across `p`.
//! * fig2 — training loss vs simulated wall clock, GoSGD vs EASGD (+PerSyn).
//! * fig3 — validation accuracy vs iterations, PerSyn vs GoSGD.

use gosgd::harness::{fig1, fig2, fig3};
use gosgd::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let a = Args::new("compare_strategies", "regenerate paper figures 1-3")
        .opt("figure", "fig1", "fig1 | fig2 | fig3")
        .opt("artifacts", "artifacts", "artifact directory root")
        .opt("model", "tiny", "model variant")
        .opt("workers", "8", "number of workers M")
        .opt("iterations", "150", "worker iterations (fig1/fig3)")
        .opt("ps", "0.01,0.4", "exchange probabilities (fig1/fig3)")
        .opt("p", "0.02", "exchange probability (fig2)")
        .opt("shards", "1", "gossip shards per exchange; > 1 adds a sharded-GoSGD series (fig2)")
        .opt("horizon", "120", "simulated seconds (fig2)")
        .opt("backend", "quadratic", "fig2 gradient backend: quadratic | pjrt")
        .opt("seed", "0", "RNG seed")
        .opt("out", "", "CSV output path")
        .parse()?;

    let out = match a.get("out")? {
        "" => None,
        p => Some(std::path::PathBuf::from(p)),
    };
    let ps: Vec<f64> = a
        .get("ps")?
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<Vec<_>, _>>()?;

    match a.get("figure")? {
        "fig1" => {
            let cfg = fig1::Fig1Config {
                artifacts_dir: a.get("artifacts")?.into(),
                model: a.get("model")?.to_string(),
                workers: a.get_usize("workers")?,
                iterations: a.get_u64("iterations")?,
                ps,
                seed: a.get_u64("seed")?,
                ema_beta: 0.9,
            };
            println!("figure 1: training loss vs iterations (model {})\n", cfg.model);
            let series = fig1::run(&cfg, out.as_deref())?;
            println!("{}", fig1::format_table(&series));
            // paper claim: GoSGD uses half the messages of PerSyn at equal p
            for pair in series.chunks(2) {
                if let [g, p] = pair {
                    println!(
                        "messages at equal rate: {} = {}, {} = {} (persyn/gosgd = {:.2}x)",
                        g.label,
                        g.messages,
                        p.label,
                        p.messages,
                        p.messages as f64 / g.messages.max(1) as f64
                    );
                }
            }
        }
        "fig2" => {
            let backend = match a.get("backend")? {
                "pjrt" => fig2::Fig2Backend::Pjrt {
                    artifacts_dir: a.get("artifacts")?.into(),
                    model: a.get("model")?.to_string(),
                },
                _ => fig2::Fig2Backend::Quadratic { dim: 1024, sigma: 0.2 },
            };
            let cfg = fig2::Fig2Config {
                backend,
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                horizon_secs: a.get_f64("horizon")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            println!(
                "figure 2: loss vs simulated wall clock (p={}, shards={}, horizon {}s)\n",
                cfg.p, cfg.shards, cfg.horizon_secs
            );
            let series = fig2::run(&cfg, out.as_deref())?;
            let threshold = series
                .iter()
                .flat_map(|s| s.points.last().map(|(_, l)| *l))
                .fold(f64::INFINITY, f64::min)
                * 1.5;
            println!("{}", fig2::format_table(&series, threshold));
        }
        "fig3" => {
            let cfg = fig3::Fig3Config {
                artifacts_dir: a.get("artifacts")?.into(),
                model: a.get("model")?.to_string(),
                workers: a.get_usize("workers")?,
                iterations: a.get_u64("iterations")?,
                ps,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            println!("figure 3: validation accuracy vs iterations (model {})\n", cfg.model);
            let series = fig3::run(&cfg, out.as_deref())?;
            println!("{}", fig3::format_table(&series));
        }
        other => {
            eprintln!("unknown figure {other}; use fig1 | fig2 | fig3");
            std::process::exit(2);
        }
    }
    if let Some(p) = &out {
        println!("series written to {}", p.display());
    }
    Ok(())
}
