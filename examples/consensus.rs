//! Reproduce paper Figure 4: consensus error under worst-case updates.
//!
//! Gradients are replaced by i.i.d. N(0,1) noise (section 5.2) and we
//! track ε(t) = Σ_m ‖x_m − x̄‖² for GoSGD and PerSyn across exchange
//! frequencies.  Pure Rust — no artifacts needed.
//!
//! ```text
//! cargo run --release --example consensus -- --out results/fig4.csv
//! ```

use gosgd::harness::fig4;
use gosgd::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let a = Args::new("consensus", "paper Fig. 4: consensus under pure-noise updates")
        .opt("workers", "8", "number of workers M")
        .opt("dim", "1000", "parameter dimension")
        .opt("rounds", "1000", "rounds (1 round = M gossip ticks)")
        .opt("ps", "0.01,0.1,0.5,1.0", "exchange probabilities")
        .opt("seed", "0", "RNG seed")
        .opt("out", "", "CSV output path (empty = console only)")
        .parse()?;

    let cfg = fig4::Fig4Config {
        workers: a.get_usize("workers")?,
        dim: a.get_usize("dim")?,
        rounds: a.get_u64("rounds")?,
        ps: a
            .get("ps")?
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<Vec<_>, _>>()?,
        seed: a.get_u64("seed")?,
        include_local: true,
    };
    println!(
        "consensus experiment: M={} dim={} rounds={} ps={:?}\n",
        cfg.workers, cfg.dim, cfg.rounds, cfg.ps
    );
    let out = match a.get("out")? {
        "" => None,
        p => Some(std::path::PathBuf::from(p)),
    };
    let series = fig4::run(&cfg, out.as_deref())?;
    println!("{}", fig4::format_table(&series));
    if let Some(p) = &out {
        println!("series written to {}", p.display());
    }

    // The paper's qualitative claims, checked live:
    let find = |tag: &str| series.iter().find(|s| s.label.contains(tag));
    if let (Some(g), Some(p)) = (find("gosgd_p0.01"), find("persyn_p0.01")) {
        println!("\npaper claim checks (p=0.01):");
        println!(
            "  magnitudes comparable: gosgd mean ε = {:.1}, persyn mean ε = {:.1}",
            g.mean_eps(),
            p.mean_eps()
        );
        println!(
            "  gossip varies less:    gosgd cv = {:.3}, persyn cv = {:.3}",
            g.cv(),
            p.cv()
        );
    }
    Ok(())
}
