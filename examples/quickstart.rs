//! Quickstart: train the tiny model with GoSGD on 8 workers.
//!
//! ```text
//! make artifacts
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole stack in ~30 lines: load AOT artifacts, build a
//! run configuration, train with gossip exchange, inspect the report.

use gosgd::config::{RunConfig, StrategyKind};
use gosgd::coordinator::Coordinator;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = RunConfig::default();
    cfg.model = "tiny".into();
    cfg.workers = 8;
    // Async engine: 8 ticks ≈ one step per worker.
    cfg.steps = 50 * cfg.workers as u64;
    cfg.strategy = StrategyKind::GoSgd { p: 0.05 };
    cfg.eval_every = 10 * cfg.workers as u64;
    cfg.eval_batches = 2;

    println!("GoSGD quickstart: {} on {}", cfg.strategy.tag(), cfg.model);
    let mut coordinator = Coordinator::new(cfg)?;
    let report = coordinator.run()?;

    println!("\n== report ==\n{}", report.summary());
    println!("\nvalidation trajectory:");
    for (step, loss, acc) in &report.evals {
        println!("  step {step:>4}: val_loss {loss:.4}  val_acc {acc:.3}");
    }
    println!(
        "\ncommunication: {} messages, {:.1} MiB total, {} barriers (gossip: none)",
        report.messages,
        report.bytes as f64 / (1024.0 * 1024.0),
        report.barriers
    );
    Ok(())
}
