//! End-to-end driver: train the paper-scale CNN with every paper strategy.
//!
//! This is the repository's full-system validation (see EXPERIMENTS.md):
//! it trains the ~1.1M-parameter CIFAR CNN (Layer-2 JAX model with
//! Layer-1 Pallas dense kernels, executed through PJRT from the Layer-3
//! Rust coordinator) on the synthetic-CIFAR stream for a few hundred
//! steps, logging the loss curve and periodic validation accuracy.
//!
//! ```text
//! make artifacts
//! cargo run --release --example train_cifar -- \
//!     --model cnn --strategy gosgd:0.02 --iterations 300
//! ```

use gosgd::config::{RunConfig, StrategyKind};
use gosgd::coordinator::Coordinator;
use gosgd::metrics::CsvWriter;
use gosgd::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let a = Args::new("train_cifar", "end-to-end CNN training through the full stack")
        .opt("artifacts", "artifacts", "artifact directory root")
        .opt("model", "cnn", "model variant: tiny | cnn | mlp_wide")
        .opt("workers", "8", "number of workers M")
        .opt("iterations", "300", "worker-local iterations")
        .opt("strategy", "gosgd:0.02", "communication strategy spec")
        .opt(
            "lr",
            "0.05",
            "learning rate (the paper's 0.1 sits at the stability edge for the BN-free CNN; \
             see EXPERIMENTS.md)",
        )
        .opt("weight-decay", "0.0001", "weight decay")
        .opt("eval-every", "50", "evaluate every N worker-iterations")
        .opt("seed", "0", "RNG seed")
        .opt("out", "results/train_cifar.csv", "loss-curve CSV")
        .parse()?;

    let strategy = StrategyKind::parse(a.get("strategy")?)?;
    let is_async = matches!(
        strategy,
        StrategyKind::GoSgd { .. }
            | StrategyKind::GoSgdSharded { .. }
            | StrategyKind::Downpour { .. }
    );
    let workers = a.get_usize("workers")?;
    let iterations = a.get_u64("iterations")?;
    let scale = if is_async { workers as u64 } else { 1 };

    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = a.get("artifacts")?.into();
    cfg.model = a.get("model")?.to_string();
    cfg.workers = workers;
    cfg.steps = iterations * scale;
    cfg.strategy = strategy;
    cfg.lr = gosgd::optim::LrSchedule::Constant(a.get_f64("lr")? as f32);
    cfg.weight_decay = a.get_f64("weight-decay")? as f32;
    cfg.eval_every = a.get_u64("eval-every")? * scale;
    cfg.seed = a.get_u64("seed")?;

    println!(
        "end-to-end: {} | model {} | M={} | {} worker-iterations ({} engine steps)",
        cfg.strategy.tag(),
        cfg.model,
        workers,
        iterations,
        cfg.steps
    );
    let t0 = std::time::Instant::now();
    let mut coordinator = Coordinator::new(cfg)?;
    println!(
        "artifacts loaded: {} params, batch {} per worker",
        coordinator.runtime().param_count(),
        coordinator.runtime().manifest().batch
    );
    let report = coordinator.run()?;
    let secs = t0.elapsed().as_secs_f64();

    println!("\n== final report ==\n{}", report.summary());
    println!("\nvalidation trajectory:");
    for (step, loss, acc) in &report.evals {
        println!(
            "  iter {:>5}: val_loss {loss:.4}  val_acc {acc:.3}",
            step / scale
        );
    }
    let ema = report.train_loss.ema(0.95);
    let first = ema.iter().take(10).sum::<f64>() / 10.0;
    let last = *ema.last().unwrap_or(&f64::NAN);
    println!("\ntrain loss (ema): {first:.4} -> {last:.4}");
    println!(
        "throughput: {:.1} grad steps/s wall ({} steps in {secs:.1}s)",
        report.steps as f64 / secs,
        report.steps
    );

    let out = a.get("out")?;
    if !out.is_empty() {
        let mut csv = CsvWriter::create(out, &["engine_step", "loss", "ema_loss"])?;
        for ((s, l), e) in report
            .train_loss
            .steps()
            .iter()
            .zip(report.train_loss.values())
            .zip(&ema)
        {
            csv.write_row(&[*s as f64, *l, *e])?;
        }
        csv.flush()?;
        println!("loss curve -> {out}");
    }
    Ok(())
}
