"""AOT compiler: lower the Layer-2 programs to HLO text for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled XLA
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For each model variant this writes into ``<out-dir>/<model>/``:

* ``train_step.hlo.txt``  -- (params, images, labels) -> (loss, grads)
* ``eval_step.hlo.txt``   -- (params, images, labels) -> (loss, correct)
* ``sgd_update.hlo.txt``  -- (params, grads, lr[1], wd[1]) -> (params',)
* ``mix.hlo.txt``         -- (x_r, x_s, w_r[1], w_s[1]) -> (mixed,)  [Pallas]
* ``params_init.bin``     -- little-endian f32 He-normal init (seed 0)
* ``manifest.json``       -- shapes, argument order, parameter table

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.

Usage::

    cd python && python -m compile.aot --model cnn --batch 16 --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text (via stablehlo -> XlaComputation)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def compile_model(model: str, batch: int, out_dir: str, seed: int = 0, eval_batch: int = None) -> dict:
    """Lower every program for one model variant and write the artifact dir.

    Returns the manifest dict (also written to ``manifest.json``).
    """
    eval_batch = eval_batch or batch
    n = M.param_count(model)
    d = os.path.join(out_dir, model)
    os.makedirs(d, exist_ok=True)

    img = _f32(batch, *M.IMAGE_SHAPE)
    lbl = _i32(batch)
    eimg = _f32(eval_batch, *M.IMAGE_SHAPE)
    elbl = _i32(eval_batch)
    p = _f32(n)
    s1 = _f32(1)

    programs = {
        "train_step": _lower(M.train_step(model), p, img, lbl),
        "eval_step": _lower(M.eval_step(model), p, eimg, elbl),
        "sgd_update": _lower(M.sgd_update(), p, p, s1, s1),
        "mix": _lower(M.gossip_mix(n), p, p, s1, s1),
    }
    for name, text in programs.items():
        with open(os.path.join(d, f"{name}.hlo.txt"), "w") as f:
            f.write(text)

    init = np.asarray(M.init_params(model, seed), dtype="<f4")
    init.tofile(os.path.join(d, "params_init.bin"))

    manifest = {
        "version": MANIFEST_VERSION,
        "model": model,
        "batch": batch,
        "eval_batch": eval_batch,
        "image_shape": list(M.IMAGE_SHAPE),
        "num_classes": M.NUM_CLASSES,
        "param_count": n,
        "init_seed": seed,
        "tensors": [t.to_json() for t in M.param_table(model)],
        "programs": {
            "train_step": {
                "file": "train_step.hlo.txt",
                "inputs": [
                    {"name": "params", "shape": [n], "dtype": "f32"},
                    {"name": "images", "shape": [batch, *M.IMAGE_SHAPE], "dtype": "f32"},
                    {"name": "labels", "shape": [batch], "dtype": "i32"},
                ],
                "outputs": [
                    {"name": "loss", "shape": [], "dtype": "f32"},
                    {"name": "grads", "shape": [n], "dtype": "f32"},
                ],
            },
            "eval_step": {
                "file": "eval_step.hlo.txt",
                "inputs": [
                    {"name": "params", "shape": [n], "dtype": "f32"},
                    {"name": "images", "shape": [eval_batch, *M.IMAGE_SHAPE], "dtype": "f32"},
                    {"name": "labels", "shape": [eval_batch], "dtype": "i32"},
                ],
                "outputs": [
                    {"name": "loss", "shape": [], "dtype": "f32"},
                    {"name": "correct", "shape": [], "dtype": "f32"},
                ],
            },
            "sgd_update": {
                "file": "sgd_update.hlo.txt",
                "inputs": [
                    {"name": "params", "shape": [n], "dtype": "f32"},
                    {"name": "grads", "shape": [n], "dtype": "f32"},
                    {"name": "lr", "shape": [1], "dtype": "f32"},
                    {"name": "wd", "shape": [1], "dtype": "f32"},
                ],
                "outputs": [{"name": "params", "shape": [n], "dtype": "f32"}],
            },
            "mix": {
                "file": "mix.hlo.txt",
                "inputs": [
                    {"name": "x_r", "shape": [n], "dtype": "f32"},
                    {"name": "x_s", "shape": [n], "dtype": "f32"},
                    {"name": "w_r", "shape": [1], "dtype": "f32"},
                    {"name": "w_s", "shape": [1], "dtype": "f32"},
                ],
                "outputs": [{"name": "mixed", "shape": [n], "dtype": "f32"}],
            },
        },
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="all", choices=["tiny", "cnn", "mlp_wide", "all"])
    ap.add_argument("--batch", type=int, default=16, help="per-worker train batch size")
    ap.add_argument("--eval-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    models = ["tiny", "cnn", "mlp_wide"] if args.model == "all" else [args.model]
    for m in models:
        man = compile_model(m, args.batch, args.out_dir, args.seed, args.eval_batch)
        sizes = {k: os.path.getsize(os.path.join(args.out_dir, m, v["file"]))
                 for k, v in man["programs"].items()}
        print(f"[aot] {m}: {man['param_count']} params, batch {args.batch} -> "
              + ", ".join(f"{k}={v//1024}KiB" for k, v in sizes.items()))


if __name__ == "__main__":
    main()
