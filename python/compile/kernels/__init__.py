"""Layer-1 Pallas kernels for the GoSGD stack.

Two kernels cover the paper's compute hot spots:

* :mod:`.mix` -- the sum-weight gossip blend (section 4, Algorithm 4 of the
  paper), a pure-bandwidth op over the flat parameter vector.
* :mod:`.matmul` -- fused ``act(x @ w + b)`` used by the dense layers of the
  Layer-2 CNN.

Both are lowered with ``interpret=True`` so the resulting HLO runs on the
CPU PJRT client (real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot execute).  :mod:`.ref` holds the pure-jnp oracles used by pytest.
"""

from . import matmul, mix, ref  # noqa: F401

__all__ = ["matmul", "mix", "ref"]
