"""Pallas fused dense-layer kernel: ``act(x @ w + b)``.

Used by every fully-connected layer of the Layer-2 CNN (the paper's
experimental network ends in two dense layers, which dominate its parameter
count and its per-step FLOPs after the convolutions).

TPU mapping: the output is tiled ``(block_m, block_n)`` on a 2-D grid; each
grid step walks the shared dimension in ``block_k`` slabs, accumulating in
an f32 VMEM scratch tile that feeds the MXU-shaped ``jnp.dot``.  Block
sizes default to 128 — the MXU systolic array edge — and the kernel insists
on divisibility rather than masking (the Layer-2 model pads its dense
dimensions to legal sizes, which is cheaper than per-tile predication).

Lowered with ``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU systolic array edge: the natural tile for f32/bf16 matmul.
MXU = 128


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, activation: str):
    """One ``(block_m, block_n)`` output tile, accumulated over k-slabs.

    Grid is ``(m_blocks, n_blocks, k_blocks)`` with k innermost; the f32
    scratch accumulator persists across the k iterations of one (i, j)
    tile (standard Pallas revisiting pattern).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        y = acc_ref[...] + b_ref[...]
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret"),
)
def matmul(
    x,
    w,
    b,
    *,
    activation: str = "none",
    block_m: int = MXU,
    block_n: int = MXU,
    block_k: int = MXU,
    interpret: bool = True,
):
    """Fused ``act(x @ w + b)``.

    Args:
        x: ``(m, k)`` f32, ``m % block_m == 0``, ``k % block_k == 0``.
        w: ``(k, n)`` f32, ``n % block_n == 0``.
        b: ``(n,)`` f32 bias.
        activation: ``"none"`` or ``"relu"`` (fused in the epilogue).
        block_m / block_n / block_k: tile sizes (MXU-edge by default).
        interpret: run the Pallas interpreter (required on CPU).

    Returns:
        ``(m, n)`` f32.
    """
    if activation not in ("none", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"dims ({m},{k},{n}) not divisible by blocks ({block_m},{block_k},{block_n})"
        )
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    kernel = functools.partial(_matmul_kernel, n_k=n_k, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b.reshape(1, n))


# VMEM working-set budget for the auto block policy (bytes); see mix.py.
VMEM_BUDGET = 14 * 1024 * 1024


def _auto_blocks(m: int, k: int, n: int, budget: int = VMEM_BUDGET):
    """Largest legal blocks whose working set fits the VMEM budget.

    §Perf (EXPERIMENTS.md): each grid step pays a large dispatch cost under
    interpret-mode lowering (a single-step 16x4096x256 dense runs ~100x
    faster than 128³ tiling), and on hardware fewer, larger tiles amortize
    the HBM→VMEM pipeline — so prefer one grid step when the whole layer
    fits, else shrink `block_k` (the accumulation axis) first, then
    `block_n`, keeping every block a divisor of its dimension.
    """

    def divisors_desc(dim, cap):
        return [d for d in range(min(dim, cap), 0, -1) if dim % d == 0]

    def working_set(bm, bk, bn):
        return 4 * (bm * bk + bk * bn + bn + 2 * bm * bn)

    bm = m  # batch axis is small in training; keep whole
    for bn in divisors_desc(n, n):
        for bk in divisors_desc(k, k):
            if working_set(bm, bk, bn) <= budget:
                return bm, bk, bn
    # Pathological fallback (layer far beyond budget): legal MXU tiles.
    def legal(dim):
        return MXU if dim % MXU == 0 else dim

    return bm, legal(k), legal(n)


def dense(x, w, b, *, activation="none", interpret=True):
    """Dense layer entry point used by the Layer-2 model.

    Uses the VMEM-budget auto block policy (legal divisors of each dim;
    whole-layer single grid step whenever it fits).
    """
    m, k = x.shape
    _, n = w.shape
    block_m, block_k, block_n = _auto_blocks(m, k, n)
    return matmul(
        x, w, b, activation=activation, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def flops(m: int, k: int, n: int) -> int:
    """FLOPs of one fused dense call (madd = 2 flops)."""
    return 2 * m * k * n + 2 * m * n


def vmem_bytes(block_m: int = MXU, block_n: int = MXU, block_k: int = MXU) -> int:
    """Per-grid-step VMEM working set (x, w slabs + bias + acc + out)."""
    return 4 * (block_m * block_k + block_k * block_n + block_n + 2 * block_m * block_n)
