"""Pallas kernel for the sum-weight gossip blend (paper Alg. 4, line 9).

This is the signature operation of GoSGD: when worker ``r`` pops a message
``(x_s, w_s)`` from its queue it replaces its local parameter vector with
the convex combination

    x_r <- w_r/(w_r+w_s) * x_r + w_s/(w_r+w_s) * x_s

over the *entire* flat parameter vector (1.3M floats for the paper's CNN,
10s-100s of MB for modern models).  The op is pure bandwidth: 3 flops per
element against 12 bytes moved, so the roofline is HBM bandwidth, not the
MXU.

TPU mapping (see DESIGN.md section "Hardware adaptation"): the flat vector
is viewed as ``(n_blocks, BLOCK_ROWS, LANES)`` with ``LANES = 128`` (the
VPU lane width) and ``BLOCK_ROWS`` a multiple of 8 (the f32 sublane tile).
Each grid step streams one block HBM->VMEM, blends on the VPU, and streams
it back; with ``BLOCK_ROWS = 512`` a block is 256 KiB/input, comfortably
double-bufferable in ~16 MiB of VMEM.  The scalar weights live in a
``(1, 1)`` block re-read by every grid step (they stay VMEM-resident).

Lowered with ``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU lane width; the last dim of every block must be a multiple of this on
# real TPU hardware.
LANES = 128
# f32 sublane tile height.
SUBLANES = 8
# Default rows per block: 512*128*4B = 256 KiB per operand block.
DEFAULT_BLOCK_ROWS = 512
# VMEM working-set budget for the auto block policy (bytes).  A TPU core
# has ~16 MiB of VMEM; we leave headroom for double buffering.
VMEM_BUDGET = 14 * 1024 * 1024


def auto_block_rows(n: int, budget: int = VMEM_BUDGET) -> int:
    """Largest block that keeps the 3-operand working set under `budget`.

    §Perf (EXPERIMENTS.md): grid-step dispatch dominates under
    interpret-mode lowering (a single-block 1.1M-element mix runs 55x
    faster than 512-row tiling), and on real hardware fewer, larger blocks
    amortize the HBM->VMEM pipeline equally well — so the policy is
    "one grid step if it fits VMEM, else the largest tile that does".
    """
    rows_needed = (n + LANES - 1) // LANES
    # 3 operand blocks (x_r, x_s, out) of block_rows*LANES f32 each.
    max_rows = budget // (3 * LANES * 4)
    rows = min(rows_needed, max_rows)
    # Round to a sublane multiple (TPU f32 tile height).
    return max(SUBLANES, (rows // SUBLANES) * SUBLANES)


def _mix_kernel(w_ref, x_r_ref, x_s_ref, o_ref):
    """Blend one ``(block_rows, LANES)`` tile.

    ``w_ref`` is a ``(1, 2)`` SMEM-style block holding ``[w_r, w_s]``; the
    ratio is computed once per grid step (scalar) and broadcast by the VPU.
    """
    w_r = w_ref[0, 0]
    w_s = w_ref[0, 1]
    t = w_s / (w_r + w_s)
    x_r = x_r_ref[...]
    x_s = x_s_ref[...]
    # One fused multiply-add per element: x_r + t*(x_s - x_r) is the
    # 2-flop/elt form of the convex combination (vs 3 flops naive).
    o_ref[...] = x_r + t * (x_s - x_r)


def padded_len(n: int, block_rows: int = DEFAULT_BLOCK_ROWS) -> int:
    """Length ``n`` rounded up to a whole number of blocks."""
    tile = block_rows * LANES
    return ((n + tile - 1) // tile) * tile


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def mix(x_r, x_s, w_r, w_s, *, block_rows: int = None, interpret: bool = True):
    """Sum-weight blend of two flat parameter vectors.

    Args:
        x_r: receiver parameters, shape ``(n,)`` f32 (any ``n >= 1``).
        x_s: sender parameters, shape ``(n,)`` f32.
        w_r: receiver gossip weight, shape ``(1,)`` or scalar f32.
        w_s: sender gossip weight, shape ``(1,)`` or scalar f32.
        block_rows: rows per ``(block_rows, 128)`` VMEM tile.
        interpret: run the Pallas interpreter (required on CPU).

    Returns:
        Blended vector, shape ``(n,)`` f32.
    """
    if x_r.shape != x_s.shape or x_r.ndim != 1:
        raise ValueError(f"mix expects equal 1-D shapes, got {x_r.shape} vs {x_s.shape}")
    n = x_r.shape[0]
    if block_rows is None:
        block_rows = auto_block_rows(n)
    tile = block_rows * LANES
    padded = padded_len(n, block_rows)
    if padded != n:
        pad = padded - n
        x_r = jnp.pad(x_r, (0, pad))
        x_s = jnp.pad(x_s, (0, pad))
    n_blocks = padded // tile
    x_r2 = x_r.reshape(n_blocks * block_rows, LANES)
    x_s2 = x_s.reshape(n_blocks * block_rows, LANES)
    w = jnp.stack(
        [jnp.asarray(w_r, jnp.float32).reshape(()), jnp.asarray(w_s, jnp.float32).reshape(())]
    ).reshape(1, 2)

    out = pl.pallas_call(
        _mix_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),  # weights: same block every step
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_rows, LANES), jnp.float32),
        interpret=interpret,
    )(w, x_r2, x_s2)
    return out.reshape(padded)[:n]


def vmem_bytes(block_rows: int = DEFAULT_BLOCK_ROWS) -> int:
    """VMEM footprint of one grid step (2 inputs + 1 output + weights).

    Used by DESIGN.md / EXPERIMENTS.md to document the TPU residency
    estimate; with double buffering the working set is twice this.
    """
    block = block_rows * LANES * 4
    return 3 * block + 2 * 4
