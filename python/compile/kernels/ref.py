"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare the Pallas
implementations against.  They are deliberately written in the most direct
jnp style possible -- no tiling, no padding -- so a mismatch always
implicates the kernel, never the oracle.
"""

import jax.numpy as jnp


def mix_ref(x_r, x_s, w_r, w_s):
    """Sum-weight gossip blend (paper Algorithm 4, line 9).

    ``x_r <- w_r/(w_r+w_s) * x_r + w_s/(w_r+w_s) * x_s``

    Args:
        x_r: receiver's flat parameter vector, shape ``(n,)``.
        x_s: sender's flat parameter vector, shape ``(n,)``.
        w_r: receiver's gossip weight, scalar or shape ``(1,)``.
        w_s: sender's gossip weight (already halved by the sender), scalar
            or shape ``(1,)``.

    Returns:
        The blended vector, shape ``(n,)``.
    """
    w_r = jnp.asarray(w_r, dtype=x_r.dtype).reshape(())
    w_s = jnp.asarray(w_s, dtype=x_r.dtype).reshape(())
    denom = w_r + w_s
    return (w_r / denom) * x_r + (w_s / denom) * x_s


def matmul_ref(x, w, b, *, activation="none"):
    """Fused dense layer ``act(x @ w + b)``.

    Args:
        x: ``(m, k)`` input activations.
        w: ``(k, n)`` weights.
        b: ``(n,)`` bias.
        activation: ``"none"`` or ``"relu"``.

    Returns:
        ``(m, n)`` output activations.
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def sgd_update_ref(params, grads, lr, weight_decay):
    """Plain SGD with weight decay folded into the gradient.

    ``p <- p - lr * (g + wd * p)`` -- the update the paper's experiments use
    (lr = 0.1, wd = 1e-4, no momentum).
    """
    lr = jnp.asarray(lr, dtype=params.dtype).reshape(())
    wd = jnp.asarray(weight_decay, dtype=params.dtype).reshape(())
    return params - lr * (grads + wd * params)
