"""Layer-2 JAX model: the paper's experimental CNN, built on the L1 kernels.

The paper trains the CIFAR-10 CNN of [9]/[26] (conv-pool blocks followed by
fully-connected layers) with plain SGD (lr 0.1, weight decay 1e-4).  We
reproduce that topology class:

* ``tiny``     -- MLP 3072 -> 64 -> 10        (~197k params; fast tests)
* ``cnn``      -- conv5x5x32/pool2, conv5x5x64/pool2, fc 4096 -> 256 -> 10
                  (~1.1M params; the paper-scale network)
* ``mlp_wide`` -- MLP 3072 -> 1024 -> 1024 -> 10 (~4.2M params; perf study)

All dense layers run through the Pallas fused matmul (:mod:`.kernels.matmul`)
in BOTH the forward and the backward pass: ``pallas_call`` has no automatic
transpose rule, so :func:`dense` installs a ``custom_vjp`` whose backward
pass is itself three Pallas matmuls (dx = g w^T, dw = x^T g, db = sum g).
Convolutions use ``lax.conv_general_dilated`` (XLA-native, NHWC).

Parameters travel as ONE flat f32 vector.  This is what makes the paper's
gossip exchange trivial on the Rust side: a message is (flat vector, weight)
and the mix artifact blends whole vectors.  :func:`param_table` records the
(name, shape, offset) layout for introspection and for the Rust
re-initializer.
"""

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import matmul as pmm

IMAGE_SHAPE = (32, 32, 3)  # NHWC CIFAR geometry
NUM_CLASSES = 10


# --------------------------------------------------------------------------
# Pallas dense layer with a Pallas backward pass
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="none"):
    """Fused ``act(x @ w + b)`` with a custom (Pallas) VJP."""
    return pmm.dense(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    y = pmm.dense(x, w, b, activation=activation)
    # For relu the output itself encodes the mask (y > 0); keeping y instead
    # of the pre-activation halves the residual footprint.
    return y, (x, w, y)


def _dense_bwd(activation, res, g):
    x, w, y = res
    if activation == "relu":
        g = g * (y > 0).astype(g.dtype)
    zero_k = jnp.zeros((x.shape[1],), jnp.float32)
    zero_n = jnp.zeros((w.shape[1],), jnp.float32)
    dx = pmm.dense(g, w.T, zero_k)          # (m, n) @ (n, k)
    dw = pmm.dense(x.T, g, zero_n)          # (k, m) @ (m, n)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


# --------------------------------------------------------------------------
# Model specs and the flat-parameter registry
# --------------------------------------------------------------------------

class TensorSpec:
    """One named parameter tensor inside the flat vector."""

    def __init__(self, name: str, shape: Tuple[int, ...], init_std: float):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.size = int(np.prod(self.shape))
        self.init_std = float(init_std)
        self.offset = 0  # assigned by _layout

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "offset": self.offset,
            "size": self.size,
            "init_std": self.init_std,
        }


def _he(fan_in: int) -> float:
    return float(np.sqrt(2.0 / fan_in))


def _conv_spec(name: str, kh, kw, cin, cout) -> List[TensorSpec]:
    return [
        TensorSpec(f"{name}.w", (kh, kw, cin, cout), _he(kh * kw * cin)),
        TensorSpec(f"{name}.b", (cout,), 0.0),
    ]


def _fc_spec(name: str, din, dout, *, scale: float = 0.5) -> List[TensorSpec]:
    """Dense layer spec.

    ``scale`` shrinks the He std: the conv/relu stack feeding the hidden FC
    grows activation variance past the He assumption, and the classifier
    layer is further shrunk (x0.1) so initial logits are near zero (initial
    loss = ln 10) — without it the paper's lr = 0.1 diverges on this
    BN-free network.
    """
    return [
        TensorSpec(f"{name}.w", (din, dout), scale * _he(din)),
        TensorSpec(f"{name}.b", (dout,), 0.0),
    ]


def _layout(specs: List[TensorSpec]) -> List[TensorSpec]:
    off = 0
    for s in specs:
        s.offset = off
        off += s.size
    return specs


_MODEL_SPECS: Dict[str, List[TensorSpec]] = {}


def param_table(model: str) -> List[TensorSpec]:
    """The (name, shape, offset) table of ``model``'s flat parameter vector."""
    if model not in _MODEL_SPECS:
        flat_in = int(np.prod(IMAGE_SHAPE))
        if model == "tiny":
            specs = _fc_spec("fc1", flat_in, 64) + _fc_spec("fc2", 64, NUM_CLASSES, scale=0.1)
        elif model == "cnn":
            specs = (
                _conv_spec("conv1", 5, 5, 3, 32)
                + _conv_spec("conv2", 5, 5, 32, 64)
                + _fc_spec("fc1", 8 * 8 * 64, 256)
                + _fc_spec("fc2", 256, NUM_CLASSES, scale=0.1)
            )
        elif model == "mlp_wide":
            specs = (
                _fc_spec("fc1", flat_in, 1024)
                + _fc_spec("fc2", 1024, 1024)
                + _fc_spec("fc3", 1024, NUM_CLASSES, scale=0.1)
            )
        else:
            raise ValueError(f"unknown model {model!r}")
        _MODEL_SPECS[model] = _layout(specs)
    return _MODEL_SPECS[model]


def param_count(model: str) -> int:
    """Total length of the flat parameter vector."""
    table = param_table(model)
    return table[-1].offset + table[-1].size


def init_params(model: str, seed: int = 0) -> jnp.ndarray:
    """He-normal initialization of the flat vector (biases zero)."""
    table = param_table(model)
    key = jax.random.PRNGKey(seed)
    parts = []
    for spec in table:
        key, sub = jax.random.split(key)
        if spec.init_std == 0.0:
            parts.append(jnp.zeros((spec.size,), jnp.float32))
        else:
            parts.append(spec.init_std * jax.random.normal(sub, (spec.size,), jnp.float32))
    return jnp.concatenate(parts)


def unflatten(model: str, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Split the flat vector back into named, shaped tensors."""
    out = {}
    for spec in param_table(model):
        out[spec.name] = lax.dynamic_slice(flat, (spec.offset,), (spec.size,)).reshape(spec.shape)
    return out


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _conv_relu_pool(x, w, b):
    """5x5 SAME conv + relu + 2x2 max pool (NHWC)."""
    y = lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = jnp.maximum(y + b[None, None, None, :], 0.0)
    return lax.reduce_window(
        y, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(model: str, flat: jnp.ndarray, images: jnp.ndarray) -> jnp.ndarray:
    """Logits for a batch of NHWC images."""
    p = unflatten(model, flat)
    batch = images.shape[0]
    if model == "tiny":
        h = images.reshape(batch, -1)
        h = dense(h, p["fc1.w"], p["fc1.b"], "relu")
        return dense(h, p["fc2.w"], p["fc2.b"], "none")
    if model == "cnn":
        h = _conv_relu_pool(images, p["conv1.w"], p["conv1.b"])   # 16x16x32
        h = _conv_relu_pool(h, p["conv2.w"], p["conv2.b"])        # 8x8x64
        h = h.reshape(batch, -1)                                  # 4096
        h = dense(h, p["fc1.w"], p["fc1.b"], "relu")
        return dense(h, p["fc2.w"], p["fc2.b"], "none")
    if model == "mlp_wide":
        h = images.reshape(batch, -1)
        h = dense(h, p["fc1.w"], p["fc1.b"], "relu")
        h = dense(h, p["fc2.w"], p["fc2.b"], "relu")
        return dense(h, p["fc3.w"], p["fc3.b"], "none")
    raise ValueError(f"unknown model {model!r}")


def loss_fn(model: str, flat: jnp.ndarray, images: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy (weight decay lives in the update step)."""
    logits = forward(model, flat, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# The exported programs (lowered to HLO by aot.py)
# --------------------------------------------------------------------------

def train_step(model: str):
    """``(flat_params, images, labels) -> (loss, flat_grads)``."""

    def step(flat, images, labels):
        loss, grads = jax.value_and_grad(lambda q: loss_fn(model, q, images, labels))(flat)
        return loss, grads

    return step


def eval_step(model: str):
    """``(flat_params, images, labels) -> (loss, correct_count)``."""

    def step(flat, images, labels):
        logits = forward(model, flat, images)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        return jnp.mean(nll), correct

    return step


def sgd_update():
    """``(flat_params, flat_grads, lr[1], wd[1]) -> (new_params,)``.

    ``p <- p - lr * (g + wd * p)`` -- the paper's optimizer (section 5.1).
    """

    def step(flat, grads, lr, wd):
        return (flat - lr[0] * (grads + wd[0] * flat),)

    return step


def gossip_mix(n: int):
    """``(x_r, x_s, w_r[1], w_s[1]) -> (mixed,)`` over n-length vectors.

    The Pallas mix kernel (paper Algorithm 4 line 9), exported standalone so
    the Rust coordinator can blend via PJRT.
    """
    from .kernels import mix as pmix

    def step(x_r, x_s, w_r, w_s):
        return (pmix.mix(x_r, x_s, w_r[0], w_s[0]),)

    return step
