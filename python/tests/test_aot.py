"""AOT pipeline tests: artifacts exist, HLO text parses, manifest is sane."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.compile_model("tiny", batch=4, out_dir=out, seed=0, eval_batch=8)
    return os.path.join(out, "tiny")


class TestArtifacts:
    def test_all_files_written(self, tiny_dir):
        for f in ["train_step.hlo.txt", "eval_step.hlo.txt", "sgd_update.hlo.txt",
                  "mix.hlo.txt", "params_init.bin", "manifest.json"]:
            assert os.path.exists(os.path.join(tiny_dir, f)), f

    def test_hlo_is_text_with_entry(self, tiny_dir):
        for f in ["train_step", "eval_step", "sgd_update", "mix"]:
            text = open(os.path.join(tiny_dir, f"{f}.hlo.txt")).read()
            assert "ENTRY" in text and "HloModule" in text, f
            # text format, not binary proto
            assert text.isprintable() or "\n" in text

    def test_manifest_consistent(self, tiny_dir):
        man = json.load(open(os.path.join(tiny_dir, "manifest.json")))
        assert man["model"] == "tiny"
        assert man["batch"] == 4
        assert man["eval_batch"] == 8
        assert man["param_count"] == M.param_count("tiny")
        total = sum(t["size"] for t in man["tensors"])
        assert total == man["param_count"]
        # offsets contiguous
        off = 0
        for t in man["tensors"]:
            assert t["offset"] == off
            off += t["size"]
        # program input shapes match param count & batch
        ts = man["programs"]["train_step"]
        assert ts["inputs"][0]["shape"] == [man["param_count"]]
        assert ts["inputs"][1]["shape"][0] == man["batch"]

    def test_params_init_matches_model_init(self, tiny_dir):
        man = json.load(open(os.path.join(tiny_dir, "manifest.json")))
        raw = np.fromfile(os.path.join(tiny_dir, "params_init.bin"), dtype="<f4")
        assert raw.shape[0] == man["param_count"]
        want = np.asarray(M.init_params("tiny", man["init_seed"]))
        np.testing.assert_allclose(raw, want, rtol=1e-6)

    def test_mix_hlo_mentions_loop_or_fusion(self, tiny_dir):
        """The pallas interpret lowering leaves a while-loop grid walk."""
        text = open(os.path.join(tiny_dir, "mix.hlo.txt")).read()
        assert "while" in text or "fusion" in text or "dynamic" in text


class TestRoundTripExecution:
    """Execute the lowered HLO with the local XLA client: numerics must
    match the eager jax programs (this is the same text the Rust runtime
    loads through PJRT)."""

    def _run_text(self, path, args):
        from jax._src.lib import xla_client as xc
        import jax
        client = jax.lib.xla_bridge.get_backend("cpu")
        # Re-lower eagerly is simpler than parsing HLO text back; instead we
        # compile the stablehlo the same way aot did and compare outputs via
        # the jitted original. Here we only check the text is non-trivial.
        return open(path).read()

    def test_train_step_text_has_two_outputs(self, tiny_dir):
        text = open(os.path.join(tiny_dir, "train_step.hlo.txt")).read()
        # lowered with return_tuple=True: ROOT is a tuple of (loss, grads)
        assert "ROOT" in text
        n = M.param_count("tiny")
        assert f"f32[{n}]" in text

    def test_eval_step_eager_vs_export_spec(self, tiny_dir):
        import jax, jax.numpy as jnp
        man = json.load(open(os.path.join(tiny_dir, "manifest.json")))
        rng = np.random.default_rng(0)
        imgs = jnp.asarray(rng.normal(size=(man["eval_batch"], 32, 32, 3)), jnp.float32)
        lbls = jnp.asarray(rng.integers(0, 10, size=(man["eval_batch"],)), jnp.int32)
        p = jnp.asarray(np.fromfile(os.path.join(tiny_dir, "params_init.bin"), dtype="<f4"))
        loss, correct = jax.jit(M.eval_step("tiny"))(p, imgs, lbls)
        assert np.isfinite(float(loss))
        assert 0 <= float(correct) <= man["eval_batch"]
