"""Kernel-vs-reference correctness: the CORE signal for Layer 1.

Hypothesis sweeps shapes, dtypes-compatible ranges and weights; every case
asserts allclose against the pure-jnp oracles in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pmm
from compile.kernels import mix as pmix
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _vec(rng, n, scale=1.0):
    return jnp.asarray(rng.normal(scale=scale, size=n), jnp.float32)


# --------------------------------------------------------------------------
# mix kernel
# --------------------------------------------------------------------------

class TestMix:
    @settings(max_examples=40, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=200_000),
        w_r=st.floats(min_value=1e-4, max_value=10.0),
        w_s=st.floats(min_value=1e-4, max_value=10.0),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref(self, n, w_r, w_s, seed):
        rng = np.random.default_rng(seed)
        x_r, x_s = _vec(rng, n), _vec(rng, n)
        got = pmix.mix(x_r, x_s, w_r, w_s)
        want = ref.mix_ref(x_r, x_s, w_r, w_s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=50_000),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_convex_combination_bounds(self, n, seed):
        """mix output is elementwise within [min(x_r,x_s), max(x_r,x_s)]."""
        rng = np.random.default_rng(seed)
        x_r, x_s = _vec(rng, n), _vec(rng, n)
        got = np.asarray(pmix.mix(x_r, x_s, 0.3, 0.7))
        lo = np.minimum(x_r, x_s) - 1e-6
        hi = np.maximum(x_r, x_s) + 1e-6
        assert np.all(got >= lo) and np.all(got <= hi)

    def test_equal_weights_is_average(self):
        rng = np.random.default_rng(0)
        x_r, x_s = _vec(rng, 9999), _vec(rng, 9999)
        got = pmix.mix(x_r, x_s, 0.5, 0.5)
        np.testing.assert_allclose(got, (x_r + x_s) / 2, rtol=1e-5, atol=1e-6)

    def test_zero_sender_weight_is_identity(self):
        rng = np.random.default_rng(1)
        x_r, x_s = _vec(rng, 4096), _vec(rng, 4096)
        got = pmix.mix(x_r, x_s, 1.0, 0.0)
        np.testing.assert_allclose(got, x_r, rtol=1e-6, atol=1e-7)

    def test_exact_block_multiple_no_padding(self):
        n = pmix.DEFAULT_BLOCK_ROWS * pmix.LANES  # exactly one block
        rng = np.random.default_rng(2)
        x_r, x_s = _vec(rng, n), _vec(rng, n)
        got = pmix.mix(x_r, x_s, 0.125, 0.875)
        want = ref.mix_ref(x_r, x_s, 0.125, 0.875)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("block_rows", [8, 64, 512])
    def test_block_size_invariance(self, block_rows):
        rng = np.random.default_rng(3)
        x_r, x_s = _vec(rng, 123_457), _vec(rng, 123_457)
        got = pmix.mix(x_r, x_s, 0.4, 0.6, block_rows=block_rows)
        want = ref.mix_ref(x_r, x_s, 0.4, 0.6)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            pmix.mix(jnp.zeros(4), jnp.zeros(5), 0.5, 0.5)

    def test_padded_len(self):
        tile = pmix.DEFAULT_BLOCK_ROWS * pmix.LANES
        assert pmix.padded_len(1) == tile
        assert pmix.padded_len(tile) == tile
        assert pmix.padded_len(tile + 1) == 2 * tile

    def test_vmem_budget(self):
        """Default block working set (x2 for double buffering) fits VMEM."""
        assert 2 * pmix.vmem_bytes() < 16 * 1024 * 1024


# --------------------------------------------------------------------------
# matmul kernel
# --------------------------------------------------------------------------

def _mkn():
    blocks = st.sampled_from([1, 2, 3])
    return st.tuples(blocks, blocks, blocks)


class TestMatmul:
    @settings(max_examples=30, deadline=None)
    @given(
        mkn=_mkn(),
        activation=st.sampled_from(["none", "relu"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_mxu_tiles(self, mkn, activation, seed):
        m, k, n = (128 * v for v in mkn)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)) / np.sqrt(k), jnp.float32)
        b = jnp.asarray(rng.normal(size=n), jnp.float32)
        got = pmm.matmul(x, w, b, activation=activation)
        want = ref.matmul_ref(x, w, b, activation=activation)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 4, 8, 16, 32]),
        k=st.sampled_from([16, 64, 128, 3072]),
        n=st.sampled_from([10, 64, 128, 256]),
        activation=st.sampled_from(["none", "relu"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_dense_irregular_shapes(self, m, k, n, activation, seed):
        """dense() picks legal blocks for the model's actual layer shapes."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)) / np.sqrt(k), jnp.float32)
        b = jnp.asarray(rng.normal(size=n), jnp.float32)
        got = pmm.dense(x, w, b, activation=activation)
        want = ref.matmul_ref(x, w, b, activation=activation)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_relu_clamps(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        b = jnp.asarray(-100.0 * np.ones(128), jnp.float32)
        got = np.asarray(pmm.dense(x, w, b, activation="relu"))
        assert np.all(got == 0.0)

    def test_indivisible_raises(self):
        x = jnp.zeros((7, 128))
        w = jnp.zeros((128, 128))
        b = jnp.zeros(128)
        with pytest.raises(ValueError):
            pmm.matmul(x, w, b, block_m=4)

    def test_bad_activation_raises(self):
        x = jnp.zeros((8, 8))
        with pytest.raises(ValueError):
            pmm.matmul(x, jnp.zeros((8, 8)), jnp.zeros(8), activation="gelu")

    def test_flops_model(self):
        assert pmm.flops(128, 256, 64) == 2 * 128 * 256 * 64 + 2 * 128 * 64

    def test_vmem_budget(self):
        assert 2 * pmm.vmem_bytes() < 16 * 1024 * 1024


# --------------------------------------------------------------------------
# sgd_update reference (host-side mirror contract)
# --------------------------------------------------------------------------

class TestSgdRef:
    def test_zero_wd_is_plain_sgd(self):
        rng = np.random.default_rng(0)
        p = _vec(rng, 1000)
        g = _vec(rng, 1000)
        got = ref.sgd_update_ref(p, g, 0.1, 0.0)
        np.testing.assert_allclose(got, p - 0.1 * g, rtol=1e-6)

    def test_wd_shrinks_params(self):
        p = jnp.ones(100)
        g = jnp.zeros(100)
        got = ref.sgd_update_ref(p, g, 0.1, 1e-4)
        np.testing.assert_allclose(got, (1 - 0.1 * 1e-4) * np.ones(100), rtol=1e-6)
