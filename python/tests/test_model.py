"""Layer-2 model tests: parameter layout, gradients, training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _batch(rng, n=8):
    imgs = jnp.asarray(rng.normal(size=(n, *M.IMAGE_SHAPE)), jnp.float32)
    lbls = jnp.asarray(rng.integers(0, M.NUM_CLASSES, size=(n,)), jnp.int32)
    return imgs, lbls


class TestParamTable:
    @pytest.mark.parametrize("model", ["tiny", "cnn", "mlp_wide"])
    def test_layout_is_contiguous(self, model):
        table = M.param_table(model)
        off = 0
        for spec in table:
            assert spec.offset == off
            assert spec.size == int(np.prod(spec.shape))
            off += spec.size
        assert off == M.param_count(model)

    def test_known_counts(self):
        # fc1: 3072*64 + 64; fc2: 64*10 + 10
        assert M.param_count("tiny") == 3072 * 64 + 64 + 64 * 10 + 10
        # conv1 5*5*3*32+32, conv2 5*5*32*64+64, fc1 4096*256+256, fc2 256*10+10
        assert M.param_count("cnn") == (5 * 5 * 3 * 32 + 32 + 5 * 5 * 32 * 64 + 64
                                        + 4096 * 256 + 256 + 256 * 10 + 10)

    @pytest.mark.parametrize("model", ["tiny", "cnn"])
    def test_unflatten_round_trip(self, model):
        flat = M.init_params(model, seed=3)
        parts = M.unflatten(model, flat)
        rebuilt = jnp.concatenate([parts[s.name].reshape(-1) for s in M.param_table(model)])
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(rebuilt))

    def test_init_deterministic_and_seed_sensitive(self):
        a = M.init_params("tiny", seed=0)
        b = M.init_params("tiny", seed=0)
        c = M.init_params("tiny", seed=1)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_biases_init_zero(self):
        flat = M.init_params("tiny", seed=0)
        parts = M.unflatten("tiny", flat)
        np.testing.assert_array_equal(np.asarray(parts["fc1.b"]), 0.0)
        np.testing.assert_array_equal(np.asarray(parts["fc2.b"]), 0.0)


class TestForward:
    @pytest.mark.parametrize("model", ["tiny", "cnn", "mlp_wide"])
    def test_logit_shape(self, model):
        rng = np.random.default_rng(0)
        imgs, _ = _batch(rng, 4)
        flat = M.init_params(model, 0)
        logits = M.forward(model, flat, imgs)
        assert logits.shape == (4, M.NUM_CLASSES)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_initial_loss_near_log10(self):
        """Random init => loss ~= ln(10) on balanced random labels."""
        rng = np.random.default_rng(0)
        imgs, lbls = _batch(rng, 64)
        flat = M.init_params("tiny", 0)
        loss = float(M.loss_fn("tiny", flat, imgs, lbls))
        assert abs(loss - np.log(10)) < 2.0

    def test_unknown_model_raises(self):
        with pytest.raises(ValueError):
            M.forward("nope", jnp.zeros(10), jnp.zeros((1, 32, 32, 3)))


class TestGradients:
    def test_finite_difference_check_tiny(self):
        """Spot-check d(loss)/d(param) against central differences."""
        rng = np.random.default_rng(0)
        imgs, lbls = _batch(rng, 4)
        flat = M.init_params("tiny", 0)
        step = jax.jit(M.train_step("tiny"))
        loss0, g = step(flat, imgs, lbls)
        g = np.asarray(g)
        eps = 1e-3
        idx = rng.integers(0, flat.shape[0], size=6)
        for i in idx:
            e = np.zeros(flat.shape[0], np.float32)
            e[i] = eps
            lp, _ = step(flat + e, imgs, lbls)
            lm, _ = step(flat - e, imgs, lbls)
            fd = (float(lp) - float(lm)) / (2 * eps)
            assert abs(fd - g[i]) < 5e-2 * max(1.0, abs(g[i])) + 1e-3, (i, fd, g[i])

    @pytest.mark.parametrize("model", ["tiny", "cnn"])
    def test_grad_shape_and_finite(self, model):
        rng = np.random.default_rng(1)
        imgs, lbls = _batch(rng, 4)
        flat = M.init_params(model, 0)
        loss, g = jax.jit(M.train_step(model))(flat, imgs, lbls)
        assert g.shape == flat.shape
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.linalg.norm(g)) > 0.0

    def test_loss_decreases_under_sgd(self):
        rng = np.random.default_rng(2)
        imgs, lbls = _batch(rng, 16)
        flat = M.init_params("tiny", 0)
        step = jax.jit(M.train_step("tiny"))
        first, _ = step(flat, imgs, lbls)
        for _ in range(20):
            _, g = step(flat, imgs, lbls)
            flat = flat - 0.1 * g
        last, _ = step(flat, imgs, lbls)
        assert float(last) < float(first) * 0.5


class TestEvalStep:
    def test_correct_count_bounds(self):
        rng = np.random.default_rng(3)
        imgs, lbls = _batch(rng, 32)
        flat = M.init_params("tiny", 0)
        loss, correct = jax.jit(M.eval_step("tiny"))(flat, imgs, lbls)
        assert 0.0 <= float(correct) <= 32.0
        assert np.isfinite(float(loss))

    def test_perfect_model_counts_all(self):
        """A model trained to memorize a tiny batch gets them all right."""
        rng = np.random.default_rng(4)
        imgs, lbls = _batch(rng, 8)
        flat = M.init_params("tiny", 0)
        step = jax.jit(M.train_step("tiny"))
        for _ in range(60):
            _, g = step(flat, imgs, lbls)
            flat = flat - 0.1 * g
        _, correct = jax.jit(M.eval_step("tiny"))(flat, imgs, lbls)
        assert float(correct) == 8.0


class TestExportedPrograms:
    def test_sgd_update_matches_ref(self):
        from compile.kernels import ref
        rng = np.random.default_rng(5)
        p = jnp.asarray(rng.normal(size=1000), jnp.float32)
        g = jnp.asarray(rng.normal(size=1000), jnp.float32)
        (got,) = M.sgd_update()(p, g, jnp.asarray([0.1]), jnp.asarray([1e-4]))
        want = ref.sgd_update_ref(p, g, 0.1, 1e-4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_gossip_mix_matches_ref(self):
        from compile.kernels import ref
        rng = np.random.default_rng(6)
        n = M.param_count("tiny")
        xr = jnp.asarray(rng.normal(size=n), jnp.float32)
        xs = jnp.asarray(rng.normal(size=n), jnp.float32)
        (got,) = M.gossip_mix(n)(xr, xs, jnp.asarray([0.125]), jnp.asarray([0.0625]))
        want = ref.mix_ref(xr, xs, 0.125, 0.0625)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
