//! Bench: payload codec encode/absorb throughput + wire-size accounting.
//!
//! Two questions, per codec (dense / top-k / u8 quantization):
//!
//! 1. **Compute**: what does encoding a shard and blending an encoded
//!    shard cost per byte?  The codecs trade wire bytes for CPU — both
//!    sides must stay far below a gradient step to be free in practice.
//! 2. **Wire**: how many encoded bytes does a message actually ship at a
//!    fixed shard count?  The acceptance line for `q8` is ≥ 3× fewer
//!    encoded bytes than `dense` at equal shard count — printed (and
//!    checked) by the summary below.
//!
//! Run with `cargo bench --bench codec_throughput`; set `BENCH_CSV` or
//! `BENCH_JSON` for machine-readable output (CI uploads the JSON as
//! `BENCH_codec.json` to accumulate the perf trajectory).

use gosgd::bench::Bencher;
use gosgd::gossip::{Codec, CodecSpec, EncodedPayload};
use gosgd::strategies::engine::Engine;
use gosgd::strategies::gosgd::GoSgd;
use gosgd::strategies::grad::NoiseSource;
use gosgd::tensor::FlatVec;
use gosgd::util::rng::Rng;

const SHARD_LEN: usize = 1 << 16; // 64k coords ≈ one shard of a 1M model / 16

fn specs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::Dense,
        CodecSpec::TopK { k: SHARD_LEN / 16 },
        CodecSpec::QuantizeU8,
    ]
}

fn main() {
    let mut b = Bencher::new("codec_throughput");
    let mut rng = Rng::new(0xC0DE);
    let payload = FlatVec::randn(SHARD_LEN, 1.0, &mut rng);
    let raw_bytes = (SHARD_LEN * 4) as u64;

    // Encode throughput (clone cost included uniformly for every codec —
    // the protocol snapshots the shard either way).
    for spec in specs() {
        let codec = spec.build();
        let mut residual = vec![0.0f32; SHARD_LEN];
        b.bench_bytes(&format!("encode_{}_64k", spec.label()), raw_bytes, || {
            std::hint::black_box(codec.encode(payload.clone(), &mut residual));
        });
    }

    // Absorb (decode-blend) throughput on a pre-encoded payload.
    for spec in specs() {
        let codec = spec.build();
        let mut residual = vec![0.0f32; SHARD_LEN];
        let enc = codec.encode(payload.clone(), &mut residual);
        let mut x = vec![0.0f32; SHARD_LEN];
        b.bench_bytes(&format!("absorb_{}_64k", spec.label()), raw_bytes, || {
            enc.blend_into(&mut x, 0.25);
            std::hint::black_box(&x);
        });
    }

    // Wire accounting at a fixed shard count, end to end through the
    // engine driver (the codec-vs-shard sweep the acceptance line reads).
    println!("\nconfig                 bytes/msg  raw/msg  compression  messages");
    let dim = 4096;
    let shards = 8;
    let mut dense_per_msg = 0.0f64;
    for spec in specs() {
        let src = NoiseSource::new(dim, 0xBEEF);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(0.4).with_shards(shards).with_codec(spec)),
            src,
            8,
            &init,
            1.0,
            0.0,
            0x5EED,
        );
        eng.run(8000).unwrap();
        let comm = eng.state().comm;
        assert!(comm.messages > 0);
        let per_msg = comm.bytes as f64 / comm.messages as f64;
        let raw_per_msg = comm.raw_bytes as f64 / comm.messages as f64;
        if spec == CodecSpec::Dense {
            dense_per_msg = per_msg;
        }
        println!(
            "m8_s{shards}_{:<12} {:>10.0}  {:>7.0}  {:>10.2}x  {:>8}",
            spec.label(),
            per_msg,
            raw_per_msg,
            raw_per_msg / per_msg,
            comm.messages
        );
        if spec == CodecSpec::QuantizeU8 {
            let ratio = dense_per_msg / per_msg;
            assert!(
                ratio >= 3.0,
                "acceptance: q8 must ship >= 3x fewer encoded bytes than dense \
                 at equal shard count, got {ratio:.2}x"
            );
            println!("  -> q8 vs dense at equal shard count: {ratio:.2}x fewer encoded bytes");
        }
    }

    // One EncodedPayload body-size sanity line per codec (headers aside).
    println!();
    for spec in specs() {
        let codec = spec.build();
        let mut residual = vec![0.0f32; SHARD_LEN];
        let enc: EncodedPayload = codec.encode(payload.clone(), &mut residual);
        println!(
            "body bytes {}: {} (dense would be {})",
            spec.label(),
            enc.payload_wire_bytes(),
            raw_bytes
        );
    }

    b.finish();
}
