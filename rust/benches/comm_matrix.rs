//! Bench: section-3 communication-matrix application.
//!
//! The framework is an analysis tool, but its cost still matters for the
//! cross-check suites: sparse-row application must scale with touched
//! rows (1 for a gossip exchange) rather than with M.

use gosgd::bench::Bencher;
use gosgd::framework::{generators, Stacked};
use gosgd::tensor::FlatVec;
use gosgd::util::rng::Rng;

fn main() {
    let mut b = Bencher::new("comm_matrix");
    let mut rng = Rng::new(0);
    let m = 8;
    let dim = 100_000;
    let vecs: Vec<FlatVec> = (0..=m).map(|_| FlatVec::randn(dim, 1.0, &mut rng)).collect();
    let state = Stacked::from_vecs(vecs).unwrap();

    // Gossip exchange: touches exactly 1 row regardless of M.
    {
        let k = generators::gossip_exchange(m, 2, 5, 0.0625, 0.125).unwrap();
        b.bench_bytes("gossip_exchange_apply", (3 * dim * 4) as u64, || {
            std::hint::black_box(k.apply(&state).unwrap());
        });
    }

    // Full averaging (PerSyn sync): touches all M+1 rows.
    {
        let k = generators::allreduce(m).unwrap();
        b.bench_bytes(
            "allreduce_apply",
            ((m + 1) * (m + 1) * dim * 4) as u64,
            || {
                std::hint::black_box(k.apply(&state).unwrap());
            },
        );
    }

    // EASGD elastic sync.
    {
        let k = generators::easgd(0, 1, 0.9 / m as f64, m).unwrap();
        b.bench("easgd_apply", || {
            std::hint::black_box(k.apply(&state).unwrap());
        });
    }

    // Scalar-path application (analysis workloads sweep thousands of these).
    {
        let k = generators::allreduce(m).unwrap();
        let x: Vec<f64> = (0..=m).map(|i| i as f64).collect();
        b.bench_elems("allreduce_apply_scalars", (m + 1) as u64, || {
            std::hint::black_box(k.apply_scalars(&x).unwrap());
        });
    }

    // Composition (building P_t^T products for spectral analysis).
    {
        let k1 = generators::allreduce(m).unwrap();
        let k2 = generators::easgd(0, 1, 0.1, m).unwrap();
        b.bench("compose_9x9", || {
            std::hint::black_box(k1.compose(&k2).unwrap());
        });
    }

    b.finish();
}
