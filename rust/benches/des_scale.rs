//! Bench: million-worker DES scale — events/sec and resident bytes/worker.
//!
//! The timing-wheel scheduler, lazy copy-on-write worker models, and
//! sparse churn/telemetry state exist so a fleet three orders of magnitude
//! past the paper's 8 workers still simulates in bounded memory.  This
//! bench pins that claim:
//!
//! * a **1,048,576-worker** hypercube + q8 run completes, with hard
//!   ceilings on resident bytes per worker — cold (constructed, never
//!   stepped) and hot (simulated past every worker's first wake);
//! * heap and wheel schedulers produce **identical trace hashes** at a
//!   65,536-worker fleet (the tests pin small fleets; this pins scale);
//! * events/sec is recorded across fleet sizes for the perf trajectory.
//!
//! Reporting convention: each JSON/CSV row is one *run* (`iters = 1`,
//! recorded via `Bencher::record` — these runs are far too slow for the
//! sampled loop).  `elems_per_iter` carries the run's event count (steps +
//! messages), so `Melem/s` reads directly as millions of events per
//! second; `bytes_per_iter` carries **resident bytes per worker** at the
//! end of the run, not bytes moved, so ignore the GB/s column for this
//! group.
//!
//! Run with `cargo bench --bench des_scale`; set `BENCH_JSON` (CI uses
//! `BENCH_des_scale.json`) or `BENCH_CSV` for machine-readable output.

use std::time::Instant;

use gosgd::bench::Bencher;
use gosgd::gossip::{CodecSpec, TopologySpec};
use gosgd::sim::{DesEngine, DesStrategy, SchedulerKind, TimeModel};
use gosgd::strategies::grad::QuadraticSource;
use gosgd::tensor::FlatVec;

const DIM: usize = 64;
const SHARDS: usize = 4;
const P: f64 = 0.05;
const SEED: u64 = 0x5CA1E;

/// Ceiling on resident bytes per worker for a constructed-but-unstarted
/// fleet (cold: every model is the shared replica; the dominant costs are
/// the worker struct, its per-shard weights, and its pending wake event).
const COLD_BYTES_PER_WORKER: usize = 768;
/// Ceiling once workers have stepped (hot: adds one `DIM`-coordinate f32
/// model copy per woken worker plus mailbox/trace capacity).
const HOT_BYTES_PER_WORKER: usize = 1536;

fn engine(workers: usize, kind: SchedulerKind) -> DesEngine {
    DesEngine::new(
        DesStrategy::ShardedGoSgd { p: P, shards: SHARDS },
        TimeModel::paper_like(),
        workers,
        &FlatVec::zeros(DIM),
        0.5,
        0.0,
        SEED,
    )
    .unwrap()
    .with_scheduler(kind)
    .with_codec(CodecSpec::QuantizeU8)
    .with_topology(TopologySpec::Hypercube)
}

/// Run a fleet to `horizon` and record one row; returns (events, bytes/worker).
fn run_fleet(
    b: &mut Bencher,
    name: &str,
    workers: usize,
    kind: SchedulerKind,
    horizon: f64,
) -> (u64, usize, u64, Vec<f32>) {
    let mut grad = QuadraticSource::new(DIM, 0.1, SEED ^ 0x11);
    let mut eng = engine(workers, kind);
    let t0 = Instant::now();
    eng.run(&mut grad, horizon).unwrap();
    let elapsed = t0.elapsed();
    let rep = eng.report();
    let events = rep.steps + rep.messages;
    let per_worker = eng.state_bytes() / workers;
    b.record(name, elapsed, Some(per_worker as u64), Some(events));
    let hash = rep.trace_hash();
    let consensus = eng.consensus_model().unwrap().as_slice().to_vec();
    (events, per_worker, hash, consensus)
}

fn main() {
    let mut b = Bencher::new("des_scale");

    // Fleet-size sweep: events/sec trajectory at 4k and 64k workers.
    for shift in [12u32, 16] {
        let workers = 1usize << shift;
        let (events, per_worker, _, _) = run_fleet(
            &mut b,
            &format!("wheel_{}k_workers_0.5s", workers >> 10),
            workers,
            SchedulerKind::Wheel,
            0.5,
        );
        assert!(events > workers as u64, "fleet {workers}: suspiciously few events");
        println!("  {workers} workers: {per_worker} resident bytes/worker");
    }

    // Scheduler equivalence at scale: 65,536 workers, identical trace
    // hashes and bit-identical consensus under heap vs wheel.
    let (_, _, wheel_hash, wheel_x) =
        run_fleet(&mut b, "wheel_64k_equivalence_0.3s", 1 << 16, SchedulerKind::Wheel, 0.3);
    let (_, _, heap_hash, heap_x) =
        run_fleet(&mut b, "heap_64k_equivalence_0.3s", 1 << 16, SchedulerKind::Heap, 0.3);
    assert_eq!(
        wheel_hash, heap_hash,
        "acceptance: heap and wheel schedulers must produce identical traces"
    );
    assert_eq!(wheel_x, heap_x, "heap and wheel consensus models diverged");
    println!("  64k heap == wheel: trace hash {wheel_hash:#018x}");

    // The tentpole: one million workers, cold then hot.
    let workers = 1usize << 20;
    let t0 = Instant::now();
    let mut eng = engine(workers, SchedulerKind::Wheel);
    let mut grad = QuadraticSource::new(DIM, 0.1, SEED ^ 0x11);
    // Horizon 0.0 starts the engine (schedules every initial wake) but
    // processes nothing: all million workers must still share the one
    // cold replica.
    eng.run(&mut grad, 0.0).unwrap();
    let build = t0.elapsed();
    assert_eq!(eng.cold_workers(), workers, "unstarted workers must stay cold");
    let cold_per_worker = eng.state_bytes() / workers;
    b.record("cold_1m_workers", build, Some(cold_per_worker as u64), None);
    println!("  1M workers cold: {cold_per_worker} bytes/worker (ceiling {COLD_BYTES_PER_WORKER})");
    assert!(
        cold_per_worker <= COLD_BYTES_PER_WORKER,
        "acceptance: cold fleet must cost <= {COLD_BYTES_PER_WORKER} bytes/worker, \
         got {cold_per_worker}"
    );

    // Hot: past every worker's first wake (stragglers included: worst
    // first wake is ~0.115 s + 3x the 100 ms mean compute).
    let t1 = Instant::now();
    eng.run(&mut grad, 0.45).unwrap();
    let elapsed = t1.elapsed();
    let rep = eng.report();
    let events = rep.steps + rep.messages;
    let hot_per_worker = eng.state_bytes() / workers;
    b.record("hot_1m_workers_0.45s", elapsed, Some(hot_per_worker as u64), Some(events));
    println!("  1M workers hot:  {hot_per_worker} bytes/worker (ceiling {HOT_BYTES_PER_WORKER})");
    assert!(
        hot_per_worker <= HOT_BYTES_PER_WORKER,
        "acceptance: hot fleet must cost <= {HOT_BYTES_PER_WORKER} bytes/worker, \
         got {hot_per_worker}"
    );
    assert_eq!(eng.cold_workers(), 0, "0.45 s covers every worker's first wake");
    assert!(
        rep.steps >= workers as u64,
        "every worker must step at least once, got {} steps for {workers} workers",
        rep.steps
    );
    let evps = events as f64 / elapsed.as_secs_f64();
    println!("  1M workers hot:  {events} events in {elapsed:.2?} ({evps:.0} events/sec)");

    b.finish();
}
