//! Bench: DES event throughput with the finite-bandwidth fabric on.
//!
//! The fabric turns every gossip send into a small pipeline (NIC → link →
//! switch arbiter → link → NIC) driven by its own event heap, so each
//! message costs a handful of extra heap operations instead of one.  The
//! acceptance line pins that overhead: a fabric-on DES run must finish in
//! **< 3× the ideal-fabric wall time** at identical protocol settings —
//! asserted below for the rack and wan presets, printed for edge.
//!
//! Run with `cargo bench --bench fabric_throughput`; set `BENCH_CSV` or
//! `BENCH_JSON` for machine-readable output (CI uploads the JSON as
//! `BENCH_fabric.json` to accumulate the perf trajectory).

use gosgd::bench::Bencher;
use gosgd::sim::{DesEngine, DesStrategy, FabricSpec, TimeModel};
use gosgd::strategies::grad::QuadraticSource;
use gosgd::tensor::FlatVec;

const DIM: usize = 512;
const WORKERS: usize = 8;
const HORIZON: f64 = 30.0;

fn run_des(spec: FabricSpec) -> (u64, u64) {
    let mut grad = QuadraticSource::new(DIM, 0.1, 0x11);
    let mut eng = DesEngine::new(
        DesStrategy::ShardedGoSgd { p: 0.3, shards: 4 },
        TimeModel::paper_like(),
        WORKERS,
        &FlatVec::zeros(DIM),
        1.0,
        0.0,
        0xFAB1,
    )
    .unwrap()
    .with_fabric(spec);
    eng.run(&mut grad, HORIZON).unwrap();
    let rep = eng.report();
    (rep.steps, rep.messages)
}

fn main() {
    let mut b = Bencher::new("fabric_throughput");

    // Step + message counts per run, so mean_ns translates to events/sec.
    let specs = [
        ("ideal", FabricSpec::Ideal),
        ("rack", FabricSpec::Rack),
        ("wan", FabricSpec::Wan),
        ("edge", FabricSpec::Edge),
    ];
    let mut means = Vec::new();
    for (label, spec) in specs {
        let (steps, messages) = run_des(spec);
        assert!(steps > 0 && messages > 0, "{label}: empty run");
        let mean = b
            .bench_elems(&format!("des_30s_{label}"), steps + messages, || {
                std::hint::black_box(run_des(spec));
            })
            .mean_ns;
        means.push((label, mean));
    }

    let ideal = means[0].1;
    println!();
    for &(label, mean) in &means[1..] {
        let slowdown = mean / ideal;
        println!("{label:<5} vs ideal: {slowdown:.2}x wall time");
        if label != "edge" {
            assert!(
                slowdown < 3.0,
                "acceptance: {label} fabric must stay under 3x the ideal DES \
                 wall time, got {slowdown:.2}x"
            );
        }
    }

    b.finish();
}
