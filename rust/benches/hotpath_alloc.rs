//! Bench + acceptance gate: the zero-allocation gossip hot path.
//!
//! Installs a counting global allocator and drives the full steady-state
//! exchange — emit → encode → enqueue → drain → absorb/blend — through
//! the shared `gosgd::bench::ExchangePair` harness, with and without a
//! `BufferPool` attached.  Two outputs:
//!
//! 1. **ns/exchange** for every codec, pooled vs unpooled (the
//!    before/after of the pooling change), written to `BENCH_hotpath.json`
//!    when `BENCH_JSON` is set (CI uploads it beside `BENCH_codec.json`).
//! 2. **allocations/exchange**, measured at the allocator.  The acceptance
//!    assertions make allocation regressions a CI failure:
//!    * dense and q8 with a pool: **exactly 0** steady-state heap
//!      allocations per exchange;
//!    * top-k with a pool: bounded by a small constant *total* (its
//!      index/value/scratch buffers are pooled too; after warm-up the
//!      freelist serves every size class);
//!    * unpooled: strictly positive (sanity that the counter counts).
//!
//! The same contract runs as a plain test suite in
//! `rust/tests/alloc_regression.rs`, over the identical harness.

use gosgd::bench::{Bencher, ExchangePair};
use gosgd::gossip::CodecSpec;
use gosgd::util::alloc_count::CountingAllocator;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator::new();

/// Paper-scale-ish model slice: 64k parameters in 4 shards.
const DIM: usize = 1 << 16;
const SHARDS: usize = 4;
const SHARD_LEN: usize = DIM / SHARDS;

/// Heap allocations over `iters` exchanges after `warmup` warm ones.
fn measure_allocs(codec: CodecSpec, pooled: bool, warmup: usize, iters: usize) -> u64 {
    let mut pair = ExchangePair::new(codec, pooled, DIM, SHARDS, 0x407);
    for _ in 0..warmup {
        pair.exchange();
    }
    CountingAllocator::reset();
    for _ in 0..iters {
        pair.exchange();
    }
    CountingAllocator::allocations()
}

fn main() {
    let specs = [
        CodecSpec::Dense,
        CodecSpec::QuantizeU8,
        CodecSpec::TopK { k: SHARD_LEN / 16 },
    ];

    // ---- latency: ns/exchange, pooled vs unpooled ----------------------
    let mut b = Bencher::new("hotpath_alloc");
    let bytes = (SHARD_LEN * 4) as u64; // raw payload moved per exchange
    for spec in specs {
        for pooled in [false, true] {
            let mode = if pooled { "pooled" } else { "unpooled" };
            let mut pair = ExchangePair::new(spec, pooled, DIM, SHARDS, 0x407);
            b.bench_bytes(&format!("exchange_{}_{mode}", spec.label()), bytes, || {
                pair.exchange();
            });
        }
    }

    // ---- the acceptance gate: allocations per steady-state exchange ----
    let (warmup, iters) = (512usize, 512usize);
    println!("\ncodec      mode      allocs over {iters} exchanges   allocs/exchange");
    let mut report = Vec::new();
    for spec in specs {
        for pooled in [false, true] {
            let n = measure_allocs(spec, pooled, warmup, iters);
            println!(
                "{:<10} {:<9} {:>10}                      {:>8.3}",
                spec.label(),
                if pooled { "pooled" } else { "unpooled" },
                n,
                n as f64 / iters as f64
            );
            report.push((spec, pooled, n));
        }
    }
    for (spec, pooled, n) in report {
        match (spec, pooled) {
            (CodecSpec::Dense, true) | (CodecSpec::QuantizeU8, true) => assert_eq!(
                n,
                0,
                "acceptance: {} with a pool must perform ZERO steady-state heap \
                 allocations per exchange, measured {n} over {iters}",
                spec.label()
            ),
            (CodecSpec::TopK { .. }, true) => assert!(
                n <= 16,
                "acceptance: pooled top-k must stay within a bounded constant of \
                 allocations ({n} over {iters} exchanges)"
            ),
            (_, false) => assert!(
                n > 0,
                "sanity: the unpooled path must allocate (counter broken?)"
            ),
        }
    }
    println!("\nzero-allocation acceptance passed (dense/q8 = 0, top-k bounded)");

    b.finish();
}
