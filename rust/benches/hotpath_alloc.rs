//! Bench + acceptance gate: the zero-allocation gossip hot path.
//!
//! Installs a counting global allocator and drives the full steady-state
//! exchange — emit → encode → enqueue → drain → absorb/blend — through
//! the shared `gosgd::bench::ExchangePair` harness, with and without a
//! `BufferPool` attached.  Two outputs:
//!
//! 1. **ns/exchange** for every codec, pooled vs unpooled (the
//!    before/after of the pooling change), written to `BENCH_hotpath.json`
//!    when `BENCH_JSON` is set (CI uploads it beside `BENCH_codec.json`).
//! 2. **allocations/exchange**, measured at the allocator.  The acceptance
//!    assertions make allocation regressions a CI failure:
//!    * dense and q8 with a pool: **exactly 0** steady-state heap
//!      allocations per exchange;
//!    * top-k with a pool: bounded by a small constant *total* (its
//!      index/value/scratch buffers are pooled too; after warm-up the
//!      freelist serves every size class);
//!    * unpooled: strictly positive (sanity that the counter counts).
//!
//! A third gate covers the DES scheduler: the timing wheel's steady-state
//! pop path (lazy per-slot sorts through the persistent drain buffer,
//! level-1 chunk pours through the reused scratch) must perform **zero**
//! heap allocations once every capacity is warm — the property the
//! parallel executor's per-lane wheels lean on.
//!
//! The same contract runs as a plain test suite in
//! `rust/tests/alloc_regression.rs`, over the identical harness.

use gosgd::bench::{Bencher, ExchangePair};
use gosgd::gossip::CodecSpec;
use gosgd::sim::TimingWheel;
use gosgd::util::alloc_count::CountingAllocator;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator::new();

/// Paper-scale-ish model slice: 64k parameters in 4 shards.
const DIM: usize = 1 << 16;
const SHARDS: usize = 4;
const SHARD_LEN: usize = DIM / SHARDS;

/// Heap allocations over `iters` exchanges after `warmup` warm ones.
fn measure_allocs(codec: CodecSpec, pooled: bool, warmup: usize, iters: usize) -> u64 {
    let mut pair = ExchangePair::new(codec, pooled, DIM, SHARDS, 0x407);
    for _ in 0..warmup {
        pair.exchange();
    }
    CountingAllocator::reset();
    for _ in 0..iters {
        pair.exchange();
    }
    CountingAllocator::allocations()
}

/// Heap allocations on the timing wheel's steady-state pop path.
///
/// Each round fills one 256-tick window with `PER_TICK` events per tick
/// and drains it completely — after the warm-up rounds every capacity in
/// play (level-0 slots, the persistent sorted drain buffer, the level-1
/// pour scratch) has reached its fixed point, so the measured round's
/// pops (lazy per-slot sorts, chunk pours, cursor advances included)
/// must touch only recycled storage.
fn wheel_pop_allocs(warm_rounds: usize) -> u64 {
    const TICK: f64 = 1e-3;
    const PER_TICK: usize = 16;
    let mut wheel: TimingWheel<u64> = TimingWheel::new(TICK);
    let mut seq = 0u64;
    let mut push_round = |wheel: &mut TimingWheel<u64>, r: usize| {
        for i in 0..256usize {
            for j in 0..PER_TICK {
                let off = (j as f64 + 0.5) / PER_TICK as f64 * TICK * 0.98;
                seq += 1;
                wheel.push((r * 256 + i) as f64 * TICK + off, seq, seq);
            }
        }
    };
    let drain_round = |wheel: &mut TimingWheel<u64>| {
        let mut popped = 0usize;
        let mut prev = f64::NEG_INFINITY;
        while let Some(e) = wheel.pop() {
            assert!(e.time >= prev, "wheel pop order regressed");
            prev = e.time;
            popped += 1;
        }
        assert_eq!(popped, 256 * PER_TICK, "wheel lost events");
    };
    for r in 0..warm_rounds {
        push_round(&mut wheel, r);
        drain_round(&mut wheel);
    }
    push_round(&mut wheel, warm_rounds);
    CountingAllocator::reset();
    drain_round(&mut wheel);
    CountingAllocator::allocations()
}

fn main() {
    let specs = [
        CodecSpec::Dense,
        CodecSpec::QuantizeU8,
        CodecSpec::TopK { k: SHARD_LEN / 16 },
    ];

    // ---- latency: ns/exchange, pooled vs unpooled ----------------------
    let mut b = Bencher::new("hotpath_alloc");
    let bytes = (SHARD_LEN * 4) as u64; // raw payload moved per exchange
    for spec in specs {
        for pooled in [false, true] {
            let mode = if pooled { "pooled" } else { "unpooled" };
            let mut pair = ExchangePair::new(spec, pooled, DIM, SHARDS, 0x407);
            b.bench_bytes(&format!("exchange_{}_{mode}", spec.label()), bytes, || {
                pair.exchange();
            });
        }
    }

    // ---- the acceptance gate: allocations per steady-state exchange ----
    let (warmup, iters) = (512usize, 512usize);
    println!("\ncodec      mode      allocs over {iters} exchanges   allocs/exchange");
    let mut report = Vec::new();
    for spec in specs {
        for pooled in [false, true] {
            let n = measure_allocs(spec, pooled, warmup, iters);
            println!(
                "{:<10} {:<9} {:>10}                      {:>8.3}",
                spec.label(),
                if pooled { "pooled" } else { "unpooled" },
                n,
                n as f64 / iters as f64
            );
            report.push((spec, pooled, n));
        }
    }
    for (spec, pooled, n) in report {
        match (spec, pooled) {
            (CodecSpec::Dense, true) | (CodecSpec::QuantizeU8, true) => assert_eq!(
                n,
                0,
                "acceptance: {} with a pool must perform ZERO steady-state heap \
                 allocations per exchange, measured {n} over {iters}",
                spec.label()
            ),
            (CodecSpec::TopK { .. }, true) => assert!(
                n <= 16,
                "acceptance: pooled top-k must stay within a bounded constant of \
                 allocations ({n} over {iters} exchanges)"
            ),
            (_, false) => assert!(
                n > 0,
                "sanity: the unpooled path must allocate (counter broken?)"
            ),
        }
    }
    println!("\nzero-allocation acceptance passed (dense/q8 = 0, top-k bounded)");

    // ---- the DES scheduler: steady-state wheel pops allocate nothing ----
    // The parallel executor runs one wheel per lane, so a stray per-pop
    // allocation would multiply by thread count × events; the persistent
    // drain buffer keeps the lazy per-slot sorts on recycled storage.
    let wheel_allocs = wheel_pop_allocs(3);
    println!("timing-wheel steady-state drain: {wheel_allocs} allocations over 4096 pops");
    assert_eq!(
        wheel_allocs, 0,
        "acceptance: the wheel's steady-state pop path (sorted drain swaps, \
         chunk pours) must perform ZERO heap allocations"
    );

    b.finish();
}
