//! Bench: the gossip mix hot path (paper Algorithm 4 line 9).
//!
//! Perf target (DESIGN.md §Perf): the host blend is a pure-bandwidth op —
//! it reads 2 vectors and writes 1, so its roofline is ≈ memcpy-bandwidth/3.
//! Also measures the Pallas `mix` artifact through PJRT when artifacts are
//! present (the same op at L1), and the end-to-end message cost
//! (clone + push + drain + blend).

use gosgd::bench::Bencher;
use gosgd::gossip::{EncodedPayload, Message, MessageQueue, SumWeight};
use gosgd::tensor::{BufferPool, FlatVec};
use gosgd::util::rng::Rng;

fn main() {
    let mut b = Bencher::new("mix_throughput");
    let mut rng = Rng::new(0);

    for &n in &[100_000usize, 1_105_098, 4_206_602] {
        // 1.1M = the paper-scale CNN parameter count; 4.2M = mlp_wide.
        let x_s = FlatVec::randn(n, 1.0, &mut rng);
        let mut x_r = FlatVec::randn(n, 1.0, &mut rng);
        let bytes = (3 * n * 4) as u64; // read 2 + write 1
        let label = format!("host_mix_n{n}");
        b.bench_bytes(&label, bytes, || {
            x_r.mix_from(&x_s, 0.125, 0.0625).unwrap();
        });
    }

    // Memcpy reference for the roofline ratio.
    {
        let n = 1_105_098usize;
        let src = vec![1.0f32; n];
        let mut dst = vec![0.0f32; n];
        b.bench_bytes("memcpy_reference_n1105098", (2 * n * 4) as u64, || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&dst);
        });
    }

    // Full message path: pooled snapshot + queue + drain + blend — the
    // steady-state loop recycles one buffer instead of cloning 1.1M
    // floats' worth of fresh heap per message.
    {
        let n = 1_105_098usize;
        let pool = BufferPool::shared();
        let q = MessageQueue::unbounded();
        let x_s = FlatVec::randn(n, 1.0, &mut rng);
        let mut x_r = FlatVec::randn(n, 1.0, &mut rng);
        let mut w_r = SumWeight::init(8);
        let mut inbox = Vec::new();
        b.bench_bytes("full_message_path_n1105098", (4 * n * 4) as u64, || {
            let snapshot = FlatVec::pooled_copy(&pool, x_s.as_slice());
            q.push(Message::new(
                EncodedPayload::Dense(snapshot),
                SumWeight::from_value(0.0625),
                0,
                0,
            ));
            q.drain_into(&mut inbox);
            for msg in inbox.drain(..) {
                let t = w_r.absorb(msg.weight);
                let body = msg.payload.as_dense().expect("dense bench payload");
                x_r.mix_from(body, 1.0 - t, t).unwrap();
            }
        });
    }

    // The L1 Pallas mix artifact through PJRT (same op, compiled path).
    if std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        let rt = gosgd::runtime::ModelRuntime::load("artifacts/tiny").unwrap();
        let n = rt.param_count();
        let x_r = FlatVec::randn(n, 1.0, &mut rng);
        let x_s = FlatVec::randn(n, 1.0, &mut rng);
        b.bench_bytes(&format!("pjrt_pallas_mix_n{n}"), (3 * n * 4) as u64, || {
            std::hint::black_box(rt.mix(&x_r, &x_s, 0.125, 0.0625).unwrap());
        });
    } else {
        println!("(skipping pjrt_pallas_mix: run `make artifacts`)");
    }

    b.finish();
}
