//! Bench: wire-stack throughput for the networked runtime.
//!
//! Three layers, bottom up, so a regression pinpoints itself:
//!
//! 1. `frame_roundtrip_*` — message body encode → frame (CRC) → chunked
//!    reassembly → body decode, per codec.  This is the pure
//!    serialization tax every networked gossip message pays.
//! 2. `transport_*` — one message through the full connection layer
//!    (outbox → flush → pipe → reader → decode → ack) against the same
//!    message through the in-process `MessageQueue` the threaded runtime
//!    uses.  The delta is the cost of crash-safe delivery accounting.
//! 3. `lockstep_loopback_*` — end-to-end `NetGossip::run_lockstep`
//!    steps/sec, the number the loopback-equivalence suite executes.
//!
//! Run with `cargo bench --bench net_throughput`; set `BENCH_CSV` or
//! `BENCH_JSON` for machine-readable output (CI uploads the JSON as
//! `BENCH_net.json` to accumulate the perf trajectory).

use gosgd::bench::Bencher;
use gosgd::gossip::{CodecSpec, Message, MessageQueue, ProtocolCore, TopologySpec};
use gosgd::net::{ConnManager, FrameKind, FrameReader, LoopbackPipe};
use gosgd::strategies::grad::{GradSource, QuadraticSource};
use gosgd::tensor::FlatVec;
use gosgd::util::rng::Rng;
use gosgd::worker::NetGossip;

const DIM: usize = 4096;

/// One real emitted message at the bench dimension.
fn sample_message(codec: CodecSpec) -> Message {
    let mut core = ProtocolCore::new(0, 4, DIM, 1.0, TopologySpec::UniformRandom, 1)
        .unwrap()
        .with_codec(codec);
    let mut x = FlatVec::zeros(DIM);
    Rng::new(0xBE7).fill_normal(x.as_mut_slice(), 1.0);
    core.emit_to(&x, 1).unwrap().into_message(0, 7)
}

fn main() {
    let mut b = Bencher::new("net_throughput");

    // Layer 0: the CRC kernel in isolation, bytes/sec — the
    // slicing-by-8 speedup (vs the old bytewise loop) lands here, and
    // regressions in it pinpoint themselves below the frame layer.
    let mut rng = Rng::new(0xCC32);
    let payload: Vec<u8> = (0..1 << 20).map(|_| rng.next_u64() as u8).collect();
    b.bench_bytes("crc32_1mib", payload.len() as u64, || {
        std::hint::black_box(gosgd::net::frame::crc32(&payload));
    });

    // Layer 1: the serialization tax, bytes/sec per codec.
    let codecs = [
        ("dense", CodecSpec::Dense),
        ("top256", CodecSpec::TopK { k: 256 }),
        ("q8", CodecSpec::QuantizeU8),
    ];
    for (label, codec) in codecs {
        let msg = sample_message(codec);
        let wire = gosgd::net::frame::frame_bytes(FrameKind::Gossip, 0, &msg.to_wire_body());
        let mut frame_buf = Vec::with_capacity(wire.len());
        let mut reader = FrameReader::new();
        b.bench_bytes(&format!("frame_roundtrip_{label}"), wire.len() as u64, || {
            frame_buf.clear();
            gosgd::net::frame::encode_frame(
                &mut frame_buf,
                FrameKind::Gossip,
                0,
                &msg.to_wire_body(),
            );
            reader.feed(&frame_buf);
            let frame = reader.try_next().unwrap().expect("one frame per feed");
            let back = Message::decode_body(&frame.body).unwrap();
            std::hint::black_box(back.payload.coord_count());
        });
    }

    // Layer 2: one message through each transport, ns/message.
    let msg = sample_message(CodecSpec::Dense);

    let queue = MessageQueue::unbounded();
    let mut scratch = Vec::new();
    let queue_ns = b
        .bench_elems("transport_queue", 1, || {
            queue.push(msg.clone());
            scratch.clear();
            queue.drain_into(&mut scratch);
            std::hint::black_box(scratch.len());
        })
        .mean_ns;

    let mut cm = ConnManager::new(2, 64);
    let pipe = LoopbackPipe::new();
    let mut reader = FrameReader::new();
    let mut chunk = Vec::new();
    let framed_ns = b
        .bench_elems("transport_framed", 1, || {
            cm.enqueue(1, msg.clone());
            cm.flush(1, 0, &pipe);
            loop {
                chunk.clear();
                if pipe.read_into(&mut chunk, 64 * 1024) == 0 {
                    break;
                }
                reader.feed(&chunk);
            }
            let frame = reader.try_next().unwrap().expect("one frame per flush");
            pipe.ack((gosgd::net::FRAME_HEADER_BYTES + frame.body.len()) as u64);
            cm.prune_acked(1, &pipe);
            let back = Message::decode_body(&frame.body).unwrap();
            std::hint::black_box(back.weight.value());
        })
        .mean_ns;
    println!("\ncrash-safe transport vs raw queue: {:.2}x ns/message", framed_ns / queue_ns);

    // Layer 3: end-to-end lockstep loopback fleets, worker-steps/sec.
    for (label, codec) in [("dense", CodecSpec::Dense), ("q8", CodecSpec::QuantizeU8)] {
        let node = NetGossip {
            workers: 4,
            p: 0.5,
            steps_per_worker: 50,
            eta: 0.2,
            weight_decay: 0.0,
            seed: 0x909,
            topology: TopologySpec::UniformRandom,
            shards: 4,
            codec,
            ..NetGossip::default()
        };
        let init = FlatVec::zeros(256);
        let elems = node.workers as u64 * node.steps_per_worker;
        b.bench_elems(&format!("lockstep_loopback_{label}"), elems, || {
            let report = node
                .run_lockstep(&init, |_| {
                    Ok(Box::new(QuadraticSource::new(256, 0.1, 0x33)) as Box<dyn GradSource>)
                })
                .unwrap();
            assert!(report.messages > 0, "lockstep run gossiped nothing");
            std::hint::black_box(report.trace_hash);
        });
    }

    b.finish();
}
