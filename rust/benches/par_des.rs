//! Bench: parallel DES executor — events/sec vs thread count.
//!
//! The sharded executor (`ParallelKind::Sharded(T)`, ARCHITECTURE ch.
//! 7f) exists to buy wall-clock throughput without giving up the bit
//! for bit determinism every equivalence suite leans on.  This bench
//! pins both halves of that claim at scale:
//!
//! * **events/sec vs thread count** at a 65,536-worker and a
//!   1,048,576-worker hypercube + q8 fleet (the `des_scale.rs`
//!   configuration), one recorded row per `(fleet, threads)`;
//! * **trace-hash identity**: every thread count must reproduce the
//!   sequential run's trace hash and consensus bits — the
//!   `runtime_equivalence.rs` grid pins this at small fleets, this
//!   bench pins it at scale;
//! * **speedup acceptance**: on a machine with ≥ 8 available cores the
//!   8-thread run must clear **3×** the sequential events/sec on the
//!   65,536-worker fleet.  On smaller machines (CI shells with 1–4
//!   cores) the assertion is skipped — throughput there measures the
//!   scheduler's overhead, not its parallelism — but the identity
//!   assertions always run.
//!
//! Reporting convention follows `des_scale.rs`: one row per run
//! (`iters = 1` via `Bencher::record`), `elems_per_iter` = events
//! (steps + messages) so `Melem/s` reads as millions of events per
//! second.  Run with `cargo bench --bench par_des`; CI sets
//! `BENCH_JSON=BENCH_par_des.json` and uploads the artifact.

use std::time::Instant;

use gosgd::bench::Bencher;
use gosgd::gossip::{CodecSpec, TopologySpec};
use gosgd::sim::{DesEngine, DesStrategy, ParallelKind, TimeModel};
use gosgd::strategies::grad::QuadraticSource;
use gosgd::tensor::FlatVec;

const DIM: usize = 64;
const SHARDS: usize = 4;
const P: f64 = 0.05;
const SEED: u64 = 0x5CA1E;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn engine(workers: usize, threads: usize) -> DesEngine {
    let parallel = if threads > 1 {
        ParallelKind::Sharded(threads)
    } else {
        ParallelKind::Sequential
    };
    DesEngine::new(
        DesStrategy::ShardedGoSgd { p: P, shards: SHARDS },
        TimeModel::paper_like(),
        workers,
        &FlatVec::zeros(DIM),
        0.5,
        0.0,
        SEED,
    )
    .unwrap()
    .with_codec(CodecSpec::QuantizeU8)
    .with_topology(TopologySpec::Hypercube)
    .with_parallel(parallel)
}

/// One run: events/sec plus the identity tuple (trace hash, consensus).
fn run_fleet(
    b: &mut Bencher,
    workers: usize,
    threads: usize,
    horizon: f64,
) -> (f64, u64, Vec<f32>) {
    let mut grad = QuadraticSource::new(DIM, 0.1, SEED ^ 0x11);
    let mut eng = engine(workers, threads);
    let t0 = Instant::now();
    eng.run(&mut grad, horizon).unwrap();
    let elapsed = t0.elapsed();
    let rep = eng.report();
    let events = rep.steps + rep.messages;
    b.record(&format!("{}k_workers_{threads}t", workers >> 10), elapsed, None, Some(events));
    let evps = events as f64 / elapsed.as_secs_f64();
    let hash = rep.trace_hash();
    let consensus = eng.consensus_model().unwrap().as_slice().to_vec();
    (evps, hash, consensus)
}

fn main() {
    // Capability probe only — no thread is spawned outside the engine's
    // own (shim-routed) scoped lanes, so the model checker loses nothing.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1); // lint:allow(sync-shim)
    let mut b = Bencher::new("par_des");
    println!("machine reports {cores} available cores");

    let mut seq_64k_evps = 0.0f64;
    let mut par8_64k_evps = 0.0f64;
    for (workers, horizon) in [(1usize << 16, 0.3), (1usize << 20, 0.15)] {
        let mut reference: Option<(u64, Vec<f32>)> = None;
        for threads in THREADS {
            let (evps, hash, consensus) = run_fleet(&mut b, workers, threads, horizon);
            println!("  {workers} workers @ {threads} thread(s): {evps:.0} events/sec");
            match &reference {
                None => reference = Some((hash, consensus)),
                Some((h, x)) => {
                    assert_eq!(
                        hash, *h,
                        "acceptance: Sharded({threads}) trace diverged from \
                         sequential at {workers} workers"
                    );
                    assert_eq!(
                        consensus, *x,
                        "acceptance: Sharded({threads}) consensus diverged from \
                         sequential at {workers} workers"
                    );
                }
            }
            if workers == 1 << 16 {
                if threads == 1 {
                    seq_64k_evps = evps;
                } else if threads == 8 {
                    par8_64k_evps = evps;
                }
            }
        }
        println!("  {workers} workers: all thread counts bit-identical");
    }

    if cores >= 8 {
        let speedup = par8_64k_evps / seq_64k_evps;
        println!("  64k fleet speedup at 8 threads: {speedup:.2}x");
        assert!(
            speedup >= 3.0,
            "acceptance: 8 threads must clear 3x sequential events/sec on the \
             65,536-worker hypercube+q8 fleet (got {speedup:.2}x)"
        );
    } else {
        println!(
            "  skipping the 3x speedup acceptance: {cores} core(s) < 8 \
             (identity assertions ran)"
        );
    }

    b.finish();
}
