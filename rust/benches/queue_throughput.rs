//! Bench: message-queue operations (the gossip substrate's control path).
//!
//! Perf target (DESIGN.md §Perf): queue ops are O(1) — payload bodies
//! move, they are never copied — so push/drain must be orders of
//! magnitude cheaper than a gradient step and the protocol's overhead
//! stays negligible at p = 0.01…1.  Bodies cycle through a shared
//! [`BufferPool`] exactly as the runtimes run them, so the loop also
//! exercises the zero-allocation steady state (asserted for real in
//! `benches/hotpath_alloc.rs`).

use gosgd::bench::Bencher;
use gosgd::gossip::{EncodedPayload, Message, MessageQueue, SumWeight};
use gosgd::sync::atomic::{AtomicBool, Ordering};
use gosgd::sync::{thread, Arc};
use gosgd::tensor::{BufferPool, FlatVec};

/// A pooled paper-scale dense message: the body's storage is recycled
/// when the drained message drops, so repeated calls recycle one buffer.
fn msg(pool: &Arc<BufferPool>, n: usize) -> Message {
    Message::new(
        EncodedPayload::Dense(FlatVec::pooled(pool, n)),
        SumWeight::from_value(0.01),
        0,
        0,
    )
}

fn main() {
    let mut b = Bencher::new("queue_throughput");
    // Paper-scale CNN payload length.
    let n = 1_105_098usize;
    let pool = BufferPool::shared();

    // Single-threaded push+drain round trip (body moved, then recycled).
    {
        let q = MessageQueue::unbounded();
        let mut inbox = Vec::new();
        b.bench_elems("push_drain_roundtrip", 1, || {
            q.push(msg(&pool, n));
            q.drain_into(&mut inbox);
            std::hint::black_box(inbox.drain(..).count());
        });
    }

    // Batched: 8 producers' worth of messages drained at once.
    {
        let q = MessageQueue::unbounded();
        let mut inbox = Vec::new();
        b.bench_elems("push8_drain", 8, || {
            for _ in 0..8 {
                q.push(msg(&pool, n));
            }
            q.drain_into(&mut inbox);
            std::hint::black_box(inbox.drain(..).count());
        });
    }

    // Bounded queue with coalescing under overflow (worst case: every push
    // beyond capacity folds two 10k-float payloads through pooled scratch).
    {
        let q = MessageQueue::bounded(4).with_pool(pool.clone());
        let mut inbox = Vec::new();
        b.bench_elems("bounded_coalesce_10k", 8, || {
            for _ in 0..8 {
                q.push(msg(&pool, 10_000));
            }
            q.drain_into(&mut inbox);
            std::hint::black_box(inbox.drain(..).count());
        });
    }

    // Cross-thread contention: 4 pusher threads against one drainer, all
    // recycling through the same pool (the threaded runtime's shape).
    {
        let q = Arc::new(MessageQueue::unbounded());
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            let stop = stop.clone();
            let pool = pool.clone();
            handles.push(thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    q.push(msg(&pool, 10_000));
                    thread::yield_now();
                }
            }));
        }
        b.bench_elems("drain_under_contention", 1, || {
            std::hint::black_box(q.drain());
        });
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    }

    b.finish();
}
