//! Bench: message-queue operations (the gossip substrate's control path).
//!
//! Perf target (DESIGN.md §Perf): queue ops are O(1) with `Arc`'d payloads
//! — push/drain must be orders of magnitude cheaper than a gradient step
//! so the protocol's overhead stays negligible at p = 0.01…1.

use gosgd::bench::Bencher;
use gosgd::gossip::{EncodedPayload, Message, MessageQueue, SumWeight};
use gosgd::tensor::FlatVec;
use std::sync::Arc;

fn msg(payload: &Arc<EncodedPayload>) -> Message {
    Message::new(payload.clone(), SumWeight::from_value(0.01), 0, 0)
}

fn main() {
    let mut b = Bencher::new("queue_throughput");
    // Paper-scale CNN payload.
    let payload = Arc::new(EncodedPayload::Dense(FlatVec::zeros(1_105_098)));

    // Single-threaded push+drain round trip (payload shared, not copied).
    {
        let q = MessageQueue::unbounded();
        b.bench_elems("push_drain_roundtrip", 1, || {
            q.push(msg(&payload));
            std::hint::black_box(q.drain());
        });
    }

    // Batched: 8 producers' worth of messages drained at once.
    {
        let q = MessageQueue::unbounded();
        b.bench_elems("push8_drain", 8, || {
            for _ in 0..8 {
                q.push(msg(&payload));
            }
            std::hint::black_box(q.drain());
        });
    }

    // Bounded queue with coalescing under overflow (worst case: every push
    // beyond capacity folds two 1.1M-float payloads).
    {
        let q = MessageQueue::bounded(4);
        let small = Arc::new(EncodedPayload::Dense(FlatVec::zeros(10_000)));
        b.bench_elems("bounded_coalesce_10k", 8, || {
            for _ in 0..8 {
                q.push(Message::new(small.clone(), SumWeight::from_value(0.01), 0, 0));
            }
            std::hint::black_box(q.drain());
        });
    }

    // Cross-thread contention: 4 pusher threads against one drainer.
    {
        let q = Arc::new(MessageQueue::unbounded());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            let stop = stop.clone();
            let p = payload.clone();
            handles.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    q.push(Message::new(p.clone(), SumWeight::from_value(0.01), 0, 0));
                    std::thread::yield_now();
                }
            }));
        }
        b.bench_elems("drain_under_contention", 1, || {
            std::hint::black_box(q.drain());
        });
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    }

    b.finish();
}
