//! Bench: sharded gossip exchange — shard counts × worker counts.
//!
//! The acceptance experiment for the sharded-exchange path: at a fixed
//! per-coordinate exchange budget, sweeping `shards` must (a) cut the
//! bytes shipped per gossip event by `~1/shards`, (b) keep the consensus
//! residual in the same band as the unsharded protocol, and (c) not slow
//! the engine's tick rate (smaller snapshots mean *less* copying per
//! send).  Run with `cargo bench --bench shard_scaling`; set `BENCH_CSV`
//! for machine-readable output.

use gosgd::bench::Bencher;
use gosgd::strategies::engine::Engine;
use gosgd::strategies::gosgd::GoSgd;
use gosgd::strategies::grad::NoiseSource;
use gosgd::tensor::FlatVec;

/// One configuration's summary after a fixed run.
struct Summary {
    label: String,
    bytes_per_msg: f64,
    consensus_error: f64,
    messages: u64,
}

fn run_summary(workers: usize, shards: usize, p: f64, dim: usize, ticks: u64) -> Summary {
    let src = NoiseSource::new(dim, 0xBEEF);
    let init = FlatVec::zeros(dim);
    let mut eng = Engine::new(
        Box::new(GoSgd::new(p).with_shards(shards)),
        src,
        workers,
        &init,
        1.0,
        0.0,
        0x5EED ^ shards as u64,
    );
    eng.run(ticks).unwrap();
    let state = eng.state();
    Summary {
        label: format!("m{workers}_s{shards}"),
        bytes_per_msg: state.comm.bytes as f64 / state.comm.messages.max(1) as f64,
        consensus_error: state.stacked.consensus_error().unwrap(),
        messages: state.comm.messages,
    }
}

fn main() {
    let dim = 4096;
    let mut b = Bencher::new("shard_scaling");

    // Throughput: engine ticks/second across the sweep.  The closure runs
    // 64 ticks per call; elems/s therefore reports ticks/s directly.
    for &workers in &[4usize, 8] {
        for &shards in &[1usize, 2, 4, 8, 16] {
            let src = NoiseSource::new(dim, 1);
            let init = FlatVec::zeros(dim);
            let mut eng = Engine::new(
                Box::new(GoSgd::new(0.2).with_shards(shards)),
                src,
                workers,
                &init,
                1.0,
                0.0,
                2,
            );
            b.bench_elems(&format!("ticks_m{workers}_s{shards}"), 64, || {
                eng.run(64).unwrap();
            });
        }
    }

    // Accounting sweep: equal per-coordinate budget (p scales with shards,
    // capped at 1), long enough for the consensus residual to reach its
    // steady state.
    println!("\nconfig      bytes/msg   messages   consensus_eps");
    let base_p = 0.05;
    for &workers in &[4usize, 8] {
        for &shards in &[1usize, 2, 4, 8, 16] {
            let p = (base_p * shards as f64).min(1.0);
            let s = run_summary(workers, shards, p, dim, 20_000);
            println!(
                "{:<10} {:>10.0}  {:>9}  {:>14.4}",
                s.label, s.bytes_per_msg, s.messages, s.consensus_error
            );
        }
    }

    b.finish();
}
