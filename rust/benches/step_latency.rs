//! Bench: per-step latency of each layer of the training path.
//!
//! Breaks the end-to-end step into its parts: batch generation (L3 data),
//! PJRT train_step (L2+L1 compute), host optimizer, and the two update
//! paths (host vs `sgd_update` artifact).  Requires artifacts for the
//! PJRT entries; the host entries always run.

use gosgd::bench::Bencher;
use gosgd::data::{BatchSampler, SyntheticCifar};
use gosgd::runtime::ModelRuntime;
use gosgd::tensor::FlatVec;
use gosgd::util::rng::Rng;

fn main() {
    let mut b = Bencher::new("step_latency");
    let mut rng = Rng::new(0);

    // L3 data pipeline: one 16-image synthetic batch.
    {
        let sampler = BatchSampler::new(SyntheticCifar::new(0, 0.5, true), 16, 8);
        let mut step = 0u64;
        b.bench_elems("batch_generation_16", 16, || {
            std::hint::black_box(sampler.train_batch(1, step));
            step += 1;
        });
    }

    // Host optimizer at paper-scale parameter count.
    {
        let n = 1_105_098;
        let mut params = FlatVec::randn(n, 0.1, &mut rng);
        let grads = FlatVec::randn(n, 0.1, &mut rng);
        b.bench_bytes("host_sgd_step_n1105098", (3 * n * 4) as u64, || {
            params.sgd_step(&grads, 0.1, 1e-4).unwrap();
        });
    }

    for model in ["tiny", "cnn"] {
        let dir = format!("artifacts/{model}");
        if !std::path::Path::new(&dir).join("manifest.json").exists() {
            println!("(skipping {model}: run `make artifacts`)");
            continue;
        }
        let rt = ModelRuntime::load(&dir).unwrap();
        let params = rt.manifest().load_init_params().unwrap();
        let sampler = BatchSampler::new(
            SyntheticCifar::new(0, 0.5, true),
            rt.manifest().batch,
            8,
        );
        let batch = sampler.train_batch(1, 0);

        b.bench(&format!("pjrt_train_step_{model}"), || {
            std::hint::black_box(
                rt.train_step(&params, &batch.images, &batch.labels).unwrap(),
            );
        });

        let grads = {
            let (_, g) = rt.train_step(&params, &batch.images, &batch.labels).unwrap();
            g
        };
        b.bench(&format!("pjrt_sgd_update_{model}"), || {
            std::hint::black_box(rt.sgd_update(&params, &grads, 0.1, 1e-4).unwrap());
        });

        let eval_sampler = BatchSampler::new(
            SyntheticCifar::new(0, 0.5, false),
            rt.manifest().batch,
            8,
        );
        let vb = eval_sampler.val_batch(0, rt.manifest().eval_batch);
        b.bench(&format!("pjrt_eval_step_{model}"), || {
            std::hint::black_box(rt.eval_step(&params, &vb.images, &vb.labels).unwrap());
        });
    }

    b.finish();
}
