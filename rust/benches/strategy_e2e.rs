//! Bench: end-to-end engine throughput per strategy + ablations.
//!
//! The paper's efficiency argument is that GoSGD's communication cost is
//! negligible (p as low as 0.01 message/update).  This bench quantifies
//! it: engine steps/second per strategy at paper-scale parameter counts,
//! the overhead of p, and the peer-topology ablation from DESIGN.md.

use gosgd::bench::Bencher;
use gosgd::gossip::PeerSelector;
use gosgd::strategies::allreduce::AllReduce;
use gosgd::strategies::easgd::Easgd;
use gosgd::strategies::engine::Engine;
use gosgd::strategies::gosgd::GoSgd;
use gosgd::strategies::grad::QuadraticSource;
use gosgd::strategies::local::Local;
use gosgd::strategies::persyn::PerSyn;
use gosgd::strategies::Strategy;
use gosgd::tensor::FlatVec;

/// `steps_per_iter` is in ENGINE steps: one round (= M worker-steps) for
/// synchronous strategies, one tick (= 1 worker-step) for asynchronous
/// ones — callers pick values so every entry does 8 worker-steps/iter.
fn bench_strategy(
    b: &mut Bencher,
    label: &str,
    mk: impl Fn() -> Box<dyn Strategy>,
    dim: usize,
    steps_per_iter: u64,
) {
    let init = FlatVec::zeros(dim);
    let src = QuadraticSource::new(dim, 0.2, 1);
    let mut eng = Engine::new(mk(), src, 8, &init, 0.5, 1e-4, 2);
    b.bench_elems(label, 8, || { // 8 worker-steps per iteration
        eng.run(steps_per_iter).unwrap();
    });
}

fn main() {
    let mut b = Bencher::new("strategy_e2e");
    // Paper-scale CNN parameter count; the gradient itself is synthetic so
    // the numbers isolate *coordination* cost, not model compute.
    let dim = 1_105_098;

    bench_strategy(&mut b, "local_8w", || Box::new(Local), dim, 1);
    bench_strategy(&mut b, "allreduce_8w", || Box::new(AllReduce), dim, 1);
    bench_strategy(&mut b, "persyn_tau50_8w", || Box::new(PerSyn::new(50)), dim, 1);
    bench_strategy(
        &mut b,
        "easgd_tau50_8w",
        || Box::new(Easgd::new(0.9 / 8.0, 50)),
        dim,
        1,
    );

    // GoSGD across p: the paper's key operating points.
    for p in [0.01, 0.1, 0.5] {
        bench_strategy(
            &mut b,
            &format!("gosgd_p{p}_8w"),
            move || Box::new(GoSgd::new(p)),
            dim,
            8,
        );
    }

    // Topology ablation (DESIGN.md): uniform vs ring vs small-world.
    for (tag, sel) in [
        ("uniform", PeerSelector::Uniform),
        ("ring", PeerSelector::Ring),
        ("smallworld", PeerSelector::SmallWorld { q: 0.2 }),
    ] {
        let sel2 = sel.clone();
        bench_strategy(
            &mut b,
            &format!("gosgd_p0.1_{tag}"),
            move || Box::new(GoSgd::new(0.1).with_selector(sel2.clone())),
            100_000,
            8,
        );
    }

    b.finish();
}
