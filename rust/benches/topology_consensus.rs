//! Bench: gossip topologies — rounds-to-consensus + pick overhead.
//!
//! Two questions, per topology (uniform / ring / hypercube / rotation):
//!
//! 1. **Mixing**: starting from disagreeing workers, how many full gossip
//!    rounds (every worker: drain → send) does the sum-weight protocol
//!    need to shrink the consensus error by 10⁴×?  The acceptance line —
//!    the GossipGraD claim this repo's topologies exist to reproduce — is
//!    that the **structured rotating schedules (hypercube, rotation) beat
//!    uniform-random** on mean rounds-to-consensus: a deterministic
//!    permutation delivers exactly one message to every worker per round,
//!    while uniform draws leave coupon-collector holes.  (Ring is
//!    reported but not asserted: its O(M) diameter trades mixing speed
//!    for locality.)
//! 2. **Compute**: what does a schedule pick cost?  All topologies must
//!    be O(1) per pick — the selection can never rival a gradient step.
//!
//! Run with `cargo bench --bench topology_consensus`; set `BENCH_CSV` or
//! `BENCH_JSON` for machine-readable output (CI uploads the JSON as
//! `BENCH_topology.json` to accumulate the perf trajectory).

use gosgd::bench::Bencher;
use gosgd::gossip::{MessageQueue, ProtocolCore, TopologySpec};
use gosgd::tensor::FlatVec;
use gosgd::util::rng::Rng;

const M: usize = 16; // power of two so the hypercube is legal
const DIM: usize = 64;
const SHRINK: f64 = 1e-4;
const ROUND_CAP: u64 = 10_000;

fn specs() -> Vec<TopologySpec> {
    vec![
        TopologySpec::UniformRandom,
        TopologySpec::Ring,
        TopologySpec::Hypercube,
        TopologySpec::PartnerRotation,
    ]
}

fn consensus_error(xs: &[FlatVec]) -> f64 {
    let refs: Vec<&FlatVec> = xs.iter().collect();
    let mean = FlatVec::mean_of(&refs).unwrap();
    xs.iter().map(|x| x.dist_sq(&mean).unwrap()).sum()
}

/// Full gossip rounds (no gradients — pure mixing) until the consensus
/// error falls below `SHRINK` of its initial value.
fn rounds_to_consensus(topo: TopologySpec, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let mut xs: Vec<FlatVec> = (0..M).map(|_| FlatVec::randn(DIM, 1.0, &mut rng)).collect();
    let mut cores: Vec<ProtocolCore> = (0..M)
        .map(|w| ProtocolCore::new(w, M, DIM, 1.0, topo, 1).unwrap())
        .collect();
    let queues: Vec<MessageQueue> = (0..M).map(|_| MessageQueue::unbounded()).collect();
    let target = consensus_error(&xs) * SHRINK;
    for round in 1..=ROUND_CAP {
        for w in 0..M {
            for msg in queues[w].drain() {
                cores[w].absorb_message(&mut xs[w], &msg).unwrap();
            }
            if let Some(out) = cores[w].emit(&xs[w], M, &mut rng).unwrap() {
                let to = out.to;
                queues[to].push(out.into_message(w, round));
            }
        }
        if consensus_error(&xs) <= target {
            return round;
        }
    }
    ROUND_CAP
}

fn main() {
    let mut b = Bencher::new("topology_consensus");

    // Pick overhead: a schedule step must stay O(1) nanoseconds.
    for spec in specs() {
        let mut core = ProtocolCore::new(0, M, DIM, 1.0, spec, 1).unwrap();
        let mut rng = Rng::new(7);
        b.bench(&format!("pick_{}", spec.label()), || {
            std::hint::black_box(core.pick_peer(M, &mut rng));
        });
    }

    // Rounds-to-consensus, averaged over seeds.
    let seeds = [11u64, 12, 13, 14, 15];
    println!("\ntopology     mean_rounds  per-seed");
    let mut mean_rounds = Vec::new();
    for spec in specs() {
        let rounds: Vec<u64> = seeds.iter().map(|&s| rounds_to_consensus(spec, s)).collect();
        let mean = rounds.iter().sum::<u64>() as f64 / rounds.len() as f64;
        println!("{:<12} {:>11.1}  {:?}", spec.label(), mean, rounds);
        mean_rounds.push((spec, mean));
    }
    let mean_of = |want: TopologySpec| {
        mean_rounds
            .iter()
            .find(|(s, _)| *s == want)
            .map(|(_, m)| *m)
            .unwrap()
    };
    let uniform = mean_of(TopologySpec::UniformRandom);
    let hypercube = mean_of(TopologySpec::Hypercube);
    let rotation = mean_of(TopologySpec::PartnerRotation);
    assert!(
        uniform < ROUND_CAP as f64,
        "uniform gossip never reached consensus within {ROUND_CAP} rounds"
    );
    assert!(
        hypercube <= uniform,
        "acceptance: the hypercube schedule must beat uniform-random on mean \
         rounds-to-consensus, got {hypercube:.1} vs {uniform:.1}"
    );
    assert!(
        rotation <= uniform,
        "acceptance: the rotating-partner schedule must beat uniform-random on mean \
         rounds-to-consensus, got {rotation:.1} vs {uniform:.1}"
    );
    println!(
        "  -> structured schedules beat uniform: hypercube {hypercube:.1}, \
         rotation {rotation:.1}, uniform {uniform:.1} rounds"
    );

    b.finish();
}
