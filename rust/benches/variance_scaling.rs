//! Bench/table: Appendix A — gradient-estimator error ∝ 1/N.
//!
//! Regenerates the paper's motivating quantity: `E‖∇L − ∇̂L‖² =
//! tr(Cov)/N`.  Prints the sweep table and the fitted power-law exponent
//! (theory: −1), plus timing for the measurement itself.

use gosgd::bench::Bencher;
use gosgd::harness::variance::{fit_power_law, run, VarianceConfig};

fn main() {
    let cfg = VarianceConfig {
        dim: 256,
        batch_sizes: vec![1, 2, 4, 8, 16, 32, 64, 128],
        trials: 200,
        sigma: 0.5,
        seed: 0,
    };
    println!("== Appendix A: gradient-estimator variance scaling ==");
    let rows = run(&cfg, None).unwrap();
    println!("{:>10}  {:>14}  {:>14}", "batch N", "E||err||^2", "N * E||err||^2");
    for &(n, e) in &rows {
        println!("{n:>10}  {e:>14.6}  {:>14.6}", e * n as f64);
    }
    let alpha = fit_power_law(&rows);
    println!("\nfitted power law: error ∝ N^{alpha:.4}   (theory: N^-1)");
    let theory = cfg.dim as f64 * (cfg.sigma as f64).powi(2);
    println!("tr(Cov) = d·σ² = {theory:.2}; measured N·err ≈ {:.2}", rows[0].1);

    // Timing of the estimator itself (for the harness budget).
    let mut b = Bencher::new("variance_scaling");
    let small = VarianceConfig {
        dim: 256,
        batch_sizes: vec![16],
        trials: 20,
        sigma: 0.5,
        seed: 1,
    };
    b.bench("measure_batch16_20trials", || {
        std::hint::black_box(run(&small, None).unwrap());
    });
    b.finish();
}
