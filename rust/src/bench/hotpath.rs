//! Shared two-core exchange harness for the hot-path acceptance gates.
//!
//! The zero-allocation contract is enforced twice — by
//! `benches/hotpath_alloc.rs` (with timing + `BENCH_hotpath.json`) and by
//! `rust/tests/alloc_regression.rs` (on every `cargo test`) — and both
//! gates must drive the *identical* loop or they can drift apart.  This
//! module is that loop: the minimal closed system exercising every stage
//! of the per-message hot path (emit → encode → enqueue → drain →
//! absorb/blend) between two protocol cores.
//!
//! The exchange alternates direction (A→B then B→A) so the sum weights
//! orbit a fixed point instead of halving toward zero over a long run.

use crate::gossip::{CodecSpec, Message, MessageQueue, ProtocolCore, TopologySpec};
use crate::tensor::{BufferPool, FlatVec};
use crate::util::rng::Rng;

/// Two cores, one queue, one reusable inbox — the engine-shaped exchange
/// loop of the allocation gates.
pub struct ExchangePair {
    cores: [ProtocolCore; 2],
    xs: [FlatVec; 2],
    queue: MessageQueue,
    inbox: Vec<Message>,
    step: u64,
    turn: usize,
}

impl ExchangePair {
    /// Build the pair over a `dim`-parameter model cut into `shards`,
    /// with or without a shared [`BufferPool`] attached.  Panics on an
    /// invalid configuration (bench/test harness, not a public API).
    pub fn new(codec: CodecSpec, pooled: bool, dim: usize, shards: usize, seed: u64) -> Self {
        let pool = BufferPool::shared();
        let mk = |id: usize| {
            let core = ProtocolCore::new(id, 2, dim, 1.0, TopologySpec::UniformRandom, shards)
                .unwrap()
                .with_codec(codec);
            if pooled {
                core.with_pool(pool.clone())
            } else {
                core
            }
        };
        let mut rng = Rng::new(seed);
        ExchangePair {
            cores: [mk(0), mk(1)],
            xs: [
                FlatVec::randn(dim, 1.0, &mut rng),
                FlatVec::randn(dim, 1.0, &mut rng),
            ],
            queue: if pooled {
                MessageQueue::unbounded().with_pool(pool)
            } else {
                MessageQueue::unbounded()
            },
            inbox: Vec::new(),
            step: 0,
            turn: 0,
        }
    }

    /// One full exchange: the sender's emit/encode, the queue round trip,
    /// the receiver's drain + decode-blend.
    pub fn exchange(&mut self) {
        self.step += 1;
        let s = self.turn;
        let r = 1 - s;
        self.turn = r;
        let out = self.cores[s].emit_to(&self.xs[s], r).unwrap();
        self.queue.push(out.into_message(s, self.step));
        self.queue.drain_into(&mut self.inbox);
        for msg in self.inbox.drain(..) {
            self.cores[r].absorb_message(&mut self.xs[r], &msg).unwrap();
        }
    }

    /// Worker `w`'s current parameters (trajectory comparisons).
    pub fn params(&self, w: usize) -> &FlatVec {
        &self.xs[w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exchange_alternates_and_keeps_weights_bounded() {
        let mut pair = ExchangePair::new(CodecSpec::Dense, true, 64, 4, 3);
        for _ in 0..200 {
            pair.exchange();
        }
        // Ping-pong keeps every shard weight bounded away from zero (a
        // one-directional loop would halve one side into denormals).
        for w in 0..2 {
            for k in 0..4 {
                let v = pair.cores[w].weights()[k].value();
                assert!(v > 1e-3, "worker {w} shard {k} weight collapsed: {v}");
            }
        }
    }
}
