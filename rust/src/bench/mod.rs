//! Micro-benchmark harness (no `criterion` in the offline dep set).
//!
//! Criterion-style flow built from scratch: warm-up, calibrated batch
//! sizing, many timed samples, and a report with mean / stddev / p50 / p95
//! plus optional throughput.  Every `rust/benches/*.rs` target is a
//! `harness = false` binary built on this module, so `cargo bench` works
//! end-to-end offline.
//!
//! ```no_run
//! use gosgd::bench::Bencher;
//! let mut b = Bencher::new("demo");
//! b.bench("noop", || {});
//! b.finish();
//! ```

pub mod hotpath;

pub use hotpath::ExchangePair;

use std::time::{Duration, Instant};

use crate::util::{mean, percentile, stddev};

/// Target time per measurement phase.
const TARGET_SAMPLE_TIME: Duration = Duration::from_millis(300);
const WARMUP_TIME: Duration = Duration::from_millis(100);
const SAMPLES: usize = 20;

/// One benchmark's statistics, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
    /// Optional bytes processed per iteration (enables GB/s reporting).
    pub bytes_per_iter: Option<u64>,
    /// Optional items processed per iteration (enables Melem/s reporting).
    pub elems_per_iter: Option<u64>,
}

impl Stats {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns)
    }

    pub fn melems_per_s(&self) -> Option<f64> {
        self.elems_per_iter
            .map(|e| e as f64 * 1000.0 / self.mean_ns)
    }
}

/// Format a nanosecond quantity with a sensible unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Nanoseconds per iteration implied by a warm-up run.  Uses the
/// *measured* elapsed time, not the warm-up target: a closure slower than
/// the warm-up window runs exactly once but overshoots the window (one
/// iteration can take seconds), and dividing the 100 ms target by 1 would
/// wildly underestimate its cost and inflate the calibrated batch size.
fn per_iter_ns(elapsed: Duration, iters: u64) -> f64 {
    elapsed.as_nanos() as f64 / iters.max(1) as f64
}

/// Iterations per timed sample for a given per-iteration estimate.
fn iters_per_sample_for(per_iter: f64) -> u64 {
    ((TARGET_SAMPLE_TIME.as_nanos() as f64 / SAMPLES as f64 / per_iter).ceil() as u64).max(1)
}

/// Benchmark group runner: times closures and prints a criterion-like table.
pub struct Bencher {
    group: &'static str,
    results: Vec<Stats>,
    /// Optional CSV output path (`BENCH_CSV` env var).
    csv: Option<std::path::PathBuf>,
    /// Optional JSON output path (`BENCH_JSON` env var).
    json: Option<std::path::PathBuf>,
}

impl Bencher {
    pub fn new(group: &'static str) -> Self {
        println!("\n== bench group: {group} ==");
        let csv = std::env::var_os("BENCH_CSV").map(Into::into);
        let json = std::env::var_os("BENCH_JSON").map(Into::into);
        Bencher { group, results: Vec::new(), csv, json }
    }

    /// Time `f`, auto-calibrating iterations per sample.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Stats {
        self.bench_with(name, None, None, f)
    }

    /// Time `f` and report GB/s for `bytes` moved per call.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, f: F) -> &Stats {
        self.bench_with(name, Some(bytes), None, f)
    }

    /// Time `f` and report Melem/s for `elems` processed per call.
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elems: u64, f: F) -> &Stats {
        self.bench_with(name, None, Some(elems), f)
    }

    /// Record an externally measured result as a single-shot row: one
    /// run, already timed by the caller.  For workloads far too slow for
    /// the sampled loop (e.g. a million-worker simulation that takes tens
    /// of seconds per run), where warm-up plus `SAMPLES` repeats would
    /// cost minutes for no extra signal.  The spread statistics collapse
    /// onto the single measurement (stddev 0, p50 = p95 = mean) and the
    /// row flows into the same table/CSV/JSON as sampled benches.
    pub fn record(
        &mut self,
        name: &str,
        elapsed: Duration,
        bytes: Option<u64>,
        elems: Option<u64>,
    ) -> &Stats {
        let ns = elapsed.as_nanos() as f64;
        let stats = Stats {
            name: name.to_string(),
            mean_ns: ns,
            stddev_ns: 0.0,
            p50_ns: ns,
            p95_ns: ns,
            iters: 1,
            bytes_per_iter: bytes,
            elems_per_iter: elems,
        };
        self.report(&stats);
        self.results.push(stats);
        self.results.last().unwrap()
    }

    fn bench_with<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elems: Option<u64>,
        mut f: F,
    ) -> &Stats {
        // Warm-up + calibration: how many iters fit in the target window?
        // The estimate divides the *measured* elapsed time by the iteration
        // count — see `per_iter_ns` for why the warm-up target must not be
        // used as the numerator.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < WARMUP_TIME {
            f();
            warm_iters += 1;
        }
        let per_iter = per_iter_ns(warm_start.elapsed(), warm_iters);
        let iters_per_sample = iters_per_sample_for(per_iter);

        let mut samples_ns = Vec::with_capacity(SAMPLES);
        for _ in 0..SAMPLES {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }

        let stats = Stats {
            name: name.to_string(),
            mean_ns: mean(&samples_ns),
            stddev_ns: stddev(&samples_ns),
            p50_ns: percentile(&samples_ns, 50.0),
            p95_ns: percentile(&samples_ns, 95.0),
            iters: iters_per_sample * SAMPLES as u64,
            bytes_per_iter: bytes,
            elems_per_iter: elems,
        };
        self.report(&stats);
        self.results.push(stats);
        self.results.last().unwrap()
    }

    fn report(&self, s: &Stats) {
        let mut extra = String::new();
        if let Some(gbs) = s.throughput_gbs() {
            extra.push_str(&format!("  {gbs:.2} GB/s"));
        }
        if let Some(me) = s.melems_per_s() {
            extra.push_str(&format!("  {me:.2} Melem/s"));
        }
        println!(
            "{:<44} {:>12}/iter  ±{:>10}  p95 {:>12}{extra}",
            format!("{}/{}", self.group, s.name),
            fmt_ns(s.mean_ns),
            fmt_ns(s.stddev_ns),
            fmt_ns(s.p95_ns),
        );
    }

    /// Write CSV / JSON (if requested) and return the collected stats.
    /// A failed write aborts loudly: a bench run whose requested artifact
    /// silently vanished would poison the perf trajectory with gaps.
    pub fn finish(self) -> Vec<Stats> {
        if let Some(path) = &self.csv {
            std::fs::write(path, csv_text(self.group, &self.results)).unwrap_or_else(|e| {
                panic!("BENCH_CSV: cannot write {}: {e}", path.display())
            });
        }
        if let Some(path) = &self.json {
            std::fs::write(path, json_text(self.group, &self.results)).unwrap_or_else(|e| {
                panic!("BENCH_JSON: cannot write {}: {e}", path.display())
            });
        }
        self.results
    }
}

/// CSV rendering of a bench group's results (`BENCH_CSV`).
fn csv_text(group: &str, results: &[Stats]) -> String {
    let mut out = String::from("group,name,mean_ns,stddev_ns,p50_ns,p95_ns,iters\n");
    for s in results {
        out.push_str(&format!(
            "{},{},{},{},{},{},{}\n",
            group, s.name, s.mean_ns, s.stddev_ns, s.p50_ns, s.p95_ns, s.iters
        ));
    }
    out
}

/// JSON rendering of a bench group's results (`BENCH_JSON`) — one object
/// per benchmark, machine-readable for the CI perf trajectory.  Names are
/// bench identifiers (no quoting hazards beyond `"` and `\`, escaped
/// anyway for safety).
fn json_text(group: &str, results: &[Stats]) -> String {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    fn opt(v: Option<u64>) -> String {
        v.map_or_else(|| "null".into(), |x| x.to_string())
    }
    let rows: Vec<String> = results
        .iter()
        .map(|s| {
            format!(
                "  {{\"group\":\"{}\",\"name\":\"{}\",\"mean_ns\":{},\"stddev_ns\":{},\
                 \"p50_ns\":{},\"p95_ns\":{},\"iters\":{},\"bytes_per_iter\":{},\
                 \"elems_per_iter\":{}}}",
                esc(group),
                esc(&s.name),
                s.mean_ns,
                s.stddev_ns,
                s.p50_ns,
                s.p95_ns,
                s.iters,
                opt(s.bytes_per_iter),
                opt(s.elems_per_iter),
            )
        })
        .collect();
    format!("[\n{}\n]\n", rows.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert_eq!(fmt_ns(12_300.0), "12.30 µs");
        assert_eq!(fmt_ns(12_300_000.0), "12.30 ms");
        assert_eq!(fmt_ns(2_000_000_000.0), "2.000 s");
    }

    #[test]
    fn calibration_uses_measured_elapsed_not_the_warmup_target() {
        // Regression: a closure slower than the warm-up window runs once
        // and takes (say) 2 s.  Dividing the 100 ms warm-up *target* by 1
        // would claim 100 ms/iter — 20x too fast.  The estimate must use
        // the measured elapsed time.
        let est = per_iter_ns(Duration::from_secs(2), 1);
        assert_eq!(est, 2e9, "per-iter estimate must reflect the 2 s reality");
        // And the batch calibration keeps slow closures at 1 iter/sample
        // instead of inflating the count off a bogus estimate.
        assert_eq!(iters_per_sample_for(est), 1);
        // Fast closures still batch up to fill the sample window.
        let fast = per_iter_ns(Duration::from_millis(100), 100_000);
        assert!(iters_per_sample_for(fast) > 1000);
    }

    #[test]
    fn csv_and_json_render_all_fields() {
        let stats = vec![Stats {
            name: "blend".into(),
            mean_ns: 1500.0,
            stddev_ns: 10.0,
            p50_ns: 1490.0,
            p95_ns: 1525.0,
            iters: 4000,
            bytes_per_iter: Some(4096),
            elems_per_iter: None,
        }];
        let csv = csv_text("codec", &stats);
        assert!(csv.starts_with("group,name,mean_ns"));
        assert!(csv.contains("codec,blend,1500,10,1490,1525,4000"));
        let json = json_text("codec", &stats);
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"group\":\"codec\""));
        assert!(json.contains("\"name\":\"blend\""));
        assert!(json.contains("\"bytes_per_iter\":4096"));
        assert!(json.contains("\"elems_per_iter\":null"));
        // It must be parseable by the crate's own JSON reader.
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let rows = parsed.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "blend");
        assert_eq!(rows[0].get("iters").unwrap().as_usize().unwrap(), 4000);
    }

    #[test]
    fn record_rows_collapse_onto_the_single_measurement() {
        let mut b = Bencher::new("record-test");
        let s = b.record("one-shot", Duration::from_millis(250), Some(1_000_000), Some(500));
        assert_eq!(s.mean_ns, 250e6);
        assert_eq!(s.stddev_ns, 0.0);
        assert_eq!(s.p50_ns, 250e6);
        assert_eq!(s.p95_ns, 250e6);
        assert_eq!(s.iters, 1);
        assert_eq!(s.bytes_per_iter, Some(1_000_000));
        assert_eq!(s.elems_per_iter, Some(500));
    }

    #[test]
    fn stats_throughput() {
        let s = Stats {
            name: "x".into(),
            mean_ns: 1000.0,
            stddev_ns: 0.0,
            p50_ns: 1000.0,
            p95_ns: 1000.0,
            iters: 1,
            bytes_per_iter: Some(4000),
            elems_per_iter: Some(1000),
        };
        assert!((s.throughput_gbs().unwrap() - 4.0).abs() < 1e-12);
        assert!((s.melems_per_s().unwrap() - 1000.0).abs() < 1e-9);
    }
}
