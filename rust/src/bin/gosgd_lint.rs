//! Repo-invariant lint driver: `cargo run --bin gosgd-lint [ROOT]`.
//!
//! Scans `rust/{src,tests,benches}` under ROOT (default: the current
//! directory) against the domain rules in [`gosgd::lint`] and exits
//! non-zero on any finding — the CI `gosgd-lint` job is exactly this
//! command.  See the module docs for the rules and the per-line
//! `// lint:allow(<rule>)` escape hatch.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    match gosgd::lint::lint_tree(Path::new(&root)) {
        Err(e) => {
            eprintln!("gosgd-lint: cannot scan {root}: {e}");
            ExitCode::from(2)
        }
        Ok(report) => {
            for f in &report.findings {
                eprintln!("{f}");
            }
            if report.findings.is_empty() {
                println!("gosgd-lint: clean ({} files)", report.files);
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "gosgd-lint: {} finding(s) across {} files",
                    report.findings.len(),
                    report.files
                );
                ExitCode::FAILURE
            }
        }
    }
}
