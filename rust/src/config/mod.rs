//! Run configuration: strategy selection, model/artifact wiring,
//! optimizer and data settings, plus presets for every paper figure.

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, PeerSelector, TopologySpec};
use crate::optim::LrSchedule;
use crate::strategies::{
    allreduce::AllReduce, downpour::Downpour, easgd::Easgd, gosgd::GoSgd, local::Local,
    persyn::PerSyn, Strategy,
};

/// Which distributed-SGD algorithm to run.
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyKind {
    /// The paper's contribution (section 4); `p` = exchange probability.
    GoSgd { p: f64 },
    /// GoSGD with sharded exchange: each gossip event ships one of
    /// `shards` contiguous slices of the vector (see
    /// [`crate::gossip::shard`]), cutting per-event bandwidth `~1/shards`;
    /// `codec` optionally compresses the payload body on top (see
    /// [`crate::gossip::codec`]) and `topo` selects the gossip topology
    /// (see [`crate::gossip::topology`]; `uniform` defers to `--peer`).
    GoSgdSharded {
        p: f64,
        shards: usize,
        codec: CodecSpec,
        topo: TopologySpec,
    },
    /// Periodic synchronization every `tau` rounds (section 3.1).
    PerSyn { tau: u64 },
    /// Elastic averaging every `tau` rounds (section 3.2).
    Easgd { alpha: f64, tau: u64 },
    /// Parameter server with push/fetch cadences (section 3.3).
    Downpour { n_push: u64, n_fetch: u64 },
    /// Fully synchronous Algorithm 1.
    AllReduce,
    /// No communication baseline.
    Local,
}

impl StrategyKind {
    /// Parse a CLI strategy spec:
    /// `gosgd:0.02`, `gosgd:0.02:8` (sharded), and the full grammar
    /// `gosgd:P:SHARDS[:CODEC][:TOPO]` with codec `dense` | `q8` |
    /// `top<K>` and topology `uniform` | `ring` | `hypercube` |
    /// `rotation` (the codec may be omitted: `gosgd:0.02:8:ring`);
    /// plus `persyn:50`, `easgd:0.1:50`, `downpour:4:4`, `allreduce`,
    /// `local`.
    pub fn parse(text: &str) -> Result<StrategyKind> {
        let parts: Vec<&str> = text.split(':').collect();
        let bad = || Error::config(format!("cannot parse strategy {text:?}"));
        let parse_p = |p: &str| -> Result<f64> {
            let p: f64 = p.parse().map_err(|_| bad())?;
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::config(format!("gosgd p out of [0,1]: {p}")));
            }
            Ok(p)
        };
        let parse_shards = |shards: &str| -> Result<usize> {
            let shards: usize = shards.parse().map_err(|_| bad())?;
            if shards == 0 {
                return Err(Error::config("gosgd shards must be >= 1"));
            }
            Ok(shards)
        };
        match parts.as_slice() {
            ["gosgd", p] => Ok(StrategyKind::GoSgd { p: parse_p(p)? }),
            ["gosgd", p, shards] => Ok(StrategyKind::GoSgdSharded {
                p: parse_p(p)?,
                shards: parse_shards(shards)?,
                codec: CodecSpec::Dense,
                topo: TopologySpec::UniformRandom,
            }),
            ["gosgd", p, shards, tok] => {
                let p = parse_p(p)?;
                let shards = parse_shards(shards)?;
                // The optional 4th token is a codec or a topology — the
                // token sets are disjoint, so try the codec grammar
                // first and fall back to the topology grammar.
                let (codec, topo) = match CodecSpec::parse(tok) {
                    Ok(codec) => (codec, TopologySpec::UniformRandom),
                    Err(_) => match TopologySpec::parse(tok) {
                        Ok(topo) => (CodecSpec::Dense, topo),
                        Err(_) => {
                            return Err(Error::config(format!(
                                "cannot parse {tok:?} as a codec (dense | q8 | top<K>) or a \
                                 topology (uniform | ring | hypercube | rotation)"
                            )))
                        }
                    },
                };
                Ok(StrategyKind::GoSgdSharded { p, shards, codec, topo })
            }
            ["gosgd", p, shards, codec, topo] => Ok(StrategyKind::GoSgdSharded {
                p: parse_p(p)?,
                shards: parse_shards(shards)?,
                codec: CodecSpec::parse(codec)?,
                topo: TopologySpec::parse(topo)?,
            }),
            ["persyn", tau] => Ok(StrategyKind::PerSyn { tau: tau.parse().map_err(|_| bad())? }),
            ["easgd", alpha, tau] => Ok(StrategyKind::Easgd {
                alpha: alpha.parse().map_err(|_| bad())?,
                tau: tau.parse().map_err(|_| bad())?,
            }),
            ["downpour", np, nf] => Ok(StrategyKind::Downpour {
                n_push: np.parse().map_err(|_| bad())?,
                n_fetch: nf.parse().map_err(|_| bad())?,
            }),
            ["allreduce"] => Ok(StrategyKind::AllReduce),
            ["local"] => Ok(StrategyKind::Local),
            _ => Err(bad()),
        }
    }

    /// Short machine tag (CSV columns).
    pub fn tag(&self) -> String {
        match self {
            StrategyKind::GoSgd { p } => format!("gosgd_p{p}"),
            StrategyKind::GoSgdSharded { p, shards, codec, topo } => {
                let mut tag = format!("gosgd_p{p}_s{shards}");
                if *codec != CodecSpec::Dense {
                    tag.push('_');
                    tag.push_str(&codec.label());
                }
                if *topo != TopologySpec::UniformRandom {
                    tag.push('_');
                    // smallworld:Q carries a colon; strip it for CSV/file
                    // safety.
                    tag.push_str(&topo.label().replace(':', ""));
                }
                tag
            }
            StrategyKind::PerSyn { tau } => format!("persyn_tau{tau}"),
            StrategyKind::Easgd { alpha, tau } => format!("easgd_a{alpha}_tau{tau}"),
            StrategyKind::Downpour { n_push, n_fetch } => {
                format!("downpour_{n_push}_{n_fetch}")
            }
            StrategyKind::AllReduce => "allreduce".into(),
            StrategyKind::Local => "local".into(),
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact directory root (contains `<model>/manifest.json`).
    pub artifacts_dir: PathBuf,
    /// Model variant: `tiny`, `cnn`, `mlp_wide`.
    pub model: String,
    /// Number of workers M (paper uses 8).
    pub workers: usize,
    /// Engine steps (sync: rounds; async: single-worker ticks).
    pub steps: u64,
    /// Learning-rate schedule (paper: constant 0.1).
    pub lr: LrSchedule,
    /// Weight decay (paper: 1e-4).
    pub weight_decay: f32,
    /// Communication strategy.
    pub strategy: StrategyKind,
    /// Peer selection for GoSGD.
    pub peer: PeerSelector,
    /// Master RNG seed.
    pub seed: u64,
    /// Evaluate on the validation stream every this many steps (0 = never).
    pub eval_every: u64,
    /// Validation batches per evaluation.
    pub eval_batches: u64,
    /// Synthetic-data noise std (class overlap).
    pub data_noise: f32,
    /// Fraction of corrupted training labels (irreducible error; the
    /// train/val generalization-gap knob for the Fig. 3 experiment).
    pub label_noise: f32,
    /// Enable crop/flip augmentation (paper's setting).
    pub augment: bool,
    /// Log a loss point every this many steps.
    pub log_every: u64,
    /// Alternative init seed (None = use the artifact's bit-exact init).
    pub init_seed: Option<u64>,
    /// Write a checkpoint here when the run finishes.
    pub save_checkpoint: Option<PathBuf>,
    /// Resume from this checkpoint instead of a fresh init (worker count
    /// must match).
    pub resume_from: Option<PathBuf>,
}

impl Default for RunConfig {
    /// The paper's experimental setting (section 5.1) on the paper-scale
    /// CNN: M = 8, lr = 0.1, weight decay 1e-4, GoSGD p = 0.02.
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "cnn".into(),
            workers: 8,
            steps: 800,
            lr: LrSchedule::Constant(0.1),
            weight_decay: 1e-4,
            strategy: StrategyKind::GoSgd { p: 0.02 },
            peer: PeerSelector::Uniform,
            seed: 0,
            eval_every: 0,
            eval_batches: 4,
            data_noise: 4.0,
            label_noise: 0.1,
            augment: true,
            log_every: 1,
            init_seed: None,
            save_checkpoint: None,
            resume_from: None,
        }
    }
}

impl RunConfig {
    /// Validate invariants that would otherwise fail deep inside a run.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::config("workers must be >= 1"));
        }
        if matches!(
            self.strategy,
            StrategyKind::GoSgd { .. } | StrategyKind::GoSgdSharded { .. }
        ) && self.workers < 2
        {
            return Err(Error::config("gosgd needs at least 2 workers"));
        }
        if let StrategyKind::Easgd { alpha, .. } = self.strategy {
            if 1.0 - self.workers as f64 * alpha < 0.0 {
                return Err(Error::config(format!(
                    "easgd unstable: alpha {alpha} too large for {} workers",
                    self.workers
                )));
            }
        }
        match self.strategy {
            StrategyKind::GoSgd { p } | StrategyKind::GoSgdSharded { p, .. } => {
                if !(0.0..=1.0).contains(&p) {
                    return Err(Error::config(format!("gosgd p out of range: {p}")));
                }
            }
            _ => {}
        }
        if let StrategyKind::GoSgdSharded { shards, codec, topo, .. } = self.strategy {
            if shards == 0 {
                return Err(Error::config("gosgd shards must be >= 1"));
            }
            if codec == (CodecSpec::TopK { k: 0 }) {
                return Err(Error::config("top-k codec needs k >= 1"));
            }
            topo.validate_for(self.workers)?;
        }
        if self.steps == 0 {
            return Err(Error::config("steps must be >= 1"));
        }
        Ok(())
    }

    /// Instantiate the strategy object.
    pub fn build_strategy(&self) -> Box<dyn Strategy> {
        match &self.strategy {
            StrategyKind::GoSgd { p } => {
                Box::new(GoSgd::new(*p).with_selector(self.peer.clone()))
            }
            StrategyKind::GoSgdSharded { p, shards, codec, topo } => {
                // An explicit strategy-string topology wins; the default
                // `uniform` token defers to the legacy `--peer` flag.
                let topo = if *topo == TopologySpec::UniformRandom {
                    self.peer.clone().into()
                } else {
                    *topo
                };
                Box::new(
                    GoSgd::new(*p)
                        .with_topology(topo)
                        .with_shards(*shards)
                        .with_codec(*codec),
                )
            }
            StrategyKind::PerSyn { tau } => Box::new(PerSyn::new(*tau)),
            StrategyKind::Easgd { alpha, tau } => Box::new(Easgd::new(*alpha, *tau)),
            StrategyKind::Downpour { n_push, n_fetch } => {
                Box::new(Downpour::new(*n_push, *n_fetch, self.lr.at(0)))
            }
            StrategyKind::AllReduce => Box::new(AllReduce),
            StrategyKind::Local => Box::new(Local),
        }
    }

    /// Artifact directory for the configured model.
    pub fn model_dir(&self) -> PathBuf {
        self.artifacts_dir.join(&self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_strategy_forms() {
        assert_eq!(
            StrategyKind::parse("gosgd:0.02").unwrap(),
            StrategyKind::GoSgd { p: 0.02 }
        );
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::Dense,
                topo: TopologySpec::UniformRandom,
            }
        );
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8:q8").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::QuantizeU8,
                topo: TopologySpec::UniformRandom,
            }
        );
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8:top16").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::TopK { k: 16 },
                topo: TopologySpec::UniformRandom,
            }
        );
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8:dense").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::Dense,
                topo: TopologySpec::UniformRandom,
            }
        );
        // The 4th token may be a topology instead of a codec...
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8:rotation").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::Dense,
                topo: TopologySpec::PartnerRotation,
            }
        );
        // ...and the full 5-token grammar carries both.
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:8:q8:hypercube").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::QuantizeU8,
                topo: TopologySpec::Hypercube,
            }
        );
        assert_eq!(
            StrategyKind::parse("gosgd:0.02:1:dense:ring").unwrap(),
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 1,
                codec: CodecSpec::Dense,
                topo: TopologySpec::Ring,
            }
        );
        assert_eq!(
            StrategyKind::parse("persyn:50").unwrap(),
            StrategyKind::PerSyn { tau: 50 }
        );
        assert_eq!(
            StrategyKind::parse("easgd:0.1:50").unwrap(),
            StrategyKind::Easgd { alpha: 0.1, tau: 50 }
        );
        assert_eq!(
            StrategyKind::parse("downpour:4:8").unwrap(),
            StrategyKind::Downpour { n_push: 4, n_fetch: 8 }
        );
        assert_eq!(StrategyKind::parse("allreduce").unwrap(), StrategyKind::AllReduce);
        assert_eq!(StrategyKind::parse("local").unwrap(), StrategyKind::Local);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(StrategyKind::parse("gosgd").is_err());
        assert!(StrategyKind::parse("gosgd:2.0").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:0").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:abc").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:8:zstd").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:8:top0").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:8:q8:extra").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:8:torus").is_err());
        assert!(StrategyKind::parse("gosgd:0.1:8:ring:q8").is_err(), "codec before topo");
        assert!(StrategyKind::parse("persyn:abc").is_err());
        assert!(StrategyKind::parse("").is_err());
        assert!(StrategyKind::parse("easgd:0.1").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = RunConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        cfg.workers = 1;
        assert!(cfg.validate().is_err()); // gosgd needs >= 2
        cfg.workers = 8;
        cfg.strategy = StrategyKind::Easgd { alpha: 0.5, tau: 10 };
        assert!(cfg.validate().is_err()); // 1 - 8*0.5 < 0
        cfg.strategy = StrategyKind::AllReduce;
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
        // Hypercube topologies must fit the fleet.
        cfg.steps = 100;
        cfg.workers = 6;
        cfg.strategy = StrategyKind::parse("gosgd:0.1:4:hypercube").unwrap();
        assert!(cfg.validate().is_err());
        cfg.workers = 8;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn strategy_topology_overrides_the_peer_flag() {
        let mut cfg = RunConfig::default();
        cfg.peer = PeerSelector::Ring;
        // Explicit strategy topology wins...
        cfg.strategy = StrategyKind::parse("gosgd:0.1:4:rotation").unwrap();
        assert!(cfg.build_strategy().name().contains("topo=rotation"));
        // ...the default `uniform` defers to --peer.
        cfg.strategy = StrategyKind::parse("gosgd:0.1:4").unwrap();
        assert!(cfg.build_strategy().name().contains("topo=ring"));
    }

    #[test]
    fn build_strategy_names() {
        let mut cfg = RunConfig::default();
        assert!(cfg.build_strategy().name().starts_with("gosgd"));
        cfg.strategy =
            StrategyKind::GoSgdSharded {
            p: 0.02,
            shards: 4,
            codec: CodecSpec::Dense,
            topo: TopologySpec::UniformRandom,
        };
        assert!(cfg.build_strategy().name().contains("shards=4"));
        cfg.strategy =
            StrategyKind::GoSgdSharded {
            p: 0.02,
            shards: 4,
            codec: CodecSpec::QuantizeU8,
            topo: TopologySpec::UniformRandom,
        };
        assert!(cfg.build_strategy().name().contains("codec=q8"));
        cfg.strategy = StrategyKind::PerSyn { tau: 7 };
        assert!(cfg.build_strategy().name().contains("tau=7"));
        cfg.strategy = StrategyKind::Local;
        assert_eq!(cfg.build_strategy().name(), "local");
    }

    #[test]
    fn tags_are_filename_safe() {
        for s in [
            StrategyKind::GoSgd { p: 0.02 },
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::Dense,
                topo: TopologySpec::UniformRandom,
            },
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::TopK { k: 32 },
                topo: TopologySpec::UniformRandom,
            },
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::QuantizeU8,
                topo: TopologySpec::UniformRandom,
            },
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::QuantizeU8,
                topo: TopologySpec::Hypercube,
            },
            StrategyKind::GoSgdSharded {
                p: 0.02,
                shards: 8,
                codec: CodecSpec::Dense,
                topo: TopologySpec::SmallWorld { q: 0.2 },
            },
            StrategyKind::PerSyn { tau: 50 },
            StrategyKind::Easgd { alpha: 0.1, tau: 50 },
            StrategyKind::Downpour { n_push: 1, n_fetch: 2 },
            StrategyKind::AllReduce,
            StrategyKind::Local,
        ] {
            let tag = s.tag();
            assert!(
                !tag.contains(' ') && !tag.contains('/') && !tag.contains(':'),
                "{tag}"
            );
        }
    }
}
