//! Checkpointing: save/restore the full distributed training state.
//!
//! A deployable trainer must survive preemption.  The checkpoint captures
//! everything the paper's protocol needs to resume *exactly*: every
//! worker's parameter vector, its sum-weight (conservation must hold
//! across restarts), its local step count, its topology schedule cursor
//! (a deterministic schedule — ring index, rotation position — must
//! resume where it stopped, not restart from slot 0), and the master
//! slot.
//!
//! Format v2 (little-endian, versioned):
//!
//! ```text
//! magic "GOSGDCKP" | u32 version | u32 workers M | u64 param_count n
//! master: n × f32
//! per worker m = 1..=M: f64 weight | u64 steps | u64 topo_cursor | n × f32 params
//! u64 fletcher-style checksum over all payload bytes
//! ```
//!
//! (v1 lacked the per-worker `topo_cursor`; v1 files are rejected with a
//! version error rather than silently resetting every schedule.)
//!
//! In-flight queue messages are deliberately *not* checkpointed: the save
//! path drains every queue into its receiver first (the blend is
//! associative, so folding early is exact — same argument as queue
//! coalescing), which keeps the on-disk format simple and the weight mass
//! conserved.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::gossip::SumWeight;
use crate::strategies::ClusterState;
use crate::tensor::FlatVec;

const MAGIC: &[u8; 8] = b"GOSGDCKP";
const VERSION: u32 = 2;

/// Serializable snapshot of a cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub master: FlatVec,
    pub workers: Vec<WorkerSnapshot>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSnapshot {
    pub params: FlatVec,
    pub weight: f64,
    pub steps: u64,
    /// Topology schedule position (see
    /// [`ProtocolCore::topo_cursor`](crate::gossip::ProtocolCore::topo_cursor));
    /// 0 for the random topologies, live state for ring / hypercube /
    /// rotation schedules.
    pub topo_cursor: u64,
}

impl Checkpoint {
    /// Capture a cluster state, folding queued messages into receivers
    /// first so no weight mass is lost.
    pub fn capture(state: &mut ClusterState) -> Result<Checkpoint> {
        let m = state.workers();
        if state.sharded() {
            // Format v2 stores one sum weight per worker; a sharded run
            // carries one per (worker, shard).  Refuse rather than silently
            // collapse the per-shard masses.
            return Err(Error::config(
                "checkpointing sharded gossip runs is not supported (format v2 \
                 stores a single weight per worker)",
            ));
        }
        if state.cores[0].codec_spec().stateful() {
            // The top-k codec's error-feedback buffer is live protocol
            // state; dropping it silently would un-track pending residual
            // mass across a restart.  (Stateless codecs — dense, q8 —
            // checkpoint fine: their wire form carries no sender state.)
            return Err(Error::config(
                "checkpointing top-k gossip runs is not supported (format v2 \
                 does not store the error-feedback residual)",
            ));
        }
        // Drain all mailboxes into their owners (exact: blend associativity;
        // the blend itself is the protocol core's absorb transition).
        for w in 1..=m {
            let pending = state.queues[w].drain();
            let (cores, stacked) = (&mut state.cores, &mut state.stacked);
            for msg in pending {
                cores[w].absorb_message(stacked.worker_mut(w), &msg)?;
            }
        }
        let workers = (1..=m)
            .map(|w| WorkerSnapshot {
                params: state.stacked.worker(w).clone(),
                weight: state.cores[w].weights()[0].value(),
                steps: state.steps[w],
                topo_cursor: state.cores[w].topo_cursor(),
            })
            .collect();
        Ok(Checkpoint { master: state.stacked.master().clone(), workers })
    }

    /// Restore into a fresh cluster state.
    pub fn restore(&self) -> Result<ClusterState> {
        let m = self.workers.len();
        if m == 0 {
            return Err(Error::config("checkpoint has no workers"));
        }
        let n = self.master.len();
        let mut state = ClusterState::new(m, &FlatVec::zeros(n));
        *state.stacked.get_mut(0) = self.master.clone();
        for (i, snap) in self.workers.iter().enumerate() {
            let w = i + 1;
            if snap.params.len() != n {
                return Err(Error::shape("ragged checkpoint"));
            }
            *state.stacked.worker_mut(w) = snap.params.clone();
            state.cores[w].set_weight(0, SumWeight::from_value(snap.weight));
            state.steps[w] = snap.steps;
            // The schedule cursor survives the run config re-applying the
            // topology on the first tick (set_topology keeps the cursor),
            // so a deterministic schedule resumes exactly where it
            // stopped.
            state.cores[w].set_topo_cursor(snap.topo_cursor);
        }
        Ok(state)
    }

    /// Total gossip weight (should be ≈ 1 for a healthy checkpoint).
    pub fn total_weight(&self) -> f64 {
        self.workers.iter().map(|w| w.weight).sum()
    }

    // ---- binary serialization ------------------------------------------

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut payload = Vec::new();
        let n = self.master.len();
        payload.extend_from_slice(&(self.workers.len() as u32).to_le_bytes());
        payload.extend_from_slice(&(n as u64).to_le_bytes());
        for v in self.master.as_slice() {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for w in &self.workers {
            payload.extend_from_slice(&w.weight.to_le_bytes());
            payload.extend_from_slice(&w.steps.to_le_bytes());
            payload.extend_from_slice(&w.topo_cursor.to_le_bytes());
            for v in w.params.as_slice() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        let checksum = fletcher64(&payload);
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&checksum.to_le_bytes())?;
        f.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(&path)?);
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        if all.len() < 8 + 4 + 8 || &all[..8] != MAGIC {
            return Err(Error::artifact("not a gosgd checkpoint"));
        }
        let version = u32::from_le_bytes(all[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(Error::artifact(format!("checkpoint version {version} != {VERSION}")));
        }
        let payload = &all[12..all.len() - 8];
        let stored = u64::from_le_bytes(all[all.len() - 8..].try_into().unwrap());
        if fletcher64(payload) != stored {
            return Err(Error::artifact("checkpoint checksum mismatch (corrupt file)"));
        }
        let mut cur = Cursor { buf: payload, pos: 0 };
        let m = cur.u32()? as usize;
        let n = cur.u64()? as usize;
        let master = FlatVec::from_vec(cur.f32s(n)?);
        let mut workers = Vec::with_capacity(m);
        for _ in 0..m {
            let weight = cur.f64()?;
            let steps = cur.u64()?;
            let topo_cursor = cur.u64()?;
            let params = FlatVec::from_vec(cur.f32s(n)?);
            if weight <= 0.0 || !weight.is_finite() {
                return Err(Error::artifact(format!("bad checkpoint weight {weight}")));
            }
            workers.push(WorkerSnapshot { params, weight, steps, topo_cursor });
        }
        if cur.pos != payload.len() {
            return Err(Error::artifact("trailing bytes in checkpoint"));
        }
        Ok(Checkpoint { master, workers })
    }
}

/// Simple 64-bit Fletcher-style checksum (corruption detection, not crypto).
fn fletcher64(data: &[u8]) -> u64 {
    let mut a: u64 = 0xF1E7C8;
    let mut b: u64 = 0;
    for chunk in data.chunks(4) {
        let mut word = [0u8; 4];
        word[..chunk.len()].copy_from_slice(chunk);
        a = (a.wrapping_add(u32::from_le_bytes(word) as u64)) % 0xFFFF_FFFB;
        b = (b.wrapping_add(a)) % 0xFFFF_FFFB;
    }
    (b << 32) | a
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::artifact("truncated checkpoint"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::Message;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gosgd_ckpt_{name}.bin"))
    }

    fn populated_state(m: usize, n: usize, seed: u64) -> ClusterState {
        let mut rng = Rng::new(seed);
        let mut state = ClusterState::new(m, &FlatVec::randn(n, 1.0, &mut rng));
        for w in 1..=m {
            *state.stacked.worker_mut(w) = FlatVec::randn(n, 1.0, &mut rng);
            state.steps[w] = rng.below(1000);
        }
        state
    }

    #[test]
    fn capture_restore_round_trip() {
        let mut state = populated_state(4, 100, 1);
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        let restored = ckpt.restore().unwrap();
        for w in 1..=4 {
            assert_eq!(
                restored.stacked.worker(w).as_slice(),
                state.stacked.worker(w).as_slice()
            );
            assert_eq!(
                restored.cores[w].weights()[0].value(),
                state.cores[w].weights()[0].value()
            );
            assert_eq!(restored.steps[w], state.steps[w]);
        }
    }

    #[test]
    fn save_load_round_trip() {
        let mut state = populated_state(3, 57, 2);
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        let path = tmp("roundtrip");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ckpt);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn capture_folds_queued_messages_preserving_weight() {
        let mut state = populated_state(2, 16, 3);
        // Put a message in flight: sender 1 ships half its weight to 2
        // (the core's send-side transition, minus the payload snapshot).
        let (_, shipped) = state.cores[1].begin_send();
        let snapshot = state.stacked.worker(1).clone();
        state.queues[2].push(Message::dense(snapshot, shipped, 1, 0));
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        assert!((ckpt.total_weight() - 1.0).abs() < 1e-9, "{}", ckpt.total_weight());
    }

    #[test]
    fn corruption_is_detected() {
        let mut state = populated_state(2, 20, 4);
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        let path = tmp("corrupt");
        ckpt.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_is_detected() {
        let mut state = populated_state(2, 20, 5);
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        let path = tmp("trunc");
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 30]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn topk_codec_state_refuses_capture() {
        use crate::gossip::{CodecSpec, TopologySpec};
        let mut state = populated_state(2, 16, 9);
        state
            .configure_gossip(0.5, TopologySpec::UniformRandom, 1, CodecSpec::TopK { k: 4 })
            .unwrap();
        let err = Checkpoint::capture(&mut state).unwrap_err();
        assert!(err.to_string().contains("error-feedback"), "{err}");
        // The stateless codecs checkpoint fine.
        state
            .configure_gossip(0.5, TopologySpec::UniformRandom, 1, CodecSpec::QuantizeU8)
            .unwrap();
        assert!(Checkpoint::capture(&mut state).is_ok());
    }

    #[test]
    fn topology_cursor_round_trips_through_capture_and_restore() {
        use crate::gossip::{CodecSpec, TopologySpec};
        let m = 4;
        let mut state = populated_state(m, 16, 11);
        state
            .configure_gossip(1.0, TopologySpec::PartnerRotation, 1, CodecSpec::Dense)
            .unwrap();
        // Walk each worker's rotation schedule a different distance so the
        // cursors genuinely differ, delivering every message so no weight
        // mass is stranded.
        let mut rng = Rng::new(13);
        for w in 1..=m {
            for _ in 0..w {
                let x = state.stacked.worker(w).clone();
                let out = state.cores[w].emit(&x, m, &mut rng).unwrap().unwrap();
                state.queues[out.to + 1].push(out.into_message(w, 0));
            }
        }
        let ckpt = Checkpoint::capture(&mut state).unwrap();
        assert!((ckpt.total_weight() - 1.0).abs() < 1e-9);
        for (i, snap) in ckpt.workers.iter().enumerate() {
            assert_eq!(snap.topo_cursor, (i + 1) as u64, "worker {} cursor", i + 1);
        }
        // The cursor survives the binary round trip...
        let path = tmp("topo_cursor");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ckpt);
        std::fs::remove_file(&path).ok();
        // ...and restore + the first-tick topology re-application resume
        // the schedule exactly where the original left off: the next
        // deterministic pick of every worker matches.
        let mut restored = loaded.restore().unwrap();
        restored
            .configure_gossip(1.0, TopologySpec::PartnerRotation, 1, CodecSpec::Dense)
            .unwrap();
        for w in 1..=m {
            assert_eq!(restored.cores[w].topo_cursor(), state.cores[w].topo_cursor());
            let mut ra = Rng::new(0);
            let mut rb = Rng::new(0);
            assert_eq!(
                restored.cores[w].pick_peer(m, &mut ra),
                state.cores[w].pick_peer(m, &mut rb),
                "worker {w} resumed a different schedule position"
            );
        }
    }

    #[test]
    fn garbage_file_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn training_resumes_identically_after_restore() {
        use crate::strategies::engine::Engine;
        use crate::strategies::gosgd::GoSgd;
        use crate::strategies::grad::QuadraticSource;
        // Run 100 ticks; checkpoint; run 100 more. Separately: restore the
        // checkpoint into a fresh engine with the same RNG state... RNG
        // state is not checkpointed (by design: a resumed run is a new
        // stochastic realization), so we assert state equality at capture
        // and weight-mass health after resume.
        let dim = 32;
        let init = FlatVec::zeros(dim);
        let src = QuadraticSource::new(dim, 0.2, 6);
        let mut eng = Engine::new(Box::new(GoSgd::new(0.4)), src, 4, &init, 0.5, 0.0, 7);
        eng.run(100).unwrap();
        let ckpt = Checkpoint::capture(eng.state_mut()).unwrap();
        assert!((ckpt.total_weight() - 1.0).abs() < 1e-9);
        let restored = ckpt.restore().unwrap();
        // Steps and parameters carried over exactly.
        let total: u64 = restored.steps[1..].iter().sum();
        assert_eq!(total, 100);
        for w in 1..=4 {
            assert_eq!(
                restored.stacked.worker(w).as_slice(),
                eng.state().stacked.worker(w).as_slice()
            );
        }
    }
}
