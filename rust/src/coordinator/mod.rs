//! The leader: wires config → artifacts → engine → metrics.
//!
//! [`Coordinator`] is the high-level entry point the CLI and the examples
//! use: it loads the model artifacts, builds the data pipeline and the
//! configured strategy, runs the training engine, periodically evaluates
//! on the validation stream, and produces a [`RunReport`].

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use crate::config::RunConfig;
use crate::data::{BatchSampler, SyntheticCifar};
use crate::error::Result;
use crate::metrics::{LossCurve, Stopwatch};
use crate::runtime::{ModelRuntime, PjrtSource};
use crate::strategies::Engine;

/// Result of one coordinated training run.
#[derive(Debug, Default)]
pub struct RunReport {
    pub strategy: String,
    pub model: String,
    pub workers: usize,
    pub steps: u64,
    /// Per-engine-step training loss.
    pub train_loss: LossCurve,
    /// `(engine_step, val_loss, val_accuracy)` samples.
    pub evals: Vec<(u64, f64, f64)>,
    /// Final mean-worker validation metrics.
    pub final_loss: f64,
    pub final_accuracy: f64,
    /// Consensus error at the end.
    pub consensus_error: f64,
    /// Communication accounting.
    pub messages: u64,
    pub bytes: u64,
    pub barriers: u64,
    /// Wall-clock seconds.
    pub elapsed_secs: f64,
}

impl RunReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} model={} M={} steps={} loss={:.4} acc={:.3} eps={:.3e} msgs={} barriers={} {:.1}s",
            self.strategy,
            self.model,
            self.workers,
            self.steps,
            self.final_loss,
            self.final_accuracy,
            self.consensus_error,
            self.messages,
            self.barriers,
            self.elapsed_secs
        )
    }
}

/// Training leader.
pub struct Coordinator {
    config: RunConfig,
    runtime: ModelRuntime,
}

impl Coordinator {
    /// Load artifacts and validate the configuration.
    pub fn new(config: RunConfig) -> Result<Self> {
        config.validate()?;
        let runtime = ModelRuntime::load(config.model_dir())?;
        Ok(Coordinator { config, runtime })
    }

    pub fn config(&self) -> &RunConfig {
        &self.config
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    fn sampler(&self) -> BatchSampler {
        BatchSampler::new(
            SyntheticCifar::new(self.config.seed, self.config.data_noise, self.config.augment)
                .with_label_noise(self.config.label_noise),
            self.runtime.manifest().batch,
            self.config.workers,
        )
    }

    /// Run the configured strategy on the real model through PJRT.
    pub fn run(&mut self) -> Result<RunReport> {
        let cfg = &self.config;
        let watch = Stopwatch::start();

        let init = match cfg.init_seed {
            None => self.runtime.manifest().load_init_params()?,
            Some(seed) => self.runtime.manifest().sample_init_params(seed),
        };
        let sampler = self.sampler();
        let source = PjrtSource::new(&self.runtime, sampler, cfg.workers);
        let strategy = cfg.build_strategy();
        let mut engine = Engine::new(
            strategy,
            source,
            cfg.workers,
            &init,
            cfg.lr.at(0),
            cfg.weight_decay,
            cfg.seed,
        );
        if let Some(path) = &cfg.resume_from {
            let ckpt = Checkpoint::load(path)?;
            if ckpt.workers.len() != cfg.workers {
                return Err(crate::error::Error::config(format!(
                    "checkpoint has {} workers, config wants {}",
                    ckpt.workers.len(),
                    cfg.workers
                )));
            }
            if ckpt.master.len() != init.len() {
                return Err(crate::error::Error::shape(format!(
                    "checkpoint param count {} vs model {}",
                    ckpt.master.len(),
                    init.len()
                )));
            }
            *engine.state_mut() = ckpt.restore()?;
        }

        let mut evals = Vec::new();
        let eval_sampler = self.sampler();
        let chunk = if cfg.eval_every == 0 { cfg.steps } else { cfg.eval_every };
        let mut done = 0u64;
        while done < cfg.steps {
            let n = chunk.min(cfg.steps - done);
            engine.run(n)?;
            done += n;
            if cfg.eval_every != 0 {
                let mean = engine.consensus_model()?;
                let (vl, va) =
                    self.runtime
                        .evaluate(&mean, &eval_sampler, cfg.eval_batches)?;
                evals.push((done, vl, va));
            }
        }

        if let Some(path) = &cfg.save_checkpoint {
            Checkpoint::capture(engine.state_mut())?.save(path)?;
        }
        let mean = engine.consensus_model()?;
        let (final_loss, final_accuracy) =
            self.runtime
                .evaluate(&mean, &eval_sampler, cfg.eval_batches)?;
        let state = engine.state();
        Ok(RunReport {
            strategy: engine.strategy_name(),
            model: cfg.model.clone(),
            workers: cfg.workers,
            steps: cfg.steps,
            train_loss: engine.losses.clone(),
            evals,
            final_loss,
            final_accuracy,
            consensus_error: state.stacked.consensus_error()?,
            messages: state.comm.messages,
            bytes: state.comm.bytes,
            barriers: state.comm.barriers,
            elapsed_secs: watch.elapsed_secs(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/tiny/manifest.json").exists()
    }

    #[test]
    fn report_summary_formats() {
        let rep = RunReport {
            strategy: "gosgd(p=0.02)".into(),
            model: "tiny".into(),
            workers: 8,
            steps: 100,
            final_loss: 1.5,
            final_accuracy: 0.42,
            consensus_error: 1e-3,
            messages: 16,
            ..Default::default()
        };
        let s = rep.summary();
        assert!(s.contains("gosgd"));
        assert!(s.contains("acc=0.420"));
    }

    #[test]
    fn invalid_config_rejected_before_artifact_load() {
        let mut cfg = RunConfig::default();
        cfg.workers = 0;
        assert!(Coordinator::new(cfg).is_err());
    }

    // Full runs through PJRT live in rust/tests/integration_runtime.rs;
    // this smoke test only runs when artifacts exist (cargo test after
    // `make artifacts`).
    #[test]
    fn smoke_tiny_run_if_artifacts_present() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
            return;
        }
        let mut cfg = RunConfig::default();
        cfg.model = "tiny".into();
        cfg.workers = 2;
        cfg.steps = 4;
        cfg.strategy = StrategyKind::GoSgd { p: 0.5 };
        cfg.eval_batches = 1;
        let rep = Coordinator::new(cfg).unwrap().run().unwrap();
        assert_eq!(rep.steps, 4);
        assert!(rep.train_loss.len() >= 4);
        assert!(rep.final_loss.is_finite());
    }
}
