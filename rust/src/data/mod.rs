//! Synthetic CIFAR-like dataset (the paper trains on CIFAR-10).
//!
//! CIFAR-10 itself is not available offline, so we substitute a
//! deterministic class-conditional generator at the same geometry
//! (32×32×3, 10 classes) — see DESIGN.md §Substitutions.  What the
//! distributed-SGD experiments need from the data is (a) a non-convex
//! classification loss, (b) per-worker stochastic gradients with real
//! variance, and (c) a train/validation generalization gap.  The
//! generator provides all three:
//!
//! * each class has a fixed random *prototype* image (low-frequency
//!   pattern, seeded once from the dataset seed);
//! * a sample is `prototype[c] + texture noise`, optionally augmented with
//!   the paper's crop/flip augmentation;
//! * the noise magnitude sets the Bayes error: classes overlap, so
//!   memorizing train noise hurts validation — the regularization effect
//!   in the paper's Fig. 3 (gossip noise helps generalization) is
//!   observable.
//!
//! Everything is deterministic from `(seed, split, index)`: two workers
//! never see the same batch (they shard by index), and re-runs are exact.

pub mod sampler;

pub use sampler::BatchSampler;

use crate::util::rng::Rng;

/// Image geometry (NHWC, matching the Layer-2 model).
pub const HEIGHT: usize = 32;
pub const WIDTH: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 10;
pub const IMAGE_ELEMS: usize = HEIGHT * WIDTH * CHANNELS;

/// Which split a sample is drawn from (disjoint noise streams).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Validation,
}

/// Deterministic synthetic CIFAR-like dataset.
pub struct SyntheticCifar {
    prototypes: Vec<Vec<f32>>, // CLASSES × IMAGE_ELEMS
    noise_std: f32,
    augment: bool,
    /// Probability a *training* label is resampled uniformly — the
    /// irreducible-error knob.  Pixel noise alone cannot make a 3072-dim
    /// class-conditional Gaussian problem hard (any linear model separates
    /// it), so the train/validation generalization gap the paper's Fig. 3
    /// exercises comes from label noise: memorizing corrupted training
    /// labels strictly hurts validation accuracy.
    label_noise: f32,
    seed: u64,
}

impl SyntheticCifar {
    /// `noise_std` controls pixel-level class overlap.
    pub fn new(seed: u64, noise_std: f32, augment: bool) -> Self {
        let mut proto_rng = Rng::new(seed ^ 0xDA7A);
        let mut prototypes = Vec::with_capacity(CLASSES);
        for _ in 0..CLASSES {
            prototypes.push(Self::prototype(&mut proto_rng));
        }
        SyntheticCifar { prototypes, noise_std, augment, label_noise: 0.0, seed }
    }

    /// Corrupt a fraction of *training* labels (validation keeps truth).
    pub fn with_label_noise(mut self, q: f32) -> Self {
        assert!((0.0..=1.0).contains(&q));
        self.label_noise = q;
        self
    }

    /// Low-frequency class prototype: a sum of a few random 2-D cosine
    /// waves per channel.  Low-frequency structure matters: it gives the
    /// conv layers something spatially coherent to learn, unlike white
    /// noise.
    fn prototype(rng: &mut Rng) -> Vec<f32> {
        let mut img = vec![0.0f32; IMAGE_ELEMS];
        for c in 0..CHANNELS {
            for _wave in 0..4 {
                let fx = rng.f64() * 3.0 + 0.5;
                let fy = rng.f64() * 3.0 + 0.5;
                let phase = rng.f64() * std::f64::consts::TAU;
                let amp = (rng.f64() * 0.5 + 0.25) as f32;
                for y in 0..HEIGHT {
                    for x in 0..WIDTH {
                        let v = amp
                            * ((fx * x as f64 / WIDTH as f64 * std::f64::consts::TAU
                                + fy * y as f64 / HEIGHT as f64 * std::f64::consts::TAU
                                + phase)
                                .cos() as f32);
                        img[(y * WIDTH + x) * CHANNELS + c] += v;
                    }
                }
            }
        }
        img
    }

    /// Generate sample `index` of `split` into `out` (length IMAGE_ELEMS);
    /// returns its label.
    pub fn sample_into(&self, split: Split, index: u64, out: &mut [f32]) -> i32 {
        assert_eq!(out.len(), IMAGE_ELEMS);
        let split_tag = match split {
            Split::Train => TRAIN_TAG,
            Split::Validation => VAL_TAG,
        };
        let mut rng = Rng::new(self.seed ^ split_tag).split(index);
        let true_label = rng.below(CLASSES as u64) as i32;
        let proto = &self.prototypes[true_label as usize];
        // Normalize to ~unit pixel variance regardless of the noise level:
        // raising `noise_std` lowers the per-pixel SNR (harder problem)
        // without blowing up the optimizer's input scale.
        let scale = 1.0 / (1.0 + self.noise_std * self.noise_std).sqrt();
        for (o, &p) in out.iter_mut().zip(proto) {
            *o = scale * (p + rng.normal_f32(self.noise_std));
        }
        if self.augment && split == Split::Train {
            self.augment_in_place(out, &mut rng);
        }
        // Label corruption on the training stream only.
        if split == Split::Train
            && self.label_noise > 0.0
            && rng.bernoulli(self.label_noise as f64)
        {
            return rng.below(CLASSES as u64) as i32;
        }
        true_label
    }

    /// The paper uses the EASGD data augmentation (crop + flip).  We apply
    /// a random ±3px cyclic translation and a 50% horizontal flip.
    fn augment_in_place(&self, img: &mut [f32], rng: &mut Rng) {
        let dx = rng.below(7) as isize - 3;
        let dy = rng.below(7) as isize - 3;
        let flip = rng.bernoulli(0.5);
        let src = img.to_vec();
        for y in 0..HEIGHT as isize {
            for x in 0..WIDTH as isize {
                let sy = (y + dy).rem_euclid(HEIGHT as isize) as usize;
                let mut sx = (x + dx).rem_euclid(WIDTH as isize) as usize;
                if flip {
                    sx = WIDTH - 1 - sx;
                }
                for c in 0..CHANNELS {
                    img[(y as usize * WIDTH + x as usize) * CHANNELS + c] =
                        src[(sy * WIDTH + sx) * CHANNELS + c];
                }
            }
        }
    }

    pub fn noise_std(&self) -> f32 {
        self.noise_std
    }
}

/// Seed tags guaranteeing the train and validation noise streams are
/// disjoint.
const TRAIN_TAG: u64 = 0x7EA10;
const VAL_TAG: u64 = 0x5A11D;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_deterministic() {
        let ds = SyntheticCifar::new(7, 0.5, true);
        let mut a = vec![0.0; IMAGE_ELEMS];
        let mut b = vec![0.0; IMAGE_ELEMS];
        let la = ds.sample_into(Split::Train, 42, &mut a);
        let lb = ds.sample_into(Split::Train, 42, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticCifar::new(7, 0.5, false);
        let mut a = vec![0.0; IMAGE_ELEMS];
        let mut b = vec![0.0; IMAGE_ELEMS];
        ds.sample_into(Split::Train, 1, &mut a);
        ds.sample_into(Split::Train, 2, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let ds = SyntheticCifar::new(7, 0.5, false);
        let mut a = vec![0.0; IMAGE_ELEMS];
        let mut b = vec![0.0; IMAGE_ELEMS];
        ds.sample_into(Split::Train, 5, &mut a);
        ds.sample_into(Split::Validation, 5, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = SyntheticCifar::new(3, 0.5, false);
        let mut img = vec![0.0; IMAGE_ELEMS];
        let mut seen = [false; CLASSES];
        for i in 0..200 {
            let l = ds.sample_into(Split::Train, i, &mut img);
            assert!((0..CLASSES as i32).contains(&l));
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn class_signal_exceeds_noise_correlation() {
        // Same class, different samples must be more similar than
        // different classes, else nothing is learnable.
        let ds = SyntheticCifar::new(11, 0.5, false);
        let mut buf = vec![0.0; IMAGE_ELEMS];
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![Vec::new(); CLASSES];
        for i in 0..400 {
            let l = ds.sample_into(Split::Train, i, &mut buf);
            if by_class[l as usize].len() < 3 {
                by_class[l as usize].push(buf.clone());
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
        };
        let c0 = &by_class[0];
        let c1 = &by_class[1];
        assert!(c0.len() >= 2 && c1.len() >= 1);
        let intra = dist(&c0[0], &c0[1]);
        let inter = dist(&c0[0], &c1[0]);
        assert!(inter > intra, "inter {inter} <= intra {intra}");
    }

    #[test]
    fn label_noise_corrupts_train_only_at_rate() {
        let clean = SyntheticCifar::new(5, 0.5, false);
        let noisy = SyntheticCifar::new(5, 0.5, false).with_label_noise(0.2);
        let mut img = vec![0.0; IMAGE_ELEMS];
        let mut flipped = 0;
        let n = 2000;
        for i in 0..n {
            let lt = clean.sample_into(Split::Train, i, &mut img);
            let ln = noisy.sample_into(Split::Train, i, &mut img);
            if lt != ln {
                flipped += 1;
            }
            // Validation labels are never corrupted.
            let vt = clean.sample_into(Split::Validation, i, &mut img);
            let vn = noisy.sample_into(Split::Validation, i, &mut img);
            assert_eq!(vt, vn);
        }
        // Effective flip rate = q * (1 - 1/CLASSES) = 0.18.
        let rate = flipped as f64 / n as f64;
        assert!((rate - 0.18).abs() < 0.03, "flip rate {rate}");
    }

    #[test]
    fn augmentation_changes_pixels_not_determinism() {
        let ds_aug = SyntheticCifar::new(7, 0.5, true);
        let ds_plain = SyntheticCifar::new(7, 0.5, false);
        let mut a = vec![0.0; IMAGE_ELEMS];
        let mut b = vec![0.0; IMAGE_ELEMS];
        ds_aug.sample_into(Split::Train, 9, &mut a);
        ds_plain.sample_into(Split::Train, 9, &mut b);
        // augmentation is a permutation of pixels: multiset is preserved
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(sa, sb);
        // validation is never augmented
        ds_aug.sample_into(Split::Validation, 9, &mut a);
        ds_plain.sample_into(Split::Validation, 9, &mut b);
        assert_eq!(a, b);
    }
}
