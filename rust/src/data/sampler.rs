//! Batch sampling with per-worker sharding.
//!
//! Each worker must see an independent stochastic gradient (the whole
//! point of distributing the batch, paper section 2).  The sampler maps
//! `(worker, local_step, batch_slot)` to a unique global sample index, so
//! no two workers ever share a training sample at the same step, and the
//! stream is deterministic from the dataset seed.

use crate::data::{Split, SyntheticCifar, IMAGE_ELEMS};

/// A materialized batch ready for the runtime (NHWC f32 + i32 labels).
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub size: usize,
}

/// Deterministic sharded batch generator over [`SyntheticCifar`].
pub struct BatchSampler {
    dataset: SyntheticCifar,
    batch: usize,
    workers: usize,
}

impl BatchSampler {
    pub fn new(dataset: SyntheticCifar, batch: usize, workers: usize) -> Self {
        assert!(batch >= 1 && workers >= 1);
        BatchSampler { dataset, batch, workers }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Training batch for `worker` (1-based, engine slot convention) at its
    /// `local_step`.
    ///
    /// The worker id is checked unconditionally (not `debug_assert!`):
    /// in a release build an out-of-range id would silently alias another
    /// worker's sample stream — e.g. `worker = workers + 1` at step `t`
    /// reads exactly worker 1's samples from step `t + 1` — destroying the
    /// disjointness invariant this module promises without any visible
    /// failure.
    pub fn train_batch(&self, worker: usize, local_step: u64) -> Batch {
        assert!(
            worker >= 1 && worker <= self.workers,
            "worker id {worker} out of range 1..={} (would alias another worker's samples)",
            self.workers
        );
        // Global sample index: interleave workers so the union over workers
        // at a given step is a contiguous range (mirrors "splitting the
        // batch in subsets", section 2.1).
        let base = local_step * (self.batch * self.workers) as u64
            + ((worker - 1) * self.batch) as u64;
        self.materialize(Split::Train, base)
    }

    /// Validation batch `index` (shared across workers — evaluation is
    /// centralized).
    pub fn val_batch(&self, index: u64, size: usize) -> Batch {
        let mut images = vec![0.0f32; size * IMAGE_ELEMS];
        let mut labels = vec![0i32; size];
        let base = index * size as u64;
        for i in 0..size {
            labels[i] = self.dataset.sample_into(
                Split::Validation,
                base + i as u64,
                &mut images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS],
            );
        }
        Batch { images, labels, size }
    }

    fn materialize(&self, split: Split, base: u64) -> Batch {
        let mut images = vec![0.0f32; self.batch * IMAGE_ELEMS];
        let mut labels = vec![0i32; self.batch];
        for i in 0..self.batch {
            labels[i] = self.dataset.sample_into(
                split,
                base + i as u64,
                &mut images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS],
            );
        }
        Batch { images, labels, size: self.batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticCifar;

    fn sampler(workers: usize, batch: usize) -> BatchSampler {
        BatchSampler::new(SyntheticCifar::new(3, 0.5, false), batch, workers)
    }

    #[test]
    fn batch_shapes() {
        let s = sampler(4, 8);
        let b = s.train_batch(1, 0);
        assert_eq!(b.images.len(), 8 * IMAGE_ELEMS);
        assert_eq!(b.labels.len(), 8);
        assert_eq!(b.size, 8);
    }

    #[test]
    fn workers_get_disjoint_samples_same_step() {
        let s = sampler(2, 4);
        let b1 = s.train_batch(1, 0);
        let b2 = s.train_batch(2, 0);
        assert_ne!(b1.images, b2.images);
    }

    #[test]
    fn steps_advance_the_stream() {
        let s = sampler(2, 4);
        let a = s.train_batch(1, 0);
        let b = s.train_batch(1, 1);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn deterministic_across_instances() {
        let a = sampler(2, 4).train_batch(1, 7);
        let b = sampler(2, 4).train_batch(1, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn union_over_workers_is_contiguous_range() {
        // worker 1 step 1 must continue exactly after worker W step 0 ends:
        // compare against a 1-worker sampler covering the same global range.
        let s2 = sampler(2, 2);
        let s1 = BatchSampler::new(SyntheticCifar::new(3, 0.5, false), 4, 1);
        let w1 = s2.train_batch(1, 0);
        let w2 = s2.train_batch(2, 0);
        let all = s1.train_batch(1, 0);
        let mut combined = w1.images.clone();
        combined.extend_from_slice(&w2.images);
        assert_eq!(combined, all.images);
    }

    #[test]
    #[should_panic(expected = "out of range 1..=")]
    fn worker_zero_is_rejected_in_release_builds_too() {
        sampler(4, 2).train_batch(0, 0);
    }

    #[test]
    #[should_panic(expected = "out of range 1..=")]
    fn worker_above_the_fleet_is_rejected() {
        // Without the hard check this id would silently read worker 1's
        // step-1 samples (the aliasing the module doc rules out).
        sampler(4, 2).train_batch(5, 0);
    }

    #[test]
    fn val_batches_shared_and_indexed() {
        let s = sampler(4, 8);
        let a = s.val_batch(0, 16);
        let b = s.val_batch(0, 16);
        let c = s.val_batch(1, 16);
        assert_eq!(a.images, b.images);
        assert_ne!(a.images, c.images);
        assert_eq!(a.size, 16);
    }
}
