//! Crate-wide error type.
//!
//! Hand-rolled (no `thiserror` in the offline dep set) but with the same
//! ergonomics: every subsystem has a variant, everything implements
//! `std::error::Error`, and `?` works across `io`, `xla` and parse errors.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the GoSGD stack can fail.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / OS error (artifact loading, CSV output, ...).
    Io(std::io::Error),
    /// PJRT / XLA error from the `xla` crate (only with the `pjrt` feature).
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),
    /// Malformed artifact directory (missing file, bad manifest).
    Artifact(String),
    /// JSON syntax or schema error in `manifest.json`.
    Json(String),
    /// Invalid run configuration (bad strategy params, zero workers, ...).
    Config(String),
    /// Shape/length mismatch between tensors or literals.
    Shape(String),
    /// Worker thread panicked or poisoned a shared lock.
    Worker(String),
    /// CLI usage error.
    Cli(String),
    /// Networked-runtime error: malformed wire bytes, a failed join
    /// handshake, or socket-level I/O wrapped with peer context.  The
    /// finer-grained typed forms live with their layers
    /// ([`crate::gossip::message::WireError`] for message bodies,
    /// [`crate::net::FrameError`] for the frame codec) and convert into
    /// this variant at the runtime boundary.
    Net(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Worker(m) => write!(f, "worker error: {m}"),
            Error::Cli(m) => write!(f, "cli error: {m}"),
            Error::Net(m) => write!(f, "net error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

/// Shorthand constructors used across the crate.
impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn worker(msg: impl Into<String>) -> Self {
        Error::Worker(msg.into())
    }
    pub fn cli(msg: impl Into<String>) -> Self {
        Error::Cli(msg.into())
    }
    pub fn net(msg: impl Into<String>) -> Self {
        Error::Net(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::config("bad p");
        assert_eq!(e.to_string(), "config error: bad p");
        let e = Error::shape("1 vs 2");
        assert!(e.to_string().contains("shape"));
    }

    #[test]
    fn io_conversion_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
