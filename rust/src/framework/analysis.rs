//! Spectral analysis of the gossip communication process (paper §4).
//!
//! Randomized gossip converges to consensus exponentially fast; the rate
//! is governed by the second-largest eigenvalue modulus (SLEM) of the
//! expected communication matrix `E[K]` restricted to the
//! disagreement subspace `1⊥`.  This module computes:
//!
//! * [`expected_gossip_matrix`] — `E[K^(t)]` for GoSGD's exchange at rate
//!   `p` with uniform peer choice and the idealized 1/2 blend;
//! * [`slem`] — the contraction factor per tick, via power iteration on
//!   the mean-removed operator;
//! * [`predicted_halving_ticks`] — ticks for the expected disagreement to
//!   halve, which the tests compare against *measured* ε(t) decay of the
//!   pure-gossip protocol.

use crate::error::Result;
use crate::framework::comm_matrix::CommMatrix;

/// `E[K^(t)]` over the worker block (no master slot) for GoSGD at exchange
/// probability `p`: with prob `p/(M(M-1))` for each ordered pair `(s, r)`
/// the receiver row blends half-half (idealized Lemma-1 coefficient).
pub fn expected_gossip_matrix(m: usize, p: f64) -> Result<CommMatrix> {
    assert!(m >= 2);
    // Each ordered pair (s, r≠s): receiver r gets 1/2 x_r + 1/2 x_s.
    // Probability a given tick awakens s AND sends to r: p / (M(M-1)).
    // Expected row r: (1 - q(M-1)/1 ... ) — derive by accumulation.
    let q = p / (m as f64 * (m - 1) as f64);
    let mut dense = vec![vec![0.0; m]; m];
    for (r, row) in dense.iter_mut().enumerate() {
        row[r] = 1.0;
        for s in 0..m {
            if s == r {
                continue;
            }
            // exchange (s -> r) happens with prob q: row r moves half its
            // own mass to column s.
            row[r] -= 0.5 * q;
            row[s] += 0.5 * q;
        }
    }
    CommMatrix::from_dense(&dense)
}

/// Second-largest eigenvalue modulus of `k` on the disagreement subspace:
/// power iteration on `x ↦ K(x − x̄)` (deterministic seed vector).
pub fn slem(k: &CommMatrix, iters: usize) -> Result<f64> {
    let n = k.dim();
    assert!(n >= 2);
    // Deterministic non-uniform start vector, mean-removed.
    let mut x: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 97) as f64 / 97.0).collect();
    remove_mean(&mut x);
    normalize(&mut x);
    let mut lambda = 0.0;
    for _ in 0..iters {
        let mut y = k.apply_scalars(&x)?;
        remove_mean(&mut y);
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return Ok(0.0);
        }
        lambda = norm; // since ‖x‖ = 1
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
    }
    Ok(lambda)
}

fn remove_mean(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn normalize(x: &mut [f64]) {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 0.0 {
        for v in x.iter_mut() {
            *v /= norm;
        }
    }
}

/// Ticks for the expected disagreement to halve under contraction `λ` per
/// tick: `t½ = ln 2 / −ln λ`.
pub fn predicted_halving_ticks(lambda: f64) -> f64 {
    assert!((0.0..1.0).contains(&lambda));
    (2.0f64).ln() / (-lambda.ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::gosgd::GoSgd;
    use crate::strategies::grad::NoiseSource;
    use crate::tensor::FlatVec;
    use crate::util::rng::Rng;

    #[test]
    fn expected_matrix_is_row_stochastic() {
        for m in [2, 4, 8, 16] {
            for p in [0.01, 0.1, 1.0] {
                let k = expected_gossip_matrix(m, p).unwrap();
                assert!(k.is_row_stochastic(1e-12), "m={m} p={p}");
            }
        }
    }

    #[test]
    fn slem_of_identity_is_one_and_averaging_zero() {
        let id = CommMatrix::identity(6);
        assert!((slem(&id, 50).unwrap() - 1.0).abs() < 1e-9);
        let avg = CommMatrix::from_dense(&vec![vec![1.0 / 6.0; 6]; 6]).unwrap();
        assert!(slem(&avg, 50).unwrap() < 1e-9);
    }

    #[test]
    fn higher_p_contracts_faster() {
        let m = 8;
        let l_low = slem(&expected_gossip_matrix(m, 0.1).unwrap(), 200).unwrap();
        let l_high = slem(&expected_gossip_matrix(m, 1.0).unwrap(), 200).unwrap();
        assert!(l_high < l_low, "{l_high} vs {l_low}");
        assert!(l_low < 1.0);
    }

    #[test]
    fn known_closed_form_for_expected_gossip() {
        // E[K] = (1 − qM/2)I + (q/2)𝟙𝟙ᵀ restricted to 1⊥ has eigenvalue
        // 1 − qM/2 with multiplicity M−1 (q = p/(M(M−1))).
        let m = 8;
        let p = 0.5;
        let q = p / (m as f64 * (m - 1) as f64);
        let want = 1.0 - q * m as f64 / 2.0;
        let got = slem(&expected_gossip_matrix(m, p).unwrap(), 300).unwrap();
        assert!((got - want).abs() < 1e-6, "slem {got} vs closed form {want}");
    }

    #[test]
    fn predicted_decay_matches_measured_pure_gossip() {
        // Run the real protocol with zero learning rate from scattered
        // starts and compare the measured ε halving time with the
        // prediction. The protocol's disagreement VARIANCE contracts at a
        // pair-dependent rate; expectation analysis predicts the trend, so
        // we allow a generous factor-of-3 band.
        let m = 8;
        let p = 1.0;
        let dim = 200;
        let k = expected_gossip_matrix(m, p).unwrap();
        // ε is quadratic in the disagreement: contraction per tick ≈ λ².
        let lambda = slem(&k, 300).unwrap();
        let predicted = predicted_halving_ticks(lambda * lambda);

        let src = NoiseSource::new(dim, 1);
        let mut rng = Rng::new(2);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(Box::new(GoSgd::new(p)), src, m, &init, 0.0, 0.0, 3);
        for w in 1..=m {
            *eng.state_mut().stacked.worker_mut(w) = FlatVec::randn(dim, 1.0, &mut rng);
        }
        let eps0 = eng.state().stacked.consensus_error().unwrap();
        // Measure ticks to fall below eps0 / 2 (average over the noise by
        // running to eps0/8 and dividing by 3 halvings).
        let mut ticks = 0u64;
        while eng.state().stacked.consensus_error().unwrap() > eps0 / 8.0 {
            eng.run(1).unwrap();
            ticks += 1;
            assert!(ticks < 20_000, "gossip failed to contract");
        }
        let measured = ticks as f64 / 3.0;
        let ratio = measured / predicted;
        assert!(
            (0.33..3.0).contains(&ratio),
            "halving ticks: measured {measured:.1} vs predicted {predicted:.1}"
        );
    }
}
