//! Row-sparse communication matrices `K^(t)`.
//!
//! Sizes are small (`(M+1) × (M+1)` with M = number of workers) but the
//! matrices multiply *parameter vectors* of 10⁶+ elements, so application
//! cost is dominated by the number of non-identity rows — the sparse-row
//! representation applies only those.

use crate::error::{Error, Result};
use crate::framework::stacked::Stacked;

/// One row as `(column, coefficient)` pairs.
pub type Row = Vec<(usize, f64)>;

/// A communication matrix over the stacked state `[x̃, x_1 … x_M]`.
#[derive(Clone, Debug, PartialEq)]
pub struct CommMatrix {
    n: usize,
    /// Only rows that differ from identity are stored.
    rows: Vec<(usize, Row)>,
}

impl CommMatrix {
    /// The identity (no communication — paper's "else" branches).
    pub fn identity(n: usize) -> Self {
        CommMatrix { n, rows: Vec::new() }
    }

    /// Dimension (M + 1: master slot plus workers).
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Number of non-identity rows (≈ application cost in vector ops).
    pub fn touched_rows(&self) -> usize {
        self.rows.len()
    }

    /// Replace row `r`. Entries must be in-range; duplicates are summed.
    pub fn set_row(&mut self, r: usize, entries: Row) -> Result<()> {
        if r >= self.n {
            return Err(Error::shape(format!("row {r} out of range {}", self.n)));
        }
        for &(c, _) in &entries {
            if c >= self.n {
                return Err(Error::shape(format!("col {c} out of range {}", self.n)));
            }
        }
        self.rows.retain(|(rr, _)| *rr != r);
        self.rows.push((r, entries));
        Ok(())
    }

    /// Build from a dense matrix (tests / composition results).
    pub fn from_dense(dense: &[Vec<f64>]) -> Result<Self> {
        let n = dense.len();
        let mut m = CommMatrix::identity(n);
        for (r, row) in dense.iter().enumerate() {
            if row.len() != n {
                return Err(Error::shape("ragged dense matrix"));
            }
            let mut entries: Row = row
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(c, &v)| (c, v))
                .collect();
            let is_identity_row = entries == vec![(r, 1.0)];
            if !is_identity_row {
                entries.shrink_to_fit();
                m.set_row(r, entries)?;
            }
        }
        Ok(m)
    }

    /// Dense rendering (analysis / composition).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n]; self.n];
        for (i, row) in d.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        for (r, entries) in &self.rows {
            let row = &mut d[*r];
            row.iter_mut().for_each(|v| *v = 0.0);
            for &(c, v) in entries {
                row[c] += v;
            }
        }
        d
    }

    /// Row coefficient lookup.
    pub fn coeff(&self, r: usize, c: usize) -> f64 {
        for (rr, entries) in &self.rows {
            if *rr == r {
                return entries.iter().filter(|(cc, _)| *cc == c).map(|(_, v)| v).sum();
            }
        }
        if r == c {
            1.0
        } else {
            0.0
        }
    }

    /// Every row sums to 1 (the paper's no-exploding-gradients condition).
    pub fn is_row_stochastic(&self, tol: f64) -> bool {
        self.rows.iter().all(|(_, entries)| {
            let s: f64 = entries.iter().map(|(_, v)| v).sum();
            (s - 1.0).abs() <= tol && entries.iter().all(|(_, v)| *v >= -tol)
        })
    }

    /// Apply to a stacked state of scalars (cheap analysis path).
    pub fn apply_scalars(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.n {
            return Err(Error::shape(format!("state dim {} vs matrix {}", x.len(), self.n)));
        }
        let mut out = x.to_vec();
        for (r, entries) in &self.rows {
            out[*r] = entries.iter().map(|&(c, v)| v * x[c]).sum();
        }
        Ok(out)
    }

    /// Apply to a stacked state of parameter vectors: `x'_r = Σ_c K_rc x_c`.
    ///
    /// Only non-identity rows are recomputed; untouched rows are moved, not
    /// copied.
    pub fn apply(&self, x: &Stacked) -> Result<Stacked> {
        self.apply_block(x, 0, x.vec_len())
    }

    /// Apply as one block of a **block-diagonal** operator: the matrix acts
    /// on coordinates `[offset, offset + len)` of every slot and is the
    /// identity on all other coordinates.  This is how a *sharded* gossip
    /// exchange looks in the section-3 formalism: the full operator is
    /// `diag(I, …, K, …, I)` over the shard decomposition, and the
    /// framework replay applies exactly the block that the engine's shard
    /// event touched.  `apply` is the `offset = 0, len = vec_len` special
    /// case, so both paths share float-for-float identical arithmetic.
    pub fn apply_block(&self, x: &Stacked, offset: usize, len: usize) -> Result<Stacked> {
        if x.dim() != self.n {
            return Err(Error::shape(format!("state dim {} vs matrix {}", x.dim(), self.n)));
        }
        let end = offset
            .checked_add(len)
            .ok_or_else(|| Error::shape("block range overflows usize"))?;
        if end > x.vec_len() {
            return Err(Error::shape(format!(
                "block {offset}..{end} out of vector length {}",
                x.vec_len()
            )));
        }
        let mut out = x.clone();
        for (r, entries) in &self.rows {
            let mut acc = vec![0.0f32; len];
            for &(c, v) in entries {
                crate::tensor::ops::axpy(&mut acc, v as f32, &x.get(c).as_slice()[offset..end]);
            }
            out.get_mut(*r).as_mut_slice()[offset..end].copy_from_slice(&acc);
        }
        Ok(out)
    }

    /// Matrix product `self * other` (apply `other` first).
    pub fn compose(&self, other: &CommMatrix) -> Result<CommMatrix> {
        if self.n != other.n {
            return Err(Error::shape("compose: dim mismatch"));
        }
        let a = self.to_dense();
        let b = other.to_dense();
        let mut prod = vec![vec![0.0; self.n]; self.n];
        for r in 0..self.n {
            for k in 0..self.n {
                let arv = a[r][k];
                if arv == 0.0 {
                    continue;
                }
                for c in 0..self.n {
                    prod[r][c] += arv * b[k][c];
                }
            }
        }
        CommMatrix::from_dense(&prod)
    }

    /// Spectral-gap proxy: the second-largest row sum of `|K − (1/n)𝟙𝟙ᵀ|`
    /// is expensive; instead report the maximum total-variation distance of
    /// any row from uniform — a cheap upper-bound diagnostic used by the
    /// consensus analysis in `harness::fig4`.
    pub fn max_row_tv_from_uniform(&self) -> f64 {
        let d = self.to_dense();
        let u = 1.0 / self.n as f64;
        d.iter()
            .map(|row| 0.5 * row.iter().map(|v| (v - u).abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FlatVec;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_stochastic(rng: &mut Rng, n: usize) -> CommMatrix {
        let mut dense = vec![vec![0.0; n]; n];
        for row in dense.iter_mut() {
            let mut total = 0.0;
            for v in row.iter_mut() {
                *v = rng.f64();
                total += *v;
            }
            for v in row.iter_mut() {
                *v /= total;
            }
        }
        CommMatrix::from_dense(&dense).unwrap()
    }

    #[test]
    fn identity_applies_as_noop() {
        let k = CommMatrix::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(k.apply_scalars(&x).unwrap(), x);
        assert!(k.is_row_stochastic(0.0));
        assert_eq!(k.touched_rows(), 0);
    }

    #[test]
    fn set_row_and_coeff() {
        let mut k = CommMatrix::identity(3);
        k.set_row(1, vec![(0, 0.25), (2, 0.75)]).unwrap();
        assert_eq!(k.coeff(1, 0), 0.25);
        assert_eq!(k.coeff(1, 1), 0.0);
        assert_eq!(k.coeff(0, 0), 1.0);
        assert!(k.is_row_stochastic(1e-12));
    }

    #[test]
    fn out_of_range_rejected() {
        let mut k = CommMatrix::identity(3);
        assert!(k.set_row(3, vec![]).is_err());
        assert!(k.set_row(0, vec![(5, 1.0)]).is_err());
    }

    #[test]
    fn dense_round_trip() {
        check("dense round trip", 25, |rng| {
            let n = 2 + rng.below(6) as usize;
            let k = random_stochastic(rng, n);
            let k2 = CommMatrix::from_dense(&k.to_dense()).unwrap();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let a = k.apply_scalars(&x).unwrap();
            let b = k2.apply_scalars(&x).unwrap();
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn apply_matches_dense_multiply() {
        check("sparse apply == dense multiply", 25, |rng| {
            let n = 2 + rng.below(6) as usize;
            let k = random_stochastic(rng, n);
            let d = k.to_dense();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let got = k.apply_scalars(&x).unwrap();
            for r in 0..n {
                let want: f64 = (0..n).map(|c| d[r][c] * x[c]).sum();
                assert!((got[r] - want).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn row_stochasticity_preserved_under_composition() {
        check("stochastic closed under product", 20, |rng| {
            let n = 2 + rng.below(5) as usize;
            let a = random_stochastic(rng, n);
            let b = random_stochastic(rng, n);
            let c = a.compose(&b).unwrap();
            assert!(c.is_row_stochastic(1e-9));
        });
    }

    #[test]
    fn compose_order_is_self_times_other() {
        // K2 ∘ K1 applied to x must equal K2(K1 x).
        check("compose application order", 20, |rng| {
            let n = 2 + rng.below(5) as usize;
            let k1 = random_stochastic(rng, n);
            let k2 = random_stochastic(rng, n);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let via_seq = k2.apply_scalars(&k1.apply_scalars(&x).unwrap()).unwrap();
            let via_prod = k2.compose(&k1).unwrap().apply_scalars(&x).unwrap();
            for (u, v) in via_seq.iter().zip(&via_prod) {
                assert!((u - v).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn vector_apply_matches_scalar_apply_per_component() {
        let mut rng = Rng::new(3);
        let n = 4;
        let k = random_stochastic(&mut rng, n);
        let dim = 17;
        let vecs: Vec<FlatVec> = (0..n).map(|_| FlatVec::randn(dim, 1.0, &mut rng)).collect();
        let stacked = Stacked::from_vecs(vecs.clone()).unwrap();
        let out = k.apply(&stacked).unwrap();
        for j in 0..dim {
            let x: Vec<f64> = vecs.iter().map(|v| v.as_slice()[j] as f64).collect();
            let want = k.apply_scalars(&x).unwrap();
            for r in 0..n {
                assert!(
                    (out.get(r).as_slice()[j] as f64 - want[r]).abs() < 1e-5,
                    "component {j} row {r}"
                );
            }
        }
    }

    #[test]
    fn apply_block_is_identity_outside_the_block() {
        let mut rng = Rng::new(11);
        let n = 3;
        let k = random_stochastic(&mut rng, n);
        let dim = 20;
        let vecs: Vec<FlatVec> = (0..n).map(|_| FlatVec::randn(dim, 1.0, &mut rng)).collect();
        let stacked = Stacked::from_vecs(vecs.clone()).unwrap();
        let (offset, len) = (5, 7);
        let out = k.apply_block(&stacked, offset, len).unwrap();
        let full = k.apply(&stacked).unwrap();
        for slot in 0..n {
            for j in 0..dim {
                let got = out.get(slot).as_slice()[j];
                if (offset..offset + len).contains(&j) {
                    // inside the block: exactly the full application
                    assert_eq!(got, full.get(slot).as_slice()[j], "slot {slot} comp {j}");
                } else {
                    // outside: untouched
                    assert_eq!(got, vecs[slot].as_slice()[j], "slot {slot} comp {j}");
                }
            }
        }
    }

    #[test]
    fn apply_block_rejects_out_of_range() {
        let k = CommMatrix::identity(2);
        let stacked = Stacked::zeros(1, 8);
        assert!(k.apply_block(&stacked, 6, 4).is_err());
        assert!(k.apply_block(&stacked, 0, 8).is_ok());
    }

    #[test]
    fn tv_from_uniform_diagnostics() {
        let n = 4;
        // identity rows are maximally far from uniform: TV = 1 - 1/n
        let k = CommMatrix::identity(n);
        assert!((k.max_row_tv_from_uniform() - 0.75).abs() < 1e-12);
        // fully mixing matrix: TV = 0
        let avg = CommMatrix::from_dense(&vec![vec![0.25; 4]; 4]).unwrap();
        assert!(avg.max_row_tv_from_uniform() < 1e-12);
    }
}
