//! `K^(t)` generators for every strategy the paper discusses (section 3-4).
//!
//! Conventions: stacked dimension `n = M + 1`, slot 0 is the master `x̃`,
//! slots `1..=M` are workers.  All generated matrices are row-stochastic
//! except the Downpour *send* matrix, which (as in the paper, section 3.3)
//! models a gradient push and deliberately is not.

use crate::error::Result;
use crate::framework::comm_matrix::CommMatrix;

/// Fully-synchronous averaging (Algorithm 1's communication step): every
/// slot — master and workers — becomes the mean of the workers.
pub fn allreduce(m: usize) -> Result<CommMatrix> {
    let mut k = CommMatrix::identity(m + 1);
    let row: Vec<(usize, f64)> = (1..=m).map(|c| (c, 1.0 / m as f64)).collect();
    for r in 0..=m {
        k.set_row(r, row.clone())?;
    }
    Ok(k)
}

/// PerSyn (paper section 3.1, Algorithm 2): identity except every `tau`-th
/// step, when master and all workers are replaced by the worker mean.
pub fn persyn(t: u64, tau: u64, m: usize) -> Result<CommMatrix> {
    assert!(tau >= 1);
    if t % tau == 0 {
        allreduce(m)
    } else {
        Ok(CommMatrix::identity(m + 1))
    }
}

/// EASGD (paper section 3.2): every `tau`-th step an elastic averaging;
/// otherwise identity.
///
/// ```text
/// x̃  ← (1 − Mα) x̃ + α Σ_m x_m
/// x_m ← α x̃ + (1 − α) x_m
/// ```
pub fn easgd(t: u64, tau: u64, alpha: f64, m: usize) -> Result<CommMatrix> {
    assert!(tau >= 1);
    if t % tau != 0 {
        return Ok(CommMatrix::identity(m + 1));
    }
    let mut k = CommMatrix::identity(m + 1);
    let mut master_row: Vec<(usize, f64)> = vec![(0, 1.0 - m as f64 * alpha)];
    master_row.extend((1..=m).map(|c| (c, alpha)));
    k.set_row(0, master_row)?;
    for r in 1..=m {
        k.set_row(r, vec![(0, alpha), (r, 1.0 - alpha)])?;
    }
    Ok(k)
}

/// GoSGD exchange (paper eq. 8, corrected to match Algorithm 4 — see the
/// module docs of [`crate::framework`]): receiver `r` blends convexly with
/// sender `s`; the sender's row stays identity.  Master slot untouched
/// (first row/column of the paper's matrix are zero — decentralized).
///
/// `w_s` is the weight *shipped with the message* (already halved),
/// `w_r` the receiver's current weight.
pub fn gossip_exchange(m: usize, s: usize, r: usize, w_s: f64, w_r: f64) -> Result<CommMatrix> {
    assert!(s >= 1 && s <= m && r >= 1 && r <= m && s != r, "worker slots are 1-based");
    let t = w_s / (w_s + w_r);
    let mut k = CommMatrix::identity(m + 1);
    k.set_row(r, vec![(r, 1.0 - t), (s, t)])?;
    Ok(k)
}

/// Downpour *send* (paper section 3.3): master absorbs worker `m`'s
/// variable contribution — `x̃ ← x̃ + x_m`, workers unchanged.  As in the
/// paper this is NOT row-stochastic (it transfers an accumulated gradient,
/// not an average); provided for framework completeness.
pub fn downpour_send(m_total: usize, m: usize) -> Result<CommMatrix> {
    assert!(m >= 1 && m <= m_total);
    let mut k = CommMatrix::identity(m_total + 1);
    k.set_row(0, vec![(0, 1.0), (m, 1.0)])?;
    Ok(k)
}

/// Downpour *receive*: worker `m` fetches the master model — `x_m ← x̃`.
pub fn downpour_receive(m_total: usize, m: usize) -> Result<CommMatrix> {
    assert!(m >= 1 && m <= m_total);
    let mut k = CommMatrix::identity(m_total + 1);
    k.set_row(m, vec![(0, 1.0)])?;
    Ok(k)
}

/// Messages exchanged when this matrix is applied — the paper's
/// communication-cost accounting (section 2.1/5: PerSyn costs 2M messages
/// per sync — M up, M down; EASGD 2M; GoSGD 1 per exchange).
pub fn message_cost(kind: MatrixKind, m: usize) -> u64 {
    match kind {
        MatrixKind::Identity => 0,
        MatrixKind::AllReduce | MatrixKind::PerSynSync => 2 * m as u64,
        MatrixKind::EasgdSync => 2 * m as u64,
        MatrixKind::GossipExchange => 1,
        MatrixKind::DownpourSend | MatrixKind::DownpourReceive => 1,
    }
}

/// Tag for [`message_cost`] accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatrixKind {
    Identity,
    AllReduce,
    PerSynSync,
    EasgdSync,
    GossipExchange,
    DownpourSend,
    DownpourReceive,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::stacked::Stacked;
    use crate::tensor::FlatVec;
    use crate::util::proptest::check;

    #[test]
    fn allreduce_averages_everything() {
        let k = allreduce(4).unwrap();
        assert!(k.is_row_stochastic(1e-12));
        let x = vec![99.0, 1.0, 2.0, 3.0, 4.0];
        let out = k.apply_scalars(&x).unwrap();
        for v in out {
            assert!((v - 2.5).abs() < 1e-12);
        }
    }

    #[test]
    fn persyn_fires_only_on_tau_boundary() {
        let m = 3;
        for t in 0..10u64 {
            let k = persyn(t, 4, m).unwrap();
            if t % 4 == 0 {
                assert_eq!(k.touched_rows(), m + 1, "t={t}");
            } else {
                assert_eq!(k.touched_rows(), 0, "t={t}");
            }
        }
    }

    #[test]
    fn easgd_elastic_moves_toward_each_other() {
        let alpha = 0.25;
        let k = easgd(0, 1, alpha, 2).unwrap();
        assert!(k.is_row_stochastic(1e-12));
        // x̃=0, x_1=4, x_2=8
        let out = k.apply_scalars(&[0.0, 4.0, 8.0]).unwrap();
        // x̃' = (1-2α)·0 + α(4+8) = 3 ; x_1' = α·0 + (1-α)·4 = 3 ; x_2' = 6
        assert!((out[0] - 3.0).abs() < 1e-12);
        assert!((out[1] - 3.0).abs() < 1e-12);
        assert!((out[2] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn easgd_identity_off_boundary() {
        let k = easgd(3, 4, 0.25, 2).unwrap();
        assert_eq!(k.touched_rows(), 0);
    }

    #[test]
    fn gossip_exchange_is_algorithm4_blend() {
        // w_r = 0.25, shipped w_s = 0.25 -> coefficients 1/2.
        let k = gossip_exchange(4, 2, 3, 0.25, 0.25).unwrap();
        assert!(k.is_row_stochastic(1e-12));
        let out = k.apply_scalars(&[9.0, 0.0, 4.0, 8.0, 0.0]).unwrap();
        assert_eq!(out[0], 9.0, "master untouched");
        assert_eq!(out[2], 4.0, "sender unchanged (Algorithm 4)");
        assert!((out[3] - 6.0).abs() < 1e-12, "receiver blends to midpoint");
    }

    #[test]
    fn gossip_exchange_weighting() {
        check("gossip blend coefficients", 40, |rng| {
            let w_r = rng.f64() + 1e-3;
            let w_s = rng.f64() + 1e-3;
            let k = gossip_exchange(2, 1, 2, w_s, w_r).unwrap();
            let t = w_s / (w_s + w_r);
            assert!((k.coeff(2, 1) - t).abs() < 1e-12);
            assert!((k.coeff(2, 2) - (1.0 - t)).abs() < 1e-12);
            assert!(k.is_row_stochastic(1e-12));
        });
    }

    #[test]
    fn gossip_preserves_worker_mass_in_expectation_shape() {
        // applying an equal-weight exchange twice (r<-s then s<-r) contracts
        // the pair toward their mean — consensus direction.
        let m = 2;
        let x0 = Stacked::from_vecs(vec![
            FlatVec::zeros(1),
            FlatVec::from_vec(vec![0.0]),
            FlatVec::from_vec(vec![8.0]),
        ])
        .unwrap();
        let k1 = gossip_exchange(m, 2, 1, 0.5, 0.5).unwrap();
        let x1 = k1.apply(&x0).unwrap();
        assert_eq!(x1.worker(1).as_slice(), &[4.0]);
        let e0 = x0.consensus_error().unwrap();
        let e1 = x1.consensus_error().unwrap();
        assert!(e1 < e0);
    }

    #[test]
    fn downpour_matrices() {
        let send = downpour_send(3, 2).unwrap();
        assert!(!send.is_row_stochastic(1e-12));
        let out = send.apply_scalars(&[1.0, 10.0, 20.0, 30.0]).unwrap();
        assert_eq!(out[0], 21.0);
        assert_eq!(out[2], 20.0);

        let recv = downpour_receive(3, 2).unwrap();
        assert!(recv.is_row_stochastic(1e-12));
        let out = recv.apply_scalars(&[1.0, 10.0, 20.0, 30.0]).unwrap();
        assert_eq!(out[2], 1.0);
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn message_costs_match_paper_accounting() {
        assert_eq!(message_cost(MatrixKind::GossipExchange, 8), 1);
        assert_eq!(message_cost(MatrixKind::PerSynSync, 8), 16);
        assert_eq!(message_cost(MatrixKind::EasgdSync, 8), 16);
        assert_eq!(message_cost(MatrixKind::Identity, 8), 0);
    }
}
