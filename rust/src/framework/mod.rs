//! The paper's section-3 framework: distributed SGD as a sequence of
//! communication matrices.
//!
//! Section 3 shows that every distributed-SGD variant is the recursion
//!
//! ```text
//! x^(t+1/2) = x^(t) - η v^(t)          (local computation)
//! x^(t+1)   = K^(t) x^(t+1/2)          (communication)
//! ```
//!
//! over the stacked variable `x = [x̃, x_1 … x_M]` (master slot 0, then the
//! M workers), where each `K^(t)` is row-stochastic.  This module makes
//! that formalism executable:
//!
//! * [`comm_matrix::CommMatrix`] — sparse row representation, application
//!   to stacked states, composition, stochasticity checks.
//! * [`generators`] — the `K^(t)` sequences for PerSyn, EASGD, Downpour,
//!   AllReduce, and the GoSGD exchange (paper eq. 8).
//! * [`stacked::Stacked`] — the `[x̃, x_1 … x_M]` state vector.
//!
//! The matrix framework is used two ways: as an analysis tool (consensus
//! spectra, communication-cost accounting) and as a *cross-check* — the
//! integration tests replay a strategy's event log through its matrix
//! sequence and assert the algorithmic implementation produced the same
//! states (see `rust/tests/framework_crosscheck.rs`).
//!
//! ### A note on paper eq. (8)
//!
//! Equation 8 writes the GoSGD exchange as
//! `I + t·e_r e_sᵀ + (t − 1)·e_s e_sᵀ` with `t = w_s/(w_s+w_r)`, whose row
//! `r` sums to `1 + t` and row `s` scales the *sender's* variable — which
//! contradicts Algorithm 4 (the sender's `x_s` is unchanged; the receiver
//! blends convexly).  We implement the Algorithm-4-consistent matrix
//! `I + t·e_r e_sᵀ − t·e_r e_rᵀ` (row `r` = convex blend, row `s` =
//! identity), which is row-stochastic and matches the code the paper
//! actually runs; DESIGN.md records the discrepancy.

pub mod analysis;
pub mod comm_matrix;
pub mod generators;
pub mod stacked;

pub use comm_matrix::CommMatrix;
pub use stacked::Stacked;
