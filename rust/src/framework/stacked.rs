//! The stacked state `x = [x̃, x_1 … x_M]` of the section-3 framework.
//!
//! Slot 0 is the master/test variable `x̃`; slots `1..=M` are the workers'
//! local variables.  Decentralized strategies (GoSGD) never touch slot 0 —
//! their matrices keep it at identity and the "master" value is defined
//! post-hoc as the worker mean.

use crate::error::{Error, Result};
use crate::tensor::FlatVec;

/// Stacked parameter state for matrix-framework replay and analysis.
#[derive(Clone, Debug)]
pub struct Stacked {
    vecs: Vec<FlatVec>,
}

impl Stacked {
    /// All slots zero: `M + 1` slots of `vec_len` components.
    pub fn zeros(workers: usize, vec_len: usize) -> Self {
        Stacked { vecs: vec![FlatVec::zeros(vec_len); workers + 1] }
    }

    /// Replicate one initial vector into the master and all worker slots
    /// (the paper's common initialization `x_m = x`).
    pub fn replicate(workers: usize, init: &FlatVec) -> Self {
        Stacked { vecs: vec![init.clone(); workers + 1] }
    }

    /// Build from explicit slot vectors (slot 0 = master).
    pub fn from_vecs(vecs: Vec<FlatVec>) -> Result<Self> {
        let first_len = vecs
            .first()
            .map(|v| v.len())
            .ok_or_else(|| Error::shape("stacked state needs at least one slot"))?;
        if vecs.iter().any(|v| v.len() != first_len) {
            return Err(Error::shape("ragged stacked state"));
        }
        Ok(Stacked { vecs })
    }

    /// Number of slots (M + 1).
    pub fn dim(&self) -> usize {
        self.vecs.len()
    }

    /// Number of workers (slots minus the master).
    pub fn workers(&self) -> usize {
        self.vecs.len() - 1
    }

    /// Component count of each slot vector.
    pub fn vec_len(&self) -> usize {
        self.vecs[0].len()
    }

    pub fn get(&self, slot: usize) -> &FlatVec {
        &self.vecs[slot]
    }

    pub fn get_mut(&mut self, slot: usize) -> &mut FlatVec {
        &mut self.vecs[slot]
    }

    /// Master slot `x̃`.
    pub fn master(&self) -> &FlatVec {
        &self.vecs[0]
    }

    /// Worker slot `x_m` (1-based worker index `m ∈ 1..=M`).
    pub fn worker(&self, m: usize) -> &FlatVec {
        debug_assert!(m >= 1 && m < self.vecs.len());
        &self.vecs[m]
    }

    pub fn worker_mut(&mut self, m: usize) -> &mut FlatVec {
        debug_assert!(m >= 1 && m < self.vecs.len());
        &mut self.vecs[m]
    }

    /// Mean of the worker slots (the consensus target x̄ and the model the
    /// paper returns at line 8 of Algorithm 1).
    pub fn worker_mean(&self) -> Result<FlatVec> {
        let refs: Vec<&FlatVec> = self.vecs[1..].iter().collect();
        FlatVec::mean_of(&refs)
    }

    /// Consensus error `ε = Σ_m ‖x_m − x̄‖²` (paper section 5.2).
    pub fn consensus_error(&self) -> Result<f64> {
        let mean = self.worker_mean()?;
        let mut eps = 0.0;
        for v in &self.vecs[1..] {
            eps += v.dist_sq(&mean)?;
        }
        Ok(eps)
    }

    /// Apply the local-computation half-step `x_m ← x_m − η v_m` for one
    /// worker (`v` indexed by worker slot; slot 0 never receives gradients).
    pub fn local_step(&mut self, m: usize, grad: &FlatVec, eta: f32) -> Result<()> {
        if m == 0 || m >= self.vecs.len() {
            return Err(Error::shape(format!("local_step on slot {m}")));
        }
        self.vecs[m].axpy(-eta, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn replicate_makes_all_equal() {
        let mut rng = Rng::new(0);
        let init = FlatVec::randn(32, 1.0, &mut rng);
        let s = Stacked::replicate(4, &init);
        assert_eq!(s.dim(), 5);
        assert_eq!(s.workers(), 4);
        for i in 0..5 {
            assert_eq!(s.get(i).as_slice(), init.as_slice());
        }
        assert!(s.consensus_error().unwrap() < 1e-12);
    }

    #[test]
    fn worker_mean_excludes_master() {
        let mut s = Stacked::zeros(2, 2);
        *s.get_mut(0) = FlatVec::from_vec(vec![100.0, 100.0]); // master ignored
        *s.get_mut(1) = FlatVec::from_vec(vec![1.0, 3.0]);
        *s.get_mut(2) = FlatVec::from_vec(vec![3.0, 5.0]);
        let mean = s.worker_mean().unwrap();
        assert_eq!(mean.as_slice(), &[2.0, 4.0]);
    }

    #[test]
    fn consensus_error_formula() {
        let mut s = Stacked::zeros(2, 1);
        *s.worker_mut(1) = FlatVec::from_vec(vec![0.0]);
        *s.worker_mut(2) = FlatVec::from_vec(vec![2.0]);
        // mean = 1.0; eps = 1 + 1 = 2
        assert!((s.consensus_error().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn local_step_only_touches_one_worker() {
        let mut s = Stacked::replicate(3, &FlatVec::from_vec(vec![1.0, 1.0]));
        let g = FlatVec::from_vec(vec![1.0, 2.0]);
        s.local_step(2, &g, 0.5).unwrap();
        assert_eq!(s.worker(2).as_slice(), &[0.5, 0.0]);
        assert_eq!(s.worker(1).as_slice(), &[1.0, 1.0]);
        assert_eq!(s.master().as_slice(), &[1.0, 1.0]);
        assert!(s.local_step(0, &g, 0.5).is_err());
        assert!(s.local_step(4, &g, 0.5).is_err());
    }

    #[test]
    fn ragged_input_rejected() {
        let vecs = vec![FlatVec::zeros(2), FlatVec::zeros(3)];
        assert!(Stacked::from_vecs(vecs).is_err());
        assert!(Stacked::from_vecs(vec![]).is_err());
    }
}
