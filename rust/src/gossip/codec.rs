//! Payload codecs: compress the gossip message body on the wire.
//!
//! Sharding (PR 1) cut the *per-event* cost to one slice of the vector;
//! the codec layer cuts the cost of the slice itself.  GossipGraD (Daily
//! et al., 2018) and Jin et al. (2016) both identify communication volume
//! as the binding constraint of distributed SGD at scale — and because the
//! whole protocol lives in [`ProtocolCore`](crate::gossip::ProtocolCore),
//! a codec plugged in there is inherited by all three runtimes (sequential
//! engine, OS threads, discrete-event simulator) at once.
//!
//! Three codecs implement the [`Codec`] trait:
//!
//! * [`Dense`] — identity.  The payload ships as raw `f32`s; today's
//!   behavior, bit-exact.
//! * [`TopK`] — ship only the `k` coordinates with the largest
//!   *un-communicated change*, each as an `(index, value)` pair carrying
//!   the sender's **exact** current value.  The per-shard error-feedback
//!   buffer holds the last-shipped snapshot of every coordinate; the
//!   selection score `|x_i − shipped_i|` means mass dropped from one send
//!   (a coordinate that changed but did not make the top k) keeps
//!   accumulating score until a later send ships it.  On absorb, the
//!   receiver blends only the listed coordinates — untouched coordinates
//!   keep their value while the shard's sum weight still absorbs the
//!   sender's full shipped weight.  Weight conservation therefore stays
//!   exact; *value* transport is exact only up to the residual
//!   `x − shipped` tracked in the buffer (see the round-trip tests).
//! * [`QuantizeU8`] — per-shard affine u8 quantization: 1 byte per
//!   coordinate plus two `f32`s (`min`, `step`).  Dequantize-blend on
//!   absorb is deterministic, so every runtime blends the identical
//!   dequantized values and sum-weight conservation is bit-exact.
//!
//! Wire format per codec (payload body only; every message additionally
//! pays the shared header model of
//! [`wire_bytes_for`](crate::gossip::wire_bytes_for)):
//!
//! | codec   | body bytes                          | exactness                       |
//! |---------|-------------------------------------|---------------------------------|
//! | `dense` | `4·len`                             | bit-exact                       |
//! | `topK`  | `8·k` (`k ≥ len` ships dense `4·len`) | exact values, partial coverage |
//! | `q8`    | `len + 8`                           | ±`(max−min)/510` per coordinate |
//!
//! [`CodecSpec`] is the plain-data description used by configuration and
//! the CLI (`gosgd:P:SHARDS:CODEC` accepts `dense`, `q8`, `topK` as in
//! `top32`); [`CodecSpec::build`] materializes the trait object the core
//! encodes with.
//!
//! **Storage**: every encoded body lives in pool-recyclable storage — the
//! dense form in a (possibly pooled) [`FlatVec`], the q8 codes and top-k
//! index/value arrays in [`PoolVec`]s.  [`Codec::encode_with`] takes an
//! optional [`BufferPool`]; when one is supplied (the protocol core's, on
//! the hot path) a steady-state encode performs **zero heap allocations**:
//! output buffers come from the pool and the consumed input snapshot's
//! storage flows straight back into it.  Without a pool everything
//! degrades to plain allocation ([`Codec::encode`]).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::tensor::{self, BufferPool, FlatVec, PoolVec, Poolable};

/// Plain-data codec description: parseable, comparable, copyable — the
/// form carried by configs, CLIs and reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CodecSpec {
    /// Identity: raw `f32` payloads (the paper's wire format).
    #[default]
    Dense,
    /// Keep the `k` coordinates with the largest un-shipped change.
    TopK { k: usize },
    /// Per-shard affine u8 quantization.
    QuantizeU8,
}

impl CodecSpec {
    /// Parse the CLI token: `dense`, `q8`, or `top<K>` (e.g. `top32`).
    ///
    /// ```
    /// use gosgd::gossip::CodecSpec;
    ///
    /// assert_eq!(CodecSpec::parse("dense").unwrap(), CodecSpec::Dense);
    /// assert_eq!(CodecSpec::parse("top32").unwrap(), CodecSpec::TopK { k: 32 });
    /// assert_eq!(CodecSpec::parse("q8").unwrap().label(), "q8");
    /// assert!(CodecSpec::parse("top0").is_err());
    /// assert!(CodecSpec::parse("zstd").is_err());
    /// ```
    pub fn parse(text: &str) -> Result<CodecSpec> {
        match text {
            "dense" => Ok(CodecSpec::Dense),
            "q8" => Ok(CodecSpec::QuantizeU8),
            _ => {
                if let Some(k) = text.strip_prefix("top") {
                    let k: usize = k
                        .parse()
                        .map_err(|_| Error::config(format!("cannot parse codec {text:?}")))?;
                    if k == 0 {
                        return Err(Error::config("top-k codec needs k >= 1"));
                    }
                    Ok(CodecSpec::TopK { k })
                } else {
                    Err(Error::config(format!(
                        "unknown codec {text:?} (expected dense | q8 | top<K>)"
                    )))
                }
            }
        }
    }

    /// The CLI token / report label for this codec.
    pub fn label(&self) -> String {
        match self {
            CodecSpec::Dense => "dense".into(),
            CodecSpec::TopK { k } => format!("top{k}"),
            CodecSpec::QuantizeU8 => "q8".into(),
        }
    }

    /// Materialize the encoder.
    pub fn build(&self) -> CodecRef {
        match *self {
            CodecSpec::Dense => Arc::new(Dense),
            CodecSpec::TopK { k } => Arc::new(TopK { k }),
            CodecSpec::QuantizeU8 => Arc::new(QuantizeU8),
        }
    }

    /// Whether this codec keeps per-shard encoder state in the core (only
    /// [`CodecSpec::TopK`]'s error-feedback buffer today).
    pub fn stateful(&self) -> bool {
        matches!(self, CodecSpec::TopK { .. })
    }

    /// Encoded payload-body bytes for a shard of `len` coordinates —
    /// the planning-side mirror of [`EncodedPayload::payload_wire_bytes`]
    /// (used to match bandwidth across codecs before running anything).
    pub fn payload_wire_bytes(&self, len: usize) -> usize {
        match *self {
            CodecSpec::Dense => 4 * len,
            // k >= len degenerates to a dense body (see TopK::encode).
            CodecSpec::TopK { k } if k >= len => 4 * len,
            CodecSpec::TopK { k } => 8 * k,
            CodecSpec::QuantizeU8 => len + 8,
        }
    }
}

/// A payload codec: turns one shard's raw coordinates into the form that
/// goes on the wire.  Implementations must be deterministic — all three
/// runtimes drive the same cores and the cross-runtime equivalence tests
/// demand identical trajectories.
pub trait Codec: Send + Sync + std::fmt::Debug {
    /// The plain-data description of this codec.
    fn spec(&self) -> CodecSpec;

    /// Encode one shard payload.  `residual` is the caller-owned
    /// error-feedback state for this shard: empty for stateless codecs,
    /// exactly `payload.len()` entries (the last-shipped snapshot) for
    /// [`TopK`], updated in place.  `pool` supplies recycled storage for
    /// the encoded body (and receives the consumed snapshot's storage
    /// back, if the snapshot was pooled); `None` falls back to plain
    /// allocation.
    fn encode_with(
        &self,
        payload: FlatVec,
        residual: &mut [f32],
        pool: Option<&Arc<BufferPool>>,
    ) -> EncodedPayload;

    /// [`Codec::encode_with`] without a pool (tests, cold paths).
    fn encode(&self, payload: FlatVec, residual: &mut [f32]) -> EncodedPayload {
        self.encode_with(payload, residual, None)
    }
}

/// A body buffer of `len` elements filled by `f(index)` in one write
/// pass: recycled from `pool` when one is given, freshly allocated
/// otherwise — never zeroed first.
fn body_from_fn<T: Poolable>(
    pool: Option<&Arc<BufferPool>>,
    len: usize,
    f: impl FnMut(usize) -> T,
) -> PoolVec<T> {
    match pool {
        Some(pool) => BufferPool::acquire_with(pool, len, f),
        None => PoolVec::from_vec((0..len).map(f).collect()),
    }
}

/// A body buffer copying `src` in one pass (same pool/no-pool split).
fn body_copy<T: Poolable>(pool: Option<&Arc<BufferPool>>, src: &[T]) -> PoolVec<T> {
    match pool {
        Some(pool) => BufferPool::acquire_copy(pool, src),
        None => PoolVec::from_vec(src.to_vec()),
    }
}

/// Shared handle to a codec (protocol cores are `Clone`).
pub type CodecRef = Arc<dyn Codec>;

/// Identity codec: the payload ships as raw `f32`s.
#[derive(Clone, Copy, Debug, Default)]
pub struct Dense;

impl Codec for Dense {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Dense
    }

    fn encode_with(
        &self,
        payload: FlatVec,
        _residual: &mut [f32],
        _pool: Option<&Arc<BufferPool>>,
    ) -> EncodedPayload {
        // The snapshot ships as-is; if it was pooled its storage returns
        // to the pool when the receiver drops the message.
        EncodedPayload::Dense(payload)
    }
}

/// Top-k sparsifier with error feedback.
///
/// Ships `(index, value)` pairs for the `k` coordinates whose current
/// value differs most from the value last shipped for that coordinate
/// (first send: from zero, i.e. plain largest-magnitude).  The shipped
/// values are the sender's exact current coordinates, so every blend the
/// receiver performs is the protocol's exact convex blend — sparsity only
/// limits *which* coordinates move per message, and the residual buffer
/// guarantees a persistently-changed coordinate cannot be starved: its
/// score grows until it wins a later send.
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    /// Coordinates kept per shard message (`>= 1`).
    pub k: usize,
}

impl Codec for TopK {
    fn spec(&self) -> CodecSpec {
        CodecSpec::TopK { k: self.k }
    }

    fn encode_with(
        &self,
        payload: FlatVec,
        residual: &mut [f32],
        pool: Option<&Arc<BufferPool>>,
    ) -> EncodedPayload {
        assert!(self.k >= 1, "top-k codec needs k >= 1");
        let n = payload.len();
        if n == 0 {
            return EncodedPayload::Dense(payload);
        }
        assert_eq!(
            residual.len(),
            n,
            "top-k error-feedback buffer length {} vs payload {}",
            residual.len(),
            n
        );
        if self.k >= n {
            // Degenerate: everything fits — ship dense, snapshot all.
            residual.copy_from_slice(payload.as_slice());
            return EncodedPayload::Dense(payload);
        }
        let xs = payload.as_slice();
        // O(n) selection over a pooled index scratch: partition so the k
        // largest |x - shipped| scores come first, then sort only the k
        // winners for deterministic ascending index order.  total_cmp so
        // NaN payloads cannot panic the protocol.
        let mut order: PoolVec<u32> = body_from_fn(pool, n, |i| i as u32);
        {
            let score = |i: u32| (xs[i as usize] - residual[i as usize]).abs();
            order
                .as_mut_slice()
                .select_nth_unstable_by(self.k - 1, |&a, &b| score(b).total_cmp(&score(a)));
        }
        let mut indices: PoolVec<u32> = body_copy(pool, &order.as_slice()[..self.k]);
        indices.as_mut_slice().sort_unstable();
        let values: PoolVec<f32> =
            body_from_fn(pool, self.k, |j| xs[indices.as_slice()[j] as usize]);
        // Shipped coordinates are now fully communicated; the rest keep
        // their accumulated residual |x - shipped| for later sends.
        for (&i, &v) in indices.as_slice().iter().zip(values.as_slice()) {
            residual[i as usize] = v;
        }
        // `order` and the consumed snapshot drop here — their storage
        // flows back to the pool for the next exchange.
        EncodedPayload::TopK { len: n, indices, values }
    }
}

/// The q8 range scan: `(min, max, all_finite)` over a shard in one pass.
///
/// Eight-wide chunks with eight partial min/max accumulators and a
/// per-lane finite flag, matching the [`tensor`] kernels' width so LLVM
/// keeps full-width vector `min`/`max` in flight instead of serializing
/// on one register.  Both reductions are order-independent (`f32::min`/
/// `max` are commutative-associative over any multiset up to the sign of
/// zero, and `x − (−0.0)` ≡ `x − 0.0` bit-for-bit), and `&` is exact, so
/// the chunked scan is bit-identical to the scalar loop it replaced.
///
/// Finiteness is tracked explicitly: `f32::min`/`max` *ignore* NaN
/// operands, so a NaN coordinate would otherwise slip past a
/// min/max-finiteness check and be silently quantized to `min`.
fn min_max_finite(xs: &[f32]) -> (f32, f32, bool) {
    let mut lo = [f32::INFINITY; 8];
    let mut hi = [f32::NEG_INFINITY; 8];
    let mut fin = [true; 8];
    let mut chunks = xs.chunks_exact(8);
    for c in &mut chunks {
        for i in 0..8 {
            fin[i] &= c[i].is_finite();
            lo[i] = lo[i].min(c[i]);
            hi[i] = hi[i].max(c[i]);
        }
    }
    let (mut min, mut max, mut finite) = (f32::INFINITY, f32::NEG_INFINITY, true);
    for i in 0..8 {
        finite &= fin[i];
        min = min.min(lo[i]);
        max = max.max(hi[i]);
    }
    for &v in chunks.remainder() {
        finite &= v.is_finite();
        min = min.min(v);
        max = max.max(v);
    }
    (min, max, finite)
}

/// Per-shard affine u8 quantizer: `code = round((x − min)/step)`,
/// `step = (max − min)/255`.  A constant shard (or an empty one) encodes
/// with `step = 0` and round-trips bit-exactly; a shard containing a
/// non-finite value falls back to a dense body rather than poisoning the
/// whole range.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantizeU8;

impl Codec for QuantizeU8 {
    fn spec(&self) -> CodecSpec {
        CodecSpec::QuantizeU8
    }

    fn encode_with(
        &self,
        payload: FlatVec,
        _residual: &mut [f32],
        pool: Option<&Arc<BufferPool>>,
    ) -> EncodedPayload {
        let (min, max, finite) = min_max_finite(payload.as_slice());
        if !(finite && min.is_finite() && max.is_finite()) {
            // Empty or non-finite payloads: lossless fallback.
            return EncodedPayload::Dense(payload);
        }
        let range = max - min;
        let step = range / 255.0;
        let inv = if range > 0.0 { 255.0 / range } else { 0.0 };
        let xs = payload.as_slice();
        let codes: PoolVec<u8> = body_from_fn(pool, xs.len(), |i| {
            ((xs[i] - min) * inv).round().clamp(0.0, 255.0) as u8
        });
        // The consumed snapshot drops here; pooled storage recycles.
        EncodedPayload::QuantU8 { min, step, codes }
    }
}

/// One shard payload in its on-the-wire form.
///
/// The decode side is fused into [`EncodedPayload::blend_into`] — the
/// absorb transition never materializes a dense intermediate for the
/// sparse/quantized forms.  Every body lives in pool-recyclable storage:
/// dropping a payload whose buffers came from a [`BufferPool`] returns
/// their capacity for the next exchange.
#[derive(Clone, Debug, PartialEq)]
pub enum EncodedPayload {
    /// Raw `f32` coordinates (also the fallback the other codecs degrade
    /// to on degenerate input).
    Dense(FlatVec),
    /// Sparse `(index, value)` pairs over a shard of `len` coordinates;
    /// indices are strictly ascending and unique.
    TopK {
        len: usize,
        indices: PoolVec<u32>,
        values: PoolVec<f32>,
    },
    /// Affine u8: `value_i = min + step · codes[i]`.
    QuantU8 {
        min: f32,
        step: f32,
        codes: PoolVec<u8>,
    },
}

impl EncodedPayload {
    /// Number of shard coordinates this payload covers (the decoded
    /// length, not the number of values carried).
    pub fn coord_count(&self) -> usize {
        match self {
            EncodedPayload::Dense(v) => v.len(),
            EncodedPayload::TopK { len, .. } => *len,
            EncodedPayload::QuantU8 { codes, .. } => codes.len(),
        }
    }

    /// Payload-body bytes on the wire (headers are accounted separately —
    /// see [`wire_bytes_for`](crate::gossip::wire_bytes_for)).
    pub fn payload_wire_bytes(&self) -> usize {
        match self {
            EncodedPayload::Dense(v) => 4 * v.len(),
            EncodedPayload::TopK { indices, .. } => 8 * indices.len(),
            EncodedPayload::QuantU8 { codes, .. } => codes.len() + 8,
        }
    }

    /// Whether queue coalescing may fold this payload with another of the
    /// same shard by decoding.  Sparse payloads must not fold: they carry
    /// no value for the unlisted coordinates ("receiver keeps its own"),
    /// so any dense stand-in would corrupt them.
    pub fn coalescible(&self) -> bool {
        !matches!(self, EncodedPayload::TopK { .. })
    }

    /// Direct access to a dense body, if this is one.
    pub fn as_dense(&self) -> Option<&FlatVec> {
        match self {
            EncodedPayload::Dense(v) => Some(v),
            _ => None,
        }
    }

    /// Materialize into a caller-owned slice of exactly `coord_count()`
    /// elements — the allocation-free decode used by queue coalescing's
    /// pooled scratch.  For [`EncodedPayload::TopK`] the unlisted
    /// coordinates decode to 0 — that is the *serialization* round trip,
    /// not the absorb semantics (absorb leaves them alone; use
    /// [`EncodedPayload::blend_into`]).
    pub fn decode_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.coord_count(), "decode target vs payload");
        match self {
            EncodedPayload::Dense(v) => out.copy_from_slice(v.as_slice()),
            EncodedPayload::TopK { indices, values, .. } => {
                out.fill(0.0);
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    out[i as usize] = v;
                }
            }
            EncodedPayload::QuantU8 { min, step, codes } => {
                // Eight-wide dequantize, tensor-kernel style: identical
                // per-element arithmetic, so chunking is bit-invisible.
                let mut oc = out.chunks_exact_mut(8);
                let mut cc = codes.as_slice().chunks_exact(8);
                for (os, cs) in (&mut oc).zip(&mut cc) {
                    for i in 0..8 {
                        os[i] = min + step * cs[i] as f32;
                    }
                }
                for (o, &c) in oc.into_remainder().iter_mut().zip(cc.remainder()) {
                    *o = min + step * c as f32;
                }
            }
        }
    }

    /// Materialize a fresh dense vector ([`EncodedPayload::decode_into`]
    /// with its own allocation; tests and cold paths).
    pub fn decode(&self) -> FlatVec {
        let mut out = FlatVec::zeros(self.coord_count());
        self.decode_into(out.as_mut_slice());
        out
    }

    /// The absorb kernel: blend this payload into the shard's coordinate
    /// range `x` (exactly `coord_count()` elements) with coefficient `t`
    /// — `x_i += t·(v_i − x_i)` for every coordinate the payload carries.
    /// Coordinates a sparse payload does not list keep their value.
    pub fn blend_into(&self, x: &mut [f32], t: f32) {
        debug_assert_eq!(x.len(), self.coord_count(), "payload vs shard range");
        match self {
            EncodedPayload::Dense(v) => tensor::mix_into(x, v.as_slice(), t),
            EncodedPayload::TopK { indices, values, .. } => {
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    let xi = &mut x[i as usize];
                    *xi += t * (v - *xi);
                }
            }
            EncodedPayload::QuantU8 { min, step, codes } => {
                // Fused dequantize-blend, eight-wide: the absorb-side hot
                // loop (every q8 message decodes through here exactly
                // once).  Same scalar expression per element as before —
                // bit-identical trajectories across all runtimes.
                let mut xc = x.chunks_exact_mut(8);
                let mut cc = codes.as_slice().chunks_exact(8);
                for (xs, cs) in (&mut xc).zip(&mut cc) {
                    for i in 0..8 {
                        let v = min + step * cs[i] as f32;
                        xs[i] += t * (v - xs[i]);
                    }
                }
                for (xi, &c) in xc.into_remainder().iter_mut().zip(cc.remainder()) {
                    let v = min + step * c as f32;
                    *xi += t * (v - *xi);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> FlatVec {
        FlatVec::randn(n, 1.0, rng)
    }

    #[test]
    fn spec_parse_and_label_round_trip() {
        for spec in [CodecSpec::Dense, CodecSpec::TopK { k: 32 }, CodecSpec::QuantizeU8] {
            assert_eq!(CodecSpec::parse(&spec.label()).unwrap(), spec);
            assert_eq!(spec.build().spec(), spec);
        }
        assert!(CodecSpec::parse("top0").is_err());
        assert!(CodecSpec::parse("topx").is_err());
        assert!(CodecSpec::parse("zstd").is_err());
        assert!(CodecSpec::parse("").is_err());
    }

    #[test]
    fn wire_size_table() {
        // The documented per-codec body sizes, and their planning mirror.
        let n = 1000;
        let mut rng = Rng::new(1);
        let payload = randn(&mut rng, n);
        let mut residual = vec![0.0f32; n];
        let dense = Dense.encode(payload.clone(), &mut []);
        assert_eq!(dense.payload_wire_bytes(), 4 * n);
        let topk = TopK { k: 25 }.encode(payload.clone(), &mut residual);
        assert_eq!(topk.payload_wire_bytes(), 8 * 25);
        let q8 = QuantizeU8.encode(payload.clone(), &mut []);
        assert_eq!(q8.payload_wire_bytes(), n + 8);
        for spec in [CodecSpec::Dense, CodecSpec::TopK { k: 25 }, CodecSpec::QuantizeU8] {
            let enc = spec.build().encode(payload.clone(), &mut vec![0.0f32; n]);
            assert_eq!(
                enc.payload_wire_bytes(),
                spec.payload_wire_bytes(n),
                "planning mirror diverged for {}",
                spec.label()
            );
        }
    }

    #[test]
    fn dense_round_trip_is_bit_exact() {
        check("dense round trip", 20, |rng| {
            let n = 1 + rng.below(300) as usize;
            let payload = randn(rng, n);
            let enc = Dense.encode(payload.clone(), &mut []);
            assert_eq!(enc.decode().as_slice(), payload.as_slice());
            assert_eq!(enc.coord_count(), n);
        });
    }

    #[test]
    fn quantize_round_trip_within_half_step() {
        check("q8 round trip", 30, |rng| {
            let n = 2 + rng.below(400) as usize;
            let payload = randn(rng, n);
            let enc = QuantizeU8.encode(payload.clone(), &mut []);
            let dec = enc.decode();
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in payload.as_slice() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let tol = (hi - lo) / 255.0 / 2.0 + 1e-6;
            for (a, b) in payload.as_slice().iter().zip(dec.as_slice()) {
                assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
            }
        });
    }

    #[test]
    fn quantize_constant_and_degenerate_inputs() {
        // A constant shard round-trips bit-exactly (step 0).
        let payload = FlatVec::from_vec(vec![3.5; 64]);
        let enc = QuantizeU8.encode(payload.clone(), &mut []);
        assert_eq!(enc.decode().as_slice(), payload.as_slice());
        // Non-finite input falls back to a lossless dense body — including
        // NaN, which `f32::min`/`max` would silently skip over.
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let payload = FlatVec::from_vec(vec![1.0, bad, 2.0]);
            let enc = QuantizeU8.encode(payload.clone(), &mut []);
            assert!(enc.as_dense().is_some(), "expected dense fallback for {bad}");
            assert_eq!(
                enc.decode().as_slice()[0],
                1.0,
                "fallback must be lossless"
            );
        }
        // Empty payload: dense fallback, zero coordinates.
        let enc = QuantizeU8.encode(FlatVec::zeros(0), &mut []);
        assert_eq!(enc.coord_count(), 0);
    }

    #[test]
    fn quantize_endpoints_are_exact() {
        let payload = FlatVec::from_vec(vec![-2.0, 0.5, 6.0]);
        let enc = QuantizeU8.encode(payload, &mut []);
        let dec = enc.decode();
        assert_eq!(dec.as_slice()[0], -2.0, "min maps to code 0 exactly");
        let hi = dec.as_slice()[2];
        assert!((hi - 6.0).abs() < 1e-4, "max maps to code 255: {hi}");
    }

    #[test]
    fn topk_ships_exact_values_and_tracks_the_rest() {
        // First send (zeroed buffer): selection is by raw magnitude.
        let payload = FlatVec::from_vec(vec![0.1, -5.0, 0.2, 4.0, -0.3, 0.0]);
        let mut residual = vec![0.0f32; 6];
        let enc = TopK { k: 2 }.encode(payload.clone(), &mut residual);
        match &enc {
            EncodedPayload::TopK { len, indices, values } => {
                assert_eq!(*len, 6);
                assert_eq!(indices.as_slice(), &[1, 3], "largest magnitudes, ascending");
                assert_eq!(values.as_slice(), &[-5.0, 4.0], "exact current values");
            }
            other => panic!("expected sparse payload, got {other:?}"),
        }
        // Shipped coordinates are snapshotted; the rest stay un-shipped,
        // so their full value remains pending residual (shipped 0).
        assert_eq!(residual, vec![0.0, -5.0, 0.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_residual_rotates_starved_coordinates_in() {
        // A coordinate that keeps changing but never wins on raw size must
        // eventually ship: its |x - shipped| score only grows.
        let k = 1;
        let mut residual = vec![0.0f32; 3];
        // Coordinate 0 is huge but static after the first send; coordinate
        // 2 drifts by 0.4 per send.
        let mut drift = 0.0f32;
        let first = TopK { k }.encode(FlatVec::from_vec(vec![10.0, 0.0, drift]), &mut residual);
        match first {
            EncodedPayload::TopK { ref indices, .. } => assert_eq!(indices.as_slice(), &[0]),
            _ => panic!(),
        }
        let mut shipped2 = false;
        for _ in 0..30 {
            drift += 0.4;
            let enc = TopK { k }.encode(FlatVec::from_vec(vec![10.0, 0.0, drift]), &mut residual);
            if let EncodedPayload::TopK { indices, values, .. } = enc {
                if indices.as_slice() == [2] {
                    assert_eq!(values.as_slice(), &[drift], "exact value at ship time");
                    shipped2 = true;
                    break;
                }
            }
        }
        assert!(shipped2, "drifting coordinate was starved by the static one");
    }

    #[test]
    fn topk_round_trip_is_residual_bounded() {
        // The serialization round trip: at shipped coordinates the decode
        // is bit-exact; everywhere else the deviation from the payload is
        // exactly the pending residual |x - shipped| tracked in the buffer.
        check("topk residual bound", 30, |rng| {
            let n = 4 + rng.below(200) as usize;
            let k = 1 + rng.below(n as u64 / 2) as usize;
            let mut residual: Vec<f32> = randn(rng, n).into_vec();
            let before = residual.clone();
            let payload = randn(rng, n);
            let enc = TopK { k }.encode(payload.clone(), &mut residual);
            let (indices, values) = match &enc {
                EncodedPayload::TopK { indices, values, .. } => (indices, values),
                other => panic!("expected sparse, got {other:?}"),
            };
            assert_eq!(indices.len(), k);
            for w in indices.windows(2) {
                assert!(w[0] < w[1], "indices ascending and unique");
            }
            let mut sparse = vec![false; n];
            for (&i, &v) in indices.iter().zip(values.iter()) {
                assert_eq!(v, payload.as_slice()[i as usize], "exact at shipped coords");
                assert_eq!(residual[i as usize], v, "buffer snapshots the ship");
                sparse[i as usize] = true;
            }
            for i in 0..n {
                if !sparse[i] {
                    // Un-shipped: buffer unchanged, deviation fully tracked.
                    assert_eq!(residual[i], before[i]);
                }
            }
        });
    }

    #[test]
    fn topk_k_at_least_len_degenerates_to_dense() {
        let payload = FlatVec::from_vec(vec![1.0, 2.0, 3.0]);
        let mut residual = vec![0.0f32; 3];
        let enc = TopK { k: 8 }.encode(payload.clone(), &mut residual);
        assert_eq!(enc.as_dense().unwrap().as_slice(), payload.as_slice());
        assert_eq!(residual, vec![1.0, 2.0, 3.0], "everything snapshotted");
    }

    #[test]
    fn blend_into_matches_sequential_semantics() {
        let t = 0.25f32;
        // Dense blend == mix kernel (trivially), q8 blends the dequantized
        // values, topk leaves unlisted coordinates alone.
        let payload = FlatVec::from_vec(vec![4.0, -2.0, 8.0, 0.0]);
        let base = [1.0f32, 1.0, 1.0, 1.0];
        let mut x = base;
        EncodedPayload::Dense(payload.clone()).blend_into(&mut x, t);
        for (i, &xi) in x.iter().enumerate() {
            let want = base[i] + t * (payload.as_slice()[i] - base[i]);
            assert!((xi - want).abs() < 1e-6);
        }
        let enc = QuantizeU8.encode(payload.clone(), &mut []);
        let deq = enc.decode();
        let mut x = base;
        enc.blend_into(&mut x, t);
        for (i, &xi) in x.iter().enumerate() {
            let want = base[i] + t * (deq.as_slice()[i] - base[i]);
            assert!((xi - want).abs() < 1e-6, "q8 blend must use dequantized values");
        }
        let mut residual = vec![0.0f32; 4];
        let enc = TopK { k: 2 }.encode(payload, &mut residual);
        let mut x = base;
        enc.blend_into(&mut x, t);
        assert!((x[0] - (1.0 + t * 3.0)).abs() < 1e-6, "listed coord blends");
        assert!((x[2] - (1.0 + t * 7.0)).abs() < 1e-6, "listed coord blends");
        assert_eq!(x[1], 1.0, "unlisted coord keeps its value");
        assert_eq!(x[3], 1.0, "unlisted coord keeps its value");
    }

    #[test]
    fn only_sparse_payloads_refuse_coalescing() {
        let payload = FlatVec::from_vec(vec![1.0; 8]);
        assert!(EncodedPayload::Dense(payload.clone()).coalescible());
        assert!(QuantizeU8.encode(payload.clone(), &mut []).coalescible());
        let mut residual = vec![0.0f32; 8];
        assert!(!TopK { k: 2 }.encode(payload, &mut residual).coalescible());
    }

    #[test]
    fn topk_selection_matches_sort_based_reference() {
        // The O(n) `select_nth_unstable_by` pick must produce exactly the
        // output of the straightforward full-sort reference: sort every
        // index by descending |x - shipped| score, keep the first k, ship
        // them in ascending index order with exact current values.
        check("topk selection == full-sort reference", 40, |rng| {
            let n = 4 + rng.below(300) as usize;
            let k = 1 + rng.below(n as u64 - 1) as usize;
            let payload = randn(rng, n);
            let shipped: Vec<f32> = randn(rng, n).into_vec();
            let xs = payload.as_slice();

            // Reference: full sort (descending score, total order).
            let score = |i: u32| (xs[i as usize] - shipped[i as usize]).abs();
            let mut by_score: Vec<u32> = (0..n as u32).collect();
            by_score.sort_by(|&a, &b| score(b).total_cmp(&score(a)));
            let mut want_idx: Vec<u32> = by_score[..k].to_vec();
            want_idx.sort_unstable();
            let want_val: Vec<f32> = want_idx.iter().map(|&i| xs[i as usize]).collect();
            let mut want_residual = shipped.clone();
            for (&i, &v) in want_idx.iter().zip(&want_val) {
                want_residual[i as usize] = v;
            }

            let mut residual = shipped.clone();
            match (TopK { k }).encode(payload, &mut residual) {
                EncodedPayload::TopK { len, indices, values } => {
                    assert_eq!(len, n);
                    assert_eq!(indices.as_slice(), want_idx.as_slice(), "n={n} k={k}");
                    assert_eq!(values.as_slice(), want_val.as_slice(), "n={n} k={k}");
                    assert_eq!(residual, want_residual, "n={n} k={k}");
                }
                other => panic!("expected sparse payload, got {other:?}"),
            }
        });
    }

    #[test]
    fn pooled_encode_is_byte_identical_to_unpooled() {
        // Pooling is storage, not semantics: the encoded body must be
        // identical with and without a pool, for every codec.
        let pool = BufferPool::shared();
        let mut rng = Rng::new(0xB0);
        let n = 257;
        let payload = randn(&mut rng, n);
        for spec in [CodecSpec::Dense, CodecSpec::TopK { k: 9 }, CodecSpec::QuantizeU8] {
            let codec = spec.build();
            let mut r1 = vec![0.5f32; n];
            let mut r2 = r1.clone();
            let plain = codec.encode_with(payload.clone(), &mut r1, None);
            let pooled = codec.encode_with(payload.clone(), &mut r2, Some(&pool));
            assert_eq!(plain, pooled, "{}", spec.label());
            assert_eq!(r1, r2, "{}", spec.label());
        }
    }

    #[test]
    fn pooled_encode_recycles_the_consumed_snapshot() {
        // The snapshot handed to a compressing codec dies inside encode;
        // its storage must come back out of the pool for the next one.
        let pool = BufferPool::shared();
        let n = 64;
        let snap = FlatVec::pooled(&pool, n);
        let ptr = snap.as_slice().as_ptr();
        let enc = QuantizeU8.encode_with(snap, &mut [], Some(&pool));
        assert!(matches!(enc, EncodedPayload::QuantU8 { .. }));
        assert!(pool.stats().recycled >= 1, "snapshot storage not recycled");
        let next = FlatVec::pooled(&pool, n);
        assert_eq!(next.as_slice().as_ptr(), ptr, "next snapshot reuses storage");
    }

    #[test]
    fn q8_chunked_kernels_match_naive_reference_property() {
        // The eight-wide q8 kernels (range scan, dequantize, fused
        // dequantize-blend) against scalar per-element reference loops.
        // The chunked loops perform the identical scalar arithmetic and
        // the min/max reduction is order-independent, so agreement is
        // bit-exact — covering empty, pure-tail, exact-chunk and
        // chunk+tail lengths, plus NaN/∞ lanes for the finite-flag AND.
        check("q8 chunked == naive reference", 50, |rng| {
            let n = rng.below(70) as usize;
            let mut xs: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            // One case in four poisons a random lane: the chunked scan
            // must reach the same dense-fallback verdict as the scalar.
            if n > 0 && rng.below(4) == 0 {
                let bad = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
                xs[rng.below(n as u64) as usize] = bad[rng.below(3) as usize];
            }

            // Range scan vs the scalar fold it replaced.
            let (mut min, mut max, mut finite) = (f32::INFINITY, f32::NEG_INFINITY, true);
            for &v in &xs {
                finite &= v.is_finite();
                min = min.min(v);
                max = max.max(v);
            }
            let got = min_max_finite(&xs);
            assert_eq!(got.2, finite, "finite flag n={n}");
            if finite && min.is_finite() {
                assert_eq!(got.0, min, "min n={n}");
                assert_eq!(got.1, max, "max n={n}");
            }

            let enc = QuantizeU8.encode(FlatVec::from_vec(xs.clone()), &mut []);
            if !(finite && min.is_finite() && max.is_finite()) {
                assert!(enc.as_dense().is_some(), "expected dense fallback n={n}");
                return;
            }
            let (emin, estep, codes) = match &enc {
                EncodedPayload::QuantU8 { min, step, codes } => (*min, *step, codes),
                other => panic!("expected q8 payload, got {other:?}"),
            };

            // Chunked decode_into vs the scalar dequantize.
            let mut out = vec![7.0f32; n];
            enc.decode_into(&mut out);
            for i in 0..n {
                let want = emin + estep * codes.as_slice()[i] as f32;
                assert_eq!(out[i], want, "decode n={n} i={i}");
            }

            // Chunked blend_into vs the scalar fused dequantize-blend.
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let t = rng.f32();
            let mut got = base.clone();
            enc.blend_into(&mut got, t);
            for i in 0..n {
                let v = emin + estep * codes.as_slice()[i] as f32;
                let want = base[i] + t * (v - base[i]);
                assert_eq!(got[i], want, "blend n={n} i={i}");
            }
        });
    }

    #[test]
    fn decode_into_matches_decode_for_every_codec() {
        check("decode_into == decode", 20, |rng| {
            let n = 2 + rng.below(200) as usize;
            let payload = randn(rng, n);
            let mut residual = vec![0.0f32; n];
            for spec in [CodecSpec::Dense, CodecSpec::TopK { k: 3 }, CodecSpec::QuantizeU8] {
                let enc = spec.build().encode(payload.clone(), &mut residual);
                let dec = enc.decode();
                let mut out = vec![7.0f32; enc.coord_count()];
                enc.decode_into(&mut out);
                assert_eq!(out.as_slice(), dec.as_slice(), "{}", spec.label());
            }
        });
    }
}
