//! The gossip message: `(x_s, w_s)` plus accounting metadata.
//!
//! The paper (section 4.1) encapsulates the sender's parameter vector and
//! its halved weight in a single message.  The parameter payload is shared
//! via `Arc` so pushing one snapshot to several queues (or keeping it in a
//! queue while the sender keeps training) never copies the vector — a real
//! concern at 10⁶-10⁸ floats.
//!
//! With sharded exchange ([`crate::gossip::shard`]) a message may carry
//! only one contiguous slice of the vector; the `shard` field records
//! which slice, and the shipped weight is that shard's *shard-local* sum
//! weight.  The classic whole-vector message is the `num_shards == 1`
//! special case, so nothing downstream needs to branch on "sharded or
//! not" except the blend itself.

use std::sync::Arc;

use crate::gossip::shard::Shard;
use crate::gossip::weights::SumWeight;
use crate::tensor::FlatVec;

/// One gossip message from `sender` (paper Algorithm 4, `PushMessage`).
#[derive(Clone, Debug)]
pub struct Message {
    /// Snapshot of the sender's parameters at send time — the whole vector
    /// for a full message, or just `shard.len` elements for a shard.
    pub params: Arc<FlatVec>,
    /// The sender's halved (shard-local) weight shipped with the snapshot.
    pub weight: SumWeight,
    /// Worker id of the sender (diagnostics / staleness accounting).
    pub sender: usize,
    /// Sender's local step count at send time (staleness accounting).
    pub sent_at_step: u64,
    /// Which slice of the parameter vector the payload covers.
    pub shard: Shard,
}

impl Message {
    /// Whole-vector message (the paper's protocol).
    pub fn new(params: Arc<FlatVec>, weight: SumWeight, sender: usize, sent_at_step: u64) -> Self {
        let shard = Shard::full(params.len());
        Message { params, weight, sender, sent_at_step, shard }
    }

    /// Shard message: `params` holds only the shard's `shard.len` elements.
    pub fn for_shard(
        params: Arc<FlatVec>,
        weight: SumWeight,
        sender: usize,
        sent_at_step: u64,
        shard: Shard,
    ) -> Self {
        assert_eq!(
            params.len(),
            shard.len,
            "shard payload length {} vs descriptor len {}",
            params.len(),
            shard.len
        );
        Message { params, weight, sender, sent_at_step, shard }
    }

    /// Payload size in bytes (throughput accounting).
    pub fn wire_bytes(&self) -> usize {
        wire_bytes_for(self.params.len(), !self.shard.is_full())
    }

    /// Staleness in local steps relative to the receiver's step counter.
    pub fn staleness(&self, receiver_step: u64) -> u64 {
        receiver_step.saturating_sub(self.sent_at_step)
    }
}

/// The single wire-size model every accounting path shares: a message is
/// the f32 payload + one f64 weight + 16 bytes of headers, plus an 8-byte
/// shard descriptor when the exchange is sharded.  Used by
/// [`Message::wire_bytes`] and by paths that count bytes without
/// materializing a `Message` (DES simulator, immediate-delivery mode).
pub fn wire_bytes_for(payload_len: usize, sharded: bool) -> usize {
    let shard_header = if sharded { 8 } else { 0 };
    payload_len * std::mem::size_of::<f32>() + 8 + 16 + shard_header
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::shard::ShardPlan;

    fn msg(n: usize, sent: u64) -> Message {
        Message::new(
            Arc::new(FlatVec::zeros(n)),
            SumWeight::from_value(0.5),
            3,
            sent,
        )
    }

    #[test]
    fn wire_bytes_counts_payload() {
        let m = msg(1000, 0);
        assert_eq!(m.wire_bytes(), 4000 + 24);
    }

    #[test]
    fn full_message_has_full_shard() {
        let m = msg(64, 0);
        assert!(m.shard.is_full());
        assert_eq!(m.shard.len, 64);
    }

    #[test]
    fn shard_message_is_smaller_on_the_wire() {
        let plan = ShardPlan::new(1000, 4);
        let shard = plan.shard(1);
        let m = Message::for_shard(
            Arc::new(FlatVec::zeros(shard.len)),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(m.wire_bytes(), 250 * 4 + 24 + 8);
        let full = msg(1000, 0);
        assert!(m.wire_bytes() * 3 < full.wire_bytes());
    }

    #[test]
    #[should_panic(expected = "shard payload length")]
    fn shard_payload_length_must_match_descriptor() {
        let plan = ShardPlan::new(100, 4);
        Message::for_shard(
            Arc::new(FlatVec::zeros(7)),
            SumWeight::from_value(0.25),
            0,
            0,
            plan.shard(0),
        );
    }

    #[test]
    fn staleness_saturates() {
        let m = msg(4, 10);
        assert_eq!(m.staleness(15), 5);
        assert_eq!(m.staleness(5), 0);
    }

    #[test]
    fn arc_payload_is_shared_not_copied() {
        let params = Arc::new(FlatVec::zeros(1 << 20));
        let a = Message::new(params.clone(), SumWeight::from_value(0.1), 0, 0);
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.params, &b.params));
        assert_eq!(Arc::strong_count(&params), 3);
    }
}
