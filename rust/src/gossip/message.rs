//! The gossip message: `(x_s, w_s)` plus accounting metadata.
//!
//! The paper (section 4.1) encapsulates the sender's parameter vector and
//! its halved weight in a single message.  The payload body is owned
//! directly by the message: the protocol is strictly point-to-point (one
//! emit produces one message for one receiver's queue), so there is
//! nothing to share — and owning the body keeps the steady-state hot path
//! allocation-free: the [`EncodedPayload`] travels by move from
//! `emit` through the queue to `absorb`, and when the receiver drops the
//! message, pool-backed storage flows back to the
//! [`BufferPool`](crate::tensor::BufferPool) it came from.  (Earlier
//! revisions wrapped the body in an `Arc`, which cost one heap allocation
//! per message for sharing no production path used.)
//!
//! With sharded exchange ([`crate::gossip::shard`]) a message may carry
//! only one contiguous slice of the vector; the `shard` field records
//! which slice, and the shipped weight is that shard's *shard-local* sum
//! weight.  The classic whole-vector message is the `num_shards == 1`
//! special case, so nothing downstream needs to branch on "sharded or
//! not" except the blend itself.
//!
//! With payload codecs ([`crate::gossip::codec`]) the body travels in its
//! encoded form ([`EncodedPayload`]); [`Message::wire_bytes`] prices the
//! encoded bytes actually shipped while [`Message::raw_wire_bytes`] keeps
//! the uncompressed cost for compression-ratio accounting.

use crate::gossip::codec::EncodedPayload;
use crate::gossip::shard::Shard;
use crate::gossip::weights::SumWeight;
use crate::tensor::FlatVec;

/// One gossip message from `sender` (paper Algorithm 4, `PushMessage`).
#[derive(Clone, Debug)]
pub struct Message {
    /// The shard's coordinates at send time, in wire (encoded) form — the
    /// whole vector for a full message, or `shard.len` coordinates for a
    /// shard.  Owned: dropping the message releases (or pool-recycles)
    /// the body storage.
    pub payload: EncodedPayload,
    /// The sender's halved (shard-local) weight shipped with the snapshot.
    pub weight: SumWeight,
    /// Worker id of the sender (diagnostics / staleness accounting).
    pub sender: usize,
    /// Sender's local step count at send time (staleness accounting).
    pub sent_at_step: u64,
    /// Which slice of the parameter vector the payload covers.
    pub shard: Shard,
}

impl Message {
    /// Whole-vector message (the paper's protocol).
    pub fn new(
        payload: EncodedPayload,
        weight: SumWeight,
        sender: usize,
        sent_at_step: u64,
    ) -> Self {
        let shard = Shard::full(payload.coord_count());
        Message { payload, weight, sender, sent_at_step, shard }
    }

    /// Whole-vector message with an uncompressed body (tests / benches).
    pub fn dense(params: FlatVec, weight: SumWeight, sender: usize, sent_at_step: u64) -> Self {
        Message::new(EncodedPayload::Dense(params), weight, sender, sent_at_step)
    }

    /// Shard message: `payload` covers exactly the shard's `shard.len`
    /// coordinates.
    pub fn for_shard(
        payload: EncodedPayload,
        weight: SumWeight,
        sender: usize,
        sent_at_step: u64,
        shard: Shard,
    ) -> Self {
        assert_eq!(
            payload.coord_count(),
            shard.len,
            "shard payload covers {} coordinates vs descriptor len {}",
            payload.coord_count(),
            shard.len
        );
        Message { payload, weight, sender, sent_at_step, shard }
    }

    /// Wire size in bytes of the message as actually shipped (encoded
    /// body + the shared header model).
    pub fn wire_bytes(&self) -> usize {
        encoded_wire_bytes(&self.payload, !self.shard.is_full())
    }

    /// Wire size the same message would have had with the dense codec —
    /// the denominator of every compression-ratio report.
    pub fn raw_wire_bytes(&self) -> usize {
        wire_bytes_for(self.shard.len, !self.shard.is_full())
    }

    /// Staleness in local steps relative to the receiver's step counter.
    pub fn staleness(&self, receiver_step: u64) -> u64 {
        receiver_step.saturating_sub(self.sent_at_step)
    }
}

/// The single wire-size model every accounting path shares: a message is
/// the f32 payload + one f64 weight + 16 bytes of headers, plus an 8-byte
/// shard descriptor when the exchange is sharded.  Used by
/// [`Message::raw_wire_bytes`] and by paths that count bytes without
/// materializing a `Message` (DES simulator, immediate-delivery mode,
/// the barrier baselines — all of which ship uncompressed f32 bodies).
pub fn wire_bytes_for(payload_len: usize, sharded: bool) -> usize {
    let shard_header = if sharded { 8 } else { 0 };
    payload_len * std::mem::size_of::<f32>() + 8 + 16 + shard_header
}

/// Wire size of an encoded body under the same header model: the codec's
/// body bytes + one f64 weight + 16 bytes of headers (+ 8-byte shard
/// descriptor when sharded).  The dense codec reproduces
/// [`wire_bytes_for`] exactly.
pub fn encoded_wire_bytes(payload: &EncodedPayload, sharded: bool) -> usize {
    let shard_header = if sharded { 8 } else { 0 };
    payload.payload_wire_bytes() + 8 + 16 + shard_header
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::codec::{Codec, QuantizeU8, TopK};
    use crate::gossip::shard::ShardPlan;
    use crate::tensor::BufferPool;

    fn msg(n: usize, sent: u64) -> Message {
        Message::dense(FlatVec::zeros(n), SumWeight::from_value(0.5), 3, sent)
    }

    #[test]
    fn wire_bytes_counts_payload() {
        let m = msg(1000, 0);
        assert_eq!(m.wire_bytes(), 4000 + 24);
        assert_eq!(m.raw_wire_bytes(), m.wire_bytes(), "dense: encoded == raw");
    }

    #[test]
    fn full_message_has_full_shard() {
        let m = msg(64, 0);
        assert!(m.shard.is_full());
        assert_eq!(m.shard.len, 64);
    }

    #[test]
    fn shard_message_is_smaller_on_the_wire() {
        let plan = ShardPlan::new(1000, 4);
        let shard = plan.shard(1);
        let m = Message::for_shard(
            EncodedPayload::Dense(FlatVec::zeros(shard.len)),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(m.wire_bytes(), 250 * 4 + 24 + 8);
        let full = msg(1000, 0);
        assert!(m.wire_bytes() * 3 < full.wire_bytes());
    }

    #[test]
    fn encoded_messages_report_encoded_and_raw_bytes() {
        let plan = ShardPlan::new(1024, 4);
        let shard = plan.shard(0);
        let payload = FlatVec::zeros(shard.len);
        let q8 = Message::for_shard(
            QuantizeU8.encode(payload.clone(), &mut []),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(q8.wire_bytes(), 256 + 8 + 24 + 8);
        assert_eq!(q8.raw_wire_bytes(), 256 * 4 + 24 + 8);
        assert!(q8.raw_wire_bytes() >= 3 * q8.wire_bytes());
        let mut residual = vec![0.0f32; shard.len];
        let topk = Message::for_shard(
            TopK { k: 16 }.encode(payload, &mut residual),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(topk.wire_bytes(), 16 * 8 + 24 + 8);
    }

    #[test]
    #[should_panic(expected = "shard payload covers")]
    fn shard_payload_length_must_match_descriptor() {
        let plan = ShardPlan::new(100, 4);
        Message::for_shard(
            EncodedPayload::Dense(FlatVec::zeros(7)),
            SumWeight::from_value(0.25),
            0,
            0,
            plan.shard(0),
        );
    }

    #[test]
    fn staleness_saturates() {
        let m = msg(4, 10);
        assert_eq!(m.staleness(15), 5);
        assert_eq!(m.staleness(5), 0);
    }

    #[test]
    fn dropping_a_message_recycles_pooled_payload_storage() {
        // The receive side of the zero-allocation contract: a message
        // whose body came from the pool hands the capacity back on drop.
        let pool = BufferPool::shared();
        let body = FlatVec::pooled(&pool, 4096);
        let ptr = body.as_slice().as_ptr();
        let m = Message::dense(body, SumWeight::from_value(0.1), 0, 0);
        drop(m);
        assert_eq!(pool.stats().recycled, 1);
        let next = FlatVec::pooled(&pool, 4096);
        assert_eq!(next.as_slice().as_ptr(), ptr, "payload storage reused");
    }
}
