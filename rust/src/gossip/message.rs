//! The gossip message: `(x_s, w_s)` plus accounting metadata.
//!
//! The paper (section 4.1) encapsulates the sender's parameter vector and
//! its halved weight in a single message.  The parameter payload is shared
//! via `Arc` so pushing one snapshot to several queues (or keeping it in a
//! queue while the sender keeps training) never copies the vector — a real
//! concern at 10⁶-10⁸ floats.

use std::sync::Arc;

use crate::gossip::weights::SumWeight;
use crate::tensor::FlatVec;

/// One gossip message from `sender` (paper Algorithm 4, `PushMessage`).
#[derive(Clone, Debug)]
pub struct Message {
    /// Snapshot of the sender's parameters at send time.
    pub params: Arc<FlatVec>,
    /// The sender's halved weight shipped with the snapshot.
    pub weight: SumWeight,
    /// Worker id of the sender (diagnostics / staleness accounting).
    pub sender: usize,
    /// Sender's local step count at send time (staleness accounting).
    pub sent_at_step: u64,
}

impl Message {
    pub fn new(params: Arc<FlatVec>, weight: SumWeight, sender: usize, sent_at_step: u64) -> Self {
        Message { params, weight, sender, sent_at_step }
    }

    /// Payload size in bytes (throughput accounting; a message is the
    /// parameter vector + one f64 weight + headers).
    pub fn wire_bytes(&self) -> usize {
        self.params.len() * std::mem::size_of::<f32>() + 8 + 16
    }

    /// Staleness in local steps relative to the receiver's step counter.
    pub fn staleness(&self, receiver_step: u64) -> u64 {
        receiver_step.saturating_sub(self.sent_at_step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(n: usize, sent: u64) -> Message {
        Message::new(
            Arc::new(FlatVec::zeros(n)),
            SumWeight::from_value(0.5),
            3,
            sent,
        )
    }

    #[test]
    fn wire_bytes_counts_payload() {
        let m = msg(1000, 0);
        assert_eq!(m.wire_bytes(), 4000 + 24);
    }

    #[test]
    fn staleness_saturates() {
        let m = msg(4, 10);
        assert_eq!(m.staleness(15), 5);
        assert_eq!(m.staleness(5), 0);
    }

    #[test]
    fn arc_payload_is_shared_not_copied() {
        let params = Arc::new(FlatVec::zeros(1 << 20));
        let a = Message::new(params.clone(), SumWeight::from_value(0.1), 0, 0);
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.params, &b.params));
        assert_eq!(Arc::strong_count(&params), 3);
    }
}
