//! The gossip message: `(x_s, w_s)` plus accounting metadata.
//!
//! The paper (section 4.1) encapsulates the sender's parameter vector and
//! its halved weight in a single message.  The payload body is owned
//! directly by the message: the protocol is strictly point-to-point (one
//! emit produces one message for one receiver's queue), so there is
//! nothing to share — and owning the body keeps the steady-state hot path
//! allocation-free: the [`EncodedPayload`] travels by move from
//! `emit` through the queue to `absorb`, and when the receiver drops the
//! message, pool-backed storage flows back to the
//! [`BufferPool`](crate::tensor::BufferPool) it came from.  (Earlier
//! revisions wrapped the body in an `Arc`, which cost one heap allocation
//! per message for sharing no production path used.)
//!
//! With sharded exchange ([`crate::gossip::shard`]) a message may carry
//! only one contiguous slice of the vector; the `shard` field records
//! which slice, and the shipped weight is that shard's *shard-local* sum
//! weight.  The classic whole-vector message is the `num_shards == 1`
//! special case, so nothing downstream needs to branch on "sharded or
//! not" except the blend itself.
//!
//! With payload codecs ([`crate::gossip::codec`]) the body travels in its
//! encoded form ([`EncodedPayload`]); [`Message::wire_bytes`] prices the
//! encoded bytes actually shipped while [`Message::raw_wire_bytes`] keeps
//! the uncompressed cost for compression-ratio accounting.

use crate::gossip::codec::EncodedPayload;
use crate::gossip::shard::Shard;
use crate::gossip::weights::SumWeight;
use crate::tensor::FlatVec;
use std::fmt;

/// One gossip message from `sender` (paper Algorithm 4, `PushMessage`).
#[derive(Clone, Debug)]
pub struct Message {
    /// The shard's coordinates at send time, in wire (encoded) form — the
    /// whole vector for a full message, or `shard.len` coordinates for a
    /// shard.  Owned: dropping the message releases (or pool-recycles)
    /// the body storage.
    pub payload: EncodedPayload,
    /// The sender's halved (shard-local) weight shipped with the snapshot.
    pub weight: SumWeight,
    /// Worker id of the sender (diagnostics / staleness accounting).
    pub sender: usize,
    /// Sender's local step count at send time (staleness accounting).
    pub sent_at_step: u64,
    /// Which slice of the parameter vector the payload covers.
    pub shard: Shard,
}

impl Message {
    /// Whole-vector message (the paper's protocol).
    pub fn new(
        payload: EncodedPayload,
        weight: SumWeight,
        sender: usize,
        sent_at_step: u64,
    ) -> Self {
        let shard = Shard::full(payload.coord_count());
        Message { payload, weight, sender, sent_at_step, shard }
    }

    /// Whole-vector message with an uncompressed body (tests / benches).
    pub fn dense(params: FlatVec, weight: SumWeight, sender: usize, sent_at_step: u64) -> Self {
        Message::new(EncodedPayload::Dense(params), weight, sender, sent_at_step)
    }

    /// Shard message: `payload` covers exactly the shard's `shard.len`
    /// coordinates.
    pub fn for_shard(
        payload: EncodedPayload,
        weight: SumWeight,
        sender: usize,
        sent_at_step: u64,
        shard: Shard,
    ) -> Self {
        assert_eq!(
            payload.coord_count(),
            shard.len,
            "shard payload covers {} coordinates vs descriptor len {}",
            payload.coord_count(),
            shard.len
        );
        Message { payload, weight, sender, sent_at_step, shard }
    }

    /// Wire size in bytes of the message as actually shipped (encoded
    /// body + the shared header model).
    pub fn wire_bytes(&self) -> usize {
        encoded_wire_bytes(&self.payload, !self.shard.is_full())
    }

    /// Wire size the same message would have had with the dense codec —
    /// the denominator of every compression-ratio report.
    pub fn raw_wire_bytes(&self) -> usize {
        wire_bytes_for(self.shard.len, !self.shard.is_full())
    }

    /// Staleness in local steps relative to the receiver's step counter.
    pub fn staleness(&self, receiver_step: u64) -> u64 {
        receiver_step.saturating_sub(self.sent_at_step)
    }
}

/// The single wire-size model every accounting path shares: a message is
/// the f32 payload + one f64 weight + 16 bytes of headers, plus an 8-byte
/// shard descriptor when the exchange is sharded.  Used by
/// [`Message::raw_wire_bytes`] and by paths that count bytes without
/// materializing a `Message` (DES simulator, immediate-delivery mode,
/// the barrier baselines — all of which ship uncompressed f32 bodies).
pub fn wire_bytes_for(payload_len: usize, sharded: bool) -> usize {
    let shard_header = if sharded { 8 } else { 0 };
    payload_len * std::mem::size_of::<f32>() + 8 + 16 + shard_header
}

/// Wire size of an encoded body under the same header model: the codec's
/// body bytes + one f64 weight + 16 bytes of headers (+ 8-byte shard
/// descriptor when sharded).  The dense codec reproduces
/// [`wire_bytes_for`] exactly.
pub fn encoded_wire_bytes(payload: &EncodedPayload, sharded: bool) -> usize {
    let shard_header = if sharded { 8 } else { 0 };
    payload.payload_wire_bytes() + 8 + 16 + shard_header
}

// ---------------------------------------------------------------------------
// The wire form: a message as actual bytes.
//
// Until the networked runtime, messages only ever moved by Rust move —
// the "wire" was an accounting model.  The socket runtime
// (`crate::net`) needs real bytes, and bytes that arrive from a socket
// are *untrusted*: every constructor panic in this module
// (`Message::for_shard`'s length assert, `SumWeight::from_value`'s
// positivity assert, `ShardPlan`'s geometry asserts) would become a
// remote crash.  The decode path below therefore validates everything
// and returns a typed [`WireError`] — it never panics, for any input
// byte string (pinned by the fuzz loop in `rust/tests/wire_framing.rs`).
//
// Layout of a message *body* (the frame codec in `crate::net::frame`
// wraps this in a versioned header with magic, epoch and CRC), all
// little-endian:
//
// ```text
// sender      u32    worker id of the sender
// step        u64    sender's local step at send time
// weight      f64    shipped (halved) shard sum weight
// shard       u32 ×4 index, num_shards, offset, len
// codec tag   u8     0 = dense, 1 = top-k, 2 = q8
// payload     ...    tag-dependent body (see EncodedPayload::encode_wire)
// ```
// ---------------------------------------------------------------------------

/// Codec tags on the wire (one byte after the shard descriptor).
const TAG_DENSE: u8 = 0;
const TAG_TOPK: u8 = 1;
const TAG_QUANT_U8: u8 = 2;

/// Largest admissible coordinate count in one payload.  Real shards are
/// far smaller; the bound exists so a hostile length field cannot ask
/// the decoder for an absurd allocation (allocation is additionally
/// capped by the actual bytes present — counts are checked against the
/// remaining buffer before anything is reserved).
pub const MAX_WIRE_COORDS: usize = 1 << 28;

/// Typed decode/encode failure for untrusted message bytes.
///
/// Every variant names what the decoder rejected; none of them panic.
/// Frame-level failures (bad magic, version, CRC) live one layer down in
/// [`crate::net::FrameError`] and wrap this type for body errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the field could be read.
    Truncated { field: &'static str, needed: usize, have: usize },
    /// Unknown codec tag byte.
    BadCodecTag(u8),
    /// The shipped weight is not a positive finite number ≤ 1 (the fleet
    /// total is 1, so no single message can carry more).
    BadWeight(u64),
    /// Inconsistent shard descriptor (zero shard count, index out of
    /// range, offset overflow, payload length mismatch, ...).
    BadShard(String),
    /// Malformed top-k body: `k > len`, an index out of range, or
    /// indices not strictly ascending.
    BadTopK(String),
    /// Malformed q8 body: non-finite or negative quantization range.
    BadQuant(String),
    /// A length field exceeds [`MAX_WIRE_COORDS`].
    Oversize { field: &'static str, got: u64 },
    /// Bytes left over after a complete message body.
    TrailingBytes(usize),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { field, needed, have } => {
                write!(f, "truncated wire body: {field} needs {needed} bytes, have {have}")
            }
            WireError::BadCodecTag(tag) => write!(f, "unknown codec tag {tag:#04x}"),
            WireError::BadWeight(bits) => {
                let w = f64::from_bits(*bits);
                write!(f, "bad gossip weight on the wire: {w} (bits {bits:#018x})")
            }
            WireError::BadShard(m) => write!(f, "bad shard descriptor: {m}"),
            WireError::BadTopK(m) => write!(f, "bad top-k payload: {m}"),
            WireError::BadQuant(m) => write!(f, "bad q8 payload: {m}"),
            WireError::Oversize { field, got } => {
                write!(f, "wire length field {field} = {got} exceeds the admissible maximum")
            }
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after message body"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for crate::error::Error {
    fn from(e: WireError) -> Self {
        crate::error::Error::net(e.to_string())
    }
}

/// Little-endian byte writers (hand-rolled; the crate carries no serde).
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over an untrusted byte slice.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cursor { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { field, needed: n, have: self.remaining() });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, field: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, field)?[0])
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, field)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, field)?.try_into().expect("8-byte slice")))
    }

    fn f32(&mut self, field: &'static str) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4, field)?.try_into().expect("4-byte slice")))
    }

    fn f64(&mut self, field: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8, field)?.try_into().expect("8-byte slice")))
    }

    /// A count field that sizes a following array: bounded by
    /// [`MAX_WIRE_COORDS`] *and* by the bytes actually present
    /// (`elem_bytes` per element), so no length field can force an
    /// allocation larger than the buffer that arrived.
    fn count(&mut self, field: &'static str, elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32(field)? as u64;
        if n > MAX_WIRE_COORDS as u64 {
            return Err(WireError::Oversize { field, got: n });
        }
        let n = n as usize;
        let needed = n.saturating_mul(elem_bytes);
        if self.remaining() < needed {
            return Err(WireError::Truncated { field, needed, have: self.remaining() });
        }
        Ok(n)
    }
}

impl EncodedPayload {
    /// Serialize the payload body (codec tag + tag-dependent bytes).
    /// Bit-exact: every `f32`/`u8` travels as its exact bit pattern, so
    /// encode → decode is the identity on all three variants — including
    /// non-finite dense bodies (the q8 codec legitimately degrades to
    /// dense on non-finite input).
    pub fn encode_wire(&self, out: &mut Vec<u8>) {
        match self {
            EncodedPayload::Dense(v) => {
                out.push(TAG_DENSE);
                put_u32(out, v.len() as u32);
                for &x in v.as_slice() {
                    put_f32(out, x);
                }
            }
            EncodedPayload::TopK { len, indices, values } => {
                out.push(TAG_TOPK);
                put_u32(out, *len as u32);
                put_u32(out, indices.len() as u32);
                for &i in indices.as_slice() {
                    put_u32(out, i);
                }
                for &x in values.as_slice() {
                    put_f32(out, x);
                }
            }
            EncodedPayload::QuantU8 { min, step, codes } => {
                out.push(TAG_QUANT_U8);
                put_u32(out, codes.len() as u32);
                put_f32(out, *min);
                put_f32(out, *step);
                out.extend_from_slice(codes.as_slice());
            }
        }
    }

    /// Decode one payload from untrusted bytes, returning the payload and
    /// the number of bytes consumed.  Validates everything the in-memory
    /// constructors assert: top-k indices strictly ascending and in
    /// range, `k ≤ len`, q8 range fields finite and non-negative.
    pub fn decode_wire(bytes: &[u8]) -> Result<(EncodedPayload, usize), WireError> {
        let mut cur = Cursor::new(bytes);
        let payload = decode_payload(&mut cur)?;
        Ok((payload, cur.pos))
    }
}

fn decode_payload(cur: &mut Cursor<'_>) -> Result<EncodedPayload, WireError> {
    use crate::tensor::PoolVec;
    match cur.u8("codec tag")? {
        TAG_DENSE => {
            let n = cur.count("dense count", 4)?;
            let raw = cur.take(4 * n, "dense values")?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(f32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().expect("4 bytes")));
            }
            Ok(EncodedPayload::Dense(FlatVec::from_vec(v)))
        }
        TAG_TOPK => {
            let len = cur.count("top-k len", 0)?;
            // The semantic `k ≤ len` check comes before the
            // bytes-available check so a hostile k yields `BadTopK`, not
            // a misleading truncation report.
            let k_raw = cur.u32("top-k k")? as u64;
            if k_raw > len as u64 {
                return Err(WireError::BadTopK(format!("k {k_raw} > shard len {len}")));
            }
            let k = k_raw as usize;
            let raw_idx = cur.take(4 * k, "top-k indices")?;
            let mut indices = Vec::with_capacity(k);
            let mut prev: Option<u32> = None;
            for i in 0..k {
                let idx =
                    u32::from_le_bytes(raw_idx[4 * i..4 * i + 4].try_into().expect("4 bytes"));
                if idx as usize >= len {
                    return Err(WireError::BadTopK(format!("index {idx} >= shard len {len}")));
                }
                if let Some(p) = prev {
                    if idx <= p {
                        return Err(WireError::BadTopK(format!(
                            "indices not strictly ascending ({p} then {idx})"
                        )));
                    }
                }
                prev = Some(idx);
                indices.push(idx);
            }
            let raw_val = cur.take(4 * k, "top-k values")?;
            let mut values = Vec::with_capacity(k);
            for i in 0..k {
                let raw: [u8; 4] = raw_val[4 * i..4 * i + 4].try_into().expect("4 bytes");
                values.push(f32::from_le_bytes(raw));
            }
            Ok(EncodedPayload::TopK {
                len,
                indices: PoolVec::from_vec(indices),
                values: PoolVec::from_vec(values),
            })
        }
        TAG_QUANT_U8 => {
            let n = cur.count("q8 count", 1)?;
            let min = cur.f32("q8 min")?;
            let step = cur.f32("q8 step")?;
            if !min.is_finite() || !step.is_finite() {
                return Err(WireError::BadQuant(format!(
                    "non-finite range (min {min}, step {step})"
                )));
            }
            if step < 0.0 {
                return Err(WireError::BadQuant(format!("negative step {step}")));
            }
            let codes = cur.take(n, "q8 codes")?.to_vec();
            Ok(EncodedPayload::QuantU8 { min, step, codes: PoolVec::from_vec(codes) })
        }
        tag => Err(WireError::BadCodecTag(tag)),
    }
}

impl Message {
    /// Serialize the full message body (everything except the frame
    /// header — see the module-level layout comment).
    pub fn encode_body(&self, out: &mut Vec<u8>) {
        put_u32(out, self.sender as u32);
        put_u64(out, self.sent_at_step);
        put_f64(out, self.weight.value());
        put_u32(out, self.shard.index as u32);
        put_u32(out, self.shard.num_shards as u32);
        put_u32(out, self.shard.offset as u32);
        put_u32(out, self.shard.len as u32);
        self.payload.encode_wire(out);
    }

    /// The serialized body as a fresh buffer.
    pub fn to_wire_body(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(33 + self.payload.payload_wire_bytes());
        self.encode_body(&mut out);
        out
    }

    /// Decode one message body from untrusted bytes.
    ///
    /// This is the panic-free mirror of the trusting in-memory
    /// constructors: the weight is range-checked before
    /// [`SumWeight::from_value`] (whose assert would otherwise be
    /// remotely reachable), the shard descriptor is checked for internal
    /// consistency before [`Message::for_shard`]'s length assert could
    /// fire, and the payload is validated by
    /// [`EncodedPayload::decode_wire`].  The receiving core still
    /// re-validates geometry against its *local* shard plan in
    /// [`ProtocolCore::absorb`](crate::gossip::ProtocolCore::absorb) —
    /// this layer only guarantees the bytes describe *a* well-formed
    /// message.
    pub fn decode_body(bytes: &[u8]) -> Result<Message, WireError> {
        let mut cur = Cursor::new(bytes);
        let sender = cur.u32("sender")? as usize;
        let sent_at_step = cur.u64("step")?;
        let weight = cur.f64("weight")?;
        if !weight.is_finite() || weight <= 0.0 || weight > 1.0 + 1e-6 {
            // The fleet's total mass is exactly 1, so no single message
            // can legitimately carry more (small slack for f64 dust).
            return Err(WireError::BadWeight(weight.to_bits()));
        }
        let index = cur.u32("shard index")? as usize;
        let num_shards = cur.u32("shard count")? as usize;
        let offset = cur.u32("shard offset")? as usize;
        let len = cur.u32("shard len")? as usize;
        if num_shards == 0 {
            return Err(WireError::BadShard("zero shard count".into()));
        }
        if index >= num_shards {
            return Err(WireError::BadShard(format!("index {index} >= count {num_shards}")));
        }
        if num_shards == 1 && (index != 0 || offset != 0) {
            return Err(WireError::BadShard(format!(
                "full-vector message with index {index} / offset {offset}"
            )));
        }
        match offset.checked_add(len) {
            Some(end) if len <= MAX_WIRE_COORDS && end <= MAX_WIRE_COORDS => {}
            _ => {
                return Err(WireError::BadShard(format!("range {offset}+{len} out of bounds")));
            }
        }
        let payload = decode_payload(&mut cur)?;
        if payload.coord_count() != len {
            return Err(WireError::BadShard(format!(
                "payload covers {} coordinates vs descriptor len {len}",
                payload.coord_count()
            )));
        }
        if cur.remaining() != 0 {
            return Err(WireError::TrailingBytes(cur.remaining()));
        }
        let shard = Shard { index, num_shards, offset, len };
        Ok(Message {
            payload,
            weight: SumWeight::from_value(weight),
            sender,
            sent_at_step,
            shard,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::codec::{Codec, QuantizeU8, TopK};
    use crate::gossip::shard::ShardPlan;
    use crate::tensor::BufferPool;

    fn msg(n: usize, sent: u64) -> Message {
        Message::dense(FlatVec::zeros(n), SumWeight::from_value(0.5), 3, sent)
    }

    #[test]
    fn wire_bytes_counts_payload() {
        let m = msg(1000, 0);
        assert_eq!(m.wire_bytes(), 4000 + 24);
        assert_eq!(m.raw_wire_bytes(), m.wire_bytes(), "dense: encoded == raw");
    }

    #[test]
    fn full_message_has_full_shard() {
        let m = msg(64, 0);
        assert!(m.shard.is_full());
        assert_eq!(m.shard.len, 64);
    }

    #[test]
    fn shard_message_is_smaller_on_the_wire() {
        let plan = ShardPlan::new(1000, 4);
        let shard = plan.shard(1);
        let m = Message::for_shard(
            EncodedPayload::Dense(FlatVec::zeros(shard.len)),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(m.wire_bytes(), 250 * 4 + 24 + 8);
        let full = msg(1000, 0);
        assert!(m.wire_bytes() * 3 < full.wire_bytes());
    }

    #[test]
    fn encoded_messages_report_encoded_and_raw_bytes() {
        let plan = ShardPlan::new(1024, 4);
        let shard = plan.shard(0);
        let payload = FlatVec::zeros(shard.len);
        let q8 = Message::for_shard(
            QuantizeU8.encode(payload.clone(), &mut []),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(q8.wire_bytes(), 256 + 8 + 24 + 8);
        assert_eq!(q8.raw_wire_bytes(), 256 * 4 + 24 + 8);
        assert!(q8.raw_wire_bytes() >= 3 * q8.wire_bytes());
        let mut residual = vec![0.0f32; shard.len];
        let topk = Message::for_shard(
            TopK { k: 16 }.encode(payload, &mut residual),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        assert_eq!(topk.wire_bytes(), 16 * 8 + 24 + 8);
    }

    #[test]
    #[should_panic(expected = "shard payload covers")]
    fn shard_payload_length_must_match_descriptor() {
        let plan = ShardPlan::new(100, 4);
        Message::for_shard(
            EncodedPayload::Dense(FlatVec::zeros(7)),
            SumWeight::from_value(0.25),
            0,
            0,
            plan.shard(0),
        );
    }

    #[test]
    fn staleness_saturates() {
        let m = msg(4, 10);
        assert_eq!(m.staleness(15), 5);
        assert_eq!(m.staleness(5), 0);
    }

    #[test]
    fn dropping_a_message_recycles_pooled_payload_storage() {
        // The receive side of the zero-allocation contract: a message
        // whose body came from the pool hands the capacity back on drop.
        let pool = BufferPool::shared();
        let body = FlatVec::pooled(&pool, 4096);
        let ptr = body.as_slice().as_ptr();
        let m = Message::dense(body, SumWeight::from_value(0.1), 0, 0);
        drop(m);
        assert_eq!(pool.stats().recycled, 1);
        let next = FlatVec::pooled(&pool, 4096);
        assert_eq!(next.as_slice().as_ptr(), ptr, "payload storage reused");
    }

    // -- wire form ---------------------------------------------------------

    fn wire_msg() -> Message {
        let plan = ShardPlan::new(32, 4);
        let shard = plan.shard(2);
        Message::for_shard(
            EncodedPayload::Dense(FlatVec::from_vec((0..8).map(|i| i as f32 * 0.25).collect())),
            SumWeight::from_value(0.125),
            5,
            77,
            shard,
        )
    }

    fn f32_bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.iter().map(|v| v.to_bits()).eq(b.iter().map(|v| v.to_bits()))
    }

    fn payload_eq(a: &EncodedPayload, b: &EncodedPayload) -> bool {
        match (a, b) {
            (EncodedPayload::Dense(x), EncodedPayload::Dense(y)) => {
                f32_bits_eq(x.as_slice(), y.as_slice())
            }
            (
                EncodedPayload::TopK { len: la, indices: ia, values: va },
                EncodedPayload::TopK { len: lb, indices: ib, values: vb },
            ) => {
                la == lb
                    && ia.as_slice() == ib.as_slice()
                    && f32_bits_eq(va.as_slice(), vb.as_slice())
            }
            (
                EncodedPayload::QuantU8 { min: ma, step: sa, codes: ca },
                EncodedPayload::QuantU8 { min: mb, step: sb, codes: cb },
            ) => {
                ma.to_bits() == mb.to_bits()
                    && sa.to_bits() == sb.to_bits()
                    && ca.as_slice() == cb.as_slice()
            }
            _ => false,
        }
    }

    #[test]
    fn body_round_trips_bit_exactly() {
        let m = wire_msg();
        let bytes = m.to_wire_body();
        let back = Message::decode_body(&bytes).expect("round trip");
        assert_eq!(back.sender, m.sender);
        assert_eq!(back.sent_at_step, m.sent_at_step);
        assert_eq!(back.weight.value().to_bits(), m.weight.value().to_bits());
        assert_eq!(back.shard, m.shard);
        assert!(payload_eq(&back.payload, &m.payload));
    }

    #[test]
    fn decode_rejects_truncation_at_every_length() {
        let bytes = wire_msg().to_wire_body();
        for cut in 0..bytes.len() {
            let err = Message::decode_body(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut at {cut}: got {err:?}"
            );
        }
    }

    #[test]
    fn decode_rejects_trailing_bytes() {
        let mut bytes = wire_msg().to_wire_body();
        bytes.push(0);
        assert!(matches!(
            Message::decode_body(&bytes),
            Err(WireError::TrailingBytes(1))
        ));
    }

    #[test]
    fn decode_rejects_bad_weights() {
        // Weight lives at byte offset 12 (after sender u32 + step u64).
        let template = wire_msg().to_wire_body();
        for bad in [0.0f64, -0.5, 2.0, f64::NAN, f64::INFINITY] {
            let mut bytes = template.clone();
            bytes[12..20].copy_from_slice(&bad.to_le_bytes());
            assert!(
                matches!(Message::decode_body(&bytes), Err(WireError::BadWeight(_))),
                "weight {bad} accepted"
            );
        }
    }

    #[test]
    fn decode_rejects_malformed_shard_descriptors() {
        // Shard descriptor: index@20, num_shards@24, offset@28, len@32.
        let template = wire_msg().to_wire_body();
        let cases: [(usize, u32, &str); 4] = [
            (24, 0, "zero shard count"),
            (24, 2, "index >= count"),
            (32, 9, "len != payload coords"),
            (28, u32::MAX, "offset overflow range"),
        ];
        for (off, val, why) in cases {
            let mut bytes = template.clone();
            bytes[off..off + 4].copy_from_slice(&val.to_le_bytes());
            assert!(
                matches!(Message::decode_body(&bytes), Err(WireError::BadShard(_))),
                "{why} accepted"
            );
        }
        // num_shards == 1 with a nonzero index/offset is also malformed.
        let mut bytes = template.clone();
        bytes[24..28].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(Message::decode_body(&bytes), Err(WireError::BadShard(_))));
    }

    #[test]
    fn decode_rejects_unknown_codec_tag() {
        let mut bytes = wire_msg().to_wire_body();
        bytes[36] = 0xfe; // codec tag sits after the 36-byte fixed header
        assert!(matches!(
            Message::decode_body(&bytes),
            Err(WireError::BadCodecTag(0xfe))
        ));
    }

    #[test]
    fn decode_rejects_malformed_topk() {
        let plan = ShardPlan::new(32, 4);
        let shard = plan.shard(0);
        let mut residual = vec![0.0f32; shard.len];
        let coords = FlatVec::from_vec((0..8).map(|i| i as f32 - 3.0).collect());
        let m = Message::for_shard(
            TopK { k: 3 }.encode(coords, &mut residual),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        let template = m.to_wire_body();
        let tag_at = 36;
        assert_eq!(template[tag_at], 1, "top-k tag");
        // k > len.
        let mut bytes = template.clone();
        bytes[tag_at + 5..tag_at + 9].copy_from_slice(&100u32.to_le_bytes());
        assert!(matches!(Message::decode_body(&bytes), Err(WireError::BadTopK(_))));
        // First index out of range.
        let mut bytes = template.clone();
        bytes[tag_at + 9..tag_at + 13].copy_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(Message::decode_body(&bytes), Err(WireError::BadTopK(_))));
        // Duplicate (non-ascending) indices.
        let mut bytes = template.clone();
        let first = bytes[tag_at + 9..tag_at + 13].to_vec();
        bytes[tag_at + 13..tag_at + 17].copy_from_slice(&first);
        assert!(matches!(Message::decode_body(&bytes), Err(WireError::BadTopK(_))));
    }

    #[test]
    fn decode_rejects_malformed_quant_ranges() {
        let plan = ShardPlan::new(32, 4);
        let shard = plan.shard(0);
        let m = Message::for_shard(
            QuantizeU8.encode(FlatVec::from_vec((0..8).map(|i| i as f32).collect()), &mut []),
            SumWeight::from_value(0.25),
            0,
            0,
            shard,
        );
        let template = m.to_wire_body();
        let tag_at = 36;
        assert_eq!(template[tag_at], 2, "q8 tag");
        // min @ tag+5, step @ tag+9 (after tag byte + count u32).
        let cases = [(tag_at + 5, f32::NAN), (tag_at + 9, f32::INFINITY), (tag_at + 9, -1.0f32)];
        for (off, bad) in cases {
            let mut bytes = template.clone();
            bytes[off..off + 4].copy_from_slice(&bad.to_le_bytes());
            assert!(
                matches!(Message::decode_body(&bytes), Err(WireError::BadQuant(_))),
                "q8 range {bad} at {off} accepted"
            );
        }
    }

    #[test]
    fn decode_rejects_oversize_length_fields() {
        // A dense count beyond MAX_WIRE_COORDS must be refused even if
        // the buffer could never actually hold that many values.
        let mut bytes = wire_msg().to_wire_body();
        let tag_at = 36;
        bytes[tag_at + 1..tag_at + 5]
            .copy_from_slice(&(MAX_WIRE_COORDS as u32 + 1).to_le_bytes());
        assert!(matches!(
            Message::decode_body(&bytes),
            Err(WireError::Oversize { .. })
        ));
    }

    #[test]
    fn dense_nan_payloads_travel_bit_exactly() {
        // The q8 codec legitimately falls back to dense on non-finite
        // input, so the dense wire path must carry NaN/Inf unmangled.
        let m = Message::dense(
            FlatVec::from_vec(vec![f32::NAN, f32::INFINITY, -0.0]),
            SumWeight::from_value(0.5),
            1,
            2,
        );
        let back = Message::decode_body(&m.to_wire_body()).expect("round trip");
        assert!(payload_eq(&back.payload, &m.payload));
    }
}
