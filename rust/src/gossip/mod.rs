//! Sum-weight randomized gossip substrate (paper section 4).
//!
//! GoSGD removes the parameter server by exchanging `(x, w)` pairs peer to
//! peer.  This module provides the protocol pieces, independent of any
//! training loop:
//!
//! * [`weights`] — the sum-weight bookkeeping (halve on send, add on
//!   receive) whose global conservation drives consensus correctness
//!   (paper Lemma 1 / Appendix B).
//! * [`message`] — the `(x_s, w_s)` message and its accounting metadata.
//! * [`queue`] — the per-worker concurrent mailbox of Algorithm 3/4.
//! * [`peer`] — the legacy `--peer` selection policies (the paper draws
//!   uniformly from `{1..M} \ {s}`); superseded by [`topology`], into
//!   which every selector converts.
//! * [`topology`] — pluggable gossip topologies behind the `Topology`
//!   trait: uniform random (default), ring, GossipGraD-style hypercube
//!   and rotating-partner schedules, each exposing its schedule-averaged
//!   (doubly stochastic) selection matrix for the consensus analysis.
//! * [`shard`] — the chunked-exchange extension: cut the vector into
//!   contiguous shards, each with its own sum weight, and gossip one shard
//!   per event.  Exact (the blend is per-coordinate associative), and the
//!   per-event bandwidth drops by `~1/num_shards`.
//! * [`codec`] — payload codecs for the message body: dense (identity),
//!   top-k sparsification with per-worker error feedback, and per-shard
//!   u8 quantization; wire size shrinks to the encoded form while
//!   sum-weight conservation is untouched.
//! * [`protocol`] — the runtime-agnostic protocol core: the
//!   drain/blend/send state machine of Algorithms 3/4, written once and
//!   driven by all three runtimes (sequential engine, OS threads,
//!   discrete-event simulator).
//!
//! The whole emit → encode → enqueue → coalesce → decode → blend path
//! runs on recycled storage from a
//! [`BufferPool`](crate::tensor::BufferPool) when one is attached (every
//! runtime attaches one): steady-state exchange performs **zero heap
//! allocations** for the dense and q8 codecs, pinned by
//! `benches/hotpath_alloc.rs` and the `alloc_regression` test suite.

pub mod codec;
pub mod message;
pub mod peer;
pub mod protocol;
pub mod queue;
pub mod shard;
pub mod topology;
pub mod weights;

pub use codec::{Codec, CodecRef, CodecSpec, EncodedPayload};
pub use message::{encoded_wire_bytes, wire_bytes_for, Message, WireError};
pub use peer::PeerSelector;
pub use protocol::{AliveSet, CowModel, Outbound, ProtocolCore};
pub use queue::MessageQueue;
pub use shard::{Shard, ShardPlan};
pub use topology::{Topology, TopologyRef, TopologySpec};
pub use weights::SumWeight;
