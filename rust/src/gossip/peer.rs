//! Peer-selection policies (the legacy `--peer` flag).
//!
//! The paper draws the receiver `r` uniformly from `{1..M} \ {s}` (section
//! 4).  Uniform selection gives the complete-graph gossip whose spectral
//! gap yields exponential consensus; restricted topologies trade mixing
//! speed for locality.  [`PeerSelector::Ring`] and
//! [`PeerSelector::SmallWorld`] are provided for the topology ablation
//! bench (`cargo bench --bench strategy_e2e`).
//!
//! The protocol core selects receivers through the richer
//! [`crate::gossip::topology`] subsystem (which adds the hypercube and
//! rotating-partner schedules and the mixing-matrix view); every
//! `PeerSelector` converts into a
//! [`TopologySpec`](crate::gossip::TopologySpec) via `From`.

use crate::error::{Error, Result};
use crate::gossip::topology::TopologySpec;
use crate::util::rng::Rng;

/// How a sender picks the receiver of a gossip message.
#[derive(Clone, Debug, PartialEq)]
pub enum PeerSelector {
    /// Uniform over all other workers (the paper's choice).
    Uniform,
    /// Next worker on a ring: `(s + 1) mod M` — deterministic, minimal
    /// connectivity, slowest mixing.
    Ring,
    /// Ring neighbour with probability `1 - q`, uniform long-range shortcut
    /// with probability `q` (Watts–Strogatz flavoured).
    SmallWorld { q: f64 },
}

impl PeerSelector {
    /// Pick a receiver for sender `s` among `m` workers.
    ///
    /// Delegates to the equivalent [`TopologySpec`] schedule (at slot 0)
    /// so the selection math lives in exactly one place —
    /// `gossip/topology.rs` — and cannot drift from what the protocol
    /// core does.
    pub fn pick(&self, m: usize, s: usize, rng: &mut Rng) -> usize {
        assert!(m >= 2, "need at least two workers");
        assert!(s < m);
        TopologySpec::from(self.clone()).build().next_peer(m, s, 0, rng)
    }

    /// Parse from a CLI string: `uniform`, `ring`, `smallworld:0.2`.
    ///
    /// Validates the input instead of accepting garbage: the shortcut
    /// probability of `smallworld:q` must be a finite number in `[0, 1]`
    /// (`NaN` is rejected explicitly — it would silently disable every
    /// shortcut), and anything else is a config error naming the valid
    /// forms.
    ///
    /// ```
    /// use gosgd::gossip::PeerSelector;
    ///
    /// assert_eq!(PeerSelector::parse("ring").unwrap(), PeerSelector::Ring);
    /// assert_eq!(
    ///     PeerSelector::parse("smallworld:0.25").unwrap(),
    ///     PeerSelector::SmallWorld { q: 0.25 }
    /// );
    /// assert!(PeerSelector::parse("smallworld:2.0").is_err());
    /// assert!(PeerSelector::parse("mesh").is_err());
    /// ```
    pub fn parse(text: &str) -> Result<PeerSelector> {
        match text {
            "uniform" => Ok(PeerSelector::Uniform),
            "ring" => Ok(PeerSelector::Ring),
            _ => {
                let q_text = text.strip_prefix("smallworld:").ok_or_else(|| {
                    Error::config(format!(
                        "unknown peer selector {text:?} (expected uniform | ring | smallworld:Q)"
                    ))
                })?;
                let q: f64 = q_text.parse().map_err(|_| {
                    Error::config(format!(
                        "smallworld shortcut probability is not a number: {q_text:?}"
                    ))
                })?;
                if !q.is_finite() || !(0.0..=1.0).contains(&q) {
                    return Err(Error::config(format!(
                        "smallworld shortcut probability must be in [0, 1], got {q}"
                    )));
                }
                Ok(PeerSelector::SmallWorld { q })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn uniform_never_self_and_covers() {
        check("uniform peer validity", 30, |rng| {
            let m = 2 + rng.below(10) as usize;
            let s = rng.below(m as u64) as usize;
            let sel = PeerSelector::Uniform;
            for _ in 0..50 {
                let r = sel.pick(m, s, rng);
                assert!(r < m && r != s);
            }
        });
    }

    #[test]
    fn ring_is_deterministic_successor() {
        let mut rng = Rng::new(0);
        let sel = PeerSelector::Ring;
        assert_eq!(sel.pick(8, 3, &mut rng), 4);
        assert_eq!(sel.pick(8, 7, &mut rng), 0);
    }

    #[test]
    fn smallworld_mixes_ring_and_uniform() {
        let mut rng = Rng::new(1);
        let sel = PeerSelector::SmallWorld { q: 0.5 };
        let m = 8;
        let s = 2;
        let mut ring_hits = 0;
        let mut other = 0;
        for _ in 0..2000 {
            let r = sel.pick(m, s, &mut rng);
            assert!(r != s && r < m);
            if r == 3 {
                ring_hits += 1;
            } else {
                other += 1;
            }
        }
        // ring neighbour gets ~0.5 + 0.5/7 of the mass, others only 0.5/7
        assert!(ring_hits > 900, "{ring_hits}");
        assert!(other > 600, "{other}");
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(PeerSelector::parse("uniform").unwrap(), PeerSelector::Uniform);
        assert_eq!(PeerSelector::parse("ring").unwrap(), PeerSelector::Ring);
        assert_eq!(
            PeerSelector::parse("smallworld:0.25").unwrap(),
            PeerSelector::SmallWorld { q: 0.25 }
        );
        // Boundary values are legal probabilities.
        assert_eq!(
            PeerSelector::parse("smallworld:0").unwrap(),
            PeerSelector::SmallWorld { q: 0.0 }
        );
        assert_eq!(
            PeerSelector::parse("smallworld:1").unwrap(),
            PeerSelector::SmallWorld { q: 1.0 }
        );
    }

    #[test]
    fn parse_rejects_garbage_with_config_errors() {
        for bad in [
            "mesh",
            "",
            "smallworld:",
            "smallworld:2.0",
            "smallworld:-0.1",
            "smallworld:NaN",
            "smallworld:inf",
            "smallworld:abc",
        ] {
            let err = PeerSelector::parse(bad).unwrap_err();
            assert!(
                err.to_string().contains("config"),
                "{bad:?} should be a config error, got {err}"
            );
        }
    }

    #[test]
    fn all_selectors_handle_the_two_worker_edge() {
        // m = 2: the only legal receiver is the other worker, for every
        // policy (uniform has one candidate; ring's successor is the other
        // worker; smallworld's shortcut and ring move coincide).
        let mut rng = Rng::new(9);
        for sel in [
            PeerSelector::Uniform,
            PeerSelector::Ring,
            PeerSelector::SmallWorld { q: 0.0 },
            PeerSelector::SmallWorld { q: 0.5 },
            PeerSelector::SmallWorld { q: 1.0 },
        ] {
            for s in 0..2 {
                for _ in 0..50 {
                    assert_eq!(sel.pick(2, s, &mut rng), 1 - s, "{sel:?} from {s}");
                }
            }
        }
    }
}
