//! Peer-selection policies.
//!
//! The paper draws the receiver `r` uniformly from `{1..M} \ {s}` (section
//! 4).  Uniform selection gives the complete-graph gossip whose spectral
//! gap yields exponential consensus; restricted topologies trade mixing
//! speed for locality.  [`PeerSelector::Ring`] and
//! [`PeerSelector::SmallWorld`] are provided for the topology ablation
//! bench (`cargo bench --bench strategy_e2e`).

use crate::util::rng::Rng;

/// How a sender picks the receiver of a gossip message.
#[derive(Clone, Debug, PartialEq)]
pub enum PeerSelector {
    /// Uniform over all other workers (the paper's choice).
    Uniform,
    /// Next worker on a ring: `(s + 1) mod M` — deterministic, minimal
    /// connectivity, slowest mixing.
    Ring,
    /// Ring neighbour with probability `1 - q`, uniform long-range shortcut
    /// with probability `q` (Watts–Strogatz flavoured).
    SmallWorld { q: f64 },
}

impl PeerSelector {
    /// Pick a receiver for sender `s` among `m` workers.
    pub fn pick(&self, m: usize, s: usize, rng: &mut Rng) -> usize {
        assert!(m >= 2, "need at least two workers");
        assert!(s < m);
        match self {
            PeerSelector::Uniform => rng.peer(m, s),
            PeerSelector::Ring => (s + 1) % m,
            PeerSelector::SmallWorld { q } => {
                if rng.bernoulli(*q) {
                    rng.peer(m, s)
                } else {
                    (s + 1) % m
                }
            }
        }
    }

    /// Parse from a CLI string: `uniform`, `ring`, `smallworld:0.2`.
    pub fn parse(text: &str) -> Option<PeerSelector> {
        match text {
            "uniform" => Some(PeerSelector::Uniform),
            "ring" => Some(PeerSelector::Ring),
            _ => text
                .strip_prefix("smallworld:")
                .and_then(|q| q.parse().ok())
                .filter(|q| (0.0..=1.0).contains(q))
                .map(|q| PeerSelector::SmallWorld { q }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn uniform_never_self_and_covers() {
        check("uniform peer validity", 30, |rng| {
            let m = 2 + rng.below(10) as usize;
            let s = rng.below(m as u64) as usize;
            let sel = PeerSelector::Uniform;
            for _ in 0..50 {
                let r = sel.pick(m, s, rng);
                assert!(r < m && r != s);
            }
        });
    }

    #[test]
    fn ring_is_deterministic_successor() {
        let mut rng = Rng::new(0);
        let sel = PeerSelector::Ring;
        assert_eq!(sel.pick(8, 3, &mut rng), 4);
        assert_eq!(sel.pick(8, 7, &mut rng), 0);
    }

    #[test]
    fn smallworld_mixes_ring_and_uniform() {
        let mut rng = Rng::new(1);
        let sel = PeerSelector::SmallWorld { q: 0.5 };
        let m = 8;
        let s = 2;
        let mut ring_hits = 0;
        let mut other = 0;
        for _ in 0..2000 {
            let r = sel.pick(m, s, &mut rng);
            assert!(r != s && r < m);
            if r == 3 {
                ring_hits += 1;
            } else {
                other += 1;
            }
        }
        // ring neighbour gets ~0.5 + 0.5/7 of the mass, others only 0.5/7
        assert!(ring_hits > 900, "{ring_hits}");
        assert!(other > 600, "{other}");
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(PeerSelector::parse("uniform"), Some(PeerSelector::Uniform));
        assert_eq!(PeerSelector::parse("ring"), Some(PeerSelector::Ring));
        assert_eq!(
            PeerSelector::parse("smallworld:0.25"),
            Some(PeerSelector::SmallWorld { q: 0.25 })
        );
        assert_eq!(PeerSelector::parse("smallworld:2.0"), None);
        assert_eq!(PeerSelector::parse("mesh"), None);
    }
}
