//! The runtime-agnostic GoSGD protocol core (paper Algorithms 3 & 4).
//!
//! Three runtimes execute the same protocol under different clocks: the
//! sequential universal-clock [`Engine`](crate::strategies::Engine), the
//! OS-thread runtime ([`crate::worker::ThreadedGossip`]) and the
//! discrete-event simulator ([`crate::sim::DesEngine`]).  Before this
//! module existed each of them hand-copied the drain/blend/send state
//! machine; every protocol feature (sharding, topologies, churn) had to be
//! written and debugged three times.
//!
//! [`ProtocolCore`] is that state machine, extracted once: one core per
//! worker holds the per-shard sum weights, the round-robin shard cursor,
//! the exchange probability and the peer-selection policy, and exposes
//! exactly three transitions:
//!
//! * [`ProtocolCore::absorb`] — Algorithm 4 `ProcessMessages`, one
//!   message: compute the blend coefficient `t = w_s/(w_r + w_s)` from the
//!   shard-local sum weight, blend the payload into the shard's range, add
//!   the weight.
//! * [`ProtocolCore::local_step`] — the fused SGD + weight-decay update
//!   plus the local step counter.
//! * [`ProtocolCore::emit`] — Algorithm 3 lines 6-9: Bernoulli(`p`) gate,
//!   peer pick, round-robin shard-cursor advance, weight halving, payload
//!   slice.  Returns an [`Outbound`] the runtime delivers however it
//!   likes (concurrent queue, event heap, engine mailbox).
//!
//! The core never touches clocks, queues, threads or latency models —
//! those stay in the runtimes — and it does not own the parameter vector:
//! every transition borrows `x` from the runtime's storage (the engine
//! keeps params inside its [`Stacked`](crate::framework::Stacked) matrix
//! for the section-3 replay; the threaded and DES runtimes own per-worker
//! vectors), which is what lets all three drive the identical code.
//! The unsharded paper protocol is the `shards == 1` special case: one
//! sum weight, a cursor that never moves, whole-vector payloads.
//!
//! A cross-runtime test (`rust/tests/runtime_equivalence.rs`) hand-drives
//! cores next to the sequential engine and demands *bit-identical*
//! parameter trajectories for a fixed seed.
//!
//! # Example
//!
//! One sender/receiver pair, driven by hand — the same three transitions
//! every runtime calls:
//!
//! ```
//! use gosgd::gossip::{ProtocolCore, TopologySpec};
//! use gosgd::tensor::FlatVec;
//!
//! // Two workers, 4 parameters, unsharded, ring schedule.
//! let mut sender = ProtocolCore::new(0, 2, 4, 1.0, TopologySpec::Ring, 1).unwrap();
//! let mut receiver = ProtocolCore::new(1, 2, 4, 1.0, TopologySpec::Ring, 1).unwrap();
//! let xs = FlatVec::from_vec(vec![2.0; 4]);
//! let mut xr = FlatVec::zeros(4);
//!
//! // Send: the weight halves (1/2 -> 1/4) and the payload snapshots xs.
//! let out = sender.emit_to(&xs, 1).unwrap();
//! assert_eq!(out.to, 1);
//! assert!((sender.weights()[0].value() - 0.25).abs() < 1e-12);
//!
//! // Receive: blend coefficient t = 0.25 / (0.5 + 0.25) = 1/3.
//! receiver.absorb(&mut xr, out.shard, &out.payload, out.weight).unwrap();
//! assert!((xr.as_slice()[0] - 2.0 / 3.0).abs() < 1e-6);
//! assert!((receiver.weights()[0].value() - 0.75).abs() < 1e-12);
//! ```

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::gossip::codec::{Codec, CodecRef, CodecSpec, EncodedPayload};
use crate::gossip::message::{encoded_wire_bytes, wire_bytes_for, Message};
use crate::gossip::shard::{Shard, ShardPlan};
use crate::gossip::topology::{TopologyRef, TopologySpec};
use crate::gossip::weights::SumWeight;
use crate::tensor::{BufferPool, FlatVec};
use crate::util::rng::{Draws, Rng};

/// A worker's parameter vector under lazy (copy-on-write) materialization.
///
/// A million-worker fleet cannot afford `dim * 4` bytes per worker up
/// front when most workers have not taken a step yet: until a worker
/// first writes (local step or absorb), its model *is* the shared cold
/// replica, and the slot stores nothing.  [`CowModel::read`] resolves a
/// borrow against the cold replica; [`CowModel::make_hot`] materializes a
/// private copy (through the [`BufferPool`] when one is attached) on the
/// first write.  Once hot, a worker never goes back to cold.
#[derive(Clone, Debug, Default)]
pub enum CowModel {
    /// Untouched: reads resolve to the shared cold replica.
    #[default]
    Cold,
    /// Materialized: a locally owned vector that has diverged.
    Hot(FlatVec),
}

impl CowModel {
    pub fn is_cold(&self) -> bool {
        matches!(self, CowModel::Cold)
    }

    /// The materialized vector, if any.
    pub fn hot(&self) -> Option<&FlatVec> {
        match self {
            CowModel::Hot(x) => Some(x),
            CowModel::Cold => None,
        }
    }

    /// Resolve for reading: the private copy when hot, `cold` otherwise.
    pub fn read<'a>(&'a self, cold: &'a FlatVec) -> &'a FlatVec {
        match self {
            CowModel::Hot(x) => x,
            CowModel::Cold => cold,
        }
    }

    /// Resolve for writing, materializing a private copy of `cold` on the
    /// first call (from the pool when given — recycled storage, same
    /// bits).
    pub fn make_hot(&mut self, cold: &FlatVec, pool: Option<&Arc<BufferPool>>) -> &mut FlatVec {
        if self.is_cold() {
            let owned = match pool {
                Some(pool) => FlatVec::pooled_copy(pool, cold.as_slice()),
                None => cold.clone(),
            };
            *self = CowModel::Hot(owned);
        }
        match self {
            CowModel::Hot(x) => x,
            CowModel::Cold => unreachable!("materialized above"),
        }
    }
}

/// Aliveness for churn-aware sends, in whichever representation the
/// runtime keeps: a dense mask, or the sparse set of down workers (the
/// DES stores churn sparsely — a million-worker fleet with ten workers
/// down should not allocate a million-entry mask per engine).  The two
/// representations are interchangeable: [`ProtocolCore::emit_gated`]
/// draws the same randomness and repairs to the same peer for equivalent
/// inputs (pinned by a unit test below).
#[derive(Debug)]
pub enum AliveSet<'a> {
    /// Dense per-worker flags, `true` = alive.
    Mask(&'a [bool]),
    /// Sparse ids of the *down* workers; everyone else is alive.
    Down(&'a BTreeSet<usize>),
}

impl AliveSet<'_> {
    pub fn is_alive(&self, w: usize) -> bool {
        match self {
            AliveSet::Mask(mask) => mask[w],
            AliveSet::Down(down) => !down.contains(&w),
        }
    }

    /// Alive workers excluding `id` — the candidate pool for a repair.
    fn peer_count(&self, id: usize, workers: usize) -> usize {
        match self {
            AliveSet::Mask(mask) => (0..workers).filter(|&w| w != id && mask[w]).count(),
            AliveSet::Down(down) => workers - down.len() - usize::from(!down.contains(&id)),
        }
    }

    /// The `k`-th (0-based, ascending id) alive worker other than `id`.
    /// The mask arm is the reference linear scan; the sparse arm computes
    /// the same order statistic by walking only the excluded ids.
    fn kth_peer(&self, id: usize, workers: usize, k: usize) -> usize {
        match self {
            AliveSet::Mask(mask) => {
                let mut k = k;
                for w in 0..workers {
                    if w != id && mask[w] {
                        if k == 0 {
                            return w;
                        }
                        k -= 1;
                    }
                }
                unreachable!("k out of range for the alive peer count")
            }
            AliveSet::Down(down) => {
                // Start from rank k over all ids, then shift past every
                // excluded id (the down set plus `id`) in ascending order:
                // each excluded id <= the running answer displaces it by 1.
                let mut x = k;
                let mut id_pending = !down.contains(&id);
                for &e in down.iter() {
                    if id_pending && id < e {
                        if id <= x {
                            x += 1;
                        }
                        id_pending = false;
                    }
                    if e <= x {
                        x += 1;
                    }
                }
                if id_pending && id <= x {
                    x += 1;
                }
                debug_assert!(x < workers, "k out of range for the alive peer count");
                x
            }
        }
    }
}

/// One worker's protocol state machine.
#[derive(Clone, Debug)]
pub struct ProtocolCore {
    /// 0-based worker id (the topology's schedule excludes it).
    id: usize,
    /// Exchange probability per local step (the paper's `p`).
    p: f64,
    /// Receiver selection schedule (paper: uniform random) — see
    /// [`crate::gossip::topology`].
    topology: TopologyRef,
    /// Position in the topology's schedule; advances once per peer pick.
    /// Random topologies ignore it; for deterministic ones it is live
    /// protocol state and round-trips through checkpoints.
    topo_cursor: u64,
    /// The deterministic shard partition (one shard when unsharded).
    plan: ShardPlan,
    /// One sum weight per shard, each initialized to `1/M`.
    weights: Vec<SumWeight>,
    /// Round-robin shard cursor; staggered by worker id at construction so
    /// concurrent senders cover different shards from the start.
    cursor: usize,
    /// Local gradient steps taken through [`ProtocolCore::local_step`].
    steps: u64,
    /// Payload codec applied at [`ProtocolCore::emit`] (dense by default —
    /// see [`crate::gossip::codec`]).
    codec: CodecRef,
    /// Per-shard encoder state for stateful codecs (top-k error feedback:
    /// the last-shipped snapshot of each shard's coordinates).  Empty for
    /// stateless codecs.
    residuals: Vec<FlatVec>,
    /// Recycled-buffer source for emit snapshots and encoded bodies
    /// (`None` = plain allocation).  Shared by every core of a runtime so
    /// a buffer freed by one worker is reusable by any other.  Pure
    /// storage: with or without a pool the core computes bit-identical
    /// results.
    pool: Option<Arc<BufferPool>>,
}

/// The send-side product of one gossip event: everything a runtime needs
/// to deliver the message, with the sender's state already transitioned
/// (weight halved, cursor advanced, codec state updated).
#[derive(Clone, Debug)]
pub struct Outbound {
    /// 0-based receiver id.
    pub to: usize,
    /// Which slice of the vector the payload covers.
    pub shard: Shard,
    /// The sender's halved shard-local weight.
    pub weight: SumWeight,
    /// Snapshot of the shard's coordinates at send time, in wire form.
    pub payload: EncodedPayload,
}

impl Outbound {
    /// Wire size of the message as actually shipped (encoded body under
    /// the shared accounting model).
    pub fn wire_bytes(&self) -> usize {
        encoded_wire_bytes(&self.payload, !self.shard.is_full())
    }

    /// Wire size the same message would cost uncompressed (dense f32).
    pub fn raw_wire_bytes(&self) -> usize {
        wire_bytes_for(self.shard.len, !self.shard.is_full())
    }

    /// Wrap into a queueable [`Message`] (`sender` in the runtime's own id
    /// space — it is metadata only).  A pure move: the payload body is
    /// not copied and nothing is allocated.
    pub fn into_message(self, sender: usize, sent_at_step: u64) -> Message {
        if self.shard.is_full() {
            Message::new(self.payload, self.weight, sender, sent_at_step)
        } else {
            Message::for_shard(self.payload, self.weight, sender, sent_at_step, self.shard)
        }
    }
}

impl ProtocolCore {
    /// Build the core for worker `id` (0-based) in a cluster of `workers`
    /// over a `dim`-dimensional model.  Fails with a config error when `p`
    /// is not a probability, the shard count does not fit the model, or
    /// the topology does not fit the worker count (hypercube needs a
    /// power of two) — the places user input meets the dimension and the
    /// fleet size for the first time.
    pub fn new(
        id: usize,
        workers: usize,
        dim: usize,
        p: f64,
        topology: TopologySpec,
        shards: usize,
    ) -> Result<Self> {
        if !(0.0..=1.0).contains(&p) {
            return Err(Error::config(format!("gosgd p out of [0,1]: {p}")));
        }
        if shards == 0 {
            return Err(Error::config("shards must be >= 1"));
        }
        // One shard (the whole vector) fits any dimension; a real
        // partition needs at least one coordinate per shard.
        if shards > 1 && shards > dim {
            return Err(Error::config(format!(
                "cannot cut {dim} parameters into {shards} shards"
            )));
        }
        if workers == 0 {
            return Err(Error::config("workers must be >= 1"));
        }
        // A single-worker core never gossips (emit refuses), so only a
        // real fleet constrains the topology.
        if workers >= 2 {
            topology.validate_for(workers)?;
        }
        let plan = ShardPlan::new(dim, shards);
        Ok(ProtocolCore {
            id,
            p,
            topology: topology.build(),
            topo_cursor: 0,
            plan,
            weights: (0..shards).map(|_| SumWeight::init(workers)).collect(),
            cursor: id % shards,
            steps: 0,
            codec: CodecSpec::Dense.build(),
            residuals: Vec::new(),
            pool: None,
        })
    }

    /// Builder form of [`ProtocolCore::set_codec`].
    pub fn with_codec(mut self, spec: CodecSpec) -> Self {
        self.set_codec(spec);
        self
    }

    /// Builder form of [`ProtocolCore::set_pool`].
    pub fn with_pool(mut self, pool: Arc<BufferPool>) -> Self {
        self.set_pool(pool);
        self
    }

    /// Attach a buffer pool: emit snapshots and encoded bodies draw from
    /// (and retire to) recycled storage, making the steady-state exchange
    /// allocation-free.  Safe at any time — the pool never affects the
    /// numbers, only where the bytes live.
    pub fn set_pool(&mut self, pool: Arc<BufferPool>) {
        self.pool = Some(pool);
    }

    /// The attached buffer pool, if any.
    pub fn pool(&self) -> Option<&Arc<BufferPool>> {
        self.pool.as_ref()
    }

    // ---- accessors -------------------------------------------------------

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn p(&self) -> f64 {
        self.p
    }

    /// The plain-data description of the receiver-selection topology.
    pub fn topology_spec(&self) -> TopologySpec {
        self.topology.spec()
    }

    /// Current position in the topology's deterministic schedule.
    pub fn topo_cursor(&self) -> u64 {
        self.topo_cursor
    }

    /// Overwrite the schedule position (checkpoint restore).
    pub fn set_topo_cursor(&mut self, cursor: u64) {
        self.topo_cursor = cursor;
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    pub fn plan(&self) -> ShardPlan {
        self.plan
    }

    /// Per-shard sum weights (one entry when unsharded).
    pub fn weights(&self) -> &[SumWeight] {
        &self.weights
    }

    /// Per-shard weight values, as raw `f64`s (reporting).
    pub fn weight_values(&self) -> Vec<f64> {
        self.weights.iter().map(|w| w.value()).collect()
    }

    /// Mean over the per-shard weights — a single scalar per worker whose
    /// cluster-wide sum stays exactly 1 for any shard count.
    pub fn mean_weight(&self) -> f64 {
        self.weights.iter().map(|w| w.value()).sum::<f64>() / self.weights.len() as f64
    }

    /// Local steps taken through [`ProtocolCore::local_step`].
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Overwrite one shard's sum weight (checkpoint restore).
    pub fn set_weight(&mut self, k: usize, w: SumWeight) {
        self.weights[k] = w;
    }

    /// Re-point the exchange knobs without touching weight state (safe at
    /// any time; the weights are the conserved quantity, `p`/topology are
    /// policy).  The schedule cursor survives a topology swap — it is a
    /// plain position, and keeping it is what lets a checkpoint restore
    /// (which re-applies the topology on the first tick) resume the
    /// schedule exactly where it stopped.
    pub fn set_exchange(&mut self, p: f64, topology: TopologySpec) -> Result<()> {
        if !(0.0..=1.0).contains(&p) {
            return Err(Error::config(format!("gosgd p out of [0,1]: {p}")));
        }
        self.p = p;
        self.set_topology(topology);
        Ok(())
    }

    /// Switch the receiver-selection topology, keeping the schedule
    /// cursor (see [`ProtocolCore::set_exchange`]).  The caller is
    /// responsible for fleet-size validation
    /// ([`TopologySpec::validate_for`]) — the core does not know the
    /// worker count after construction.
    pub fn set_topology(&mut self, topology: TopologySpec) {
        if self.topology.spec() != topology {
            self.topology = topology.build();
        }
    }

    /// A cheap per-worker replica of this core: the topology and codec
    /// are shared behind their existing `Arc`s (two pointer copies
    /// instead of a rebuild), the counters restart, and the shard cursor
    /// staggers by the new id exactly as [`ProtocolCore::new`] would.
    /// Large fleets construct one validated template and fork it per
    /// worker — O(shards) per fork, no re-validation, no per-worker
    /// topology/codec objects.
    pub fn fork(&self, id: usize) -> ProtocolCore {
        ProtocolCore {
            id,
            p: self.p,
            topology: Arc::clone(&self.topology),
            topo_cursor: 0,
            plan: self.plan,
            weights: self.weights.clone(),
            cursor: id % self.plan.num_shards(),
            steps: 0,
            codec: Arc::clone(&self.codec),
            residuals: self.residuals.clone(),
            pool: self.pool.clone(),
        }
    }

    /// [`ProtocolCore::set_codec`] from an already-built codec, shared
    /// across a fleet: one `Arc` clone per worker instead of one codec
    /// build per worker.  Stateful codecs still get private per-shard
    /// residual buffers (error feedback is per-worker state).
    pub fn set_codec_shared(&mut self, codec: &CodecRef) {
        if self.codec.spec() == codec.spec() {
            return;
        }
        let stateful = codec.spec().stateful();
        self.codec = Arc::clone(codec);
        self.residuals = if stateful {
            self.plan.shards().iter().map(|s| FlatVec::zeros(s.len)).collect()
        } else {
            Vec::new()
        };
    }

    /// [`ProtocolCore::set_topology`] from an already-built topology,
    /// shared across a fleet (same caller-validates contract).
    pub fn set_topology_shared(&mut self, topology: &TopologyRef) {
        if self.topology.spec() != topology.spec() {
            self.topology = Arc::clone(topology);
        }
    }

    /// Estimated heap bytes owned by this core beyond its inline struct:
    /// the per-shard weights and any codec residual buffers.  `Arc`-shared
    /// state (topology, codec, pool) counts as zero — it exists once per
    /// fleet, not per worker.
    pub fn state_bytes(&self) -> usize {
        let mut total = self.weights.capacity() * std::mem::size_of::<SumWeight>();
        total += self.residuals.capacity() * std::mem::size_of::<FlatVec>();
        for r in &self.residuals {
            total += r.len() * std::mem::size_of::<f32>();
        }
        total
    }

    /// The payload codec's plain-data description.
    pub fn codec_spec(&self) -> CodecSpec {
        self.codec.spec()
    }

    /// Switch the payload codec.  Sum-weight state is untouched (the codec
    /// only shapes payload bodies); switching away from a stateful codec
    /// resets its per-shard encoder state — a top-k core's error-feedback
    /// buffer starts over from the zero snapshot.
    pub fn set_codec(&mut self, spec: CodecSpec) {
        if self.codec.spec() == spec {
            return;
        }
        self.codec = spec.build();
        self.residuals = if spec.stateful() {
            self.plan.shards().iter().map(|s| FlatVec::zeros(s.len)).collect()
        } else {
            Vec::new()
        };
    }

    // ---- transitions -----------------------------------------------------

    /// Receive transition (Algorithm 4 `ProcessMessages`, one message):
    /// absorb `weight` into the shard-local sum weight and blend `payload`
    /// into `x` over the shard's range with `t = w_s/(w_r + w_s)`.  The
    /// blend is codec-aware: a quantized body blends its dequantized
    /// values, a sparse body blends only the coordinates it lists (the
    /// rest keep their value while the weight is still fully absorbed).
    pub fn absorb(
        &mut self,
        x: &mut FlatVec,
        shard: Shard,
        payload: &EncodedPayload,
        weight: SumWeight,
    ) -> Result<()> {
        // The message's shard geometry must match the local plan exactly —
        // crediting a weight to shard `k` while blending a differently-cut
        // coordinate range would silently corrupt per-shard conservation.
        if shard.num_shards != self.plan.num_shards()
            || shard.index >= self.plan.num_shards()
            || shard != self.plan.shard(shard.index)
        {
            return Err(Error::shape(format!(
                "message shard {shard:?} does not match the local plan ({} shards over {} coordinates)",
                self.plan.num_shards(),
                self.plan.dim()
            )));
        }
        if payload.coord_count() != shard.len {
            return Err(Error::shape(format!(
                "payload covers {} coordinates vs shard len {}",
                payload.coord_count(),
                shard.len
            )));
        }
        let end = shard.offset + shard.len;
        if end > x.len() {
            return Err(Error::shape(format!(
                "shard range {}..{end} out of vector length {}",
                shard.offset,
                x.len()
            )));
        }
        let t = self.weights[shard.index].absorb(weight);
        payload.blend_into(&mut x.as_mut_slice()[shard.offset..end], t as f32);
        Ok(())
    }

    /// [`ProtocolCore::absorb`] for a queued [`Message`].
    pub fn absorb_message(&mut self, x: &mut FlatVec, msg: &Message) -> Result<()> {
        self.absorb(x, msg.shard, &msg.payload, msg.weight)
    }

    /// [`ProtocolCore::absorb`] against a copy-on-write slot: a cold
    /// worker materializes its private copy of `cold` first (an absorb is
    /// a write — the blend diverges the model), then absorbs as usual.
    pub fn absorb_cow(
        &mut self,
        slot: &mut CowModel,
        cold: &FlatVec,
        shard: Shard,
        payload: &EncodedPayload,
        weight: SumWeight,
    ) -> Result<()> {
        if let CowModel::Hot(x) = slot {
            return self.absorb(x, shard, payload, weight);
        }
        let x = slot.make_hot(cold, self.pool.as_ref());
        self.absorb(x, shard, payload, weight)
    }

    /// [`ProtocolCore::local_step`] against a copy-on-write slot
    /// (materializes on the first step).
    pub fn local_step_cow(
        &mut self,
        slot: &mut CowModel,
        cold: &FlatVec,
        grad: &FlatVec,
        eta: f32,
        wd: f32,
    ) -> Result<()> {
        if let CowModel::Hot(x) = slot {
            return self.local_step(x, grad, eta, wd);
        }
        let x = slot.make_hot(cold, self.pool.as_ref());
        self.local_step(x, grad, eta, wd)
    }

    /// Weight-only receive transition: absorb and return the blend
    /// coefficient `t` without touching any parameters.  Used by the
    /// engine's immediate-delivery cross-check, where the exchange is
    /// applied through the recorded `K^(t)` matrix instead of a payload.
    pub fn absorb_weight(&mut self, shard_index: usize, weight: SumWeight) -> f64 {
        self.weights[shard_index].absorb(weight)
    }

    /// Local update: fused SGD + weight decay, and the step counter.
    pub fn local_step(&mut self, x: &mut FlatVec, grad: &FlatVec, eta: f32, wd: f32) -> Result<()> {
        x.sgd_step(grad, eta, wd)?;
        self.steps += 1;
        Ok(())
    }

    /// Send-side state transition without a payload: advance the
    /// round-robin cursor and halve that shard's weight.  Exposed for the
    /// immediate-delivery cross-check; queued runtimes use
    /// [`ProtocolCore::emit`].
    pub fn begin_send(&mut self) -> (Shard, SumWeight) {
        let shard = self.plan.shard(self.cursor);
        self.cursor = (self.cursor + 1) % self.plan.num_shards();
        let shipped = self.weights[shard.index].halve_for_send();
        (shard, shipped)
    }

    /// Pick the next receiver from the topology's schedule, advancing
    /// the schedule cursor.  Exposed for drivers that separate the pick
    /// from the payload transition (the engine's immediate-delivery
    /// cross-check); queued runtimes use [`ProtocolCore::emit`].
    pub fn pick_peer(&mut self, workers: usize, rng: &mut dyn Draws) -> usize {
        let slot = self.topo_cursor;
        self.topo_cursor += 1;
        self.topology.next_peer(workers, self.id, slot, rng)
    }

    /// Send transition (Algorithm 3, lines 6-9): with probability `p`,
    /// pick the topology's next receiver among the `workers` others,
    /// advance the shard cursor, halve the shard's weight and snapshot
    /// its coordinates.  Returns `None` when the coin says no (or the
    /// cluster has a single worker — nobody to gossip with).
    pub fn emit(
        &mut self,
        x: &FlatVec,
        workers: usize,
        rng: &mut dyn Draws,
    ) -> Result<Option<Outbound>> {
        self.emit_alive(x, workers, rng, None)
    }

    /// [`ProtocolCore::emit`] with churn awareness: when an aliveness
    /// mask is given and the pick lands on a dead worker, the send is
    /// *repaired* instead of parking mass in a mailbox nobody drains.
    /// A deterministic schedule walks forward to the next alive peer
    /// (the schedule keeps making progress around the outage); a random
    /// topology re-draws **uniformly among the alive peers** — an index
    /// walk there would hand the dead worker's whole selection mass to
    /// its successor and skew the expected gossip matrix off doubly
    /// stochastic over the alive set.  If no other worker is alive the
    /// send is skipped entirely and no weight leaves the core (mass
    /// conservation needs no special case).
    pub fn emit_alive(
        &mut self,
        x: &FlatVec,
        workers: usize,
        rng: &mut dyn Draws,
        alive: Option<&[bool]>,
    ) -> Result<Option<Outbound>> {
        if let Some(alive) = alive {
            debug_assert_eq!(alive.len(), workers, "aliveness mask vs worker count");
        }
        let set = alive.map(AliveSet::Mask);
        self.emit_gated(x, workers, rng, set.as_ref())
    }

    /// [`ProtocolCore::emit_alive`] over either aliveness representation.
    /// Draw order and repair choice are representation-independent: a
    /// `Down` set produces the bit-identical send sequence to the
    /// equivalent `Mask` (the sparse arm computes the same uniform order
    /// statistic without scanning the fleet).
    pub fn emit_gated(
        &mut self,
        x: &FlatVec,
        workers: usize,
        rng: &mut dyn Draws,
        alive: Option<&AliveSet>,
    ) -> Result<Option<Outbound>> {
        if workers < 2 || !rng.bernoulli(self.p) {
            return Ok(None);
        }
        let mut to = self.pick_peer(workers, rng);
        if let Some(set) = alive {
            if !set.is_alive(to) {
                let candidates = set.peer_count(self.id, workers);
                if candidates == 0 {
                    return Ok(None); // nobody alive to talk to
                }
                if self.topology.spec().deterministic() {
                    // Schedule repair: next alive peer after the pick.
                    loop {
                        to = (to + 1) % workers;
                        if to != self.id && set.is_alive(to) {
                            break;
                        }
                    }
                } else {
                    // Unbiased repair: uniform over the alive peers.
                    let k = rng.below(candidates as u64) as usize;
                    to = set.kth_peer(self.id, workers, k);
                }
            }
        }
        Ok(Some(self.emit_to(x, to)?))
    }

    /// Unconditional send to a chosen receiver — the state transition of
    /// [`ProtocolCore::emit`] with the gate and peer pick already decided.
    /// The raw shard snapshot runs through the configured codec (updating
    /// any per-shard encoder state) before it leaves the core.
    ///
    /// With a pool attached ([`ProtocolCore::set_pool`]) the snapshot is
    /// copied into recycled storage instead of a fresh `clone()`/`to_vec`
    /// allocation, and the codec's output buffers are recycled the same
    /// way — the whole steady-state emit performs zero heap allocations.
    pub fn emit_to(&mut self, x: &FlatVec, to: usize) -> Result<Outbound> {
        if x.len() != self.plan.dim() {
            return Err(Error::shape(format!(
                "params length {} vs shard plan dim {}",
                x.len(),
                self.plan.dim()
            )));
        }
        let (shard, shipped) = self.begin_send();
        let raw = match &self.pool {
            Some(pool) => FlatVec::pooled_copy(
                pool,
                &x.as_slice()[shard.offset..shard.offset + shard.len],
            ),
            None if shard.is_full() => x.clone(),
            None => {
                FlatVec::from_vec(x.as_slice()[shard.offset..shard.offset + shard.len].to_vec())
            }
        };
        let residual: &mut [f32] = match self.residuals.get_mut(shard.index) {
            Some(r) => r.as_mut_slice(),
            None => &mut [],
        };
        let payload = self.codec.encode_with(raw, residual, self.pool.as_ref());
        Ok(Outbound { to, shard, weight: shipped, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(id: usize, m: usize, dim: usize, p: f64, shards: usize) -> ProtocolCore {
        ProtocolCore::new(id, m, dim, p, TopologySpec::UniformRandom, shards).unwrap()
    }

    #[test]
    fn new_validates_inputs() {
        let uni = TopologySpec::UniformRandom;
        assert!(ProtocolCore::new(0, 4, 8, 1.5, uni, 1).is_err());
        assert!(ProtocolCore::new(0, 4, 8, 0.5, uni, 0).is_err());
        assert!(ProtocolCore::new(0, 4, 8, 0.5, uni, 9).is_err());
        assert!(ProtocolCore::new(0, 0, 8, 0.5, uni, 1).is_err());
        assert!(ProtocolCore::new(0, 4, 8, 0.5, uni, 8).is_ok());
        // The trivial 1-shard core accepts any dimension, even empty —
        // ClusterState builds default cores before knowing the model.
        assert!(ProtocolCore::new(0, 2, 0, 0.0, uni, 1).is_ok());
        // The topology must fit the fleet: a 6-worker hypercube is a
        // config error, the power-of-two fleets are fine.
        assert!(ProtocolCore::new(0, 6, 8, 0.5, TopologySpec::Hypercube, 1).is_err());
        assert!(ProtocolCore::new(0, 8, 8, 0.5, TopologySpec::Hypercube, 1).is_ok());
        // Single-worker cores never gossip, so any topology is legal.
        assert!(ProtocolCore::new(0, 1, 8, 0.5, TopologySpec::Hypercube, 1).is_ok());
    }

    #[test]
    fn deterministic_topologies_walk_their_schedule_per_send() {
        let x = FlatVec::zeros(8);
        let mut rng = Rng::new(3);
        let m = 4;
        let mut c = ProtocolCore::new(0, m, 8, 1.0, TopologySpec::PartnerRotation, 1).unwrap();
        let mut seen = Vec::new();
        for _ in 0..3 {
            let out = c.emit(&x, m, &mut rng).unwrap().unwrap();
            seen.push(out.to);
        }
        assert_eq!(seen, vec![1, 2, 3], "rotation covers every peer in order");
        assert_eq!(c.topo_cursor(), 3);
        // The cursor survives a topology swap (checkpoint-restore path).
        c.set_topology(TopologySpec::Ring);
        assert_eq!(c.topo_cursor(), 3);
        c.set_topology(TopologySpec::PartnerRotation);
        let out = c.emit(&x, m, &mut rng).unwrap().unwrap();
        assert_eq!(out.to, 1, "schedule resumes at cursor 3: offset 1 + (3 mod 3)");
    }

    #[test]
    fn emit_alive_repairs_around_dead_peers_and_skips_when_alone() {
        let x = FlatVec::zeros(4);
        let mut rng = Rng::new(1);
        let mut c = ProtocolCore::new(0, 4, 4, 1.0, TopologySpec::Ring, 1).unwrap();
        // Ring successor of 0 is 1; 1 is down, so the send repairs to 2.
        let alive = [true, false, true, true];
        let out = c.emit_alive(&x, 4, &mut rng, Some(&alive[..])).unwrap().unwrap();
        assert_eq!(out.to, 2);
        // Everyone else down: the send is skipped and no weight leaves.
        let w_before = c.weights()[0].value();
        let alone = [true, false, false, false];
        assert!(c.emit_alive(&x, 4, &mut rng, Some(&alone[..])).unwrap().is_none());
        assert_eq!(c.weights()[0].value(), w_before);
        // A full mask behaves exactly like no mask.
        let all = [true; 4];
        let out = c.emit_alive(&x, 4, &mut rng, Some(&all[..])).unwrap().unwrap();
        assert_eq!(out.to, 1);
    }

    #[test]
    fn uniform_repair_redraws_unbiased_among_alive_peers() {
        // With a random topology the repair must NOT hand the dead
        // worker's selection mass to its index-successor: it re-draws
        // uniformly over the alive peers, keeping the expected matrix
        // over the alive set doubly stochastic.
        let m = 5;
        let x = FlatVec::zeros(4);
        let mut rng = Rng::new(17);
        let mut c = ProtocolCore::new(0, m, 4, 1.0, TopologySpec::UniformRandom, 1).unwrap();
        let alive = [true, true, false, true, true]; // worker 2 is down
        let mut counts = [0u32; 5];
        let trials = 6000;
        for _ in 0..trials {
            let out = c.emit_alive(&x, m, &mut rng, Some(&alive[..])).unwrap().unwrap();
            counts[out.to] += 1;
        }
        assert_eq!(counts[0], 0, "never self");
        assert_eq!(counts[2], 0, "never the dead worker");
        // Workers 1, 3 and 4 each get ~1/3 of the sends; an index-walk
        // repair would give worker 3 twice the share of the others.
        for w in [1usize, 3, 4] {
            let share = counts[w] as f64 / trials as f64;
            assert!(
                (share - 1.0 / 3.0).abs() < 0.04,
                "worker {w} share {share} (counts {counts:?})"
            );
        }
    }

    #[test]
    fn down_set_gate_is_bit_identical_to_the_mask_gate() {
        // The DES stores churn sparsely; this is the contract that makes
        // that safe: for every (topology, down-set) the sparse gate must
        // pick the same peer with the same RNG draws as the dense mask.
        let m = 9;
        let dim = 6;
        let x = FlatVec::zeros(dim);
        for topo in [
            TopologySpec::UniformRandom,
            TopologySpec::Ring,
            TopologySpec::PartnerRotation,
        ] {
            let mut by_mask = ProtocolCore::new(0, m, dim, 0.9, topo, 2).unwrap();
            let mut by_set = ProtocolCore::new(0, m, dim, 0.9, topo, 2).unwrap();
            let mut rng_a = Rng::new(0xA11CE);
            let mut rng_b = Rng::new(0xA11CE);
            let mut scen = Rng::new(42);
            for round in 0..400 {
                let mut down = BTreeSet::new();
                for w in 1..m {
                    if scen.bernoulli(0.3) {
                        down.insert(w);
                    }
                }
                let mask: Vec<bool> = (0..m).map(|w| !down.contains(&w)).collect();
                let a = by_mask.emit_alive(&x, m, &mut rng_a, Some(&mask)).unwrap();
                let set = AliveSet::Down(&down);
                let b = by_set.emit_gated(&x, m, &mut rng_b, Some(&set)).unwrap();
                match (&a, &b) {
                    (Some(oa), Some(ob)) => {
                        assert_eq!(oa.to, ob.to, "{topo:?} round {round}, down {down:?}");
                        assert_eq!(oa.shard, ob.shard);
                        assert_eq!(oa.weight.value(), ob.weight.value());
                    }
                    (None, None) => {}
                    _ => panic!(
                        "{topo:?} round {round}: gates diverged (mask {} vs set {})",
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn fork_shares_topology_and_codec_and_staggers_like_new() {
        let m = 8;
        let dim = 12;
        let template = ProtocolCore::new(0, m, dim, 0.7, TopologySpec::Hypercube, 3)
            .unwrap()
            .with_codec(CodecSpec::TopK { k: 2 })
            .with_pool(BufferPool::shared());
        for id in 0..m {
            let forked = template.fork(id);
            let fresh = ProtocolCore::new(id, m, dim, 0.7, TopologySpec::Hypercube, 3)
                .unwrap()
                .with_codec(CodecSpec::TopK { k: 2 });
            assert_eq!(forked.id(), id);
            assert_eq!(forked.steps(), 0);
            assert_eq!(forked.topo_cursor(), 0);
            assert_eq!(forked.cursor, fresh.cursor, "shard stagger for worker {id}");
            assert_eq!(forked.weight_values(), fresh.weight_values());
            assert_eq!(forked.codec_spec(), fresh.codec_spec());
            assert_eq!(forked.residuals.len(), fresh.residuals.len());
            // Shared, not rebuilt: the Arcs point at the template's objects.
            assert!(Arc::ptr_eq(&forked.topology, &template.topology));
            assert!(Arc::ptr_eq(&forked.codec, &template.codec));
            assert!(forked.pool().is_some());
            assert!(forked.state_bytes() > 0);
        }
        // Shared setters follow the same no-op-on-same-spec contract.
        let mut c = template.fork(3);
        let dense: CodecRef = CodecSpec::Dense.build();
        c.set_codec_shared(&dense);
        assert_eq!(c.codec_spec(), CodecSpec::Dense);
        assert!(c.residuals.is_empty(), "stateless codec drops residuals");
        let ring: TopologyRef = TopologySpec::Ring.build();
        c.set_topology_shared(&ring);
        assert_eq!(c.topology_spec(), TopologySpec::Ring);
        assert!(Arc::ptr_eq(&c.topology, &ring));
    }

    #[test]
    fn cow_model_materializes_on_first_write_only() {
        let dim = 8;
        let cold = FlatVec::from_vec((0..dim).map(|i| i as f32).collect());
        let mut slot = CowModel::default();
        assert!(slot.is_cold());
        assert!(slot.hot().is_none());
        // Reads resolve to the cold replica without materializing.
        assert_eq!(slot.read(&cold).as_slice(), cold.as_slice());
        assert!(slot.is_cold());

        // A local step is a write: the slot goes hot with the cold bits,
        // then applies the update to its private copy only.
        let mut c = core(0, 2, dim, 1.0, 1);
        let g = FlatVec::from_vec(vec![1.0; dim]);
        c.local_step_cow(&mut slot, &cold, &g, 0.5, 0.0).unwrap();
        assert!(!slot.is_cold());
        assert_eq!(c.steps(), 1);
        for (i, &v) in slot.read(&cold).as_slice().iter().enumerate() {
            assert!((v - (i as f32 - 0.5)).abs() < 1e-6, "coord {i}: {v}");
        }
        assert_eq!(cold.as_slice()[0], 0.0, "cold replica untouched");

        // An absorb on a cold slot also materializes, and the result is
        // bit-identical to absorbing into an owned copy of the replica.
        let mut sender = core(0, 2, dim, 1.0, 1);
        let out = sender.emit_to(&FlatVec::from_vec(vec![7.0; dim]), 1).unwrap();
        let mut cow_recv = core(1, 2, dim, 1.0, 1);
        let mut plain_recv = core(1, 2, dim, 1.0, 1);
        let mut cow_slot = CowModel::default();
        let mut owned = cold.clone();
        cow_recv
            .absorb_cow(&mut cow_slot, &cold, out.shard, &out.payload, out.weight)
            .unwrap();
        plain_recv.absorb(&mut owned, out.shard, &out.payload, out.weight).unwrap();
        assert!(!cow_slot.is_cold());
        assert_eq!(cow_slot.read(&cold).as_slice(), owned.as_slice());
        assert_eq!(
            cow_recv.weights()[0].value(),
            plain_recv.weights()[0].value()
        );

        // With a pool the materialized copy draws recycled storage.
        let pool = BufferPool::shared();
        let mut pooled = CowModel::default();
        let x = pooled.make_hot(&cold, Some(&pool));
        assert_eq!(x.as_slice(), cold.as_slice());
        assert_eq!(pool.stats().misses, 1, "first materialization is a pool miss");
    }

    #[test]
    fn weights_start_at_one_over_m_per_shard() {
        let c = core(2, 4, 12, 0.5, 3);
        assert_eq!(c.weights().len(), 3);
        for w in c.weights() {
            assert_eq!(w.value(), 0.25);
        }
        assert!((c.mean_weight() - 0.25).abs() < 1e-15);
    }

    #[test]
    fn cursor_staggered_by_worker_id_and_round_robins() {
        let dim = 12;
        let x = FlatVec::zeros(dim);
        for id in 0..5 {
            let mut c = core(id, 8, dim, 1.0, 3);
            let first = c.emit_to(&x, 0).unwrap();
            assert_eq!(first.shard.index, id % 3, "stagger for worker {id}");
            let second = c.emit_to(&x, 0).unwrap();
            assert_eq!(second.shard.index, (id + 1) % 3);
        }
    }

    #[test]
    fn emit_halves_weight_and_slices_payload() {
        let dim = 10;
        let x = FlatVec::from_vec((0..dim).map(|i| i as f32).collect());
        let mut c = core(0, 2, dim, 1.0, 2);
        let out = c.emit_to(&x, 1).unwrap();
        assert_eq!(out.to, 1);
        assert_eq!(out.shard.index, 0);
        assert_eq!(out.payload.coord_count(), out.shard.len);
        assert_eq!(out.weight.value(), 0.25, "half of the 1/2 init");
        assert_eq!(c.weights()[0].value(), 0.25);
        assert_eq!(c.weights()[1].value(), 0.5, "other shard untouched");
        assert_eq!(
            out.payload.as_dense().expect("default codec is dense").as_slice(),
            &x.as_slice()[out.shard.offset..out.shard.offset + out.shard.len]
        );
    }

    #[test]
    fn unsharded_emit_ships_whole_vector_as_full_message() {
        let x = FlatVec::from_vec(vec![1.0; 7]);
        let mut c = core(0, 4, 7, 1.0, 1);
        let out = c.emit_to(&x, 2).unwrap();
        assert!(out.shard.is_full());
        let msg = out.into_message(0, 9);
        assert!(msg.shard.is_full());
        assert_eq!(msg.sent_at_step, 9);
        assert_eq!(msg.payload.coord_count(), 7);
    }

    #[test]
    fn absorb_blends_only_the_shard_range() {
        let dim = 8;
        let mut sender = core(0, 2, dim, 1.0, 2);
        let mut receiver = core(1, 2, dim, 1.0, 2);
        let xs = FlatVec::from_vec(vec![4.0; dim]);
        let mut xr = FlatVec::zeros(dim);
        let out = sender.emit_to(&xs, 1).unwrap();
        let shard = out.shard;
        receiver.absorb(&mut xr, shard, &out.payload, out.weight).unwrap();
        // t = 0.25/(0.5 + 0.25) = 1/3: blended range becomes 4/3.
        for (i, &v) in xr.as_slice().iter().enumerate() {
            if (shard.offset..shard.offset + shard.len).contains(&i) {
                assert!((v - 4.0 / 3.0).abs() < 1e-6, "coord {i}: {v}");
            } else {
                assert_eq!(v, 0.0, "coord {i} outside the shard must be untouched");
            }
        }
        assert!((receiver.weights()[shard.index].value() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn exchange_conserves_mass_per_shard() {
        // Any emit/absorb schedule keeps each shard's total mass at 1.
        let m = 4;
        let dim = 24;
        let shards = 3;
        let mut rng = Rng::new(0xC0DE);
        let mut xs: Vec<FlatVec> = (0..m).map(|_| FlatVec::zeros(dim)).collect();
        let mut cores: Vec<ProtocolCore> =
            (0..m).map(|w| core(w, m, dim, 0.8, shards)).collect();
        let mut in_flight: Vec<Outbound> = Vec::new();
        for _ in 0..500 {
            let w = rng.below(m as u64) as usize;
            if let Some(out) = cores[w].emit(&xs[w], m, &mut rng).unwrap() {
                in_flight.push(out);
            }
            if !in_flight.is_empty() && rng.bernoulli(0.6) {
                let k = rng.below(in_flight.len() as u64) as usize;
                let out = in_flight.swap_remove(k);
                cores[out.to]
                    .absorb(&mut xs[out.to], out.shard, &out.payload, out.weight)
                    .unwrap();
            }
            for k in 0..shards {
                let mut total: f64 = cores.iter().map(|c| c.weights()[k].value()).sum();
                total += in_flight
                    .iter()
                    .filter(|o| o.shard.index == k)
                    .map(|o| o.weight.value())
                    .sum::<f64>();
                assert!((total - 1.0).abs() < 1e-9, "shard {k} mass {total}");
            }
        }
    }

    #[test]
    fn emit_respects_p_zero_and_single_worker() {
        let x = FlatVec::zeros(4);
        let mut rng = Rng::new(1);
        let mut silent = core(0, 4, 4, 0.0, 1);
        for _ in 0..100 {
            assert!(silent.emit(&x, 4, &mut rng).unwrap().is_none());
        }
        let mut lonely = core(0, 1, 4, 1.0, 1);
        assert!(lonely.emit(&x, 1, &mut rng).unwrap().is_none());
    }

    #[test]
    fn local_step_counts_and_updates() {
        let mut c = core(0, 2, 4, 0.5, 1);
        let mut x = FlatVec::from_vec(vec![1.0; 4]);
        let g = FlatVec::from_vec(vec![0.5; 4]);
        c.local_step(&mut x, &g, 0.1, 0.0).unwrap();
        assert_eq!(c.steps(), 1);
        for &v in x.as_slice() {
            assert!((v - 0.95).abs() < 1e-6);
        }
    }

    #[test]
    fn absorb_rejects_foreign_shard_geometry() {
        let mut c = core(0, 2, 8, 0.5, 2);
        let mut x = FlatVec::zeros(8);
        // Wrong shard count entirely.
        let bad = Shard { index: 5, num_shards: 6, offset: 0, len: 1 };
        let payload = EncodedPayload::Dense(FlatVec::zeros(1));
        assert!(c.absorb(&mut x, bad, &payload, SumWeight::from_value(0.1)).is_err());
        // Right count, wrong cut: plan.shard(1) is offset 4, len 4.
        let forged = Shard { index: 1, num_shards: 2, offset: 0, len: 2 };
        let payload = EncodedPayload::Dense(FlatVec::zeros(2));
        assert!(c.absorb(&mut x, forged, &payload, SumWeight::from_value(0.1)).is_err());
        // The genuine descriptor is accepted...
        let good = c.plan().shard(1);
        let payload = EncodedPayload::Dense(FlatVec::zeros(good.len));
        assert!(c.absorb(&mut x, good, &payload, SumWeight::from_value(0.1)).is_ok());
        // ...but only with a payload covering exactly the shard's range.
        let short = EncodedPayload::Dense(FlatVec::zeros(good.len - 1));
        assert!(c.absorb(&mut x, good, &short, SumWeight::from_value(0.1)).is_err());
    }

    #[test]
    fn emit_to_rejects_dim_mismatch() {
        let mut c = core(0, 2, 8, 1.0, 2);
        let x = FlatVec::zeros(5);
        assert!(c.emit_to(&x, 1).is_err());
    }

    // ---- codec-aware transitions ----------------------------------------

    #[test]
    fn q8_emit_encodes_and_cuts_wire_bytes() {
        let dim = 1024;
        let x = FlatVec::from_vec((0..dim).map(|i| i as f32).collect());
        let mut c = core(0, 2, dim, 1.0, 2).with_codec(CodecSpec::QuantizeU8);
        assert_eq!(c.codec_spec(), CodecSpec::QuantizeU8);
        let out = c.emit_to(&x, 1).unwrap();
        assert!(matches!(&out.payload, EncodedPayload::QuantU8 { .. }));
        assert_eq!(out.payload.coord_count(), out.shard.len);
        assert!(
            out.raw_wire_bytes() >= 3 * out.wire_bytes(),
            "q8 {} vs raw {}",
            out.wire_bytes(),
            out.raw_wire_bytes()
        );
    }

    #[test]
    fn topk_emit_keeps_per_shard_error_feedback() {
        // Two emits of the same shard: the second selection is driven by
        // the change since the first ship, not by raw magnitude.
        let dim = 8;
        let k = 1;
        let mut x = FlatVec::from_vec(vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let mut c = core(0, 2, dim, 1.0, 1).with_codec(CodecSpec::TopK { k });
        let first = c.emit_to(&x, 1).unwrap();
        match &first.payload {
            EncodedPayload::TopK { indices, values, .. } => {
                assert_eq!(indices.as_slice(), &[0]);
                assert_eq!(values.as_slice(), &[9.0]);
            }
            other => panic!("expected sparse payload, got {other:?}"),
        }
        // Coordinate 0 is still the largest by magnitude but it has not
        // changed since it shipped; coordinate 3 moved the most.
        x.as_mut_slice()[3] = 2.0;
        let second = c.emit_to(&x, 1).unwrap();
        match &second.payload {
            EncodedPayload::TopK { indices, values, .. } => {
                assert_eq!(indices.as_slice(), &[3]);
                assert_eq!(values.as_slice(), &[2.0]);
            }
            other => panic!("expected sparse payload, got {other:?}"),
        }
    }

    #[test]
    fn codec_exchange_conserves_mass_per_shard() {
        // The conservation schedule of `exchange_conserves_mass_per_shard`,
        // under every codec: the weights never touch the payload path.
        for spec in [CodecSpec::QuantizeU8, CodecSpec::TopK { k: 2 }] {
            let m = 4;
            let dim = 24;
            let shards = 3;
            let mut rng = Rng::new(0xC0DEC);
            let mut xs: Vec<FlatVec> = (0..m).map(|_| FlatVec::zeros(dim)).collect();
            let mut cores: Vec<ProtocolCore> = (0..m)
                .map(|w| core(w, m, dim, 0.8, shards).with_codec(spec))
                .collect();
            let mut in_flight: Vec<Outbound> = Vec::new();
            for _ in 0..300 {
                let w = rng.below(m as u64) as usize;
                if let Some(out) = cores[w].emit(&xs[w], m, &mut rng).unwrap() {
                    in_flight.push(out);
                }
                if !in_flight.is_empty() && rng.bernoulli(0.6) {
                    let k = rng.below(in_flight.len() as u64) as usize;
                    let out = in_flight.swap_remove(k);
                    cores[out.to]
                        .absorb(&mut xs[out.to], out.shard, &out.payload, out.weight)
                        .unwrap();
                }
                for k in 0..shards {
                    let mut total: f64 = cores.iter().map(|c| c.weights()[k].value()).sum();
                    total += in_flight
                        .iter()
                        .filter(|o| o.shard.index == k)
                        .map(|o| o.weight.value())
                        .sum::<f64>();
                    assert!(
                        (total - 1.0).abs() < 1e-9,
                        "codec {:?}: shard {k} mass {total}",
                        spec
                    );
                }
            }
        }
    }

    // ---- pooled hot path -------------------------------------------------

    #[test]
    fn pooled_emit_is_bit_identical_to_unpooled() {
        // Pooling is storage, not semantics: the same core config with and
        // without a pool produces identical outbound messages and weights.
        let dim = 48;
        let x = FlatVec::from_vec((0..dim).map(|i| (i as f32).sin()).collect());
        for codec in [CodecSpec::Dense, CodecSpec::QuantizeU8, CodecSpec::TopK { k: 3 }] {
            let pool = BufferPool::shared();
            let mut plain = core(0, 4, dim, 1.0, 3).with_codec(codec);
            let mut pooled = core(0, 4, dim, 1.0, 3).with_codec(codec).with_pool(pool);
            for _ in 0..7 {
                let a = plain.emit_to(&x, 1).unwrap();
                let b = pooled.emit_to(&x, 1).unwrap();
                assert_eq!(a.shard, b.shard);
                assert_eq!(a.weight.value(), b.weight.value());
                assert_eq!(a.payload, b.payload, "codec {codec:?}");
            }
        }
    }

    #[test]
    fn pooled_emit_recycles_snapshot_storage_across_sends() {
        let dim = 32;
        let pool = BufferPool::shared();
        let x = FlatVec::from_vec(vec![1.0; dim]);
        let mut c = core(0, 2, dim, 1.0, 1).with_pool(pool.clone());
        assert!(c.pool().is_some());
        // First send: cold pool, fresh buffer.
        let out = c.emit_to(&x, 1).unwrap();
        assert_eq!(pool.stats().hits, 0);
        drop(out); // payload storage retires to the pool
        assert_eq!(pool.stats().recycled, 1);
        // Second send: the snapshot comes straight off the freelist.
        let out = c.emit_to(&x, 1).unwrap();
        assert_eq!(pool.stats().hits, 1);
        // And absorbing it returns the storage once more.
        let mut receiver = core(1, 2, dim, 1.0, 1).with_pool(pool.clone());
        let mut xr = FlatVec::zeros(dim);
        receiver.absorb(&mut xr, out.shard, &out.payload, out.weight).unwrap();
        drop(out);
        assert_eq!(pool.stats().recycled, 2);
    }

    #[test]
    fn switching_codecs_resets_error_feedback_only() {
        let dim = 6;
        let x = FlatVec::from_vec(vec![5.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let mut c = core(0, 2, dim, 1.0, 1).with_codec(CodecSpec::TopK { k: 1 });
        let _ = c.emit_to(&x, 1).unwrap();
        let w_after = c.weights()[0].value();
        c.set_codec(CodecSpec::Dense);
        assert_eq!(c.codec_spec(), CodecSpec::Dense);
        assert_eq!(c.weights()[0].value(), w_after, "weights untouched by codec swap");
        // Back to top-k: buffer starts over, so selection is by raw
        // magnitude again — coordinate 0 wins even though it shipped once.
        c.set_codec(CodecSpec::TopK { k: 1 });
        let out = c.emit_to(&x, 1).unwrap();
        match &out.payload {
            EncodedPayload::TopK { indices, .. } => assert_eq!(indices.as_slice(), &[0]),
            other => panic!("expected sparse payload, got {other:?}"),
        }
    }
}
