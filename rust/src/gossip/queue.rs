//! Per-worker concurrent mailbox (paper Algorithm 3: "each worker is
//! endowed with a queue q_m which can be concurrently accessed by all
//! workers").
//!
//! Requirements straight from the paper's protocol:
//!
//! * **Non-blocking push** — a sender must never wait for the receiver
//!   (asymmetric gossip; the whole point of section 4).
//! * **Batch drain** — the receiver processes *all* pending messages before
//!   its next gradient step (`ProcessMessages` loops until empty).
//! * **FIFO** per queue — messages blend in arrival order.
//!
//! Implementation: `Mutex<VecDeque>`; the lock is held for O(1) pointer
//! moves only (payload bodies move, they are never copied), so contention
//! is negligible compared to a gradient step.  The steady-state drain path
//! is [`MessageQueue::drain_into`], which refills a caller-owned `Vec` —
//! after warm-up neither push nor drain touches the heap, which is what
//! the hot-path allocation bench pins.
//!
//! An optional bound sheds the *oldest* message on overflow — under
//! sum-weight semantics dropping a message would destroy weight mass, so
//! instead of dropping, `push` coalesces: overflow folds the oldest two
//! *compatible* messages into one blended message, preserving total weight
//! exactly.  With sharded exchange, "compatible" means covering the same
//! coordinate range (same [`Shard::key`](crate::gossip::Shard::key)): the
//! shard-wise blend is associative, so folding same-shard messages leaves
//! the receiver's final state unchanged, while folding across shards would
//! mix unrelated coordinates.  With payload codecs, both messages must
//! additionally be [`EncodedPayload::coalescible`]: dense and quantized
//! bodies fold by (de)coding — the dequantize-blend is deterministic, so
//! the fold equals sequential processing — while sparse top-k bodies never
//! fold (they carry no value for unlisted coordinates, so any dense
//! stand-in would corrupt them).  A fold that must decode an encoded body
//! takes its dense scratch from the queue's [`BufferPool`]
//! ([`MessageQueue::with_pool`]) when one is attached, so even overflow
//! coalescing stays allocation-free once warm.  If no two queued messages
//! are compatible the queue is allowed to exceed its bound (tracked in the
//! `over_capacity` stat) rather than lose mass.

use crate::sync::{Arc, Mutex};
use std::collections::VecDeque;

use crate::gossip::codec::EncodedPayload;
use crate::gossip::message::Message;
use crate::gossip::weights::SumWeight;
use crate::tensor::{BufferPool, FlatVec};

/// Statistics counters for one queue (all monotonic).
#[derive(Debug, Default, Clone, Copy)]
pub struct QueueStats {
    pub pushed: u64,
    pub drained: u64,
    pub coalesced: u64,
    /// Pushes that left a bounded queue over its bound because no two
    /// queued messages covered the same shard (nothing could be folded).
    pub over_capacity: u64,
    pub max_depth: usize,
}

/// A worker's mailbox.
#[derive(Debug)]
pub struct MessageQueue {
    inner: Mutex<Inner>,
    capacity: Option<usize>,
    /// Recycled-buffer source for coalesce scratch (None = plain alloc).
    pool: Option<Arc<BufferPool>>,
}

#[derive(Debug)]
struct Inner {
    deque: VecDeque<Message>,
    stats: QueueStats,
}

impl MessageQueue {
    /// Unbounded queue (the paper's model).
    pub fn unbounded() -> Self {
        MessageQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), stats: QueueStats::default() }),
            capacity: None,
            pool: None,
        }
    }

    /// Bounded queue that *coalesces* (never drops) on overflow.
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity >= 2, "coalescing bound needs capacity >= 2");
        MessageQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), stats: QueueStats::default() }),
            capacity: Some(capacity),
            pool: None,
        }
    }

    /// Attach a buffer pool: coalesce folds that need a dense scratch
    /// draw it from here instead of allocating.
    pub fn with_pool(mut self, pool: Arc<BufferPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Non-blocking push (paper `PushMessage`). Never fails, never waits.
    pub fn push(&self, msg: Message) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.deque.push_back(msg);
        g.stats.pushed += 1;
        if let Some(cap) = self.capacity {
            if g.deque.len() > cap {
                // Fold the two oldest same-shard messages into one: weights
                // add, the payload blends by the sum-weight rule, so the
                // receiver observes exactly the same final state as if it
                // had processed both (associativity of the blend).
                if let Some((i, j)) = oldest_compatible_pair(&g.deque) {
                    let b = g.deque.remove(j).expect("index in range");
                    let a = g.deque.remove(i).expect("index in range");
                    g.deque.insert(i, coalesce(a, b, self.pool.as_ref()));
                    g.stats.coalesced += 1;
                } else {
                    // No two messages share a shard: folding would corrupt
                    // coordinates and dropping would destroy weight mass.
                    // Stretch the bound instead (worst case num_shards
                    // distinct shards queued).
                    g.stats.over_capacity += 1;
                }
            }
        }
        let depth = g.deque.len();
        if depth > g.stats.max_depth {
            g.stats.max_depth = depth;
        }
    }

    /// Drain everything currently queued into a caller-owned buffer
    /// (paper `ProcessMessages`).  The steady-state path: the caller
    /// reuses the same `Vec` across wakes, so neither side of the
    /// exchange allocates once capacities are warm.
    pub fn drain_into(&self, out: &mut Vec<Message>) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.stats.drained += g.deque.len() as u64;
        out.extend(g.deque.drain(..));
    }

    /// Drain into a fresh `Vec` (tests / cold paths).
    pub fn drain(&self) -> Vec<Message> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }

    /// Current depth (diagnostics only; racy by nature).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.lock().expect("queue poisoned").stats
    }
}

/// Oldest pair of indices `(i, j)`, `i < j`, whose messages cover the same
/// coordinate range and whose payloads may be folded by decoding (no
/// sparse bodies).  O(n²) over the queue depth, which the capacity bound
/// keeps tiny.
fn oldest_compatible_pair(deque: &VecDeque<Message>) -> Option<(usize, usize)> {
    for i in 0..deque.len() {
        if !deque[i].payload.coalescible() {
            continue;
        }
        for j in (i + 1)..deque.len() {
            if deque[i].shard.key() == deque[j].shard.key() && deque[j].payload.coalescible() {
                return Some((i, j));
            }
        }
    }
    None
}

/// Fold message `a` into message `b` preserving total weight: the combined
/// payload is the sum-weight blend of the two payloads (a dense body).
/// Both messages must cover the same shard and be coalescible — quantized
/// bodies fold via their deterministic dequantization, which is exactly
/// what the receiver would have blended one at a time.
///
/// A dense `a` blends *in place* on its own (possibly pooled) buffer; an
/// encoded `a` decodes into a scratch buffer drawn from `pool` when one
/// is attached.  `b`'s body blends through the fused
/// [`EncodedPayload::blend_into`] kernel, so no second dense intermediate
/// ever exists, and both original bodies' storage recycles on drop.
fn coalesce(a: Message, b: Message, pool: Option<&Arc<BufferPool>>) -> Message {
    debug_assert_eq!(a.shard.key(), b.shard.key(), "coalescing across shards");
    debug_assert!(
        a.payload.coalescible() && b.payload.coalescible(),
        "coalescing a sparse payload"
    );
    let w_a = a.weight.value();
    let w_b = b.weight.value();
    let mut blended: FlatVec = match a.payload {
        EncodedPayload::Dense(v) => v,
        other => {
            let mut scratch = match pool {
                Some(pool) => FlatVec::pooled(pool, other.coord_count()),
                None => FlatVec::zeros(other.coord_count()),
            };
            other.decode_into(scratch.as_mut_slice());
            scratch
        }
    };
    // blended <- (w_a * a + w_b * b) / (w_a + w_b): the same fused
    // x += t (y - x) pass the receiver would run, t = w_b / (w_a + w_b).
    let t = (w_b / (w_a + w_b)) as f32;
    b.payload.blend_into(blended.as_mut_slice(), t);
    Message::for_shard(
        EncodedPayload::Dense(blended),
        SumWeight::from_value(w_a + w_b),
        b.sender,
        b.sent_at_step,
        b.shard,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::codec::{Codec, QuantizeU8, TopK};
    use crate::util::proptest::check;

    fn msg(val: f32, w: f64, sender: usize) -> Message {
        Message::dense(
            FlatVec::from_vec(vec![val; 8]),
            SumWeight::from_value(w),
            sender,
            0,
        )
    }

    fn first_coord(m: &Message) -> f32 {
        m.payload.decode().as_slice()[0]
    }

    #[test]
    fn fifo_order() {
        let q = MessageQueue::unbounded();
        q.push(msg(1.0, 0.1, 0));
        q.push(msg(2.0, 0.1, 1));
        q.push(msg(3.0, 0.1, 2));
        let out = q.drain();
        let vals: Vec<f32> = out.iter().map(first_coord).collect();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_empties_queue() {
        let q = MessageQueue::unbounded();
        q.push(msg(1.0, 0.5, 0));
        assert_eq!(q.drain().len(), 1);
        assert_eq!(q.drain().len(), 0);
    }

    #[test]
    fn drain_into_reuses_the_caller_buffer() {
        let q = MessageQueue::unbounded();
        let mut inbox: Vec<Message> = Vec::with_capacity(8);
        for round in 0..5 {
            for i in 0..3 {
                q.push(msg(i as f32, 0.1, i));
            }
            q.drain_into(&mut inbox);
            assert_eq!(inbox.len(), 3, "round {round}");
            let cap = inbox.capacity();
            inbox.clear();
            assert_eq!(inbox.capacity(), cap, "capacity survives the clear");
        }
        let s = q.stats();
        assert_eq!(s.pushed, 15);
        assert_eq!(s.drained, 15);
    }

    #[test]
    fn drain_into_appends_after_existing_elements() {
        let q = MessageQueue::unbounded();
        q.push(msg(2.0, 0.1, 0));
        let mut inbox = vec![msg(1.0, 0.1, 9)];
        q.drain_into(&mut inbox);
        let vals: Vec<f32> = inbox.iter().map(first_coord).collect();
        assert_eq!(vals, vec![1.0, 2.0]);
    }

    #[test]
    fn stats_track_push_drain() {
        let q = MessageQueue::unbounded();
        for i in 0..5 {
            q.push(msg(i as f32, 0.1, 0));
        }
        q.drain();
        let s = q.stats();
        assert_eq!(s.pushed, 5);
        assert_eq!(s.drained, 5);
        assert_eq!(s.max_depth, 5);
        assert_eq!(s.coalesced, 0);
    }

    #[test]
    fn bounded_coalesces_preserving_weight() {
        let q = MessageQueue::bounded(2);
        q.push(msg(0.0, 0.25, 0));
        q.push(msg(1.0, 0.25, 1));
        q.push(msg(2.0, 0.5, 2)); // overflow: folds the two oldest
        let out = q.drain();
        assert_eq!(out.len(), 2);
        let total_w: f64 = out.iter().map(|m| m.weight.value()).sum();
        assert!((total_w - 1.0).abs() < 1e-12, "weight mass lost: {total_w}");
        // Folded payload is the weight-blend of 0.0 and 1.0 at equal weight.
        assert!((first_coord(&out[0]) - 0.5).abs() < 1e-6);
        assert_eq!(q.stats().coalesced, 1);
    }

    #[test]
    fn coalesced_blend_equals_sequential_processing() {
        // Receiver state after absorbing (m1 then m2) must equal absorbing
        // the coalesced fold — associativity of the sum-weight blend.
        let mut direct = FlatVec::from_vec(vec![10.0; 8]);
        let mut w_direct = SumWeight::from_value(0.5);
        let m1 = msg(2.0, 0.25, 0);
        let m2 = msg(6.0, 0.25, 1);
        let t1 = w_direct.absorb(m1.weight);
        direct.mix_from(m1.payload.as_dense().unwrap(), 1.0 - t1, t1).unwrap();
        let t2 = w_direct.absorb(m2.weight);
        direct.mix_from(m2.payload.as_dense().unwrap(), 1.0 - t2, t2).unwrap();

        let mut folded = FlatVec::from_vec(vec![10.0; 8]);
        let mut w_folded = SumWeight::from_value(0.5);
        let c = coalesce(msg(2.0, 0.25, 0), msg(6.0, 0.25, 1), None);
        let t = w_folded.absorb(c.weight);
        folded.mix_from(c.payload.as_dense().unwrap(), 1.0 - t, t).unwrap();

        assert!((w_direct.value() - w_folded.value()).abs() < 1e-12);
        for i in 0..8 {
            assert!(
                (direct.as_slice()[i] - folded.as_slice()[i]).abs() < 1e-5,
                "{:?} vs {:?}",
                direct.as_slice(),
                folded.as_slice()
            );
        }
    }

    #[test]
    fn sharded_overflow_only_folds_same_shard() {
        use crate::gossip::shard::ShardPlan;
        let plan = ShardPlan::new(8, 2);
        let mk = |k: usize, val: f32, w: f64| {
            let shard = plan.shard(k);
            Message::for_shard(
                EncodedPayload::Dense(FlatVec::from_vec(vec![val; shard.len])),
                SumWeight::from_value(w),
                0,
                0,
                shard,
            )
        };
        let q = MessageQueue::bounded(2);
        // Two distinct shards: nothing can fold, the bound stretches.
        q.push(mk(0, 1.0, 0.25));
        q.push(mk(1, 2.0, 0.25));
        q.push(mk(0, 3.0, 0.25));
        // Overflow fired once and folded the two shard-0 messages.
        let out = q.drain();
        assert_eq!(out.len(), 2);
        assert_eq!(q.stats().coalesced, 1);
        let s0: Vec<&Message> = out.iter().filter(|m| m.shard.index == 0).collect();
        assert_eq!(s0.len(), 1);
        assert!((s0[0].weight.value() - 0.5).abs() < 1e-12);
        assert!((first_coord(s0[0]) - 2.0).abs() < 1e-6, "blend of 1 and 3");
        // Now three mutually incompatible shards: bound must stretch.
        let plan3 = ShardPlan::new(9, 3);
        let q = MessageQueue::bounded(2);
        for k in 0..3 {
            let shard = plan3.shard(k);
            q.push(Message::for_shard(
                EncodedPayload::Dense(FlatVec::zeros(shard.len)),
                SumWeight::from_value(0.1),
                0,
                0,
                shard,
            ));
        }
        assert_eq!(q.len(), 3, "no compatible pair: queue stretches");
        assert_eq!(q.stats().over_capacity, 1);
    }

    #[test]
    fn property_bounded_pushes_conserve_weight_per_shard_and_globally() {
        // Satellite invariant: ANY sequence of pushes into a bounded
        // (coalescing) queue conserves the total sum weight exactly — per
        // shard and globally — no matter how often overflow folds.
        use crate::gossip::shard::ShardPlan;
        // BTreeMap, not HashMap: these per-shard masses are f64
        // accumulators, and hash iteration order would make the `sum()`
        // below nondeterministic across runs (the exact hazard
        // gosgd-lint's hash-order rule flags).
        use std::collections::BTreeMap;
        check("queue coalescing conserves weight", 50, |rng| {
            let dim = 16 + rng.below(200) as usize;
            let num_shards = 1 + rng.below(6) as usize;
            let plan = ShardPlan::new(dim, num_shards);
            let cap = 2 + rng.below(4) as usize;
            let q = MessageQueue::bounded(cap);
            let n_pushes = 1 + rng.below(60) as usize;
            let mut pushed: BTreeMap<(usize, usize), f64> = BTreeMap::new();
            for i in 0..n_pushes {
                let k = rng.below(num_shards as u64) as usize;
                let shard = plan.shard(k);
                let w = rng.f64() + 1e-6;
                *pushed.entry(shard.key()).or_insert(0.0) += w;
                q.push(Message::for_shard(
                    EncodedPayload::Dense(FlatVec::from_vec(vec![i as f32; shard.len])),
                    SumWeight::from_value(w),
                    i % 4,
                    i as u64,
                    shard,
                ));
            }
            let mut drained: BTreeMap<(usize, usize), f64> = BTreeMap::new();
            let mut total_out = 0.0;
            for m in q.drain() {
                *drained.entry(m.shard.key()).or_insert(0.0) += m.weight.value();
                total_out += m.weight.value();
            }
            let total_in: f64 = pushed.values().sum();
            assert!(
                (total_in - total_out).abs() < 1e-9,
                "global mass {total_in} -> {total_out}"
            );
            for (key, w_in) in &pushed {
                let w_out = drained.get(key).copied().unwrap_or(0.0);
                assert!(
                    (w_in - w_out).abs() < 1e-9,
                    "shard {key:?} mass {w_in} -> {w_out}"
                );
            }
        });
    }

    #[test]
    fn coalesce_reuses_a_uniquely_owned_payload_buffer() {
        // Dense fold: the blend runs in place on `a`'s existing buffer
        // instead of touching the heap — the allocation survives the fold.
        let a = msg(2.0, 0.25, 0);
        let ptr = a.payload.as_dense().unwrap().as_slice().as_ptr();
        let b = msg(6.0, 0.25, 1);
        let c = coalesce(a, b, None);
        let folded = c.payload.as_dense().unwrap();
        assert!((folded.as_slice()[0] - 4.0).abs() < 1e-6);
        assert_eq!(folded.as_slice().as_ptr(), ptr, "expected in-place blend");
    }

    #[test]
    fn coalesce_of_encoded_bodies_uses_pooled_scratch() {
        // Folding two q8 bodies needs one dense scratch; with a pool
        // attached that scratch is recycled storage, and both encoded
        // bodies' buffers flow back to the pool when the fold drops them.
        let pool = BufferPool::shared();
        let n = 64;
        let body = |val: f32| {
            QuantizeU8.encode_with(
                FlatVec::from_vec((0..n).map(|i| val + i as f32).collect()),
                &mut [],
                Some(&pool),
            )
        };
        let q = MessageQueue::bounded(2).with_pool(pool.clone());
        q.push(Message::new(body(0.0), SumWeight::from_value(0.25), 0, 0));
        q.push(Message::new(body(100.0), SumWeight::from_value(0.25), 1, 0));
        // Warm the f32 freelist so the fold's scratch is a hit.
        drop(FlatVec::pooled(&pool, n));
        let before = pool.stats();
        q.push(Message::new(body(200.0), SumWeight::from_value(0.5), 2, 0));
        assert_eq!(q.stats().coalesced, 1);
        let after = pool.stats();
        assert!(after.hits > before.hits, "fold scratch must come from the pool");
        assert!(
            after.recycled > before.recycled,
            "folded-away encoded bodies must recycle"
        );
        let total_w: f64 = q.drain().iter().map(|m| m.weight.value()).sum();
        assert!((total_w - 1.0).abs() < 1e-12);
    }

    #[test]
    fn coalescing_two_quantized_messages_equals_sequential_processing() {
        // Satellite invariant: two encoded same-shard messages fold through
        // their deterministic decode — the receiver's final state matches
        // absorbing them one at a time, and the fold's weight is the sum.
        let body = |vals: Vec<f32>| QuantizeU8.encode(FlatVec::from_vec(vals), &mut []);
        let m1 = Message::new(
            body(vec![2.0, -1.0, 0.5, 8.0]),
            SumWeight::from_value(0.25),
            0,
            0,
        );
        let m2 = Message::new(
            body(vec![6.0, 3.0, -2.0, 1.0]),
            SumWeight::from_value(0.25),
            1,
            0,
        );

        let mut direct = FlatVec::from_vec(vec![10.0; 4]);
        let mut w_direct = SumWeight::from_value(0.5);
        for m in [&m1, &m2] {
            let t = w_direct.absorb(m.weight);
            let deq = m.payload.decode();
            direct.mix_from(&deq, 1.0 - t, t).unwrap();
        }

        let c = coalesce(m1, m2, None);
        assert!(c.payload.as_dense().is_some(), "fold produces a dense body");
        assert!((c.weight.value() - 0.5).abs() < 1e-12);
        let mut folded = FlatVec::from_vec(vec![10.0; 4]);
        let mut w_folded = SumWeight::from_value(0.5);
        let t = w_folded.absorb(c.weight);
        folded.mix_from(c.payload.as_dense().unwrap(), 1.0 - t, t).unwrap();
        assert!((w_direct.value() - w_folded.value()).abs() < 1e-12);
        for (a, b) in direct.as_slice().iter().zip(folded.as_slice()) {
            assert!((a - b).abs() < 1e-5, "{direct:?} vs {folded:?}");
        }
    }

    #[test]
    fn sparse_messages_never_fold_the_bound_stretches() {
        // Top-k bodies carry no value for unlisted coordinates; folding
        // them would corrupt the "receiver keeps its own value" semantics,
        // so overflow stretches the bound instead (mass intact).
        let sparse = |vals: Vec<f32>| {
            let n = vals.len();
            let mut residual = vec![0.0f32; n];
            TopK { k: 1 }.encode(FlatVec::from_vec(vals), &mut residual)
        };
        let q = MessageQueue::bounded(2);
        q.push(Message::new(sparse(vec![1.0; 8]), SumWeight::from_value(0.2), 0, 0));
        q.push(Message::new(sparse(vec![2.0; 8]), SumWeight::from_value(0.2), 1, 0));
        q.push(Message::new(sparse(vec![3.0; 8]), SumWeight::from_value(0.2), 2, 0));
        assert_eq!(q.stats().coalesced, 0);
        assert_eq!(q.stats().over_capacity, 1);
        let out = q.drain();
        assert_eq!(out.len(), 3, "nothing folded, nothing dropped");
        let total: f64 = out.iter().map(|m| m.weight.value()).sum();
        assert!((total - 0.6).abs() < 1e-12);
        // A dense pair behind a sparse head still folds: compatibility is
        // per pair, not per queue.
        let q = MessageQueue::bounded(2);
        q.push(Message::new(sparse(vec![1.0; 8]), SumWeight::from_value(0.2), 0, 0));
        q.push(msg(4.0, 0.2, 1));
        q.push(msg(8.0, 0.2, 2));
        assert_eq!(q.stats().coalesced, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_pushers_lose_nothing() {
        let q = Arc::new(MessageQueue::unbounded());
        let rounds: usize = if cfg!(miri) { 25 } else { 250 };
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            handles.push(crate::sync::thread::spawn(move || {
                for i in 0..rounds {
                    q.push(msg(i as f32, 0.001, t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.drain().len(), 4 * rounds);
        assert_eq!(q.stats().pushed, 4 * rounds as u64);
    }

    #[test]
    fn same_seed_drains_produce_identical_blend_order() {
        // Determinism regression for the coalescing path: the same seeded
        // push sequence into two bounded queues must drain as bitwise
        // identical messages in identical order — any map-iteration or
        // fold-order nondeterminism inside push/coalesce would break the
        // DES trace hashes that gate PRs.
        use crate::gossip::shard::ShardPlan;
        use crate::util::rng::Rng;
        let run = |seed: u64| -> Vec<(usize, usize, u64, Vec<u32>)> {
            let plan = ShardPlan::new(24, 3);
            let q = MessageQueue::bounded(2);
            let mut rng = Rng::new(seed);
            for i in 0..40 {
                let k = rng.below(3) as usize;
                let shard = plan.shard(k);
                let w = rng.f64() + 1e-3;
                let vals: Vec<f32> = (0..shard.len).map(|_| rng.f64() as f32 - 0.5).collect();
                q.push(Message::for_shard(
                    EncodedPayload::Dense(FlatVec::from_vec(vals)),
                    SumWeight::from_value(w),
                    i % 5,
                    i as u64,
                    shard,
                ));
            }
            q.drain()
                .iter()
                .map(|m| {
                    (
                        m.shard.key().0,
                        m.shard.key().1,
                        m.weight.value().to_bits(),
                        m.payload.decode().as_slice().iter().map(|v| v.to_bits()).collect(),
                    )
                })
                .collect()
        };
        let a = run(0xD5_0123);
        let b = run(0xD5_0123);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must replay bit-identically through coalescing");
    }
}
