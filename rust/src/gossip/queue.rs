//! Per-worker concurrent mailbox (paper Algorithm 3: "each worker is
//! endowed with a queue q_m which can be concurrently accessed by all
//! workers").
//!
//! Requirements straight from the paper's protocol:
//!
//! * **Non-blocking push** — a sender must never wait for the receiver
//!   (asymmetric gossip; the whole point of section 4).
//! * **Batch drain** — the receiver processes *all* pending messages before
//!   its next gradient step (`ProcessMessages` loops until empty).
//! * **FIFO** per queue — messages blend in arrival order.
//!
//! Implementation: `Mutex<VecDeque>`; the lock is held for O(1) pointer
//! moves only (payloads are `Arc`ed), so contention is negligible compared
//! to a gradient step.  An optional bound sheds the *oldest* message on
//! overflow — under sum-weight semantics dropping a message would destroy
//! weight mass, so instead of dropping, `push` coalesces: overflow folds
//! the oldest two messages into one blended message, preserving total
//! weight exactly.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::gossip::message::Message;
use crate::gossip::weights::SumWeight;
use crate::tensor::FlatVec;

/// Statistics counters for one queue (all monotonic).
#[derive(Debug, Default, Clone, Copy)]
pub struct QueueStats {
    pub pushed: u64,
    pub drained: u64,
    pub coalesced: u64,
    pub max_depth: usize,
}

/// A worker's mailbox.
#[derive(Debug)]
pub struct MessageQueue {
    inner: Mutex<Inner>,
    capacity: Option<usize>,
}

#[derive(Debug)]
struct Inner {
    deque: VecDeque<Message>,
    stats: QueueStats,
}

impl MessageQueue {
    /// Unbounded queue (the paper's model).
    pub fn unbounded() -> Self {
        MessageQueue { inner: Mutex::new(Inner { deque: VecDeque::new(), stats: QueueStats::default() }), capacity: None }
    }

    /// Bounded queue that *coalesces* (never drops) on overflow.
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity >= 2, "coalescing bound needs capacity >= 2");
        MessageQueue { inner: Mutex::new(Inner { deque: VecDeque::new(), stats: QueueStats::default() }), capacity: Some(capacity) }
    }

    /// Non-blocking push (paper `PushMessage`). Never fails, never waits.
    pub fn push(&self, msg: Message) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.deque.push_back(msg);
        g.stats.pushed += 1;
        if let Some(cap) = self.capacity {
            if g.deque.len() > cap {
                // Fold the two oldest messages into one: weights add, the
                // parameter payload blends by the sum-weight rule, so the
                // receiver observes exactly the same final state as if it
                // had processed both (associativity of the blend).
                let a = g.deque.pop_front().expect("len > cap >= 2");
                let b = g.deque.pop_front().expect("len > cap >= 2");
                g.deque.push_front(coalesce(a, b));
                g.stats.coalesced += 1;
            }
        }
        let depth = g.deque.len();
        if depth > g.stats.max_depth {
            g.stats.max_depth = depth;
        }
    }

    /// Drain everything currently queued (paper `ProcessMessages`).
    pub fn drain(&self) -> Vec<Message> {
        let mut g = self.inner.lock().expect("queue poisoned");
        let out: Vec<Message> = g.deque.drain(..).collect();
        g.stats.drained += out.len() as u64;
        out
    }

    /// Current depth (diagnostics only; racy by nature).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.lock().expect("queue poisoned").stats
    }
}

/// Fold message `a` into message `b` preserving total weight:
/// the combined payload is the sum-weight blend of the two payloads.
fn coalesce(a: Message, b: Message) -> Message {
    let w_a = a.weight.value();
    let w_b = b.weight.value();
    let mut blended: FlatVec = (*a.params).clone();
    // blended <- (w_a * a + w_b * b) / (w_a + w_b)
    blended
        .mix_from(&b.params, w_a, w_b)
        .expect("coalesce: length mismatch inside one queue");
    Message::new(
        std::sync::Arc::new(blended),
        SumWeight::from_value(w_a + w_b),
        b.sender,
        b.sent_at_step,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn msg(val: f32, w: f64, sender: usize) -> Message {
        Message::new(
            Arc::new(FlatVec::from_vec(vec![val; 8])),
            SumWeight::from_value(w),
            sender,
            0,
        )
    }

    #[test]
    fn fifo_order() {
        let q = MessageQueue::unbounded();
        q.push(msg(1.0, 0.1, 0));
        q.push(msg(2.0, 0.1, 1));
        q.push(msg(3.0, 0.1, 2));
        let out = q.drain();
        let vals: Vec<f32> = out.iter().map(|m| m.params.as_slice()[0]).collect();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_empties_queue() {
        let q = MessageQueue::unbounded();
        q.push(msg(1.0, 0.5, 0));
        assert_eq!(q.drain().len(), 1);
        assert_eq!(q.drain().len(), 0);
    }

    #[test]
    fn stats_track_push_drain() {
        let q = MessageQueue::unbounded();
        for i in 0..5 {
            q.push(msg(i as f32, 0.1, 0));
        }
        q.drain();
        let s = q.stats();
        assert_eq!(s.pushed, 5);
        assert_eq!(s.drained, 5);
        assert_eq!(s.max_depth, 5);
        assert_eq!(s.coalesced, 0);
    }

    #[test]
    fn bounded_coalesces_preserving_weight() {
        let q = MessageQueue::bounded(2);
        q.push(msg(0.0, 0.25, 0));
        q.push(msg(1.0, 0.25, 1));
        q.push(msg(2.0, 0.5, 2)); // overflow: folds the two oldest
        let out = q.drain();
        assert_eq!(out.len(), 2);
        let total_w: f64 = out.iter().map(|m| m.weight.value()).sum();
        assert!((total_w - 1.0).abs() < 1e-12, "weight mass lost: {total_w}");
        // Folded payload is the weight-blend of 0.0 and 1.0 at equal weight.
        assert!((out[0].params.as_slice()[0] - 0.5).abs() < 1e-6);
        assert_eq!(q.stats().coalesced, 1);
    }

    #[test]
    fn coalesced_blend_equals_sequential_processing() {
        // Receiver state after absorbing (m1 then m2) must equal absorbing
        // the coalesced fold — associativity of the sum-weight blend.
        let mut direct = FlatVec::from_vec(vec![10.0; 8]);
        let mut w_direct = SumWeight::from_value(0.5);
        let m1 = msg(2.0, 0.25, 0);
        let m2 = msg(6.0, 0.25, 1);
        let t1 = w_direct.absorb(m1.weight);
        direct.mix_from(&m1.params, 1.0 - t1, t1).unwrap();
        let t2 = w_direct.absorb(m2.weight);
        direct.mix_from(&m2.params, 1.0 - t2, t2).unwrap();

        let mut folded = FlatVec::from_vec(vec![10.0; 8]);
        let mut w_folded = SumWeight::from_value(0.5);
        let c = coalesce(msg(2.0, 0.25, 0), msg(6.0, 0.25, 1));
        let t = w_folded.absorb(c.weight);
        folded.mix_from(&c.params, 1.0 - t, t).unwrap();

        assert!((w_direct.value() - w_folded.value()).abs() < 1e-12);
        for i in 0..8 {
            assert!(
                (direct.as_slice()[i] - folded.as_slice()[i]).abs() < 1e-5,
                "{:?} vs {:?}",
                direct.as_slice(),
                folded.as_slice()
            );
        }
    }

    #[test]
    fn concurrent_pushers_lose_nothing() {
        let q = Arc::new(MessageQueue::unbounded());
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    q.push(msg(i as f32, 0.001, t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.drain().len(), 1000);
        assert_eq!(q.stats().pushed, 1000);
    }
}
