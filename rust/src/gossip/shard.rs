//! Sharded gossip: partition the parameter vector across gossip events.
//!
//! The paper's protocol ships the *entire* `x_s` per exchange — fine for
//! the CIFAR CNN (~4 MB), fatal at 10⁸+ parameters.  Because the
//! sum-weight blend is associative *per coordinate*, the vector can be cut
//! into contiguous shards, each carrying its **own** sum weight, and each
//! gossip event can ship a single shard: per-shard the protocol is exactly
//! the paper's (halve on send, add on receive, convex blend), so per-shard
//! weight conservation and the consensus argument hold unchanged — chunked
//! blending is exact, not approximate (cf. GossipGraD's gradient
//! partitioning, Daily et al. 2018).
//!
//! [`Shard`] describes one slice on the wire; [`ShardPlan`] is the static,
//! deterministic partition every worker derives from `(dim, num_shards)` —
//! no negotiation, no metadata exchange.

/// One contiguous slice of the parameter vector, as carried by a message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Shard index in `0..num_shards`.
    pub index: usize,
    /// Total shards in the sender's plan (1 = unsharded full vector).
    pub num_shards: usize,
    /// First coordinate covered.
    pub offset: usize,
    /// Number of coordinates covered.
    pub len: usize,
}

impl Shard {
    /// The whole-vector "shard" of the classic protocol.
    pub fn full(dim: usize) -> Self {
        Shard { index: 0, num_shards: 1, offset: 0, len: dim }
    }

    /// Whether this message covers the entire parameter vector.
    pub fn is_full(&self) -> bool {
        self.num_shards == 1
    }

    /// Coalescing key: two messages may be folded together only when they
    /// cover the same coordinate range.
    pub fn key(&self) -> (usize, usize) {
        (self.offset, self.len)
    }
}

/// Deterministic even partition of `dim` coordinates into `num_shards`
/// contiguous ranges (the first `dim % num_shards` ranges get one extra
/// coordinate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    dim: usize,
    num_shards: usize,
}

impl ShardPlan {
    pub fn new(dim: usize, num_shards: usize) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        // The trivial 1-shard plan is the whole vector and is valid for
        // any dimension (including the degenerate empty model).
        assert!(
            num_shards == 1 || dim >= num_shards,
            "cannot cut {dim} coordinates into {num_shards} shards"
        );
        ShardPlan { dim, num_shards }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// Descriptor of shard `k`.
    pub fn shard(&self, k: usize) -> Shard {
        assert!(k < self.num_shards, "shard {k} out of {}", self.num_shards);
        let base = self.dim / self.num_shards;
        let rem = self.dim % self.num_shards;
        let (offset, len) = if k < rem {
            (k * (base + 1), base + 1)
        } else {
            (rem * (base + 1) + (k - rem) * base, base)
        };
        Shard { index: k, num_shards: self.num_shards, offset, len }
    }

    /// All shard descriptors in index order.
    pub fn shards(&self) -> Vec<Shard> {
        (0..self.num_shards).map(|k| self.shard(k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn full_shard_covers_everything() {
        let s = Shard::full(100);
        assert!(s.is_full());
        assert_eq!(s.offset, 0);
        assert_eq!(s.len, 100);
        assert_eq!(s.key(), (0, 100));
    }

    #[test]
    fn plan_of_one_shard_is_full_vector() {
        let p = ShardPlan::new(17, 1);
        assert_eq!(p.shard(0), Shard::full(17));
        // Degenerate but legal: the 1-shard plan over an empty vector.
        let empty = ShardPlan::new(0, 1);
        assert_eq!(empty.shard(0), Shard::full(0));
    }

    #[test]
    fn shards_tile_the_vector_exactly() {
        check("shards tile [0, dim)", 50, |rng| {
            let dim = 1 + rng.below(2000) as usize;
            let s = 1 + rng.below(dim.min(16) as u64) as usize;
            let plan = ShardPlan::new(dim, s);
            let mut cursor = 0;
            for (k, sh) in plan.shards().iter().enumerate() {
                assert_eq!(sh.index, k);
                assert_eq!(sh.num_shards, s);
                assert_eq!(sh.offset, cursor, "gap or overlap before shard {k}");
                assert!(sh.len >= 1);
                cursor += sh.len;
            }
            assert_eq!(cursor, dim, "shards must cover the whole vector");
        });
    }

    #[test]
    fn shards_are_balanced() {
        let plan = ShardPlan::new(10, 3);
        let lens: Vec<usize> = plan.shards().iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        let plan = ShardPlan::new(12, 4);
        let lens: Vec<usize> = plan.shards().iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![3, 3, 3, 3]);
    }

    #[test]
    #[should_panic(expected = "cannot cut")]
    fn more_shards_than_coordinates_rejected() {
        ShardPlan::new(3, 4);
    }
}
