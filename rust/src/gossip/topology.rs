//! Pluggable gossip topologies: who a sender gossips *to*.
//!
//! The paper draws the receiver uniformly from `{1..M} \ {s}` — the
//! complete-graph gossip whose expected exchange matrix is doubly
//! stochastic and whose spectral gap gives exponential consensus.
//! GossipGraD (Daily et al., 2018) showed that *structured, rotating*
//! partner schedules (ring / hypercube) reach consensus with far fewer
//! messages at scale, and Jin et al. (2016) motivate comparing exchange
//! patterns at fixed bandwidth.  This module makes the topology a
//! first-class, pluggable axis of the protocol:
//!
//! * [`TopologySpec`] — the plain-data description carried by configs and
//!   the CLI (`gosgd:P:SHARDS[:CODEC][:TOPO]` accepts `uniform | ring |
//!   hypercube | rotation`); [`TopologySpec::build`] materializes the
//!   [`Topology`] the protocol core picks peers with.
//! * [`Topology`] — next-peer schedule plus the *mixing-graph view*: the
//!   schedule-averaged peer-selection matrix `E[S]` with
//!   `S[s][r] = Pr(s picks r)`, which the consensus theory needs to be
//!   doubly stochastic (see `docs/ARCHITECTURE.md`, "Gossip matrices &
//!   topologies").
//!
//! Deterministic topologies are driven by a per-worker **schedule
//! cursor** owned by [`ProtocolCore`](crate::gossip::ProtocolCore) — it
//! advances once per peer pick, is checkpointed, and repairs around dead
//! peers under churn (the DES passes an aliveness mask; see
//! [`ProtocolCore::emit_alive`](crate::gossip::ProtocolCore::emit_alive)).
//!
//! | topology    | CLI token      | schedule at cursor `c`                  | period  |
//! |-------------|----------------|------------------------------------------|---------|
//! | uniform     | `uniform`      | uniform over the `M − 1` others (paper)  | 1       |
//! | ring        | `ring`         | successor `(s + 1) mod M`                | 1       |
//! | hypercube   | `hypercube`    | `s XOR 2^(c mod d)`, `d = log2 M`        | `d`     |
//! | rotation    | `rotation`     | `(s + 1 + (c mod (M−1))) mod M`          | `M − 1` |
//! | small world | `smallworld:Q` | ring successor, long-range w.p. `Q`      | 1       |
//!
//! Every schedule above averages to a doubly stochastic selection matrix
//! (`hypercube` requires a power-of-two `M`, enforced by
//! [`TopologySpec::validate_for`]); the property test lives in
//! `rust/tests/runtime_equivalence.rs`.
//!
//! ```
//! use gosgd::gossip::TopologySpec;
//! use gosgd::util::rng::Rng;
//!
//! let spec = TopologySpec::parse("rotation").unwrap();
//! assert_eq!(spec, TopologySpec::PartnerRotation);
//!
//! // Worker 0 of 4 rotates through offsets 1, 2, 3, 1, ...
//! let topo = spec.build();
//! let mut rng = Rng::new(0); // deterministic schedules ignore the RNG
//! assert_eq!(topo.next_peer(4, 0, 0, &mut rng), 1);
//! assert_eq!(topo.next_peer(4, 0, 1, &mut rng), 2);
//! assert_eq!(topo.next_peer(4, 0, 2, &mut rng), 3);
//! assert_eq!(topo.next_peer(4, 0, 3, &mut rng), 1);
//!
//! // The schedule-averaged selection matrix is doubly stochastic.
//! let m = 8;
//! let mat = TopologySpec::Hypercube.expected_matrix(m);
//! for r in 0..m {
//!     let col: f64 = (0..m).map(|s| mat[s * m + r]).sum();
//!     assert!((col - 1.0).abs() < 1e-12);
//! }
//! ```

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::gossip::peer::PeerSelector;
use crate::util::rng::{Draws, Rng};

/// Plain-data topology description: parseable, comparable, copyable —
/// the form carried by configs, CLIs and reports.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum TopologySpec {
    /// Uniform over the other `M − 1` workers (the paper's choice).
    #[default]
    UniformRandom,
    /// Deterministic ring successor `(s + 1) mod M` — minimal
    /// connectivity, slowest mixing, perfectly local traffic.
    Ring,
    /// GossipGraD-style hypercube: at schedule step `c` worker `s` sends
    /// to `s XOR 2^(c mod d)` with `d = log2 M`.  Each round is a perfect
    /// matching; all `M` workers reach each other within `d` steps.
    /// Requires a power-of-two worker count
    /// ([`TopologySpec::validate_for`]).
    Hypercube,
    /// Rotating partner schedule: at step `c` worker `s` sends to
    /// `(s + 1 + (c mod (M − 1))) mod M` — a deterministic cycle through
    /// every peer, one permutation per step.
    PartnerRotation,
    /// Ring successor with probability `1 − q`, uniform long-range
    /// shortcut with probability `q` (Watts–Strogatz flavoured).
    SmallWorld { q: f64 },
}

impl TopologySpec {
    /// Parse the CLI token: `uniform`, `ring`, `hypercube`, `rotation`,
    /// or `smallworld:Q` (the last only outside the colon-separated
    /// strategy grammar).
    pub fn parse(text: &str) -> Result<TopologySpec> {
        match text {
            "uniform" => Ok(TopologySpec::UniformRandom),
            "ring" => Ok(TopologySpec::Ring),
            "hypercube" => Ok(TopologySpec::Hypercube),
            "rotation" => Ok(TopologySpec::PartnerRotation),
            _ if text.starts_with("smallworld") => {
                // Reuse the PeerSelector validation for smallworld:Q so
                // both grammars reject the same garbage the same way.
                PeerSelector::parse(text).map(Into::into)
            }
            _ => Err(Error::config(format!(
                "unknown topology {text:?} (expected uniform | ring | hypercube | \
                 rotation | smallworld:Q)"
            ))),
        }
    }

    /// The CLI token / report label for this topology.
    pub fn label(&self) -> String {
        match self {
            TopologySpec::UniformRandom => "uniform".into(),
            TopologySpec::Ring => "ring".into(),
            TopologySpec::Hypercube => "hypercube".into(),
            TopologySpec::PartnerRotation => "rotation".into(),
            TopologySpec::SmallWorld { q } => format!("smallworld:{q}"),
        }
    }

    /// Whether the schedule is deterministic (cursor-driven, no RNG).
    pub fn deterministic(&self) -> bool {
        matches!(
            self,
            TopologySpec::Ring | TopologySpec::Hypercube | TopologySpec::PartnerRotation
        )
    }

    /// Validate the topology against a worker count.  The hypercube
    /// schedule is only a sequence of perfect matchings — and its
    /// expected matrix only doubly stochastic — when `M` is a power of
    /// two, so anything else is a config error.
    pub fn validate_for(&self, workers: usize) -> Result<()> {
        if matches!(self, TopologySpec::Hypercube)
            && (workers < 2 || !workers.is_power_of_two())
        {
            return Err(Error::config(format!(
                "hypercube topology needs a power-of-two worker count >= 2, got {workers}"
            )));
        }
        Ok(())
    }

    /// Materialize the schedule.
    pub fn build(&self) -> TopologyRef {
        match *self {
            TopologySpec::UniformRandom => Arc::new(UniformRandom),
            TopologySpec::Ring => Arc::new(Ring),
            TopologySpec::Hypercube => Arc::new(Hypercube),
            TopologySpec::PartnerRotation => Arc::new(PartnerRotation),
            TopologySpec::SmallWorld { q } => Arc::new(SmallWorld { q }),
        }
    }

    /// Convenience: the schedule-averaged selection matrix (see
    /// [`Topology::expected_matrix`]).
    pub fn expected_matrix(&self, m: usize) -> Vec<f64> {
        self.build().expected_matrix(m)
    }
}

/// The legacy `--peer` selector names a subset of the topologies.
impl From<PeerSelector> for TopologySpec {
    fn from(sel: PeerSelector) -> TopologySpec {
        match sel {
            PeerSelector::Uniform => TopologySpec::UniformRandom,
            PeerSelector::Ring => TopologySpec::Ring,
            PeerSelector::SmallWorld { q } => TopologySpec::SmallWorld { q },
        }
    }
}

/// A gossip topology: the next-peer schedule plus its mixing-graph view.
///
/// Implementations must be deterministic functions of `(m, s, slot)` and
/// the RNG stream — all three runtimes drive the same cores and the
/// cross-runtime equivalence tests demand identical trajectories.
pub trait Topology: Send + Sync + std::fmt::Debug {
    /// The plain-data description of this topology.
    fn spec(&self) -> TopologySpec;

    /// Schedule period: after how many cursor steps the deterministic
    /// schedule repeats (1 for the random topologies).
    fn period(&self, m: usize) -> u64;

    /// Receiver for sender `s` among `m` workers at schedule position
    /// `slot`.  Never returns `s`.  Random topologies ignore `slot`;
    /// deterministic ones ignore `rng`.  The draw source is `dyn` so the
    /// same schedule runs off the engine-wide [`Rng`] stream or a
    /// per-worker [`CounterRng`](crate::util::rng::CounterRng) lane.
    fn next_peer(&self, m: usize, s: usize, slot: u64, rng: &mut dyn Draws) -> usize;

    /// The mixing-graph view: the `m × m` row-major matrix `E[S]` with
    /// `S[s][r] = Pr(s picks r)`, averaged over the RNG and one full
    /// schedule period.  Rows always sum to 1; the consensus analysis
    /// additionally needs columns summing to 1 (doubly stochastic),
    /// which every shipped topology satisfies on its valid worker
    /// counts.
    fn expected_matrix(&self, m: usize) -> Vec<f64>;
}

/// Shared handle to a topology (protocol cores are `Clone`).
pub type TopologyRef = Arc<dyn Topology>;

/// Average a deterministic schedule over one period — the exact
/// mixing-graph view for the cursor-driven topologies.
fn matrix_from_schedule(topo: &dyn Topology, m: usize) -> Vec<f64> {
    let period = topo.period(m).max(1);
    let mut mat = vec![0.0; m * m];
    // Deterministic schedules never touch the RNG; a fixed seed keeps
    // this helper pure either way.
    let mut rng = Rng::new(0);
    for s in 0..m {
        for slot in 0..period {
            let r = topo.next_peer(m, s, slot, &mut rng);
            mat[s * m + r] += 1.0 / period as f64;
        }
    }
    mat
}

/// The paper's uniform draw over the other `M − 1` workers.
#[derive(Clone, Copy, Debug, Default)]
pub struct UniformRandom;

impl Topology for UniformRandom {
    fn spec(&self) -> TopologySpec {
        TopologySpec::UniformRandom
    }

    fn period(&self, _m: usize) -> u64 {
        1
    }

    fn next_peer(&self, m: usize, s: usize, _slot: u64, rng: &mut dyn Draws) -> usize {
        rng.peer(m, s)
    }

    fn expected_matrix(&self, m: usize) -> Vec<f64> {
        let p = 1.0 / (m - 1) as f64;
        let mut mat = vec![p; m * m];
        for s in 0..m {
            mat[s * m + s] = 0.0;
        }
        mat
    }
}

/// Deterministic ring successor.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ring;

impl Topology for Ring {
    fn spec(&self) -> TopologySpec {
        TopologySpec::Ring
    }

    fn period(&self, _m: usize) -> u64 {
        1
    }

    fn next_peer(&self, m: usize, s: usize, _slot: u64, _rng: &mut dyn Draws) -> usize {
        (s + 1) % m
    }

    fn expected_matrix(&self, m: usize) -> Vec<f64> {
        matrix_from_schedule(self, m)
    }
}

/// Number of hypercube dimensions for `m` workers: `ceil(log2 m)`.
fn hypercube_dims(m: usize) -> usize {
    debug_assert!(m >= 2);
    (usize::BITS - (m - 1).leading_zeros()) as usize
}

/// GossipGraD-style rotating hypercube dimension.
#[derive(Clone, Copy, Debug, Default)]
pub struct Hypercube;

impl Topology for Hypercube {
    fn spec(&self) -> TopologySpec {
        TopologySpec::Hypercube
    }

    fn period(&self, m: usize) -> u64 {
        hypercube_dims(m) as u64
    }

    fn next_peer(&self, m: usize, s: usize, slot: u64, _rng: &mut dyn Draws) -> usize {
        let d = hypercube_dims(m);
        let start = (slot % d as u64) as usize;
        // For a power-of-two m the first candidate is always in range.
        // The scan only matters for non-power-of-two counts (rejected by
        // validate_for, but next_peer must still be total): the partner
        // along the sender's own highest set bit is always < s, so some
        // dimension always lands in range.
        for j in 0..d {
            let partner = s ^ (1usize << ((start + j) % d));
            if partner < m {
                return partner;
            }
        }
        (s + 1) % m
    }

    fn expected_matrix(&self, m: usize) -> Vec<f64> {
        matrix_from_schedule(self, m)
    }
}

/// Deterministic rotation through every peer.
#[derive(Clone, Copy, Debug, Default)]
pub struct PartnerRotation;

impl Topology for PartnerRotation {
    fn spec(&self) -> TopologySpec {
        TopologySpec::PartnerRotation
    }

    fn period(&self, m: usize) -> u64 {
        (m as u64 - 1).max(1)
    }

    fn next_peer(&self, m: usize, s: usize, slot: u64, _rng: &mut dyn Draws) -> usize {
        let offset = 1 + (slot % (m as u64 - 1)) as usize;
        (s + offset) % m
    }

    fn expected_matrix(&self, m: usize) -> Vec<f64> {
        matrix_from_schedule(self, m)
    }
}

/// Ring neighbour with a probability-`q` uniform shortcut.
#[derive(Clone, Copy, Debug)]
pub struct SmallWorld {
    pub q: f64,
}

impl Topology for SmallWorld {
    fn spec(&self) -> TopologySpec {
        TopologySpec::SmallWorld { q: self.q }
    }

    fn period(&self, _m: usize) -> u64 {
        1
    }

    fn next_peer(&self, m: usize, s: usize, _slot: u64, rng: &mut dyn Draws) -> usize {
        if rng.bernoulli(self.q) {
            rng.peer(m, s)
        } else {
            (s + 1) % m
        }
    }

    fn expected_matrix(&self, m: usize) -> Vec<f64> {
        // Shortcut mass spreads uniformly (the successor can also be the
        // shortcut's draw); the remaining 1 − q sits on the successor.
        let shortcut = self.q / (m - 1) as f64;
        let mut mat = vec![shortcut; m * m];
        for s in 0..m {
            mat[s * m + s] = 0.0;
            mat[s * m + (s + 1) % m] += 1.0 - self.q;
        }
        mat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_specs() -> Vec<TopologySpec> {
        vec![
            TopologySpec::UniformRandom,
            TopologySpec::Ring,
            TopologySpec::Hypercube,
            TopologySpec::PartnerRotation,
            TopologySpec::SmallWorld { q: 0.25 },
        ]
    }

    #[test]
    fn parse_label_round_trips() {
        for spec in all_specs() {
            assert_eq!(TopologySpec::parse(&spec.label()).unwrap(), spec);
            assert_eq!(spec.build().spec(), spec);
        }
        assert!(TopologySpec::parse("torus").is_err());
        assert!(TopologySpec::parse("").is_err());
        assert!(TopologySpec::parse("smallworld:2.0").is_err());
        assert!(TopologySpec::parse("smallworld:NaN").is_err());
    }

    #[test]
    fn peer_selector_converts_losslessly() {
        assert_eq!(
            TopologySpec::from(PeerSelector::Uniform),
            TopologySpec::UniformRandom
        );
        assert_eq!(TopologySpec::from(PeerSelector::Ring), TopologySpec::Ring);
        assert_eq!(
            TopologySpec::from(PeerSelector::SmallWorld { q: 0.5 }),
            TopologySpec::SmallWorld { q: 0.5 }
        );
    }

    #[test]
    fn next_peer_never_returns_self_and_stays_in_range() {
        let mut rng = Rng::new(7);
        for spec in all_specs() {
            let topo = spec.build();
            for m in [2usize, 4, 8] {
                for s in 0..m {
                    for slot in 0..(2 * topo.period(m)) {
                        let r = topo.next_peer(m, s, slot, &mut rng);
                        assert!(r < m && r != s, "{spec:?} m={m} s={s} slot={slot} -> {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn hypercube_rounds_are_perfect_matchings() {
        // Power-of-two m: at every slot, partner-of-partner is self.
        let topo = Hypercube;
        let mut rng = Rng::new(0);
        for m in [2usize, 4, 8, 16] {
            for slot in 0..topo.period(m) {
                for s in 0..m {
                    let r = topo.next_peer(m, s, slot, &mut rng);
                    let back = topo.next_peer(m, r, slot, &mut rng);
                    assert_eq!(back, s, "m={m} slot={slot}: {s} -> {r} -> {back}");
                }
            }
        }
    }

    #[test]
    fn rotation_covers_every_peer_once_per_period() {
        let topo = PartnerRotation;
        let mut rng = Rng::new(0);
        let m = 6;
        for s in 0..m {
            let mut seen = vec![false; m];
            for slot in 0..topo.period(m) {
                let r = topo.next_peer(m, s, slot, &mut rng);
                assert!(!seen[r], "peer {r} repeated within the period");
                seen[r] = true;
            }
            assert_eq!(seen.iter().filter(|&&x| x).count(), m - 1);
        }
    }

    #[test]
    fn expected_matrices_are_doubly_stochastic() {
        for spec in all_specs() {
            // Hypercube only on power-of-two counts; everything else on
            // awkward counts too.
            let ms: &[usize] = if spec == TopologySpec::Hypercube {
                &[2, 4, 8, 16]
            } else {
                &[2, 3, 5, 8]
            };
            for &m in ms {
                let mat = spec.expected_matrix(m);
                for s in 0..m {
                    let row: f64 = mat[s * m..(s + 1) * m].iter().sum();
                    assert!((row - 1.0).abs() < 1e-12, "{spec:?} m={m} row {s}: {row}");
                    assert_eq!(mat[s * m + s], 0.0, "{spec:?} m={m}: self-loop at {s}");
                }
                for r in 0..m {
                    let col: f64 = (0..m).map(|s| mat[s * m + r]).sum();
                    assert!((col - 1.0).abs() < 1e-12, "{spec:?} m={m} col {r}: {col}");
                }
            }
        }
    }

    #[test]
    fn expected_matrix_matches_the_empirical_pick_frequency() {
        // The analytic matrices of the random topologies must agree with
        // what next_peer actually does.
        let mut rng = Rng::new(42);
        for spec in [TopologySpec::UniformRandom, TopologySpec::SmallWorld { q: 0.3 }] {
            let m = 5;
            let topo = spec.build();
            let want = topo.expected_matrix(m);
            let trials = 40_000;
            for s in 0..m {
                let mut counts = vec![0u32; m];
                for _ in 0..trials {
                    counts[topo.next_peer(m, s, 0, &mut rng)] += 1;
                }
                for r in 0..m {
                    let got = counts[r] as f64 / trials as f64;
                    assert!(
                        (got - want[s * m + r]).abs() < 0.015,
                        "{spec:?} s={s} r={r}: {got} vs {}",
                        want[s * m + r]
                    );
                }
            }
        }
    }

    #[test]
    fn validate_for_rejects_non_power_of_two_hypercubes() {
        assert!(TopologySpec::Hypercube.validate_for(8).is_ok());
        assert!(TopologySpec::Hypercube.validate_for(2).is_ok());
        for bad in [0usize, 1, 3, 6, 12] {
            assert!(
                TopologySpec::Hypercube.validate_for(bad).is_err(),
                "hypercube must reject M = {bad}"
            );
        }
        // Everything else accepts any count the protocol accepts.
        for spec in all_specs() {
            if spec != TopologySpec::Hypercube {
                assert!(spec.validate_for(3).is_ok(), "{spec:?}");
            }
        }
    }

    #[test]
    fn deterministic_flag_matches_rng_usage() {
        // A deterministic topology must not consume RNG state.
        for spec in all_specs() {
            let topo = spec.build();
            let mut a = Rng::new(9);
            let mut b = a.clone();
            let _ = topo.next_peer(8, 3, 5, &mut a);
            if spec.deterministic() {
                assert_eq!(a.next_u64(), b.next_u64(), "{spec:?} consumed RNG");
            } else {
                assert_ne!(a.next_u64(), b.next_u64(), "{spec:?} ignored its RNG");
            }
        }
    }
}
