//! Sum-weight bookkeeping (paper section 4 + Appendix B).
//!
//! Each worker carries a scalar weight `w_m`, initialized to `1/M`.  On a
//! send the sender *halves* its weight and ships the other half inside the
//! message; on a receive the weight is *added*.  Two facts make the
//! protocol correct:
//!
//! 1. **Conservation**: the total `Σ_m w_m` (counting in-flight messages)
//!    is invariant — halving + shipping moves mass, never creates it.
//! 2. **Lemma 1**: `E[w_r / (w_r + w_s)] = 1/2`, so in expectation every
//!    blend is an unweighted average and GoSGD performs gradient descent on
//!    the consensus-augmented objective (Appendix B).
//!
//! Both are enforced by the tests below (conservation as a property test
//! over arbitrary exchange schedules, the lemma as a statistical test).

/// A worker's gossip weight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SumWeight(f64);

impl SumWeight {
    /// Initial weight `1/M` (paper Algorithm 3, line 2).
    pub fn init(m: usize) -> Self {
        assert!(m > 0);
        SumWeight(1.0 / m as f64)
    }

    /// Raw value.
    pub fn value(&self) -> f64 {
        self.0
    }

    /// Construct from a raw value (message deserialization).
    pub fn from_value(w: f64) -> Self {
        assert!(w > 0.0 && w.is_finite(), "gossip weight must be positive, got {w}");
        SumWeight(w)
    }

    /// Sender side of an exchange: halve in place, return the shipped half
    /// (paper eq. 9 / Algorithm 4 `PushMessage`).
    pub fn halve_for_send(&mut self) -> SumWeight {
        self.0 *= 0.5;
        SumWeight(self.0)
    }

    /// Receiver side: blend coefficient for the incoming message, then
    /// absorb its weight (Algorithm 4 `ProcessMessages`, lines 9-10).
    ///
    /// Returns `t = w_s / (w_r + w_s)`, the coefficient applied to the
    /// *sender's* variable in `x_r <- (1-t) x_r + t x_s`.
    pub fn absorb(&mut self, incoming: SumWeight) -> f64 {
        let t = incoming.0 / (self.0 + incoming.0);
        self.0 += incoming.0;
        t
    }
}

impl Default for SumWeight {
    /// Single-worker default (weight 1).
    fn default() -> Self {
        SumWeight(1.0)
    }
}

/// Total weight across workers and in-flight messages — test/diagnostic
/// helper for the conservation invariant.
pub fn total_weight(workers: &[SumWeight], in_flight: &[SumWeight]) -> f64 {
    workers.iter().map(|w| w.0).sum::<f64>() + in_flight.iter().map(|w| w.0).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn init_is_one_over_m() {
        assert_eq!(SumWeight::init(8).value(), 0.125);
        assert_eq!(SumWeight::init(1).value(), 1.0);
    }

    #[test]
    fn halve_for_send_splits_evenly() {
        let mut w = SumWeight::from_value(0.5);
        let shipped = w.halve_for_send();
        assert_eq!(w.value(), 0.25);
        assert_eq!(shipped.value(), 0.25);
    }

    #[test]
    fn absorb_returns_blend_coefficient() {
        let mut w = SumWeight::from_value(0.25);
        let t = w.absorb(SumWeight::from_value(0.75));
        assert!((t - 0.75).abs() < 1e-12);
        assert!((w.value() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_weight_rejected() {
        SumWeight::from_value(0.0);
    }

    #[test]
    fn conservation_under_arbitrary_schedules() {
        // Property: for any sequence of send/deliver events among M workers
        // the total mass (workers + in-flight) stays exactly 1.
        check("sum-weight conservation", 100, |rng| {
            let m = 2 + rng.below(14) as usize;
            let mut weights: Vec<SumWeight> = (0..m).map(|_| SumWeight::init(m)).collect();
            let mut in_flight: Vec<(usize, SumWeight)> = Vec::new();
            for _ in 0..200 {
                if rng.bernoulli(0.5) || in_flight.is_empty() {
                    // send
                    let s = rng.below(m as u64) as usize;
                    let r = rng.peer(m, s);
                    let shipped = weights[s].halve_for_send();
                    in_flight.push((r, shipped));
                } else {
                    // deliver (possibly out of order)
                    let k = rng.below(in_flight.len() as u64) as usize;
                    let (r, w) = in_flight.swap_remove(k);
                    weights[r].absorb(w);
                }
                let flight: Vec<SumWeight> = in_flight.iter().map(|(_, w)| *w).collect();
                let total = total_weight(&weights, &flight);
                assert!((total - 1.0).abs() < 1e-9, "total drifted: {total}");
            }
        });
    }

    #[test]
    fn lemma1_weights_equal_in_expectation_ratio_documented() {
        // Paper Lemma 1 proves E[w^(t)] = λ^t·1: all worker weights are
        // EQUAL IN EXPECTATION.  The paper then concludes
        // E[w_r/(w_r+w_s)] = 1/2; operationally that does NOT hold exactly
        // — the receiver's full weight is blended against the sender's
        // *halved* weight, and the expectation of the ratio is not the
        // ratio of expectations (Jensen gap).  Measured, the coefficient
        // sits near 0.6 (see DESIGN.md §Paper-discrepancies); consensus
        // convergence is unaffected because the blend stays convex and the
        // mass conserved.  This test pins both facts.
        let m = 8;
        let p = 0.5;
        let mut rng = Rng::new(0xB10B);
        let mut weights: Vec<SumWeight> = (0..m).map(|_| SumWeight::init(m)).collect();
        let mut coeffs = Vec::new();
        let mut weight_sums = vec![0.0f64; m];
        let mut samples = 0u64;
        // queues of pending (receiver, weight)
        let mut queues: Vec<Vec<SumWeight>> = vec![Vec::new(); m];
        for _ in 0..60_000 {
            let s = rng.below(m as u64) as usize;
            // drain own queue first (Algorithm 3 line 4)
            let pending = std::mem::take(&mut queues[s]);
            for w in pending {
                coeffs.push(1.0 - weights[s].absorb(w)); // w_r/(w_r+w_s)
            }
            if rng.bernoulli(p) {
                let r = rng.peer(m, s);
                let shipped = weights[s].halve_for_send();
                queues[r].push(shipped);
            }
            for (i, w) in weights.iter().enumerate() {
                weight_sums[i] += w.value();
            }
            samples += 1;
        }
        // (a) The actual lemma: time-average weight is the same for every
        //     worker (symmetry / equal expectations).
        let means: Vec<f64> = weight_sums.iter().map(|s| s / samples as f64).collect();
        let grand = means.iter().sum::<f64>() / m as f64;
        for (i, mu) in means.iter().enumerate() {
            assert!(
                (mu - grand).abs() / grand < 0.1,
                "worker {i} mean weight {mu} deviates from {grand}"
            );
        }
        // (b) The measured blend coefficient is stable and ≈ 0.6 — NOT the
        //     paper's idealized 1/2; pinned so a regression is visible.
        let mean_coeff: f64 = coeffs.iter().sum::<f64>() / coeffs.len() as f64;
        assert!(
            (0.55..0.68).contains(&mean_coeff),
            "E[w_r/(w_r+w_s)] = {mean_coeff} (n={}) left its documented band",
            coeffs.len()
        );
    }

    #[test]
    fn weights_converge_back_toward_uniform() {
        // After heavy exchange, weights should stay positive and bounded.
        let m = 8;
        let mut rng = Rng::new(77);
        let mut weights: Vec<SumWeight> = (0..m).map(|_| SumWeight::init(m)).collect();
        for _ in 0..10_000 {
            let s = rng.below(m as u64) as usize;
            let r = rng.peer(m, s);
            let shipped = weights[s].halve_for_send();
            weights[r].absorb(shipped);
        }
        let total: f64 = weights.iter().map(|w| w.value()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        for w in &weights {
            assert!(w.value() > 0.0);
        }
    }
}
