//! Codec-comparison harness: consensus distance and train loss across
//! payload codecs at **fixed wall-clock bandwidth** (DES).
//!
//! A codec is only worth its accuracy loss if the saved bytes buy
//! something.  This harness makes the tradeoff explicit: every series
//! gets the *same wire budget per simulated second* — the dense baseline
//! runs at the configured `p`, and each compressed codec runs at
//! `p · (dense message bytes / its message bytes)` (capped at 1), so a
//! codec that ships 4× fewer bytes gossips 4× more often.  Under the
//! simulator's bandwidth-dominated latency model the per-second wire
//! usage then matches across series, and the question becomes purely:
//! which codec converts a byte of bandwidth into the most consensus and
//! loss progress?
//!
//! ```text
//! cargo run --release -- figure --figure codecs \
//!     --p 0.05 --shards 8 --codecs dense,top32,q8 \
//!     --horizon 120 --out results/codecs.csv
//! ```

use std::path::Path;

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, ShardPlan};
use crate::metrics::{ema_series, CsvWriter};
use crate::sim::{DesEngine, DesStrategy, FabricSpec, ParallelKind, TimeModel};
use crate::strategies::grad::QuadraticSource;
use crate::tensor::FlatVec;

/// Configuration for the codec comparison.
#[derive(Clone, Debug)]
pub struct CodecFigConfig {
    pub workers: usize,
    /// Exchange probability of the **dense** baseline; compressed codecs
    /// get proportionally more sends for the same bandwidth.
    pub p: f64,
    /// Gossip shards per exchange (1 = whole-vector messages).
    pub shards: usize,
    /// Codecs to compare.
    pub codecs: Vec<CodecSpec>,
    /// Quadratic-backend dimension and gradient noise.
    pub dim: usize,
    pub sigma: f32,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    /// Network model every series runs through (`Ideal` reproduces the
    /// pre-fabric figures; a finite preset adds NIC/switch contention).
    pub fabric: FabricSpec,
    /// DES executor threads (1 = sequential; more runs the sharded
    /// parallel executor — bit-identical results).
    pub threads: usize,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss traces.
    pub ema_beta: f64,
}

impl Default for CodecFigConfig {
    fn default() -> Self {
        CodecFigConfig {
            workers: 8,
            p: 0.05,
            shards: 8,
            codecs: vec![
                CodecSpec::Dense,
                CodecSpec::TopK { k: 32 },
                CodecSpec::QuantizeU8,
            ],
            dim: 1024,
            sigma: 0.2,
            horizon_secs: 120.0,
            time_model: TimeModel::paper_like(),
            fabric: FabricSpec::Ideal,
            threads: 1,
            seed: 0,
            eta: 1.0,
            weight_decay: 0.0,
            ema_beta: 0.95,
        }
    }
}

/// One codec's series.
#[derive(Clone, Debug)]
pub struct CodecSeries {
    pub label: String,
    /// `(sim_seconds, ema_loss)`.
    pub points: Vec<(f64, f64)>,
    /// The bandwidth-matched exchange probability this series ran at.
    pub effective_p: f64,
    pub steps: u64,
    pub messages: u64,
    /// Encoded wire bytes actually shipped.
    pub bytes: u64,
    /// Uncompressed cost of the same messages.
    pub raw_bytes: u64,
    /// Final consensus error `Σ_m ‖x_m − x̄‖²`.
    pub consensus_error: f64,
}

/// Mean encoded message bytes for `spec` over the shard plan (headers
/// included) — the planning-side quantity behind the bandwidth matching.
fn mean_message_bytes(spec: CodecSpec, dim: usize, shards: usize) -> f64 {
    let plan = ShardPlan::new(dim, shards);
    let sharded = shards > 1;
    let header = 8 + 16 + if sharded { 8 } else { 0 };
    let total: usize = plan
        .shards()
        .iter()
        .map(|s| spec.payload_wire_bytes(s.len) + header)
        .sum();
    total as f64 / shards as f64
}

fn run_one(cfg: &CodecFigConfig, spec: CodecSpec, effective_p: f64) -> Result<CodecSeries> {
    let mut grad = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed ^ 0xC0DE);
    let init = FlatVec::zeros(cfg.dim);
    let strategy = if cfg.shards > 1 {
        DesStrategy::ShardedGoSgd { p: effective_p, shards: cfg.shards }
    } else {
        DesStrategy::GoSgd { p: effective_p }
    };
    let mut eng = DesEngine::new(
        strategy,
        cfg.time_model.clone(),
        cfg.workers,
        &init,
        cfg.eta,
        cfg.weight_decay,
        cfg.seed,
    )?
    .with_codec(spec)
    .with_fabric(cfg.fabric)
    .with_parallel(if cfg.threads > 1 {
        ParallelKind::Sharded(cfg.threads)
    } else {
        ParallelKind::Sequential
    });
    eng.run(&mut grad, cfg.horizon_secs)?;
    let consensus_error = eng.consensus_error()?;
    let rep = eng.report();
    Ok(CodecSeries {
        label: spec.label(),
        points: ema_series(&rep.trace, cfg.ema_beta),
        effective_p,
        steps: rep.steps,
        messages: rep.messages,
        bytes: rep.bytes,
        raw_bytes: rep.raw_bytes,
        consensus_error,
    })
}

/// Run every configured codec at matched bandwidth.
pub fn run(cfg: &CodecFigConfig, out: Option<&Path>) -> Result<Vec<CodecSeries>> {
    if !(cfg.p > 0.0 && cfg.p <= 1.0) {
        return Err(Error::config(format!(
            "codec comparison needs an exchange probability in (0, 1], got {}",
            cfg.p
        )));
    }
    if cfg.codecs.is_empty() {
        return Err(Error::config("codec comparison needs at least one codec"));
    }
    if cfg.shards == 0 || (cfg.shards > 1 && cfg.shards > cfg.dim) {
        return Err(Error::config(format!(
            "cannot cut {} parameters into {} shards",
            cfg.dim, cfg.shards
        )));
    }
    let dense_bytes = mean_message_bytes(CodecSpec::Dense, cfg.dim, cfg.shards);
    let mut series = Vec::with_capacity(cfg.codecs.len());
    for &spec in &cfg.codecs {
        let ratio = dense_bytes / mean_message_bytes(spec, cfg.dim, cfg.shards);
        let effective_p = (cfg.p * ratio).min(1.0);
        series.push(run_one(cfg, spec, effective_p)?);
    }
    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "loss"])?;
        for s in &series {
            for &(t, l) in &s.points {
                csv.write_tagged_row(&s.label, &[t, l])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline comparison.
pub fn format_table(series: &[CodecSeries]) -> String {
    let mut out = String::from(
        "codec        p_eff   steps   messages    enc_MB    raw_MB   consensus_eps\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<12} {:>5.3}  {:>6}  {:>9}  {:>8.2}  {:>8.2}  {:>14.5}\n",
            s.label,
            s.effective_p,
            s.steps,
            s.messages,
            s.bytes as f64 / 1e6,
            s.raw_bytes as f64 / 1e6,
            s.consensus_error,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CodecFigConfig {
        CodecFigConfig {
            dim: 512,
            shards: 4,
            p: 0.1,
            horizon_secs: 40.0,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn codec_grid_runs_and_matches_bandwidth() {
        let cfg = small_cfg();
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 3);
        let by_label = |l: &str| {
            series
                .iter()
                .find(|s| s.label == l)
                .unwrap_or_else(|| panic!("missing series {l}"))
        };
        let dense = by_label("dense");
        let q8 = by_label("q8");
        assert_eq!(dense.effective_p, cfg.p);
        assert!(q8.effective_p > dense.effective_p, "q8 gets more sends per byte");
        // Dense: encoded == raw; q8: >= 3x compression at shard len 128.
        assert_eq!(dense.bytes, dense.raw_bytes);
        assert!(q8.raw_bytes >= 3 * q8.bytes, "{} vs {}", q8.bytes, q8.raw_bytes);
        // Bandwidth matching: encoded bytes per simulated second agree
        // within the stochastic send-count noise.
        let rate = |s: &CodecSeries| s.bytes as f64 / cfg.horizon_secs;
        let ratio = rate(q8) / rate(dense);
        assert!(
            (0.5..2.0).contains(&ratio),
            "q8 wire rate {} vs dense {} (ratio {ratio})",
            rate(q8),
            rate(dense)
        );
        // Everyone trains and reaches a finite consensus.
        for s in &series {
            assert!(s.steps > 0 && s.messages > 0);
            assert!(s.consensus_error.is_finite());
            let early: f64 = s.points.iter().take(30).map(|(_, l)| l).sum::<f64>() / 30.0;
            let late: f64 = s.points[s.points.len() - 30..]
                .iter()
                .map(|(_, l)| l)
                .sum::<f64>()
                / 30.0;
            assert!(late < early, "{}: {early} -> {late}", s.label);
        }
    }

    #[test]
    fn unsharded_comparison_runs_too() {
        let cfg = CodecFigConfig {
            shards: 1,
            codecs: vec![CodecSpec::Dense, CodecSpec::QuantizeU8],
            horizon_secs: 20.0,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 2);
        assert!(series.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn codec_grid_runs_through_a_finite_fabric() {
        let cfg = CodecFigConfig {
            fabric: FabricSpec::Rack,
            horizon_secs: 20.0,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|s| s.steps > 0 && s.messages > 0));
    }

    #[test]
    fn degenerate_knobs_are_config_errors() {
        let cfg = CodecFigConfig { p: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = CodecFigConfig { codecs: Vec::new(), ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = CodecFigConfig { shards: 4096, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("gosgd_codecs_test");
        let path = dir.join("codecs.csv");
        let cfg = CodecFigConfig { horizon_secs: 10.0, dim: 128, ..small_cfg() };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,loss\n"));
        assert!(text.lines().count() > 10);
        std::fs::remove_dir_all(&dir).ok();
    }
}
