//! Fabric-comparison harness: consensus distance and train loss across
//! network fabrics at **equal fabric budget** (DES).
//!
//! The codec and topology harnesses ask which protocol choice converts a
//! byte into the most progress — under an ideal network.  This harness
//! inverts the question: the protocol is pinned (same `(p, shards,
//! codec, topology)` for every series, so the *offered* traffic is
//! identical by construction — the equal fabric budget) and only the
//! network changes, from the ideal scalar-latency model through the
//! `rack` / `wan` / `edge` presets.  What the figure shows is how much
//! consensus and loss progress the same gossip stream loses to NIC
//! serialization, link delay + jitter, and switch oversubscription —
//! the contention costs GossipGraD argues actually decide the
//! gossip-vs-all-reduce question.
//!
//! Consensus is sampled along the horizon (the DES resumes across `run`
//! calls), so the output carries a per-fabric *consensus curve* next to
//! the loss curve, plus the fabric's queueing-delay accounting.
//!
//! ```text
//! cargo run --release -- figure --figure fabrics \
//!     --p 0.3 --shards 4 --fabrics ideal,rack,wan,edge \
//!     --horizon 120 --out results/fabrics.csv
//! ```

use std::path::Path;

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, TopologySpec};
use crate::metrics::{ema_series, CsvWriter};
use crate::sim::{DesEngine, DesStrategy, FabricSpec, ParallelKind, TimeModel};
use crate::strategies::grad::QuadraticSource;
use crate::tensor::FlatVec;

/// Configuration for the fabric comparison.
#[derive(Clone, Debug)]
pub struct FabricFigConfig {
    pub workers: usize,
    /// Exchange probability — shared by every series (equal offered load).
    pub p: f64,
    /// Gossip shards per exchange (1 = whole-vector messages).
    pub shards: usize,
    /// Payload codec — shared by every series.
    pub codec: CodecSpec,
    /// Receiver-selection topology — shared by every series.
    pub topology: TopologySpec,
    /// Fabrics to compare.
    pub fabrics: Vec<FabricSpec>,
    /// Quadratic-backend dimension and gradient noise.
    pub dim: usize,
    pub sigma: f32,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    /// Consensus samples taken along the horizon.
    pub samples: usize,
    /// DES executor threads (1 = sequential; more runs the sharded
    /// parallel executor — bit-identical results).
    pub threads: usize,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss traces.
    pub ema_beta: f64,
}

impl Default for FabricFigConfig {
    fn default() -> Self {
        FabricFigConfig {
            workers: 8,
            p: 0.3,
            shards: 4,
            codec: CodecSpec::Dense,
            topology: TopologySpec::UniformRandom,
            fabrics: vec![
                FabricSpec::Ideal,
                FabricSpec::Rack,
                FabricSpec::Wan,
                FabricSpec::Edge,
            ],
            dim: 4096,
            sigma: 0.2,
            horizon_secs: 120.0,
            time_model: TimeModel::paper_like(),
            samples: 40,
            threads: 1,
            seed: 0,
            eta: 1.0,
            weight_decay: 0.0,
            ema_beta: 0.95,
        }
    }
}

/// One fabric's series.
#[derive(Clone, Debug)]
pub struct FabricSeries {
    pub label: String,
    /// `(sim_seconds, ema_loss)`.
    pub loss: Vec<(f64, f64)>,
    /// `(sim_seconds, Σ_m ‖x_m − x̄‖²)` sampled along the horizon.
    pub consensus: Vec<(f64, f64)>,
    pub steps: u64,
    pub messages: u64,
    /// Encoded wire bytes actually shipped.
    pub bytes: u64,
    /// Total seconds messages spent queued inside the fabric (sender
    /// NICs + switch + receiver NICs); 0 under the ideal model.
    pub queued_secs: f64,
    /// Peak per-worker transmit-link utilization; 0 under ideal.
    pub peak_nic_utilization: f64,
    /// Final consensus error.
    pub final_consensus: f64,
}

fn run_one(cfg: &FabricFigConfig, fabric: FabricSpec) -> Result<FabricSeries> {
    let mut grad = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed ^ 0xFAB);
    let init = FlatVec::zeros(cfg.dim);
    let strategy = if cfg.shards > 1 {
        DesStrategy::ShardedGoSgd { p: cfg.p, shards: cfg.shards }
    } else {
        DesStrategy::GoSgd { p: cfg.p }
    };
    let mut eng = DesEngine::new(
        strategy,
        cfg.time_model.clone(),
        cfg.workers,
        &init,
        cfg.eta,
        cfg.weight_decay,
        cfg.seed,
    )?
    .with_codec(cfg.codec)
    .with_topology(cfg.topology)
    .with_fabric(fabric)
    .with_parallel(if cfg.threads > 1 {
        ParallelKind::Sharded(cfg.threads)
    } else {
        ParallelKind::Sequential
    });
    // The DES resumes across run calls, so consensus can be sampled along
    // the horizon without disturbing the event stream.
    let mut consensus = Vec::with_capacity(cfg.samples);
    for i in 1..=cfg.samples.max(1) {
        let t = cfg.horizon_secs * i as f64 / cfg.samples.max(1) as f64;
        eng.run(&mut grad, t)?;
        consensus.push((t, eng.consensus_error()?));
    }
    let final_consensus = eng.consensus_error()?;
    let rep = eng.report();
    let (queued_secs, peak_nic_utilization) = match &rep.fabric {
        Some(stats) => {
            let peak = stats
                .nic_utilization(rep.end_time)
                .into_iter()
                .fold(0.0f64, f64::max);
            (stats.queued_secs(), peak)
        }
        None => (0.0, 0.0),
    };
    Ok(FabricSeries {
        label: fabric.label(),
        loss: ema_series(&rep.trace, cfg.ema_beta),
        consensus,
        steps: rep.steps,
        messages: rep.messages,
        bytes: rep.bytes,
        queued_secs,
        peak_nic_utilization,
        final_consensus,
    })
}

/// Run every configured fabric under the shared offered load.
pub fn run(cfg: &FabricFigConfig, out: Option<&Path>) -> Result<Vec<FabricSeries>> {
    if !(cfg.p > 0.0 && cfg.p <= 1.0) {
        return Err(Error::config(format!(
            "fabric comparison needs an exchange probability in (0, 1], got {}",
            cfg.p
        )));
    }
    if cfg.fabrics.is_empty() {
        return Err(Error::config("fabric comparison needs at least one fabric"));
    }
    if cfg.shards == 0 || (cfg.shards > 1 && cfg.shards > cfg.dim) {
        return Err(Error::config(format!(
            "cannot cut {} parameters into {} shards",
            cfg.dim, cfg.shards
        )));
    }
    // Fail the whole grid up front rather than after minutes of sim.
    cfg.topology.validate_for(cfg.workers)?;
    let mut series = Vec::with_capacity(cfg.fabrics.len());
    for &fabric in &cfg.fabrics {
        series.push(run_one(cfg, fabric)?);
    }
    if let Some(path) = out {
        // Two curves per fabric, tagged `<label>/loss` and
        // `<label>/consensus`.
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "value"])?;
        for s in &series {
            let loss_tag = format!("{}/loss", s.label);
            for &(t, l) in &s.loss {
                csv.write_tagged_row(&loss_tag, &[t, l])?;
            }
            let eps_tag = format!("{}/consensus", s.label);
            for &(t, e) in &s.consensus {
                csv.write_tagged_row(&eps_tag, &[t, e])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline comparison.
pub fn format_table(series: &[FabricSeries]) -> String {
    let mut out = String::from(
        "fabric        steps   messages    enc_MB   queued_s  peak_util   consensus_eps\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<12} {:>6}  {:>9}  {:>8.2}  {:>9.2}  {:>9.3}  {:>14.5}\n",
            s.label,
            s.steps,
            s.messages,
            s.bytes as f64 / 1e6,
            s.queued_secs,
            s.peak_nic_utilization,
            s.final_consensus,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FabricFigConfig {
        FabricFigConfig {
            dim: 256,
            shards: 4,
            p: 0.3,
            horizon_secs: 40.0,
            samples: 10,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn fabric_grid_runs_at_equal_offered_load() {
        let cfg = small_cfg();
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 4);
        let by_label = |l: &str| {
            series
                .iter()
                .find(|s| s.label == l)
                .unwrap_or_else(|| panic!("missing series {l}"))
        };
        let ideal = by_label("ideal");
        assert_eq!(ideal.queued_secs, 0.0, "the ideal model never queues");
        for s in &series {
            assert!(s.steps > 0 && s.messages > 0, "{} sent nothing", s.label);
            // Equal fabric budget: fire-and-forget compute is untouched by
            // the network, so every series offers the same load within
            // stochastic noise.
            let ratio = s.messages as f64 / ideal.messages as f64;
            assert!(
                (0.8..1.25).contains(&ratio),
                "{}: offered load drifted ({} vs ideal {})",
                s.label,
                s.messages,
                ideal.messages
            );
            assert!(!s.loss.is_empty());
            assert_eq!(s.consensus.len(), cfg.samples);
            for w in s.consensus.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert!(s.final_consensus.is_finite());
            assert!((0.0..1.0).contains(&s.peak_nic_utilization), "{}", s.label);
            // Everyone still trains through every fabric.
            let early: f64 = s.loss.iter().take(30).map(|(_, l)| l).sum::<f64>() / 30.0;
            let late: f64 =
                s.loss[s.loss.len() - 30..].iter().map(|(_, l)| l).sum::<f64>() / 30.0;
            assert!(late < early, "{}: {early} -> {late}", s.label);
        }
    }

    #[test]
    fn congested_custom_fabric_accumulates_queueing_delay() {
        // A deliberately starved custom fabric (10 kB/s NICs) must show
        // the queueing the presets are calibrated to mostly avoid.
        let cfg = FabricFigConfig {
            fabrics: vec![FabricSpec::parse("custom:0.01:1:4").unwrap()],
            dim: 1024,
            horizon_secs: 20.0,
            samples: 4,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert!(
            series[0].queued_secs > 0.0,
            "starved NICs must queue, got {}",
            series[0].queued_secs
        );
        assert!(series[0].peak_nic_utilization > 0.1);
    }

    #[test]
    fn degenerate_knobs_are_config_errors() {
        let cfg = FabricFigConfig { p: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = FabricFigConfig { fabrics: Vec::new(), ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = FabricFigConfig { shards: 4096, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        // Hypercube + a non-power-of-two fleet fails up front.
        let cfg = FabricFigConfig {
            workers: 6,
            topology: TopologySpec::Hypercube,
            ..small_cfg()
        };
        assert!(run(&cfg, None).is_err());
    }

    #[test]
    fn csv_written_with_both_curves() {
        let dir = std::env::temp_dir().join("gosgd_fabrics_test");
        let path = dir.join("fabrics.csv");
        let cfg = FabricFigConfig {
            horizon_secs: 10.0,
            dim: 64,
            samples: 4,
            fabrics: vec![FabricSpec::Ideal, FabricSpec::Rack],
            ..small_cfg()
        };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,value\n"));
        assert!(text.contains("rack/loss,"));
        assert!(text.contains("rack/consensus,"));
        assert!(text.contains("ideal/consensus,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
