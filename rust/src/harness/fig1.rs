//! Figure 1: training loss vs iterations — PerSyn vs GoSGD across `p`.
//!
//! Paper section 5.1: both methods train the CIFAR CNN with M = 8,
//! lr = 0.1, wd = 1e-4, at exchange frequencies p ∈ {0.01, …, 0.4}.
//! Expected shape: PerSyn slightly faster *per iteration*; both nearly
//! insensitive to `p` down to 0.01; all far better than no communication.
//!
//! "Iteration" on the x-axis is a *worker-local* step: for the synchronous
//! PerSyn one engine round = one iteration; for asynchronous GoSGD, M
//! engine ticks = one iteration (each worker advanced once on average).

use std::path::Path;

use crate::config::{RunConfig, StrategyKind};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::metrics::CsvWriter;

/// Configuration for the Fig. 1 sweep.
#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub artifacts_dir: std::path::PathBuf,
    pub model: String,
    pub workers: usize,
    /// Worker-local iterations per series.
    pub iterations: u64,
    /// Exchange probabilities to sweep.
    pub ps: Vec<f64>,
    pub seed: u64,
    /// EMA smoothing for the reported curve.
    pub ema_beta: f64,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            workers: 8,
            iterations: 150,
            ps: vec![0.01, 0.4],
            seed: 0,
            ema_beta: 0.9,
        }
    }
}

/// One strategy's loss-vs-iteration series.
#[derive(Clone, Debug)]
pub struct LossSeries {
    pub label: String,
    /// `(worker_iteration, ema_loss)`.
    pub points: Vec<(u64, f64)>,
    pub messages: u64,
    pub final_loss: f64,
}

impl LossSeries {
    /// Iterations to reach `threshold` (paper's convergence-speed metric).
    pub fn iters_to(&self, threshold: f64) -> Option<u64> {
        self.points.iter().find(|(_, l)| *l < threshold).map(|(i, _)| *i)
    }
}

fn run_one(base: &Fig1Config, strategy: StrategyKind) -> Result<LossSeries> {
    let is_async = matches!(strategy, StrategyKind::GoSgd { .. });
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = base.artifacts_dir.clone();
    cfg.model = base.model.clone();
    cfg.workers = base.workers;
    cfg.strategy = strategy.clone();
    cfg.seed = base.seed;
    cfg.eval_every = 0;
    // Async engines need M ticks per worker-iteration.
    cfg.steps = if is_async {
        base.iterations * base.workers as u64
    } else {
        base.iterations
    };
    let rep = Coordinator::new(cfg)?.run()?;

    let ema = rep.train_loss.ema(base.ema_beta);
    let scale = if is_async { base.workers as u64 } else { 1 };
    let points: Vec<(u64, f64)> = ema
        .iter()
        .enumerate()
        .filter(|(i, _)| (*i as u64) % scale == 0)
        .map(|(i, &l)| (i as u64 / scale, l))
        .collect();
    Ok(LossSeries {
        label: strategy.tag(),
        final_loss: *ema.last().unwrap_or(&f64::NAN),
        points,
        messages: rep.messages,
    })
}

/// Run the PerSyn-vs-GoSGD sweep; CSV columns `series,iteration,loss`.
pub fn run(cfg: &Fig1Config, out: Option<&Path>) -> Result<Vec<LossSeries>> {
    let mut series = Vec::new();
    for &p in &cfg.ps {
        series.push(run_one(cfg, StrategyKind::GoSgd { p })?);
        series.push(run_one(
            cfg,
            StrategyKind::PerSyn { tau: (1.0 / p).round().max(1.0) as u64 },
        )?);
    }
    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["series", "iteration", "loss"])?;
        for s in &series {
            for &(i, l) in &s.points {
                csv.write_tagged_row(&s.label, &[i as f64, l])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table: final loss + messages per series.
pub fn format_table(series: &[LossSeries]) -> String {
    let mut out = String::from("series                  final_loss    messages\n");
    for s in series {
        out.push_str(&format!(
            "{:<22} {:>11.4}  {:>10}\n",
            s.label, s.final_loss, s.messages
        ));
    }
    out
}
