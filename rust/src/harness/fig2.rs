//! Figure 2: training loss vs wall clock — GoSGD vs EASGD at p = 0.02.
//!
//! Paper section 5.1: "GoSGD is significantly faster than EASGD", because
//! (a) its updates never block and (b) it needs half the messages at the
//! same exchange rate.  The testbed is a single CPU core, so wall time is
//! *simulated* by the discrete-event engine ([`crate::sim::des`]) with
//! GPU-era compute/latency ratios, while the gradients are real (PJRT
//! model or the quadratic proxy) — see DESIGN.md §Substitutions.

use std::path::Path;

use crate::data::{BatchSampler, SyntheticCifar};
use crate::error::Result;
use crate::metrics::{ema_series, CsvWriter};
use crate::runtime::{ModelRuntime, PjrtSource};
use crate::sim::{DesEngine, DesStrategy, TimeModel};
use crate::strategies::grad::{GradSource, QuadraticSource};
use crate::tensor::FlatVec;

/// Gradient backend for the wall-clock experiment.
#[derive(Clone, Debug, PartialEq)]
pub enum Fig2Backend {
    /// Real Layer-2 model via PJRT (artifact dir + model name).
    Pjrt { artifacts_dir: std::path::PathBuf, model: String },
    /// Noisy quadratic (no artifacts needed; shape-faithful).
    Quadratic { dim: usize, sigma: f32 },
}

/// Configuration for the Fig. 2 comparison.
#[derive(Clone, Debug)]
pub struct Fig2Config {
    pub backend: Fig2Backend,
    pub workers: usize,
    /// Exchange probability (paper: 0.02).
    pub p: f64,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss trace.
    pub ema_beta: f64,
    /// When > 1, adds a sharded-GoSGD series (one shard per exchange) to
    /// the comparison — the per-event latency and bytes drop by
    /// `~1/shards` while the blend stays exact per shard.
    pub shards: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            backend: Fig2Backend::Quadratic { dim: 1024, sigma: 0.2 },
            workers: 8,
            p: 0.02,
            horizon_secs: 120.0,
            time_model: TimeModel::paper_like(),
            seed: 0,
            eta: 1.0,
            weight_decay: 0.0,
            ema_beta: 0.95,
            shards: 1,
        }
    }
}

/// One wall-clock series.
#[derive(Clone, Debug)]
pub struct WallClockSeries {
    pub label: String,
    /// `(sim_seconds, ema_loss)`.
    pub points: Vec<(f64, f64)>,
    pub steps: u64,
    pub messages: u64,
    /// Wire bytes those messages carried (sharding shrinks this).
    pub bytes: u64,
    pub blocked_secs: f64,
}

impl WallClockSeries {
    /// Simulated seconds to reach `threshold` loss.
    pub fn secs_to(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|(_, l)| *l < threshold).map(|(t, _)| *t)
    }
}

fn run_strategy(cfg: &Fig2Config, strategy: DesStrategy, label: &str) -> Result<WallClockSeries> {
    let run_with = |grad: &mut dyn GradSource, init: &FlatVec| -> Result<WallClockSeries> {
        let mut eng = DesEngine::new(
            strategy.clone(),
            cfg.time_model.clone(),
            cfg.workers,
            init,
            cfg.eta,
            cfg.weight_decay,
            cfg.seed,
        )?;
        eng.run(grad, cfg.horizon_secs)?;
        let rep = eng.report();
        Ok(WallClockSeries {
            label: label.to_string(),
            points: ema_series(&rep.trace, cfg.ema_beta),
            steps: rep.steps,
            messages: rep.messages,
            bytes: rep.bytes,
            blocked_secs: rep.blocked_secs,
        })
    };

    match &cfg.backend {
        Fig2Backend::Quadratic { dim, sigma } => {
            let mut grad = QuadraticSource::new(*dim, *sigma, cfg.seed ^ 0xF162);
            let init = FlatVec::zeros(*dim);
            run_with(&mut grad, &init)
        }
        Fig2Backend::Pjrt { artifacts_dir, model } => {
            let runtime = ModelRuntime::load(artifacts_dir.join(model))?;
            let sampler = BatchSampler::new(
                SyntheticCifar::new(cfg.seed, 4.0, true),
                runtime.manifest().batch,
                cfg.workers,
            );
            let mut grad = PjrtSource::new(&runtime, sampler, cfg.workers);
            let init = runtime.manifest().load_init_params()?;
            run_with(&mut grad, &init)
        }
    }
}

/// Run GoSGD vs EASGD (and the PerSyn reference) under simulated time.
/// With `cfg.shards > 1` a sharded-GoSGD series is appended.
pub fn run(cfg: &Fig2Config, out: Option<&Path>) -> Result<Vec<WallClockSeries>> {
    let tau = (1.0 / cfg.p).round().max(1.0) as u64;
    let mut series = vec![
        run_strategy(cfg, DesStrategy::GoSgd { p: cfg.p }, &format!("gosgd_p{}", cfg.p))?,
        run_strategy(
            cfg,
            DesStrategy::Easgd { alpha: 0.9 / cfg.workers as f64, tau },
            &format!("easgd_tau{tau}"),
        )?,
        run_strategy(cfg, DesStrategy::PerSyn { tau }, &format!("persyn_tau{tau}"))?,
    ];
    if cfg.shards > 1 {
        series.push(run_strategy(
            cfg,
            DesStrategy::ShardedGoSgd { p: cfg.p, shards: cfg.shards },
            &format!("gosgd_p{}_s{}", cfg.p, cfg.shards),
        )?);
    }
    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "loss"])?;
        for s in &series {
            for &(t, l) in &s.points {
                csv.write_tagged_row(&s.label, &[t, l])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline comparison.
pub fn format_table(series: &[WallClockSeries], threshold: f64) -> String {
    let mut out = String::from(
        "series              steps   messages  kB/msg  blocked_s   secs_to_threshold\n",
    );
    for s in series {
        let secs = s
            .secs_to(threshold)
            .map(|t| format!("{t:.1}"))
            .unwrap_or_else(|| "-".into());
        let kb_per_msg = if s.messages > 0 {
            s.bytes as f64 / s.messages as f64 / 1024.0
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<18} {:>6}  {:>9}  {:>6.2}  {:>9.1}  {:>14}\n",
            s.label, s.steps, s.messages, kb_per_msg, s.blocked_secs, secs
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gosgd_beats_easgd_in_sim_time() {
        let cfg = Fig2Config {
            backend: Fig2Backend::Quadratic { dim: 256, sigma: 0.2 },
            horizon_secs: 60.0,
            p: 0.05,
            seed: 2,
            ..Default::default()
        };
        let series = run(&cfg, None).unwrap();
        let gossip = &series[0];
        let easgd = &series[1];
        // More steps in the same simulated time (no blocking).
        assert!(gossip.steps > easgd.steps);
        assert_eq!(gossip.blocked_secs, 0.0);
        assert!(easgd.blocked_secs > 0.0);
        // Reaches a mid-range loss earlier.
        let mid = 0.5 * (gossip.points[0].1 + gossip.points.last().unwrap().1);
        let (g, e) = (gossip.secs_to(mid), easgd.secs_to(mid));
        if let (Some(g), Some(e)) = (g, e) {
            assert!(g <= e * 1.1, "gossip {g}s vs easgd {e}s");
        }
    }

    #[test]
    fn sharded_series_appended_with_smaller_messages() {
        let cfg = Fig2Config {
            backend: Fig2Backend::Quadratic { dim: 512, sigma: 0.2 },
            horizon_secs: 30.0,
            p: 0.1,
            seed: 5,
            shards: 4,
            ..Default::default()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 4);
        let full = &series[0];
        let sharded = &series[3];
        assert!(sharded.label.contains("s4"));
        assert_eq!(sharded.blocked_secs, 0.0);
        let ratio = (sharded.bytes as f64 / sharded.messages as f64)
            / (full.bytes as f64 / full.messages as f64);
        assert!(ratio < 0.35, "bytes/msg ratio {ratio}");
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("gosgd_fig2_test");
        let path = dir.join("fig2.csv");
        let cfg = Fig2Config {
            backend: Fig2Backend::Quadratic { dim: 64, sigma: 0.2 },
            horizon_secs: 5.0,
            seed: 3,
            ..Default::default()
        };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,loss\n"));
        assert!(text.lines().count() > 10);
        std::fs::remove_dir_all(&dir).ok();
    }
}
