//! Figure 3: validation accuracy vs iterations — PerSyn vs GoSGD.
//!
//! Paper section 5.1: at p = 0.01 both reach equivalent validation
//! accuracy; at p = 0.4 GoSGD generalizes *better* despite a higher
//! training loss — the randomized exchanges act as a regularizer (the
//! paper compares the effect to DropConnect-style stochastic exploration).

use std::path::Path;

use crate::config::{RunConfig, StrategyKind};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::metrics::CsvWriter;

/// Configuration for the Fig. 3 sweep.
#[derive(Clone, Debug)]
pub struct Fig3Config {
    pub artifacts_dir: std::path::PathBuf,
    pub model: String,
    pub workers: usize,
    pub iterations: u64,
    pub ps: Vec<f64>,
    pub seed: u64,
    /// Evaluate every this many worker-iterations.
    pub eval_every: u64,
    pub eval_batches: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            workers: 8,
            iterations: 150,
            ps: vec![0.01, 0.4],
            seed: 0,
            eval_every: 25,
            eval_batches: 4,
        }
    }
}

/// One strategy's accuracy-vs-iteration series.
#[derive(Clone, Debug)]
pub struct AccuracySeries {
    pub label: String,
    /// `(worker_iteration, val_loss, val_accuracy)`.
    pub points: Vec<(u64, f64, f64)>,
    pub final_accuracy: f64,
    pub final_train_loss: f64,
}

fn run_one(base: &Fig3Config, strategy: StrategyKind) -> Result<AccuracySeries> {
    let is_async = matches!(strategy, StrategyKind::GoSgd { .. });
    let scale = if is_async { base.workers as u64 } else { 1 };
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = base.artifacts_dir.clone();
    cfg.model = base.model.clone();
    cfg.workers = base.workers;
    cfg.strategy = strategy.clone();
    cfg.seed = base.seed;
    cfg.steps = base.iterations * scale;
    cfg.eval_every = base.eval_every * scale;
    cfg.eval_batches = base.eval_batches;
    let rep = Coordinator::new(cfg)?.run()?;
    Ok(AccuracySeries {
        label: strategy.tag(),
        points: rep
            .evals
            .iter()
            .map(|&(s, l, a)| (s / scale, l, a))
            .collect(),
        final_accuracy: rep.final_accuracy,
        final_train_loss: rep.train_loss.window_mean(
            rep.train_loss.len().saturating_sub(20),
            rep.train_loss.len(),
        ),
    })
}

/// Run the sweep; CSV columns `series,iteration,val_loss,val_accuracy`.
pub fn run(cfg: &Fig3Config, out: Option<&Path>) -> Result<Vec<AccuracySeries>> {
    let mut series = Vec::new();
    for &p in &cfg.ps {
        series.push(run_one(cfg, StrategyKind::GoSgd { p })?);
        series.push(run_one(
            cfg,
            StrategyKind::PerSyn { tau: (1.0 / p).round().max(1.0) as u64 },
        )?);
    }
    if let Some(path) = out {
        let mut csv =
            CsvWriter::create(path, &["series", "iteration", "val_loss", "val_accuracy"])?;
        for s in &series {
            for &(i, l, a) in &s.points {
                csv.write_tagged_row(&s.label, &[i as f64, l, a])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table.
pub fn format_table(series: &[AccuracySeries]) -> String {
    let mut out =
        String::from("series                  final_acc   final_train_loss\n");
    for s in series {
        out.push_str(&format!(
            "{:<22} {:>9.3}  {:>16.4}\n",
            s.label, s.final_accuracy, s.final_train_loss
        ));
    }
    out
}
