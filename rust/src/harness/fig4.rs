//! Figure 4: consensus error ε(t) under worst-case (pure noise) updates.
//!
//! Paper section 5.2: replace every gradient by an i.i.d. `N(0,1)` draw —
//! local models drift apart as fast as possible and only communication
//! holds them together.  The figure plots `ε(t) = Σ_m ‖x_m − x̄‖²` for
//! GoSGD and PerSyn at several exchange frequencies `p`.
//!
//! Expected shapes (what the paper shows and our assertions check):
//! * PerSyn: periodic sawtooth — ε collapses to 0 at each sync, grows in
//!   between; the amplitude scales with `tau = 1/p`.
//! * GoSGD: same *magnitude* as PerSyn's envelope but far less variation.
//! * Both are bounded; the no-communication baseline grows linearly.

use std::path::Path;

use crate::error::Result;
use crate::metrics::CsvWriter;
use crate::strategies::engine::Engine;
use crate::strategies::gosgd::GoSgd;
use crate::strategies::grad::NoiseSource;
use crate::strategies::local::Local;
use crate::strategies::persyn::PerSyn;
use crate::strategies::Strategy;
use crate::tensor::FlatVec;

/// Configuration for the consensus experiment.
#[derive(Clone, Debug)]
pub struct Fig4Config {
    /// Workers (paper: 8).
    pub workers: usize,
    /// Parameter dimension (paper's CNN has ~1.7M; 1000 reproduces the
    /// dynamics at a fraction of the cost — ε concentrates fast in d).
    pub dim: usize,
    /// Rounds to simulate (one round = M single-worker ticks for GoSGD).
    pub rounds: u64,
    /// Exchange frequencies/probabilities to sweep (paper: 0.01 … 1).
    pub ps: Vec<f64>,
    pub seed: u64,
    /// Include the no-communication baseline series.
    pub include_local: bool,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config {
            workers: 8,
            dim: 1000,
            rounds: 1000,
            ps: vec![0.01, 0.1, 0.5, 1.0],
            seed: 0,
            include_local: true,
        }
    }
}

/// One output series.
#[derive(Clone, Debug)]
pub struct ConsensusSeries {
    pub label: String,
    /// `(round, epsilon)` samples.
    pub points: Vec<(u64, f64)>,
}

impl ConsensusSeries {
    pub fn mean_eps(&self) -> f64 {
        // skip warmup third
        let skip = self.points.len() / 3;
        let tail = &self.points[skip..];
        tail.iter().map(|(_, e)| e).sum::<f64>() / tail.len() as f64
    }

    pub fn max_eps(&self) -> f64 {
        self.points.iter().map(|(_, e)| *e).fold(0.0, f64::max)
    }

    /// Coefficient of variation of the tail — the paper's "PerSyn has big
    /// variation, GoSGD much less" claim, quantified.
    pub fn cv(&self) -> f64 {
        let skip = self.points.len() / 3;
        let tail: Vec<f64> = self.points[skip..].iter().map(|(_, e)| *e).collect();
        let mean = crate::util::mean(&tail);
        if mean == 0.0 {
            return 0.0;
        }
        crate::util::stddev(&tail) / mean
    }
}

fn run_one(
    strategy: Box<dyn Strategy>,
    label: String,
    cfg: &Fig4Config,
    async_clock: bool,
) -> Result<ConsensusSeries> {
    let src = NoiseSource::new(cfg.dim, cfg.seed ^ 0xF16_4);
    let init = FlatVec::zeros(cfg.dim);
    // Paper: the "gradient" IS the noise, so lr = 1, no decay.
    let mut eng = Engine::new(strategy, src, cfg.workers, &init, 1.0, 0.0, cfg.seed);
    let ticks_per_round = if async_clock { cfg.workers as u64 } else { 1 };
    let mut points = Vec::with_capacity(cfg.rounds as usize);
    for round in 0..cfg.rounds {
        eng.run(ticks_per_round)?;
        points.push((round + 1, eng.state().stacked.consensus_error()?));
    }
    Ok(ConsensusSeries { label, points })
}

/// Run the full sweep; write CSV if `out` is given.
pub fn run(cfg: &Fig4Config, out: Option<&Path>) -> Result<Vec<ConsensusSeries>> {
    let mut series = Vec::new();
    for &p in &cfg.ps {
        series.push(run_one(
            Box::new(GoSgd::new(p)),
            format!("gosgd_p{p}"),
            cfg,
            true,
        )?);
        series.push(run_one(
            Box::new(PerSyn::from_probability(p)),
            format!("persyn_p{p}"),
            cfg,
            false,
        )?);
    }
    if cfg.include_local {
        series.push(run_one(Box::new(Local), "local".into(), cfg, false)?);
    }
    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["series", "round", "epsilon"])?;
        for s in &series {
            for &(r, e) in &s.points {
                csv.write_tagged_row(&s.label, &[r as f64, e])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Pretty-printed comparison table (the console rendering of Fig. 4).
pub fn format_table(series: &[ConsensusSeries]) -> String {
    let mut out = String::from(
        "series                 mean_eps      max_eps        cv\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<22} {:>10.3}  {:>10.3}  {:>8.3}\n",
            s.label,
            s.mean_eps(),
            s.max_eps(),
            s.cv()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Fig4Config {
        Fig4Config {
            workers: 8,
            dim: 200,
            rounds: 300,
            ps: vec![0.1],
            seed: 1,
            include_local: true,
        }
    }

    #[test]
    fn gossip_and_persyn_same_magnitude_gossip_less_variation() {
        let series = run(&small_cfg(), None).unwrap();
        let gossip = &series[0];
        let persyn = &series[1];
        let local = &series[2];
        // same order of magnitude (paper: "as both share the same magnitude")
        let ratio = gossip.mean_eps() / persyn.mean_eps();
        assert!(
            (0.2..5.0).contains(&ratio),
            "magnitude ratio {ratio}: gossip {} persyn {}",
            gossip.mean_eps(),
            persyn.mean_eps()
        );
        // PerSyn's sawtooth has much higher relative variation.
        assert!(
            gossip.cv() < persyn.cv(),
            "gossip cv {} vs persyn cv {}",
            gossip.cv(),
            persyn.cv()
        );
        // Both are far below the no-communication baseline.
        assert!(gossip.max_eps() < local.points.last().unwrap().1);
    }

    #[test]
    fn higher_p_means_lower_consensus_error() {
        let mut cfg = small_cfg();
        cfg.ps = vec![0.05, 0.5];
        cfg.include_local = false;
        let series = run(&cfg, None).unwrap();
        let gossip_low = &series[0]; // p = 0.05
        let gossip_high = &series[2]; // p = 0.5
        assert!(
            gossip_high.mean_eps() < gossip_low.mean_eps(),
            "p=0.5 {} vs p=0.05 {}",
            gossip_high.mean_eps(),
            gossip_low.mean_eps()
        );
    }

    #[test]
    fn csv_output_is_written() {
        let dir = std::env::temp_dir().join("gosgd_fig4_test");
        let path = dir.join("fig4.csv");
        let mut cfg = small_cfg();
        cfg.rounds = 20;
        cfg.include_local = false;
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,round,epsilon\n"));
        assert_eq!(text.lines().count(), 1 + 2 * 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_formats() {
        let mut cfg = small_cfg();
        cfg.rounds = 30;
        cfg.include_local = false;
        let series = run(&cfg, None).unwrap();
        let table = format_table(&series);
        assert!(table.contains("gosgd_p0.1"));
        assert!(table.contains("persyn_p0.1"));
    }
}
