//! Experiment harnesses: one module per paper figure.
//!
//! Each harness regenerates the data series behind a figure of the
//! paper's evaluation (section 5) and writes a CSV under `results/`:
//!
//! * [`fig1`] — training loss vs iterations, PerSyn vs GoSGD across `p`.
//! * [`fig2`] — training loss vs (simulated) wall clock, GoSGD vs EASGD.
//! * [`fig3`] — validation accuracy vs iterations, PerSyn vs GoSGD.
//! * [`fig4`] — consensus error ε(t) under pure-noise updates.
//! * [`variance`] — Appendix A: gradient-estimator error ∝ 1/N.
//! * [`scenarios`] — beyond the paper: GoSGD vs the barrier baseline
//!   under heterogeneous compute and crash/rejoin worker churn (DES).
//! * [`codecs`] — beyond the paper: consensus distance and train loss
//!   across payload codecs (dense / top-k / u8 quantization) at fixed
//!   wall-clock bandwidth (DES).
//! * [`topologies`] — beyond the paper: consensus distance and train
//!   loss across gossip topologies (uniform / ring / hypercube /
//!   partner rotation) at equal encoded-byte budget (DES).
//! * [`fabrics`] — beyond the paper: the same gossip stream through the
//!   ideal / rack / wan / edge network fabrics at equal offered load
//!   (DES with finite-bandwidth fabric).
//! * [`scale`] — beyond the paper: consensus and loss curves as the
//!   fleet grows by orders of magnitude (timing-wheel DES with
//!   copy-on-write worker state and sampled telemetry).

pub mod codecs;
pub mod fabrics;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod scale;
pub mod scenarios;
pub mod topologies;
pub mod variance;
