//! Fleet-scaling harness: consensus and loss curves as the worker count
//! grows by orders of magnitude (DES).
//!
//! The paper's evaluation stops at 8 workers; the simulator does not.
//! With the timing-wheel scheduler, copy-on-write worker models, and
//! sampled telemetry, the same gossip protocol runs at thousands to a
//! million simulated workers in bounded memory.  This harness sweeps a
//! list of fleet sizes at fixed protocol settings (hypercube schedule +
//! u8-quantized payloads by default — the cheapest wire format that
//! scales) and records, per fleet: the consensus curve, the loss curve,
//! resident bytes per worker, and simulator throughput in events/sec.
//!
//! Consensus at megafleet scale is computed over the strided telemetry
//! sample (see `DesEngine::with_telemetry_sample`), not the full fleet —
//! the estimator the scaling chapter of `docs/ARCHITECTURE.md` describes.
//!
//! ```text
//! cargo run --release -- figure --figure scale \
//!     --fleets 4096,65536,1048576 --codec q8 --topology hypercube \
//!     --horizon 2 --out results/scale.csv
//! ```

use std::path::Path;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, TopologySpec};
use crate::metrics::{ema_series, CsvWriter};
use crate::sim::{DesEngine, DesStrategy, ParallelKind, TimeModel};
use crate::strategies::grad::QuadraticSource;
use crate::tensor::FlatVec;

/// Configuration for the fleet-scaling sweep.
#[derive(Clone, Debug)]
pub struct ScaleFigConfig {
    /// Fleet sizes to sweep (hypercube needs powers of two).
    pub fleets: Vec<usize>,
    /// Exchange probability — fixed across fleets.
    pub p: f64,
    /// Gossip shards per exchange.
    pub shards: usize,
    /// Payload codec (default u8 quantization).
    pub codec: CodecSpec,
    /// Gossip topology (default hypercube — O(1) peer selection and
    /// log-diameter mixing, the schedule built for large fleets).
    pub topology: TopologySpec,
    /// Quadratic-backend dimension and gradient noise.
    pub dim: usize,
    pub sigma: f32,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    /// Consensus samples taken along the horizon.
    pub samples: usize,
    /// Telemetry sample size per fleet (strided worker subset).
    pub telemetry: usize,
    /// DES executor threads (1 = sequential; more runs the sharded
    /// parallel executor — bit-identical results).
    pub threads: usize,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss traces.
    pub ema_beta: f64,
}

impl Default for ScaleFigConfig {
    fn default() -> Self {
        ScaleFigConfig {
            fleets: vec![1 << 12, 1 << 16],
            p: 0.05,
            shards: 4,
            codec: CodecSpec::QuantizeU8,
            topology: TopologySpec::Hypercube,
            dim: 64,
            sigma: 0.2,
            horizon_secs: 2.0,
            time_model: TimeModel::paper_like(),
            samples: 8,
            telemetry: 1024,
            threads: 1,
            seed: 0,
            eta: 0.5,
            weight_decay: 0.0,
            ema_beta: 0.95,
        }
    }
}

/// One fleet size's series.
#[derive(Clone, Debug)]
pub struct ScaleSeries {
    pub workers: usize,
    /// `(sim_seconds, ema_loss)` over the telemetry sample.
    pub loss: Vec<(f64, f64)>,
    /// `(sim_seconds, Σ_sample ‖x_m − x̄‖²)` along the horizon.
    pub consensus: Vec<(f64, f64)>,
    pub steps: u64,
    pub messages: u64,
    /// Resident bytes per worker at the end of the run.
    pub bytes_per_worker: usize,
    /// Simulator throughput: (steps + messages) / wall seconds.
    pub events_per_sec: f64,
    pub final_consensus: f64,
}

fn run_one(cfg: &ScaleFigConfig, workers: usize) -> Result<ScaleSeries> {
    let mut grad = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed ^ 0x5CA1);
    let init = FlatVec::zeros(cfg.dim);
    let mut eng = DesEngine::new(
        DesStrategy::ShardedGoSgd { p: cfg.p, shards: cfg.shards },
        cfg.time_model.clone(),
        workers,
        &init,
        cfg.eta,
        cfg.weight_decay,
        cfg.seed,
    )?
    .with_codec(cfg.codec)
    .with_topology(cfg.topology)
    .with_telemetry_sample(cfg.telemetry)
    .with_parallel(if cfg.threads > 1 {
        ParallelKind::Sharded(cfg.threads)
    } else {
        ParallelKind::Sequential
    });
    let wall = Instant::now();
    let mut consensus = Vec::with_capacity(cfg.samples);
    for i in 1..=cfg.samples.max(1) {
        let t = cfg.horizon_secs * i as f64 / cfg.samples.max(1) as f64;
        eng.run(&mut grad, t)?;
        consensus.push((t, eng.consensus_error()?));
    }
    let elapsed = wall.elapsed().as_secs_f64();
    let final_consensus = eng.consensus_error()?;
    let bytes_per_worker = eng.state_bytes() / workers;
    let rep = eng.report();
    Ok(ScaleSeries {
        workers,
        loss: ema_series(&rep.trace, cfg.ema_beta),
        consensus,
        steps: rep.steps,
        messages: rep.messages,
        bytes_per_worker,
        events_per_sec: (rep.steps + rep.messages) as f64 / elapsed.max(1e-9),
        final_consensus,
    })
}

/// Sweep every configured fleet size at fixed protocol settings.
pub fn run(cfg: &ScaleFigConfig, out: Option<&Path>) -> Result<Vec<ScaleSeries>> {
    if !(cfg.p > 0.0 && cfg.p <= 1.0) {
        return Err(Error::config(format!(
            "fleet scaling needs an exchange probability in (0, 1], got {}",
            cfg.p
        )));
    }
    if cfg.fleets.is_empty() {
        return Err(Error::config("fleet scaling needs at least one fleet size"));
    }
    if cfg.shards == 0 || (cfg.shards > 1 && cfg.shards > cfg.dim) {
        return Err(Error::config(format!(
            "cannot cut {} parameters into {} shards",
            cfg.dim, cfg.shards
        )));
    }
    for &workers in &cfg.fleets {
        if workers < 2 {
            return Err(Error::config(format!(
                "fleet scaling needs at least 2 workers per fleet, got {workers}"
            )));
        }
        // Fail the whole sweep up front rather than hours into a megafleet.
        cfg.topology.validate_for(workers)?;
    }
    let mut series = Vec::with_capacity(cfg.fleets.len());
    for &workers in &cfg.fleets {
        series.push(run_one(cfg, workers)?);
    }
    if let Some(path) = out {
        // Two curves per fleet, tagged `scale_<workers>/loss` and
        // `scale_<workers>/consensus`.
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "value"])?;
        for s in &series {
            let loss_tag = format!("scale_{}/loss", s.workers);
            for &(t, l) in &s.loss {
                csv.write_tagged_row(&loss_tag, &[t, l])?;
            }
            let eps_tag = format!("scale_{}/consensus", s.workers);
            for &(t, e) in &s.consensus {
                csv.write_tagged_row(&eps_tag, &[t, e])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline scaling numbers.
pub fn format_table(series: &[ScaleSeries]) -> String {
    let mut out = String::from(
        "workers       steps    messages   bytes/worker    events/sec   consensus_eps\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<10} {:>9}  {:>10}  {:>13}  {:>12.0}  {:>14.5}\n",
            s.workers, s.steps, s.messages, s.bytes_per_worker, s.events_per_sec, s.final_consensus,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ScaleFigConfig {
        ScaleFigConfig {
            fleets: vec![16, 64],
            p: 0.2,
            horizon_secs: 10.0,
            samples: 5,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn fleet_sweep_produces_both_curves_per_fleet() {
        let cfg = small_cfg();
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 2);
        for s in &series {
            assert!(s.steps > 0 && s.messages > 0, "{} workers sent nothing", s.workers);
            assert!(!s.loss.is_empty());
            assert_eq!(s.consensus.len(), cfg.samples);
            for w in s.consensus.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert!(s.final_consensus.is_finite());
            assert!(s.bytes_per_worker > 0);
            assert!(s.events_per_sec > 0.0);
        }
        // The larger fleet takes more total steps over the same horizon.
        assert!(series[1].steps > series[0].steps);
    }

    #[test]
    fn degenerate_knobs_are_config_errors() {
        let cfg = ScaleFigConfig { p: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = ScaleFigConfig { fleets: Vec::new(), ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = ScaleFigConfig { fleets: vec![1], ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        // Hypercube + a non-power-of-two fleet fails up front.
        let cfg = ScaleFigConfig { fleets: vec![24], ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = ScaleFigConfig { shards: 4096, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
    }

    #[test]
    fn telemetry_sampling_keeps_consensus_finite_on_a_bigger_fleet() {
        // 256 workers with an 8-worker telemetry sample: the consensus
        // estimator runs over the strided subset, stays finite, and the
        // sweep still completes quickly.
        let cfg = ScaleFigConfig {
            fleets: vec![256],
            telemetry: 8,
            horizon_secs: 5.0,
            samples: 3,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 1);
        assert!(series[0].final_consensus.is_finite());
        assert!(series[0].steps > 256);
    }

    #[test]
    fn csv_written_with_per_fleet_tags() {
        let dir = std::env::temp_dir().join("gosgd_scale_test");
        let path = dir.join("scale.csv");
        let cfg = ScaleFigConfig {
            fleets: vec![16, 32],
            horizon_secs: 5.0,
            samples: 3,
            ..small_cfg()
        };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,value\n"));
        assert!(text.contains("scale_16/loss,"));
        assert!(text.contains("scale_16/consensus,"));
        assert!(text.contains("scale_32/consensus,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
