//! Scenario-diversity harness: GoSGD under heterogeneous compute and
//! worker churn (DES).
//!
//! The paper's evaluation assumes a homogeneous, reliable cluster.  Real
//! fleets are neither: machines differ in speed (mixed hardware
//! generations, co-tenancy) and workers crash and come back.  This
//! harness runs the gossip protocol — and the PerSyn barrier baseline —
//! through the discrete-event simulator under four scenarios:
//!
//! * `uniform` — the paper's setting (baseline).
//! * `hetero`  — persistent per-worker compute multipliers
//!   ([`ScenarioModel::compute_scale`]); one slow machine, everyone else
//!   unaffected under gossip, everyone dragged down under a barrier.
//! * `churn`   — crash/rejoin worker churn
//!   ([`ScenarioModel::crash_mtbf`] / [`ScenarioModel::rejoin_mttr`]);
//!   mailboxes buffer through downtime, weight mass is conserved.
//! * `hetero_churn` — both at once.
//!
//! ```text
//! cargo run --release -- figure --figure scenarios \
//!     --p 0.05 --hetero 1,1,1,1,1,1,1,4 --mtbf 20 --mttr 5 \
//!     --horizon 120 --out results/scenarios.csv
//! ```

use std::path::Path;

use crate::error::Result;
use crate::metrics::{ema_series, CsvWriter};
use crate::sim::{DesEngine, DesStrategy, FabricSpec, ParallelKind, ScenarioModel, TimeModel};
use crate::strategies::grad::QuadraticSource;
use crate::tensor::FlatVec;

/// Configuration for the scenario comparison.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub workers: usize,
    /// Exchange probability for the gossip series.
    pub p: f64,
    /// Gossip shards per exchange (1 = whole-vector messages).
    pub shards: usize,
    /// Quadratic-backend dimension and gradient noise.
    pub dim: usize,
    pub sigma: f32,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    /// Network model for the gossip series (`Ideal` reproduces the
    /// pre-fabric figures).  The PerSyn baseline always runs ideal: its
    /// barrier synchronizes through master paths the fabric does not
    /// model, and the engine rejects the combination.
    pub fabric: FabricSpec,
    /// Compute multipliers for the hetero series, cycled over the workers
    /// (`w % len`, matching [`ScenarioModel::scale`]).  Empty = the
    /// default shape: every worker at 1.0 except one 4× straggler.
    pub compute_scale: Vec<f64>,
    /// Mean seconds between crashes / mean downtime for the churn series.
    pub crash_mtbf: f64,
    pub rejoin_mttr: f64,
    /// DES executor threads for the gossip series (1 = sequential; more
    /// runs the sharded parallel executor — bit-identical results).  The
    /// barrier baselines always run sequentially.
    pub threads: usize,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss traces.
    pub ema_beta: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            workers: 8,
            p: 0.05,
            shards: 1,
            dim: 512,
            sigma: 0.2,
            horizon_secs: 120.0,
            time_model: TimeModel::paper_like(),
            fabric: FabricSpec::Ideal,
            // Empty = derive the default shape (one 4× straggler).
            compute_scale: Vec::new(),
            crash_mtbf: 20.0,
            rejoin_mttr: 5.0,
            threads: 1,
            seed: 0,
            eta: 1.0,
            weight_decay: 0.0,
            ema_beta: 0.95,
        }
    }
}

/// One scenario series.
#[derive(Clone, Debug)]
pub struct ScenarioSeries {
    pub label: String,
    /// `(sim_seconds, ema_loss)`.
    pub points: Vec<(f64, f64)>,
    pub steps: u64,
    pub messages: u64,
    pub bytes: u64,
    pub blocked_secs: f64,
    pub crashes: u64,
    pub downtime_secs: f64,
}

fn run_one(
    cfg: &ScenarioConfig,
    strategy: DesStrategy,
    scenario: ScenarioModel,
    label: &str,
) -> Result<ScenarioSeries> {
    let mut grad = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed ^ 0x5CE0);
    let init = FlatVec::zeros(cfg.dim);
    // Only the fire-and-forget series route through a finite fabric; the
    // barrier baseline keeps the ideal model (the engine would reject the
    // combination as a config error).
    let fabric = if strategy.fire_and_forget() {
        cfg.fabric
    } else {
        FabricSpec::Ideal
    };
    let parallel = if cfg.threads > 1 && strategy.fire_and_forget() {
        ParallelKind::Sharded(cfg.threads)
    } else {
        ParallelKind::Sequential
    };
    let mut eng = DesEngine::new(
        strategy,
        cfg.time_model.clone(),
        cfg.workers,
        &init,
        cfg.eta,
        cfg.weight_decay,
        cfg.seed,
    )?
    .with_scenario(scenario)
    .with_fabric(fabric)
    .with_parallel(parallel);
    eng.run(&mut grad, cfg.horizon_secs)?;
    let rep = eng.report();
    Ok(ScenarioSeries {
        label: label.to_string(),
        points: ema_series(&rep.trace, cfg.ema_beta),
        steps: rep.steps,
        messages: rep.messages,
        bytes: rep.bytes,
        blocked_secs: rep.blocked_secs,
        crashes: rep.crashes,
        downtime_secs: rep.downtime_secs,
    })
}

/// Run the scenario grid: gossip under uniform / hetero / churn / both,
/// plus PerSyn under uniform and hetero (the barrier pays for the
/// straggler; churn would deadlock it, which is the point).
pub fn run(cfg: &ScenarioConfig, out: Option<&Path>) -> Result<Vec<ScenarioSeries>> {
    if !(cfg.p > 0.0 && cfg.p <= 1.0) {
        // p = 0 would also saturate the PerSyn tau below into a
        // never-syncing baseline — reject instead of comparing nonsense.
        return Err(crate::error::Error::config(format!(
            "scenarios needs an exchange probability in (0, 1], got {}",
            cfg.p
        )));
    }
    if !(cfg.crash_mtbf > 0.0
        && cfg.crash_mtbf.is_finite()
        && cfg.rejoin_mttr > 0.0
        && cfg.rejoin_mttr.is_finite())
    {
        // Disabled churn would silently duplicate the baseline under a
        // "churn" label.
        return Err(crate::error::Error::config(format!(
            "scenarios needs positive churn parameters (mtbf {}, mttr {})",
            cfg.crash_mtbf, cfg.rejoin_mttr
        )));
    }
    // Empty multipliers = the default shape; an explicit list keeps the
    // cycled `w % len` semantics of `ScenarioModel::scale` but must
    // actually slow some worker down, or the "hetero" series would be the
    // uniform series relabeled.
    let compute_scale = if cfg.compute_scale.is_empty() {
        let mut v = vec![1.0; cfg.workers.saturating_sub(1)];
        v.push(4.0);
        v
    } else {
        cfg.compute_scale.clone()
    };
    let hetero = ScenarioModel { compute_scale, ..ScenarioModel::none() };
    if (0..cfg.workers).all(|w| hetero.scale(w) == 1.0) {
        return Err(crate::error::Error::config(format!(
            "every one of the {} workers gets compute multiplier 1.0 from {:?} — \
             the hetero series would equal the baseline",
            cfg.workers, hetero.compute_scale
        )));
    }
    let gossip = if cfg.shards > 1 {
        DesStrategy::ShardedGoSgd { p: cfg.p, shards: cfg.shards }
    } else {
        DesStrategy::GoSgd { p: cfg.p }
    };
    let churn = ScenarioModel {
        compute_scale: Vec::new(),
        crash_mtbf: cfg.crash_mtbf,
        rejoin_mttr: cfg.rejoin_mttr,
    };
    let both = ScenarioModel {
        compute_scale: hetero.compute_scale.clone(),
        crash_mtbf: cfg.crash_mtbf,
        rejoin_mttr: cfg.rejoin_mttr,
    };
    let tau = (1.0 / cfg.p).round().max(1.0) as u64;
    let series = vec![
        run_one(cfg, gossip.clone(), ScenarioModel::none(), "gosgd_uniform")?,
        run_one(cfg, gossip.clone(), hetero.clone(), "gosgd_hetero")?,
        run_one(cfg, gossip.clone(), churn, "gosgd_churn")?,
        run_one(cfg, gossip, both, "gosgd_hetero_churn")?,
        run_one(
            cfg,
            DesStrategy::PerSyn { tau },
            ScenarioModel::none(),
            &format!("persyn_tau{tau}_uniform"),
        )?,
        run_one(
            cfg,
            DesStrategy::PerSyn { tau },
            hetero,
            &format!("persyn_tau{tau}_hetero"),
        )?,
    ];
    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "loss"])?;
        for s in &series {
            for &(t, l) in &s.points {
                csv.write_tagged_row(&s.label, &[t, l])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline comparison.
pub fn format_table(series: &[ScenarioSeries]) -> String {
    let mut out = String::from(
        "series                     steps   messages  blocked_s  crashes  downtime_s\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<25} {:>6}  {:>9}  {:>9.1}  {:>7}  {:>10.1}\n",
            s.label, s.steps, s.messages, s.blocked_secs, s.crashes, s.downtime_secs
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ScenarioConfig {
        ScenarioConfig {
            dim: 64,
            horizon_secs: 50.0,
            p: 0.1,
            crash_mtbf: 8.0,
            rejoin_mttr: 3.0,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn scenario_grid_runs_and_shows_the_expected_shape() {
        let cfg = small_cfg();
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 6);
        let by_label = |l: &str| {
            series
                .iter()
                .find(|s| s.label.contains(l))
                .unwrap_or_else(|| panic!("missing series {l}"))
        };
        let uniform = by_label("gosgd_uniform");
        let hetero = by_label("gosgd_hetero");
        let churn = by_label("gosgd_churn");
        // Gossip never blocks, in any scenario.
        assert_eq!(uniform.blocked_secs, 0.0);
        assert_eq!(hetero.blocked_secs, 0.0);
        // The straggler only costs its own steps.
        assert!(hetero.steps < uniform.steps, "{} vs {}", hetero.steps, uniform.steps);
        // Churn crashes workers and costs downtime, but training goes on.
        assert!(churn.crashes > 0);
        assert!(churn.downtime_secs > 0.0);
        assert!(churn.steps < uniform.steps);
        assert!(churn.steps > 0);
        // The barrier baseline pays for the persistent straggler.
        let persyn_uniform = by_label("persyn_tau10_uniform");
        let persyn_hetero = by_label("persyn_tau10_hetero");
        assert!(
            persyn_hetero.blocked_secs > persyn_uniform.blocked_secs,
            "persyn hetero {} vs uniform {}",
            persyn_hetero.blocked_secs,
            persyn_uniform.blocked_secs
        );
        // Gossip keeps descending under the combined scenario.
        let both = by_label("gosgd_hetero_churn");
        let early: f64 = both.points.iter().take(30).map(|(_, l)| l).sum::<f64>() / 30.0;
        let late: f64 = both.points[both.points.len() - 30..]
            .iter()
            .map(|(_, l)| l)
            .sum::<f64>()
            / 30.0;
        assert!(late < early, "{early} -> {late}");
    }

    #[test]
    fn sharded_gossip_scenarios_run_too() {
        let cfg = ScenarioConfig { shards: 4, ..small_cfg() };
        let series = run(&cfg, None).unwrap();
        assert!(series[0].messages > 0);
        assert!(series.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn scenario_grid_runs_through_a_finite_fabric() {
        // The gossip series take the fabric; PerSyn silently keeps ideal
        // (instead of erroring the whole grid out).
        let cfg = ScenarioConfig {
            fabric: FabricSpec::Wan,
            horizon_secs: 30.0,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 6);
        assert!(series.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn degenerate_knobs_are_config_errors() {
        // p = 0 would saturate the PerSyn tau into a never-syncing run.
        let cfg = ScenarioConfig { p: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        // A multiplier list whose reachable entries are all 1.0 would
        // silently relabel the uniform series as "hetero".
        let cfg = ScenarioConfig {
            workers: 4,
            compute_scale: vec![1.0, 1.0],
            ..small_cfg()
        };
        assert!(run(&cfg, None).is_err());
        // Disabled churn would duplicate the baseline under a churn label.
        let cfg = ScenarioConfig { crash_mtbf: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
    }

    #[test]
    fn default_hetero_shape_adapts_to_the_worker_count() {
        // Empty compute_scale derives one straggler regardless of fleet
        // size — the CLI default works for any --workers.
        let cfg = ScenarioConfig { workers: 4, horizon_secs: 20.0, ..small_cfg() };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 6);
        assert!(series.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("gosgd_scenarios_test");
        let path = dir.join("scenarios.csv");
        let cfg = ScenarioConfig { horizon_secs: 10.0, dim: 32, ..small_cfg() };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,loss\n"));
        assert!(text.lines().count() > 10);
        std::fs::remove_dir_all(&dir).ok();
    }
}
