//! Topology-comparison harness: consensus distance and train loss across
//! gossip topologies at **equal encoded-byte budget** (DES).
//!
//! GossipGraD (Daily et al., 2018) argues that structured, rotating
//! partner schedules reach consensus with far fewer messages than
//! uniform-random gossip; Jin et al. (2016) motivate comparing exchange
//! patterns at fixed bandwidth.  This harness runs that comparison: every
//! series shares the same `(p, shards, codec)` — messages are the same
//! size and fire at the same expected rate, so the wire budget per
//! simulated second is identical by construction — and only the
//! receiver-selection topology varies.  The question is purely: which
//! mixing graph converts a byte of gossip into the most consensus and
//! loss progress?
//!
//! Consensus is sampled along the horizon (the DES resumes across `run`
//! calls), so the output carries a per-topology *consensus curve* next to
//! the loss curve.
//!
//! ```text
//! cargo run --release -- figure --figure topologies \
//!     --p 0.05 --shards 4 --topologies uniform,ring,hypercube,rotation \
//!     --horizon 120 --out results/topologies.csv
//! ```

use std::path::Path;

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, TopologySpec};
use crate::metrics::{ema_series, CsvWriter};
use crate::sim::{DesEngine, DesStrategy, FabricSpec, ParallelKind, TimeModel};
use crate::strategies::grad::QuadraticSource;
use crate::tensor::FlatVec;

/// Configuration for the topology comparison.
#[derive(Clone, Debug)]
pub struct TopoFigConfig {
    pub workers: usize,
    /// Exchange probability — shared by every series (equal budget).
    pub p: f64,
    /// Gossip shards per exchange (1 = whole-vector messages).
    pub shards: usize,
    /// Payload codec — shared by every series (equal budget).
    pub codec: CodecSpec,
    /// Topologies to compare.
    pub topologies: Vec<TopologySpec>,
    /// Quadratic-backend dimension and gradient noise.
    pub dim: usize,
    pub sigma: f32,
    /// Simulated horizon in seconds.
    pub horizon_secs: f64,
    pub time_model: TimeModel,
    /// Network model every series runs through (`Ideal` reproduces the
    /// pre-fabric figures; a finite preset adds NIC/switch contention).
    pub fabric: FabricSpec,
    /// Consensus samples taken along the horizon.
    pub samples: usize,
    /// DES executor threads (1 = sequential; more runs the sharded
    /// parallel executor — bit-identical results).
    pub threads: usize,
    pub seed: u64,
    pub eta: f32,
    pub weight_decay: f32,
    /// EMA smoothing for the loss traces.
    pub ema_beta: f64,
}

impl Default for TopoFigConfig {
    fn default() -> Self {
        TopoFigConfig {
            workers: 8,
            p: 0.05,
            shards: 4,
            codec: CodecSpec::Dense,
            topologies: vec![
                TopologySpec::UniformRandom,
                TopologySpec::Ring,
                TopologySpec::Hypercube,
                TopologySpec::PartnerRotation,
            ],
            dim: 1024,
            sigma: 0.2,
            horizon_secs: 120.0,
            time_model: TimeModel::paper_like(),
            fabric: FabricSpec::Ideal,
            samples: 40,
            threads: 1,
            seed: 0,
            eta: 1.0,
            weight_decay: 0.0,
            ema_beta: 0.95,
        }
    }
}

/// One topology's series.
#[derive(Clone, Debug)]
pub struct TopoSeries {
    pub label: String,
    /// `(sim_seconds, ema_loss)`.
    pub loss: Vec<(f64, f64)>,
    /// `(sim_seconds, Σ_m ‖x_m − x̄‖²)` sampled along the horizon.
    pub consensus: Vec<(f64, f64)>,
    pub steps: u64,
    pub messages: u64,
    /// Encoded wire bytes actually shipped.
    pub bytes: u64,
    /// Final consensus error.
    pub final_consensus: f64,
}

fn run_one(cfg: &TopoFigConfig, topology: TopologySpec) -> Result<TopoSeries> {
    let mut grad = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed ^ 0x7090);
    let init = FlatVec::zeros(cfg.dim);
    let strategy = if cfg.shards > 1 {
        DesStrategy::ShardedGoSgd { p: cfg.p, shards: cfg.shards }
    } else {
        DesStrategy::GoSgd { p: cfg.p }
    };
    let mut eng = DesEngine::new(
        strategy,
        cfg.time_model.clone(),
        cfg.workers,
        &init,
        cfg.eta,
        cfg.weight_decay,
        cfg.seed,
    )?
    .with_codec(cfg.codec)
    .with_topology(topology)
    .with_fabric(cfg.fabric)
    .with_parallel(if cfg.threads > 1 {
        ParallelKind::Sharded(cfg.threads)
    } else {
        ParallelKind::Sequential
    });
    // The DES resumes across run calls, so consensus can be sampled along
    // the horizon without disturbing the event stream.
    let mut consensus = Vec::with_capacity(cfg.samples);
    for i in 1..=cfg.samples.max(1) {
        let t = cfg.horizon_secs * i as f64 / cfg.samples.max(1) as f64;
        eng.run(&mut grad, t)?;
        consensus.push((t, eng.consensus_error()?));
    }
    let final_consensus = eng.consensus_error()?;
    let rep = eng.report();
    Ok(TopoSeries {
        label: topology.label(),
        loss: ema_series(&rep.trace, cfg.ema_beta),
        consensus,
        steps: rep.steps,
        messages: rep.messages,
        bytes: rep.bytes,
        final_consensus,
    })
}

/// Run every configured topology at the shared byte budget.
pub fn run(cfg: &TopoFigConfig, out: Option<&Path>) -> Result<Vec<TopoSeries>> {
    if !(cfg.p > 0.0 && cfg.p <= 1.0) {
        return Err(Error::config(format!(
            "topology comparison needs an exchange probability in (0, 1], got {}",
            cfg.p
        )));
    }
    if cfg.topologies.is_empty() {
        return Err(Error::config("topology comparison needs at least one topology"));
    }
    if cfg.shards == 0 || (cfg.shards > 1 && cfg.shards > cfg.dim) {
        return Err(Error::config(format!(
            "cannot cut {} parameters into {} shards",
            cfg.dim, cfg.shards
        )));
    }
    for topo in &cfg.topologies {
        // Fail the whole grid up front rather than after hours of sim.
        topo.validate_for(cfg.workers)?;
    }
    let mut series = Vec::with_capacity(cfg.topologies.len());
    for &topo in &cfg.topologies {
        series.push(run_one(cfg, topo)?);
    }
    if let Some(path) = out {
        // Two curves per topology, tagged `<label>/loss` and
        // `<label>/consensus`.
        let mut csv = CsvWriter::create(path, &["series", "sim_seconds", "value"])?;
        for s in &series {
            let loss_tag = format!("{}/loss", s.label);
            for &(t, l) in &s.loss {
                csv.write_tagged_row(&loss_tag, &[t, l])?;
            }
            let eps_tag = format!("{}/consensus", s.label);
            for &(t, e) in &s.consensus {
                csv.write_tagged_row(&eps_tag, &[t, e])?;
            }
        }
        csv.flush()?;
    }
    Ok(series)
}

/// Console table with the headline comparison.
pub fn format_table(series: &[TopoSeries]) -> String {
    let mut out = String::from(
        "topology      steps   messages    enc_MB   consensus_eps\n",
    );
    for s in series {
        out.push_str(&format!(
            "{:<12} {:>6}  {:>9}  {:>8.2}  {:>14.5}\n",
            s.label,
            s.steps,
            s.messages,
            s.bytes as f64 / 1e6,
            s.final_consensus,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TopoFigConfig {
        TopoFigConfig {
            dim: 256,
            shards: 4,
            p: 0.2,
            horizon_secs: 40.0,
            samples: 10,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn topology_grid_runs_at_equal_byte_budget() {
        let cfg = small_cfg();
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 4);
        let by_label = |l: &str| {
            series
                .iter()
                .find(|s| s.label == l)
                .unwrap_or_else(|| panic!("missing series {l}"))
        };
        let uniform = by_label("uniform");
        // Equal budget: every series sends the same-size messages at the
        // same p, so per-second bytes agree within stochastic noise.
        for s in &series {
            assert!(s.steps > 0 && s.messages > 0, "{} sent nothing", s.label);
            let ratio = s.bytes as f64 / uniform.bytes as f64;
            assert!(
                (0.8..1.25).contains(&ratio),
                "{}: byte budget drifted ({} vs uniform {})",
                s.label,
                s.bytes,
                uniform.bytes
            );
            // Both curves exist and the consensus samples cover the
            // horizon monotonically in time.
            assert!(!s.loss.is_empty());
            assert_eq!(s.consensus.len(), cfg.samples);
            for w in s.consensus.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert!(s.final_consensus.is_finite());
            // Everyone still trains.
            let early: f64 = s.loss.iter().take(30).map(|(_, l)| l).sum::<f64>() / 30.0;
            let late: f64 =
                s.loss[s.loss.len() - 30..].iter().map(|(_, l)| l).sum::<f64>() / 30.0;
            assert!(late < early, "{}: {early} -> {late}", s.label);
        }
    }

    #[test]
    fn degenerate_knobs_are_config_errors() {
        let cfg = TopoFigConfig { p: 0.0, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = TopoFigConfig { topologies: Vec::new(), ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        let cfg = TopoFigConfig { shards: 4096, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
        // Hypercube in the grid + a non-power-of-two fleet fails up front.
        let cfg = TopoFigConfig { workers: 6, ..small_cfg() };
        assert!(run(&cfg, None).is_err());
    }

    #[test]
    fn topology_grid_runs_through_a_finite_fabric() {
        let cfg = TopoFigConfig {
            fabric: FabricSpec::Rack,
            topologies: vec![TopologySpec::UniformRandom, TopologySpec::Ring],
            horizon_secs: 20.0,
            samples: 5,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 2);
        assert!(series.iter().all(|s| s.steps > 0 && s.messages > 0));
    }

    #[test]
    fn unsharded_comparison_runs_too() {
        let cfg = TopoFigConfig {
            shards: 1,
            topologies: vec![TopologySpec::UniformRandom, TopologySpec::PartnerRotation],
            horizon_secs: 20.0,
            samples: 5,
            ..small_cfg()
        };
        let series = run(&cfg, None).unwrap();
        assert_eq!(series.len(), 2);
        assert!(series.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn csv_written_with_both_curves() {
        let dir = std::env::temp_dir().join("gosgd_topologies_test");
        let path = dir.join("topologies.csv");
        let cfg = TopoFigConfig {
            horizon_secs: 10.0,
            dim: 64,
            samples: 4,
            topologies: vec![TopologySpec::UniformRandom, TopologySpec::Ring],
            ..small_cfg()
        };
        run(&cfg, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,sim_seconds,value\n"));
        assert!(text.contains("ring/loss,"));
        assert!(text.contains("ring/consensus,"));
        assert!(text.contains("uniform/consensus,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
