//! Appendix A: gradient-estimator error scales as 1/N.
//!
//! The paper motivates distribution by the Monte-Carlo argument
//! `E‖∇L − ∇̂L‖² = tr(Cov)/N`: doubling the (effective) batch halves the
//! gradient error — which is exactly what Algorithm 1 buys with M workers.
//! This harness measures the error empirically on the noisy quadratic for
//! a sweep of batch sizes and fits the power law.

use std::path::Path;

use crate::error::Result;
use crate::metrics::CsvWriter;
use crate::strategies::grad::{GradSource, QuadraticSource};
use crate::tensor::FlatVec;

/// Configuration for the variance-scaling experiment.
#[derive(Clone, Debug)]
pub struct VarianceConfig {
    pub dim: usize,
    /// Batch sizes to sweep.
    pub batch_sizes: Vec<usize>,
    /// Monte-Carlo trials per batch size.
    pub trials: usize,
    /// Per-sample gradient noise std.
    pub sigma: f32,
    pub seed: u64,
}

impl Default for VarianceConfig {
    fn default() -> Self {
        VarianceConfig {
            dim: 256,
            batch_sizes: vec![1, 2, 4, 8, 16, 32, 64],
            trials: 200,
            sigma: 0.5,
            seed: 0,
        }
    }
}

/// `(N, measured E‖error‖²)` rows.
pub fn run(cfg: &VarianceConfig, out: Option<&Path>) -> Result<Vec<(usize, f64)>> {
    let mut src = QuadraticSource::new(cfg.dim, cfg.sigma, cfg.seed);
    let params = FlatVec::zeros(cfg.dim);

    // True gradient: (x - x*)/d with zero noise.
    let mut true_grad = FlatVec::zeros(cfg.dim);
    {
        let mut clean = QuadraticSource::new(cfg.dim, 0.0, cfg.seed);
        clean.grad(1, &params, 0, &mut true_grad)?;
    }

    let mut rows = Vec::new();
    let mut buf = FlatVec::zeros(cfg.dim);
    let mut step = 0u64;
    for &n in &cfg.batch_sizes {
        let mut total_err = 0.0;
        for _ in 0..cfg.trials {
            // Average N independent single-sample gradients.
            let mut avg = FlatVec::zeros(cfg.dim);
            for _ in 0..n {
                src.grad(1, &params, step, &mut buf)?;
                step += 1;
                avg.axpy(1.0 / n as f32, &buf)?;
            }
            total_err += avg.dist_sq(&true_grad)?;
        }
        rows.push((n, total_err / cfg.trials as f64));
    }

    if let Some(path) = out {
        let mut csv = CsvWriter::create(path, &["batch_size", "grad_error_sq"])?;
        for &(n, e) in &rows {
            csv.write_row(&[n as f64, e])?;
        }
        csv.flush()?;
    }
    Ok(rows)
}

/// Fit `error = c · N^alpha` by least squares in log-log space; Appendix A
/// predicts `alpha = −1`.
pub fn fit_power_law(rows: &[(usize, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = rows
        .iter()
        .map(|&(n, e)| ((n as f64).ln(), e.ln()))
        .collect();
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|(x, _)| x).sum();
    let sy: f64 = pts.iter().map(|(_, y)| y).sum();
    let sxx: f64 = pts.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = pts.iter().map(|(x, y)| x * y).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_scales_inverse_with_batch() {
        let cfg = VarianceConfig {
            dim: 128,
            batch_sizes: vec![1, 4, 16, 64],
            trials: 150,
            sigma: 0.5,
            seed: 3,
        };
        let rows = run(&cfg, None).unwrap();
        let alpha = fit_power_law(&rows);
        assert!(
            (alpha + 1.0).abs() < 0.15,
            "expected ~N^-1 scaling, got N^{alpha:.3}: {rows:?}"
        );
    }

    #[test]
    fn error_magnitude_matches_theory() {
        // E‖err‖² = d σ² / N for σ² I covariance.
        let cfg = VarianceConfig {
            dim: 64,
            batch_sizes: vec![8],
            trials: 300,
            sigma: 0.5,
            seed: 7,
        };
        let rows = run(&cfg, None).unwrap();
        let want = 64.0 * 0.25 / 8.0;
        let got = rows[0].1;
        assert!(
            (got - want).abs() / want < 0.2,
            "theory {want}, measured {got}"
        );
    }

    #[test]
    fn power_law_fit_on_exact_data() {
        let rows: Vec<(usize, f64)> = vec![(1, 8.0), (2, 4.0), (4, 2.0), (8, 1.0)];
        let alpha = fit_power_law(&rows);
        assert!((alpha + 1.0).abs() < 1e-9, "{alpha}");
    }
}
