//! # GoSGD — Distributed SGD with Gossip Exchange
//!
//! Full-system reproduction of *"GoSGD: Distributed Optimization for Deep
//! Learning with Gossip Exchange"* (Blot, Picard, Cord, 2018).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): the sum-weight
//!   gossip blend and the fused dense matmul, authored in Pallas and lowered
//!   (interpret mode) into the L2 programs.
//! * **L2 — JAX model** (`python/compile/model.py`): the paper's CIFAR CNN
//!   forward/backward, AOT-lowered to HLO text at build time.
//! * **L3 — this crate**: the distributed-training runtime. Worker threads,
//!   message queues, the randomized-gossip protocol, the communication-matrix
//!   framework of the paper's section 3, and every strategy the paper
//!   discusses (GoSGD, PerSyn, EASGD, Downpour, fully-synchronous AllReduce).
//!
//! Python never runs on the training path: `make artifacts` lowers the JAX
//! programs once, and the `gosgd` binary loads them through PJRT
//! ([`runtime`]).
//!
//! ## Quick tour
//!
//! * [`strategies`] — the paper's algorithms behind one [`strategies::Strategy`]
//!   trait; GoSGD itself is the contribution (Algorithm 3 + 4).
//! * [`framework`] — section 3's communication-matrix formalism; every
//!   strategy can be *compiled* to its `K^(t)` sequence and cross-checked.
//! * [`gossip`] — sum-weight protocol substrate: weights, messages, queues,
//!   the sharded-exchange extension (`gossip::shard`) that ships one
//!   chunk of the vector per gossip event for large models, the payload
//!   codecs (`gossip::codec`: dense / top-k with error feedback / u8
//!   quantization) that compress each chunk on the wire, the pluggable
//!   gossip topologies (`gossip::topology`: uniform / ring / hypercube /
//!   partner rotation, each with its doubly stochastic expected gossip
//!   matrix), and the runtime-agnostic protocol core (`gossip::protocol`)
//!   all three runtimes drive.
//! * [`worker`] / [`coordinator`] — the threaded runtime.
//! * [`runtime`] — PJRT executor for the AOT artifacts.
//! * [`sim`] — discrete-event simulator used for the wall-clock experiment
//!   (paper Fig. 2), the consensus experiment (Fig. 4), and the
//!   straggler/churn scenario grid (`sim::ScenarioModel`).
//! * [`harness`] — one module per paper figure/table; regenerates the series.
//! * [`sync`] — the concurrency shim every atomic/thread primitive routes
//!   through; under `--cfg loom` it swaps in a bounded model checker that
//!   exhaustively interleaves the pool and queue protocols.
//! * [`lint`] — the `gosgd-lint` domain rules (shim discipline, hash-order
//!   determinism, ambient time/RNG, `// SAFETY:` coverage).

// Every `unsafe fn` body must spell out its own `unsafe {}` blocks, and
// every block carries a `// SAFETY:` comment (the clippy lint audits what
// gosgd-lint also enforces repo-wide).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod framework;
pub mod gossip;
pub mod harness;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod net;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod strategies;
pub mod sync;
pub mod tensor;
pub mod util;
pub mod worker;

pub use error::{Error, Result};
