//! `gosgd-lint`: domain invariants the compiler cannot enforce.
//!
//! The crate's correctness story leans on three repo-wide disciplines
//! that are invisible to rustc:
//!
//! 1. **Shim discipline** (`sync-shim`): no `std::sync::atomic` or
//!    `std::thread` outside `rust/src/sync/`.  Every primitive must route
//!    through [`crate::sync`] or the loom lane cannot model it.
//! 2. **Iteration-order determinism** (`hash-order`): no `HashMap` /
//!    `HashSet` in `sim/`, `gossip/` or `strategies/`.  Hash iteration
//!    order changes run to run; feeding it into f64 accumulation (or any
//!    ordered output) breaks the same-seed trace hashes that gate PRs.
//!    Use `BTreeMap`/`BTreeSet` or a keyed `Vec`.
//! 3. **No ambient time or randomness** (`sim-time`): no `Instant`,
//!    `SystemTime`, `std::time::`, `rand::` or `thread_rng` in those same
//!    determinism-critical paths.  Clocks come from the DES, randomness
//!    from [`crate::util::rng`].
//!
//! 4. **Socket isolation** (`net-isolation`): no `std::net` outside
//!    `rust/src/net/`.  The loopback and TCP transports are bit-identical
//!    only because they share every byte of protocol code; a stray socket
//!    in another layer would fork the code path the equivalence suite
//!    pins.  Sockets live in `net::runtime`, everything else talks
//!    frames and pipes.
//!
//! Plus one safety discipline everywhere (`safety-comment`): every
//! `unsafe` block and `unsafe impl` carries a `// SAFETY:` comment within
//! the four lines above it (the compiler checks `unsafe` is *declared*,
//! this checks it is *justified*).
//!
//! A violation can be waived on its own line with
//! `// lint:allow(<rule>)` — the escape hatch is per-line and named, so
//! waivers are greppable.
//!
//! The scanner is a small hand-rolled Rust lexer, not a parser: it masks
//! string literals, char literals and (nested) comments to spaces —
//! preserving newlines, so byte offsets map to line numbers — and then
//! pattern-matches the surviving code text with identifier-boundary
//! checks.  That is exactly enough precision for these rules (the
//! patterns are fully-qualified path fragments and type names), with no
//! dependency on a real parser in the offline build environment.
//!
//! Run it as `cargo run --bin gosgd-lint` from the repo root; the binary
//! exits non-zero on any finding, and the `current_tree_is_clean` test
//! below makes a lint regression fail plain `cargo test` too.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation at a specific file/line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule id (also the `lint:allow(...)` tag).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Result of scanning a tree.
#[derive(Debug)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files: usize,
    pub findings: Vec<Finding>,
}

const SYNC_RULE: &str = "sync-shim";
const HASH_RULE: &str = "hash-order";
const TIME_RULE: &str = "sim-time";
const SAFETY_RULE: &str = "safety-comment";
const NET_RULE: &str = "net-isolation";

const SYNC_PATTERNS: [&str; 3] = ["std::sync::atomic", "core::sync::atomic", "std::thread"];
const NET_PATTERNS: [&str; 1] = ["std::net"];
const HASH_PATTERNS: [&str; 2] = ["HashMap", "HashSet"];
const TIME_PATTERNS: [&str; 5] =
    ["Instant", "SystemTime", "std::time::", "rand::", "thread_rng"];

/// Directories whose code feeds the deterministic replay path.
const DETERMINISM_DIRS: [&str; 3] = ["/sim/", "/gossip/", "/strategies/"];

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Replace every string literal, char literal and comment with spaces,
/// preserving newlines (so byte offsets keep their line numbers) and
/// leaving all other code bytes untouched.
pub fn mask(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'/' if b.get(i + 1) == Some(&b'/') => {
                while i < b.len() && b[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Rust block comments nest.
                let mut depth = 1usize;
                out[i] = b' ';
                out[i + 1] = b' ';
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else {
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'"' => i = mask_plain_string(b, &mut out, i),
            // Raw (and raw-byte) strings: escapes are inert and `"` can
            // appear inside, so they need their own scan.  A leading
            // ident byte means this `r`/`b` is part of an identifier.
            b'r' | b'b' if i == 0 || !is_ident_byte(b[i - 1]) => {
                match raw_string_open(b, i) {
                    Some((quote, hashes)) => i = mask_raw_string(b, &mut out, quote, hashes),
                    // Not a raw string: plain code byte (a `b"..."` byte
                    // string falls through to the `"` arm next round).
                    None => i += 1,
                }
            }
            b'\'' => {
                let n1 = b.get(i + 1).copied();
                let n2 = b.get(i + 2).copied();
                let lifetime = matches!(n1, Some(c) if c.is_ascii_alphabetic() || c == b'_')
                    && n2 != Some(b'\'');
                if lifetime {
                    i += 1; // just the quote; the label is ordinary code
                } else {
                    i = mask_char_literal(b, &mut out, i);
                }
            }
            _ => i += 1,
        }
    }
    String::from_utf8(out).expect("masking only writes ASCII spaces over ASCII bytes")
}

/// Mask `"..."` contents handling `\` escapes; returns the index just
/// past the closing quote (or EOF on an unterminated literal).
fn mask_plain_string(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let mut i = start + 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                out[i] = b' ';
                if let Some(&n) = b.get(i + 1) {
                    if n != b'\n' {
                        out[i + 1] = b' ';
                    }
                }
                i += 2;
            }
            b'"' => return i + 1,
            b'\n' => i += 1,
            _ => {
                out[i] = b' ';
                i += 1;
            }
        }
    }
    i
}

/// Detect `r"`, `r#..#"`, `br"`, `br#..#"` starting at `i`; returns the
/// opening-quote index and the hash count.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j, hashes))
    } else {
        None // e.g. a raw identifier `r#match`
    }
}

/// Mask a raw string's contents; `quote` is the opening `"`.  Returns the
/// index just past the closing delimiter.
fn mask_raw_string(b: &[u8], out: &mut [u8], quote: usize, hashes: usize) -> usize {
    let mut i = quote + 1;
    while i < b.len() {
        let closes = b[i] == b'"'
            && b[i + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes;
        if closes {
            return i + 1 + hashes;
        }
        if b[i] != b'\n' {
            out[i] = b' ';
        }
        i += 1;
    }
    i
}

/// Mask a char (or byte-char) literal's contents; returns the index just
/// past the closing quote.
fn mask_char_literal(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let mut i = start + 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                out[i] = b' ';
                if i + 1 < b.len() {
                    out[i + 1] = b' ';
                }
                i += 2;
            }
            b'\'' => return i + 1,
            b'\n' => return i, // not a char literal after all; bail out
            _ => {
                out[i] = b' ';
                i += 1;
            }
        }
    }
    i
}

/// Byte offsets of identifier-boundary matches of `pat` in `masked`.
/// Boundary checks apply only on sides where the pattern edge is itself
/// an identifier byte (so `std::time::` matches even when followed by a
/// type name, but `Instant` does not match inside `Instantiate`).
fn find_pattern(masked: &str, pat: &str) -> Vec<usize> {
    let mb = masked.as_bytes();
    let pb = pat.as_bytes();
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = masked[from..].find(pat) {
        let at = from + pos;
        let end = at + pb.len();
        let pre_ok = !is_ident_byte(pb[0]) || at == 0 || !is_ident_byte(mb[at - 1]);
        let post_ok =
            !is_ident_byte(pb[pb.len() - 1]) || end >= mb.len() || !is_ident_byte(mb[end]);
        if pre_ok && post_ok {
            hits.push(at);
        }
        from = end;
    }
    hits
}

/// 1-based line number of byte offset `at`.
fn line_of(text: &str, at: usize) -> usize {
    text.as_bytes()[..at].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Byte offsets of `unsafe` tokens that open a block, an `impl`, or a
/// `trait` — the places a `// SAFETY:` justification is required.
/// (`unsafe fn` is skipped: under `deny(unsafe_op_in_unsafe_fn)` its body
/// operations sit in their own `unsafe {}` blocks, which are flagged.)
fn unsafe_sites(masked: &str) -> Vec<usize> {
    let mb = masked.as_bytes();
    find_pattern(masked, "unsafe")
        .into_iter()
        .filter(|&at| {
            let mut j = at + "unsafe".len();
            while j < mb.len() && mb[j].is_ascii_whitespace() {
                j += 1;
            }
            if j >= mb.len() {
                return false;
            }
            mb[j] == b'{' || masked[j..].starts_with("impl") || masked[j..].starts_with("trait")
        })
        .collect()
}

/// Does the original source waive `rule` on `line` (1-based)?
fn waived(lines: &[&str], line: usize, rule: &str) -> bool {
    lines
        .get(line - 1)
        .is_some_and(|l| l.contains("lint:allow(") && l.contains(rule))
}

/// Is `// SAFETY:` present on the site's line or the four above it?
fn has_safety_comment(lines: &[&str], line: usize) -> bool {
    let lo = line.saturating_sub(5);
    lines[lo..line.min(lines.len())].iter().any(|l| l.contains("SAFETY:"))
}

/// Lint a single file's source.  `file` is the repo-relative path (it
/// drives the directory-scoped rules), `src` the file contents.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let rel = file.replace('\\', "/");
    let masked = mask(src);
    let lines: Vec<&str> = src.lines().collect();
    let mut findings = Vec::new();
    let mut seen: Vec<(usize, &'static str)> = Vec::new();
    let mut push = |findings: &mut Vec<Finding>,
                    seen: &mut Vec<(usize, &'static str)>,
                    line: usize,
                    rule: &'static str,
                    message: String| {
        if waived(&lines, line, rule) || seen.contains(&(line, rule)) {
            return;
        }
        seen.push((line, rule));
        findings.push(Finding { file: rel.clone(), line, rule, message });
    };

    let in_shim = rel.contains("src/sync/") || rel.ends_with("src/sync.rs");
    if !in_shim {
        for pat in SYNC_PATTERNS {
            for at in find_pattern(&masked, pat) {
                push(
                    &mut findings,
                    &mut seen,
                    line_of(&masked, at),
                    SYNC_RULE,
                    format!(
                        "`{pat}` outside the sync shim: route every atomic/thread \
                         primitive through `crate::sync` so the loom lane can model it"
                    ),
                );
            }
        }
    }

    let in_net = rel.contains("src/net/");
    if !in_net {
        for pat in NET_PATTERNS {
            for at in find_pattern(&masked, pat) {
                push(
                    &mut findings,
                    &mut seen,
                    line_of(&masked, at),
                    NET_RULE,
                    format!(
                        "`{pat}` outside rust/src/net/: sockets live behind the frame \
                         codec in net::runtime so the loopback and TCP transports share \
                         every byte of protocol code"
                    ),
                );
            }
        }
    }

    if DETERMINISM_DIRS.iter().any(|d| rel.contains(d)) {
        for pat in HASH_PATTERNS {
            for at in find_pattern(&masked, pat) {
                push(
                    &mut findings,
                    &mut seen,
                    line_of(&masked, at),
                    HASH_RULE,
                    format!(
                        "`{pat}` in a determinism-critical path: hash iteration order is \
                         nondeterministic and poisons f64 accumulation / trace hashes — \
                         use BTreeMap/BTreeSet or a keyed Vec"
                    ),
                );
            }
        }
        for pat in TIME_PATTERNS {
            for at in find_pattern(&masked, pat) {
                push(
                    &mut findings,
                    &mut seen,
                    line_of(&masked, at),
                    TIME_RULE,
                    format!(
                        "`{pat}` in a simulation path: ambient time/randomness breaks \
                         same-seed replay — take clocks from the DES and randomness \
                         from util::rng"
                    ),
                );
            }
        }
    }

    for at in unsafe_sites(&masked) {
        let line = line_of(&masked, at);
        if !has_safety_comment(&lines, line) {
            push(
                &mut findings,
                &mut seen,
                line,
                SAFETY_RULE,
                "`unsafe` without a `// SAFETY:` comment within the 4 lines above it".to_string(),
            );
        }
    }

    findings
}

/// Recursively collect `.rs` files, sorted for a deterministic report.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `<root>/rust/{src,tests,benches}`.
pub fn lint_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches"] {
        collect_rs(&root.join(sub), &mut files)?;
    }
    let mut findings = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    Ok(Report { files: files.len(), findings })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(file: &str, src: &str) -> Vec<&'static str> {
        lint_source(file, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn flags_std_atomics_and_threads_outside_the_shim() {
        let bad = "use std::sync::atomic::AtomicUsize;\nfn f() { std::thread::spawn(|| {}); }\n";
        let found = lint_source("rust/src/tensor/foo.rs", bad);
        assert_eq!(found.len(), 2, "{found:?}");
        assert_eq!(found[0].rule, "sync-shim");
        assert_eq!(found[0].line, 1);
        assert_eq!(found[1].line, 2);
        // The shim itself is the one allowed home.
        assert!(lint_source("rust/src/sync/mod.rs", bad).is_empty());
        assert!(lint_source("rust/src/sync/model.rs", bad).is_empty());
    }

    #[test]
    fn comments_and_strings_do_not_trigger_rules() {
        let ok = concat!(
            "// std::thread is forbidden here, says this comment\n",
            "/* and std::sync::atomic inside /* nested */ blocks too */\n",
            "const DOC: &str = \"std::sync::atomic::AtomicU64\";\n",
            "const RAW: &str = r#\"std::thread::spawn\"#;\n",
        );
        assert!(rules("rust/src/gossip/x.rs", ok).is_empty());
    }

    #[test]
    fn flags_hash_collections_only_in_determinism_dirs() {
        let bad = "use std::collections::HashMap;\nlet s: HashSet<u32> = HashSet::new();\n";
        assert_eq!(rules("rust/src/sim/foo.rs", bad), ["hash-order", "hash-order"]);
        assert_eq!(rules("rust/src/gossip/foo.rs", bad).len(), 2);
        assert_eq!(rules("rust/src/strategies/foo.rs", bad).len(), 2);
        // Outside the deterministic paths, hash collections are fine.
        assert!(rules("rust/src/harness/foo.rs", bad).is_empty());
        assert!(rules("rust/src/util/foo.rs", bad).is_empty());
    }

    #[test]
    fn flags_ambient_time_and_rng_in_sim_paths() {
        let bad = "let t0 = std::time::Instant::now();\n";
        let found = lint_source("rust/src/sim/clock.rs", bad);
        // `Instant` and `std::time::` both hit line 1; the report dedupes
        // to one finding per (line, rule).
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "sim-time");
        assert_eq!(rules("rust/src/strategies/r.rs", "let r = thread_rng();\n"), ["sim-time"]);
        assert_eq!(rules("rust/src/gossip/t.rs", "use std::time::SystemTime;\n"), ["sim-time"]);
        // Word boundaries: `Instantiate` is not `Instant`.
        assert!(rules("rust/src/sim/doc.rs", "fn instantiate_Instantiate() {}\n").is_empty());
    }

    #[test]
    fn flags_raw_sockets_outside_the_net_module() {
        let bad = "use std::net::TcpStream;\nfn f() { let _ = std::net::TcpListener::bind(\"x\"); }\n";
        let found = lint_source("rust/src/worker/foo.rs", bad);
        assert_eq!(found.len(), 2, "{found:?}");
        assert_eq!(found[0].rule, "net-isolation");
        assert_eq!(found[0].line, 1);
        assert_eq!(found[1].line, 2);
        // The net module is the one allowed home.
        assert!(lint_source("rust/src/net/runtime.rs", bad).is_empty());
        // Mentions in comments and strings are fine anywhere.
        let ok = "// std::net stays in net::runtime\nconst S: &str = \"std::net::TcpStream\";\n";
        assert!(rules("rust/src/worker/foo.rs", ok).is_empty());
    }

    #[test]
    fn flags_unsafe_without_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let found = lint_source("rust/src/util/foo.rs", bad);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "safety-comment");
        assert_eq!(found[0].line, 2);
        let ok = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(lint_source("rust/src/util/foo.rs", ok).is_empty());
    }

    #[test]
    fn flags_unsafe_impl_and_skips_unsafe_fn_declarations() {
        let bad = "unsafe impl Send for X {}\n";
        assert_eq!(rules("rust/src/tensor/x.rs", bad), ["safety-comment"]);
        let ok = "// SAFETY: X owns no thread-affine state.\nunsafe impl Send for X {}\n";
        assert!(rules("rust/src/tensor/x.rs", ok).is_empty());
        // An `unsafe fn` declaration needs no comment of its own: its
        // body's unsafe blocks carry the justifications.
        let decl = "unsafe fn g() {}\n";
        assert!(rules("rust/src/tensor/x.rs", decl).is_empty());
    }

    #[test]
    fn lint_allow_waives_a_rule_on_its_line_only() {
        let waived = "use std::collections::HashMap; // lint:allow(hash-order) keyed by id\n";
        assert!(rules("rust/src/sim/w.rs", waived).is_empty());
        // The waiver names a rule; a different rule on the same line still fires.
        let wrong_tag = "use std::collections::HashMap; // lint:allow(sim-time)\n";
        assert_eq!(rules("rust/src/sim/w.rs", wrong_tag), ["hash-order"]);
        // And it does not leak to other lines.
        let next_line = "// lint:allow(hash-order)\nuse std::collections::HashMap;\n";
        assert_eq!(rules("rust/src/sim/w.rs", next_line), ["hash-order"]);
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        // The `'"'` char literal must not be read as a string delimiter —
        // if it were, the real violation after it would be masked away.
        let bad = "fn f() { let q = '\"'; let t = std::thread::current(); }\n";
        assert_eq!(rules("rust/src/gossip/c.rs", bad), ["sync-shim"]);
        // Lifetimes are not char literals.
        let ok = "fn g<'a>(x: &'a str) -> &'a str { x }\n";
        assert!(rules("rust/src/gossip/c.rs", ok).is_empty());
        // Escaped quote inside a char literal.
        let esc = "fn h() -> char { '\\'' }\n";
        assert!(rules("rust/src/gossip/c.rs", esc).is_empty());
    }

    #[test]
    fn masking_preserves_line_numbers() {
        let src = "line1\n/* comment\nspanning\nlines */\nstd::thread::yield_now();\n";
        let found = lint_source("rust/src/sim/m.rs", src);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].line, 5, "{found:?}");
    }

    #[test]
    fn current_tree_is_clean() {
        // The repo itself must satisfy its own invariants — this is the
        // tier-1 guard that keeps gosgd-lint green without the CI lane.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = lint_tree(root).expect("scan repo tree");
        assert!(
            report.files >= 60,
            "expected to scan the full tree, saw only {} files",
            report.files
        );
        let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(rendered.is_empty(), "lint violations:\n{}", rendered.join("\n"));
    }
}
