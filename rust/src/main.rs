//! `gosgd` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`     — run one distributed-training job on the real model.
//! * `consensus` — regenerate the paper's Fig. 4 (consensus under noise).
//! * `figure`    — regenerate Fig. 1 / 2 / 3 series.
//! * `variance`  — Appendix A variance-scaling measurement.
//! * `inspect`   — print an artifact manifest.
//! * `net`       — one multi-process gossip worker (seed or joiner).
//!
//! Examples:
//!
//! ```text
//! gosgd train --model tiny --strategy gosgd:0.02 --workers 8 --steps 400
//! gosgd consensus --out results/fig4.csv
//! gosgd figure --figure fig1 --model tiny --iterations 150
//! gosgd inspect --model cnn
//! gosgd net --listen 127.0.0.1:7000 --workers 2 --steps 200   # seed
//! gosgd net --join 127.0.0.1:7000                             # joiner
//! ```

use gosgd::config::{RunConfig, StrategyKind};
use gosgd::coordinator::Coordinator;
use gosgd::error::Result;
use gosgd::gossip::PeerSelector;
use gosgd::gossip::CodecSpec;
use gosgd::gossip::TopologySpec;
use gosgd::harness::{
    codecs, fabrics, fig1, fig2, fig3, fig4, scale, scenarios, topologies, variance,
};
use gosgd::model::Manifest;
use gosgd::optim::LrSchedule;
use gosgd::sim::FabricSpec;
use gosgd::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    match cmd {
        "train" => cmd_train(rest),
        "consensus" => cmd_consensus(rest),
        "figure" => cmd_figure(rest),
        "variance" => cmd_variance(rest),
        "inspect" => cmd_inspect(rest),
        "net" => cmd_net(rest),
        _ => {
            println!(
                "gosgd — GoSGD distributed training (paper reproduction)\n\n\
                 subcommands: train | consensus | figure | variance | inspect | net\n\
                 use `gosgd <subcommand> --help` for options"
            );
            Ok(())
        }
    }
}

fn train_args() -> Args {
    Args::new("gosgd train", "run one distributed training job")
        .opt("artifacts", "artifacts", "artifact directory root")
        .opt("model", "tiny", "model variant: tiny | cnn | mlp_wide")
        .opt("workers", "8", "number of workers M")
        .opt("steps", "200", "engine steps (rounds or ticks)")
        .opt(
            "strategy",
            "gosgd:0.02",
            "gosgd:P:SHARDS[:CODEC][:TOPO] (codec: dense | q8 | top<K>; topo: uniform | ring | \
             hypercube | rotation) | persyn:TAU | easgd:A:TAU | downpour:NP:NF | allreduce | local",
        )
        .opt("lr", "0.1", "learning rate (or step:BASE:GAMMA:EVERY)")
        .opt("weight-decay", "0.0001", "weight decay")
        .opt("seed", "0", "RNG seed")
        .opt(
            "peer",
            "uniform",
            "peer selector: uniform | ring | smallworld:Q (a strategy-string TOPO overrides it)",
        )
        .opt("eval-every", "0", "evaluate every N steps (0 = only at end)")
        .opt("eval-batches", "4", "validation batches per evaluation")
        .opt("data-noise", "4.0", "synthetic data class-overlap noise")
        .opt("loss-csv", "", "write the training-loss curve to this CSV")
        .opt("save-checkpoint", "", "write a checkpoint here at the end")
        .opt("resume-from", "", "resume from a checkpoint file")
}

fn parse_run_config(a: &Args) -> Result<RunConfig> {
    Ok(RunConfig {
        artifacts_dir: a.get("artifacts")?.into(),
        model: a.get("model")?.to_string(),
        workers: a.get_usize("workers")?,
        steps: a.get_u64("steps")?,
        strategy: StrategyKind::parse(a.get("strategy")?)?,
        lr: LrSchedule::parse(a.get("lr")?).ok_or_else(|| gosgd::Error::cli("bad --lr"))?,
        weight_decay: a.get_f64("weight-decay")? as f32,
        seed: a.get_u64("seed")?,
        peer: PeerSelector::parse(a.get("peer")?)?,
        eval_every: a.get_u64("eval-every")?,
        eval_batches: a.get_u64("eval-batches")?,
        data_noise: a.get_f64("data-noise")? as f32,
        save_checkpoint: non_empty(a.get("save-checkpoint")?),
        resume_from: non_empty(a.get("resume-from")?),
        ..RunConfig::default()
    })
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = train_args().parse_from(argv)?;
    let cfg = parse_run_config(&a)?;
    println!(
        "training: {} on {} with M={} for {} steps",
        cfg.strategy.tag(),
        cfg.model,
        cfg.workers,
        cfg.steps
    );
    let report = Coordinator::new(cfg)?.run()?;
    println!("{}", report.summary());
    for (step, vl, va) in &report.evals {
        println!("  eval @ {step}: loss {vl:.4} acc {va:.3}");
    }
    let csv_path = a.get("loss-csv")?;
    if !csv_path.is_empty() {
        let mut csv = gosgd::metrics::CsvWriter::create(csv_path, &["step", "loss"])?;
        for (s, l) in report.train_loss.steps().iter().zip(report.train_loss.values()) {
            csv.write_row(&[*s as f64, *l])?;
        }
        csv.flush()?;
        println!("loss curve -> {csv_path}");
    }
    Ok(())
}

fn cmd_consensus(argv: Vec<String>) -> Result<()> {
    let a = Args::new("gosgd consensus", "paper Fig. 4: consensus under noise")
        .opt("workers", "8", "number of workers")
        .opt("dim", "1000", "parameter dimension")
        .opt("rounds", "1000", "rounds to simulate")
        .opt("ps", "0.01,0.1,0.5,1.0", "comma-separated exchange probabilities")
        .opt("seed", "0", "RNG seed")
        .opt("out", "", "CSV output path")
        .parse_from(argv)?;
    let cfg = fig4::Fig4Config {
        workers: a.get_usize("workers")?,
        dim: a.get_usize("dim")?,
        rounds: a.get_u64("rounds")?,
        ps: parse_list(a.get("ps")?)?,
        seed: a.get_u64("seed")?,
        include_local: true,
    };
    let out = non_empty(a.get("out")?);
    let series = fig4::run(&cfg, out.as_deref())?;
    println!("{}", fig4::format_table(&series));
    Ok(())
}

fn cmd_figure(argv: Vec<String>) -> Result<()> {
    let a = Args::new("gosgd figure", "regenerate a paper figure's series")
        .opt(
            "figure",
            "fig1",
            "fig1 | fig2 | fig3 | scenarios | codecs | topologies | fabrics | scale",
        )
        .opt("artifacts", "artifacts", "artifact directory root")
        .opt("model", "tiny", "model variant")
        .opt("workers", "8", "number of workers")
        .opt("iterations", "150", "worker iterations (fig1/fig3)")
        .opt("ps", "0.01,0.4", "exchange probabilities (fig1/fig3)")
        .opt("p", "0.02", "exchange probability (fig2/scenarios/codecs/topologies/fabrics)")
        .opt(
            "shards",
            "1",
            "gossip shards per exchange (fig2/scenarios/codecs/topologies/fabrics)",
        )
        .opt("codecs", "dense,top32,q8", "payload codecs to compare (codecs)")
        .opt(
            "codec",
            "dense",
            "payload codec shared by every series (topologies/fabrics/scale)",
        )
        .opt(
            "topologies",
            "uniform,ring,hypercube,rotation",
            "gossip topologies to compare (topologies)",
        )
        .opt(
            "topology",
            "uniform",
            "gossip topology shared by every series (fabrics/scale)",
        )
        .opt(
            "fleets",
            "4096,65536",
            "fleet sizes to sweep, largest last (scale; hypercube needs powers of two)",
        )
        .opt("telemetry", "1024", "telemetry sample size per fleet (scale)")
        .opt(
            "fabric",
            "ideal",
            "network fabric: ideal | rack | wan | edge | custom:BW_MBS:DELAY_MS:OVERSUB[:JFRAC] \
             (scenarios/codecs/topologies)",
        )
        .opt(
            "fabrics",
            "ideal,rack,wan,edge",
            "network fabrics to compare (fabrics)",
        )
        .opt("horizon", "120", "simulated seconds (fig2/scenarios/codecs/topologies/fabrics)")
        .opt(
            "threads",
            "1",
            "DES executor threads (scenarios/codecs/topologies/fabrics/scale); \
             >1 runs the deterministic sharded executor — identical results",
        )
        .opt("backend", "quadratic", "fig2 gradients: quadratic | pjrt")
        .opt(
            "hetero",
            "",
            "compute multipliers, cycled over workers; empty = one 4x straggler (scenarios)",
        )
        .opt("mtbf", "20", "mean seconds between worker crashes (scenarios)")
        .opt("mttr", "5", "mean downtime before rejoin (scenarios)")
        .opt("seed", "0", "RNG seed")
        .opt("out", "", "CSV output path")
        .parse_from(argv)?;
    let out = non_empty(a.get("out")?);
    match a.get("figure")? {
        "fig1" => {
            let cfg = fig1::Fig1Config {
                artifacts_dir: a.get("artifacts")?.into(),
                model: a.get("model")?.to_string(),
                workers: a.get_usize("workers")?,
                iterations: a.get_u64("iterations")?,
                ps: parse_list(a.get("ps")?)?,
                seed: a.get_u64("seed")?,
                ema_beta: 0.9,
            };
            let series = fig1::run(&cfg, out.as_deref())?;
            println!("{}", fig1::format_table(&series));
        }
        "fig2" => {
            let backend = match a.get("backend")? {
                "pjrt" => fig2::Fig2Backend::Pjrt {
                    artifacts_dir: a.get("artifacts")?.into(),
                    model: a.get("model")?.to_string(),
                },
                _ => fig2::Fig2Backend::Quadratic { dim: 1024, sigma: 0.2 },
            };
            let cfg = fig2::Fig2Config {
                backend,
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                horizon_secs: a.get_f64("horizon")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = fig2::run(&cfg, out.as_deref())?;
            let threshold = series
                .iter()
                .flat_map(|s| s.points.last().map(|(_, l)| *l))
                .fold(f64::INFINITY, f64::min)
                * 1.5;
            println!("{}", fig2::format_table(&series, threshold));
        }
        "fig3" => {
            let cfg = fig3::Fig3Config {
                artifacts_dir: a.get("artifacts")?.into(),
                model: a.get("model")?.to_string(),
                workers: a.get_usize("workers")?,
                iterations: a.get_u64("iterations")?,
                ps: parse_list(a.get("ps")?)?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = fig3::run(&cfg, out.as_deref())?;
            println!("{}", fig3::format_table(&series));
        }
        "codecs" => {
            let codec_specs = a
                .get("codecs")?
                .split(',')
                .map(|s| CodecSpec::parse(s.trim()))
                .collect::<Result<Vec<CodecSpec>>>()?;
            let cfg = codecs::CodecFigConfig {
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                codecs: codec_specs,
                horizon_secs: a.get_f64("horizon")?,
                fabric: FabricSpec::parse(a.get("fabric")?)?,
                threads: a.get_usize("threads")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = codecs::run(&cfg, out.as_deref())?;
            println!("{}", codecs::format_table(&series));
        }
        "topologies" => {
            let topo_specs = a
                .get("topologies")?
                .split(',')
                .map(|s| TopologySpec::parse(s.trim()))
                .collect::<Result<Vec<TopologySpec>>>()?;
            let cfg = topologies::TopoFigConfig {
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                codec: CodecSpec::parse(a.get("codec")?)?,
                topologies: topo_specs,
                horizon_secs: a.get_f64("horizon")?,
                fabric: FabricSpec::parse(a.get("fabric")?)?,
                threads: a.get_usize("threads")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = topologies::run(&cfg, out.as_deref())?;
            println!("{}", topologies::format_table(&series));
        }
        "fabrics" => {
            let fabric_specs = a
                .get("fabrics")?
                .split(',')
                .map(|s| FabricSpec::parse(s.trim()))
                .collect::<Result<Vec<FabricSpec>>>()?;
            let cfg = fabrics::FabricFigConfig {
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                codec: CodecSpec::parse(a.get("codec")?)?,
                topology: TopologySpec::parse(a.get("topology")?)?,
                fabrics: fabric_specs,
                horizon_secs: a.get_f64("horizon")?,
                threads: a.get_usize("threads")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = fabrics::run(&cfg, out.as_deref())?;
            println!("{}", fabrics::format_table(&series));
        }
        "scale" => {
            let fleets = a
                .get("fleets")?
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| gosgd::Error::cli(format!("bad fleet size {s:?}")))
                })
                .collect::<Result<Vec<usize>>>()?;
            let cfg = scale::ScaleFigConfig {
                fleets,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                codec: CodecSpec::parse(a.get("codec")?)?,
                topology: TopologySpec::parse(a.get("topology")?)?,
                horizon_secs: a.get_f64("horizon")?,
                telemetry: a.get_usize("telemetry")?,
                threads: a.get_usize("threads")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = scale::run(&cfg, out.as_deref())?;
            println!("{}", scale::format_table(&series));
        }
        "scenarios" => {
            let cfg = scenarios::ScenarioConfig {
                workers: a.get_usize("workers")?,
                p: a.get_f64("p")?,
                shards: a.get_usize("shards")?,
                horizon_secs: a.get_f64("horizon")?,
                compute_scale: match a.get("hetero")? {
                    "" => Vec::new(),
                    list => parse_list(list)?,
                },
                crash_mtbf: a.get_f64("mtbf")?,
                rejoin_mttr: a.get_f64("mttr")?,
                fabric: FabricSpec::parse(a.get("fabric")?)?,
                threads: a.get_usize("threads")?,
                seed: a.get_u64("seed")?,
                ..Default::default()
            };
            let series = scenarios::run(&cfg, out.as_deref())?;
            println!("{}", scenarios::format_table(&series));
        }
        other => return Err(gosgd::Error::cli(format!("unknown figure {other}"))),
    }
    Ok(())
}

fn cmd_variance(argv: Vec<String>) -> Result<()> {
    let a = Args::new("gosgd variance", "Appendix A: grad error ∝ 1/N")
        .opt("dim", "256", "parameter dimension")
        .opt("trials", "200", "Monte-Carlo trials per batch size")
        .opt("out", "", "CSV output path")
        .parse_from(argv)?;
    let cfg = variance::VarianceConfig {
        dim: a.get_usize("dim")?,
        trials: a.get_usize("trials")?,
        ..Default::default()
    };
    let out = non_empty(a.get("out")?);
    let rows = variance::run(&cfg, out.as_deref())?;
    println!("batch_size  grad_error_sq");
    for (n, e) in &rows {
        println!("{n:>10}  {e:>12.6}");
    }
    println!("power-law exponent: {:.3} (theory: -1)", variance::fit_power_law(&rows));
    Ok(())
}

fn cmd_inspect(argv: Vec<String>) -> Result<()> {
    let a = Args::new("gosgd inspect", "print an artifact manifest")
        .opt("artifacts", "artifacts", "artifact directory root")
        .opt("model", "tiny", "model variant")
        .parse_from(argv)?;
    let dir = std::path::Path::new(a.get("artifacts")?).join(a.get("model")?);
    let m = Manifest::load(&dir)?;
    println!("model {} @ {}", m.model, dir.display());
    println!("  params: {}  batch: {}  eval_batch: {}", m.param_count, m.batch, m.eval_batch);
    println!("  tensors:");
    for t in &m.tensors {
        println!("    {:<12} {:?} @ {}", t.name, t.shape, t.offset);
    }
    println!("  programs:");
    for p in &m.programs {
        println!(
            "    {:<12} {} ({} inputs, {} outputs)",
            p.name,
            p.file,
            p.inputs.len(),
            p.outputs.len()
        );
    }
    Ok(())
}

/// `gosgd net` — run ONE process of a multi-process socket fleet.
///
/// The seed (`--listen`, no `--join`) owns worker 0, admits the joiners,
/// and replays the run configuration to each through the join handshake,
/// so only the seed's knobs matter; joiners need nothing but `--join`
/// (plus `--listen` for their mesh port in fleets of three or more).
/// After the run, the seed prints the fleet-wide mass audit line
/// (`fleet mass 1.000000`) that the CI net lane greps for.
///
/// All socket work lives in `gosgd::net::runtime`; this function only
/// shuttles strings — `gosgd-lint`'s net-isolation rule keeps `std::net`
/// out of every other module, including this one.
fn cmd_net(argv: Vec<String>) -> Result<()> {
    let a = Args::new("gosgd net", "one worker process of a socket gossip fleet")
        .opt("listen", "", "address to listen on (seed port, or a joiner's mesh port)")
        .opt("join", "", "seed address to dial (absent = this node seeds the fleet)")
        .opt("workers", "2", "fleet size M (seed only; replayed to joiners)")
        .opt("dim", "64", "parameter dimension")
        .opt("p", "0.05", "per-step gossip probability")
        .opt("steps", "200", "local SGD steps per worker")
        .opt("lr", "0.1", "learning rate")
        .opt("weight-decay", "0.0001", "weight decay")
        .opt("seed", "0", "RNG seed")
        .opt("topology", "uniform", "uniform | ring | hypercube | rotation | smallworld:Q")
        .opt("shards", "1", "shard count for partial-vector gossip")
        .opt("codec", "dense", "dense | q8 | top<K>")
        .opt("sigma", "0.1", "gradient noise scale of the quadratic source")
        .parse_from(argv)?;
    let config = gosgd::net::FleetConfig {
        workers: a.get_usize("workers")?,
        dim: a.get_usize("dim")?,
        p: a.get_f64("p")?,
        steps_per_worker: a.get_u64("steps")?,
        eta: a.get_f64("lr")? as f32,
        weight_decay: a.get_f64("weight-decay")? as f32,
        seed: a.get_u64("seed")?,
        topology: TopologySpec::parse(a.get("topology")?)?,
        shards: a.get_usize("shards")?,
        codec: CodecSpec::parse(a.get("codec")?)?,
    };
    let node = gosgd::net::NetNodeConfig {
        listen: a.get("listen")?.to_string(),
        join: non_empty_string(a.get("join")?),
        config,
        sigma: a.get_f64("sigma")? as f32,
    };
    if node.join.is_none() && node.listen.is_empty() {
        return Err(gosgd::Error::cli("a seed needs --listen; a joiner needs --join"));
    }
    let report = node.run()?;
    println!(
        "worker {} finished: {} messages, {} payload bytes",
        report.id, report.messages, report.bytes
    );
    Ok(())
}

fn non_empty_string(s: &str) -> Option<String> {
    if s.is_empty() {
        None
    } else {
        Some(s.to_string())
    }
}

fn parse_list(text: &str) -> Result<Vec<f64>> {
    text.split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| gosgd::Error::cli(format!("bad number {s:?}")))
        })
        .collect()
}

fn non_empty(s: &str) -> Option<std::path::PathBuf> {
    if s.is_empty() {
        None
    } else {
        Some(s.into())
    }
}
