//! Minimal CSV writer for experiment outputs.
//!
//! Every harness run writes its series under `results/` so the paper
//! figures can be re-plotted from machine-readable data.  Only writing is
//! needed; fields are escaped per RFC 4180 when they contain separators.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;

/// Buffered CSV file writer.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut w = CsvWriter {
            out: BufWriter::new(File::create(path)?),
            columns: header.len(),
        };
        w.write_row_strs(header)?;
        Ok(w)
    }

    fn escape(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Write one row of string fields (must match header width).
    pub fn write_row_strs(&mut self, fields: &[&str]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.columns, "csv row width mismatch");
        let line: Vec<String> = fields.iter().map(|f| Self::escape(f)).collect();
        writeln!(self.out, "{}", line.join(","))?;
        Ok(())
    }

    /// Write one row of numeric fields.
    pub fn write_row(&mut self, fields: &[f64]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_strs(&refs)
    }

    /// Mixed row: a string tag followed by numbers (the common shape
    /// `strategy,p,step,value`).
    pub fn write_tagged_row(&mut self, tag: &str, fields: &[f64]) -> Result<()> {
        let mut strs = vec![tag.to_string()];
        strs.extend(fields.iter().map(|v| format!("{v}")));
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_strs(&refs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("gosgd_csv_test");
        let path = dir.join("out.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.write_row(&[1.0, 2.5]).unwrap();
            w.write_tagged_row("gosgd", &[3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\ngosgd,3\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let dir = std::env::temp_dir().join("gosgd_csv_test2");
        let path = dir.join("esc.csv");
        {
            let mut w = CsvWriter::create(&path, &["x"]).unwrap();
            w.write_row_strs(&["a,b"]).unwrap();
            w.write_row_strs(&["say \"hi\""]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x\n\"a,b\"\n\"say \"\"hi\"\"\"\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
