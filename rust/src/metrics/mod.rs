//! Run metrics: loss curves, consensus error, timing, CSV output.
//!
//! Everything the figure harnesses need to regenerate the paper's series:
//! per-step loss ([`LossCurve`]), the consensus error ε(t) of section 5.2
//! ([`consensus_error`]), and a small CSV writer so every experiment
//! leaves a machine-readable trace in `results/`.

pub mod csv;

pub use csv::CsvWriter;

use crate::error::Result;
use crate::framework::Stacked;

/// Per-step scalar series with exponential-moving-average smoothing —
/// the paper's training-loss curves are EMA-smoothed by necessity (batch
/// losses are noisy).
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    steps: Vec<u64>,
    values: Vec<f64>,
}

impl LossCurve {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, step: u64, value: f64) {
        self.steps.push(step);
        self.values.push(value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn steps(&self) -> &[u64] {
        &self.steps
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }

    /// Mean of the values whose *index* lies in `[lo, hi)`.
    pub fn window_mean(&self, lo: usize, hi: usize) -> f64 {
        let hi = hi.min(self.values.len());
        if lo >= hi {
            return f64::NAN;
        }
        self.values[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
    }

    /// EMA smoothing with decay `beta` (new = beta*old + (1-beta)*x).
    pub fn ema(&self, beta: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.values.len());
        let mut acc = None;
        for &v in &self.values {
            let next = match acc {
                None => v,
                Some(prev) => beta * prev + (1.0 - beta) * v,
            };
            out.push(next);
            acc = Some(next);
        }
        out
    }

    /// First step index at which the EMA-smoothed loss drops below
    /// `threshold` (the "iterations to reach loss L" metric of Fig. 1/2).
    pub fn first_step_below(&self, threshold: f64, beta: f64) -> Option<u64> {
        let ema = self.ema(beta);
        ema.iter()
            .position(|&v| v < threshold)
            .map(|i| self.steps[i])
    }

    /// Downsample to at most `n` evenly spaced points (plot-friendly).
    pub fn downsample(&self, n: usize) -> Vec<(u64, f64)> {
        if self.values.is_empty() || n == 0 {
            return Vec::new();
        }
        let stride = (self.values.len() + n - 1) / n;
        self.steps
            .iter()
            .zip(&self.values)
            .step_by(stride.max(1))
            .map(|(&s, &v)| (s, v))
            .collect()
    }
}

/// Consensus error `ε(t) = Σ_m ‖x_m − x̄‖²` (paper section 5.2).
pub fn consensus_error(stacked: &Stacked) -> Result<f64> {
    stacked.consensus_error()
}

/// EMA smoothing over a `(time, value)` trace, preserving the time axis —
/// the pair-shaped sibling of [`LossCurve::ema`], shared by the DES
/// harnesses (fig2, scenarios).
pub fn ema_series(points: &[(f64, f64)], beta: f64) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(points.len());
    let mut acc = None;
    for &(t, v) in points {
        let next = match acc {
            None => v,
            Some(prev) => beta * prev + (1.0 - beta) * v,
        };
        out.push((t, next));
        acc = Some(next);
    }
    out
}

/// Simple wall-clock stopwatch for run phases.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(vals: &[f64]) -> LossCurve {
        let mut c = LossCurve::new();
        for (i, &v) in vals.iter().enumerate() {
            c.push(i as u64, v);
        }
        c
    }

    #[test]
    fn window_mean_bounds() {
        let c = curve(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.window_mean(0, 2), 1.5);
        assert_eq!(c.window_mean(2, 100), 3.5);
        assert!(c.window_mean(3, 3).is_nan());
    }

    #[test]
    fn ema_smooths() {
        let c = curve(&[0.0, 10.0]);
        let e = c.ema(0.5);
        assert_eq!(e, vec![0.0, 5.0]);
        // beta=0 -> raw values
        assert_eq!(c.ema(0.0), vec![0.0, 10.0]);
    }

    #[test]
    fn first_step_below_finds_crossing() {
        let c = curve(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        assert_eq!(c.first_step_below(2.5, 0.0), Some(3));
        assert_eq!(c.first_step_below(0.5, 0.0), None);
    }

    #[test]
    fn downsample_keeps_order() {
        let c = curve(&(0..100).map(|i| i as f64).collect::<Vec<_>>());
        let d = c.downsample(10);
        assert!(d.len() <= 10 + 1);
        assert_eq!(d[0], (0, 0.0));
        for w in d.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        assert!(curve(&[]).downsample(5).is_empty());
    }
}
