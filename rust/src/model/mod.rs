//! Model metadata: the artifact manifest and the flat-parameter registry.
//!
//! The Layer-2 compiler (`python/compile/aot.py`) writes a `manifest.json`
//! next to every HLO artifact describing the model's parameter table
//! (name/shape/offset into the flat vector), program signatures, and batch
//! geometry.  This module parses it and provides parameter initialization:
//! either bit-exact from `params_init.bin` (the jax He-normal init, seed
//! recorded in the manifest) or re-sampled in Rust from the recorded
//! per-tensor `init_std` for alternative seeds.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::FlatVec;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init_std: f64,
}

/// One program argument/result in an HLO artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One exported program (train_step / eval_step / sgd_update / mix).
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub image_shape: Vec<usize>,
    pub num_classes: usize,
    pub param_count: usize,
    pub init_seed: u64,
    pub tensors: Vec<TensorSpec>,
    pub programs: Vec<ProgramSpec>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        let json = Json::parse(&text)?;
        Self::from_json(dir, &json)
    }

    fn from_json(dir: PathBuf, json: &Json) -> Result<Manifest> {
        let tensors = json
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(TensorSpec {
                    name: t.get("name")?.as_str()?.to_string(),
                    shape: t.get("shape")?.as_usize_vec()?,
                    offset: t.get("offset")?.as_usize()?,
                    size: t.get("size")?.as_usize()?,
                    init_std: t.get("init_std")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let parse_args = |arr: &Json| -> Result<Vec<ArgSpec>> {
            arr.as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.as_str()?.to_string(),
                        shape: a.get("shape")?.as_usize_vec()?,
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect()
        };

        let programs_obj = json.get("programs")?;
        let mut programs = Vec::new();
        if let Json::Obj(map) = programs_obj {
            for (name, p) in map {
                programs.push(ProgramSpec {
                    name: name.clone(),
                    file: p.get("file")?.as_str()?.to_string(),
                    inputs: parse_args(p.get("inputs")?)?,
                    outputs: parse_args(p.get("outputs")?)?,
                });
            }
        } else {
            return Err(Error::json("programs must be an object"));
        }

        let manifest = Manifest {
            dir,
            model: json.get("model")?.as_str()?.to_string(),
            batch: json.get("batch")?.as_usize()?,
            eval_batch: json.get("eval_batch")?.as_usize()?,
            image_shape: json.get("image_shape")?.as_usize_vec()?,
            num_classes: json.get("num_classes")?.as_usize()?,
            param_count: json.get("param_count")?.as_usize()?,
            init_seed: json.get("init_seed")?.as_usize()? as u64,
            tensors,
            programs,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Internal consistency: offsets contiguous, sizes match shapes, total
    /// equals `param_count`, required programs present.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for t in &self.tensors {
            if t.offset != off {
                return Err(Error::artifact(format!(
                    "tensor {} offset {} != expected {off}",
                    t.name, t.offset
                )));
            }
            let prod: usize = t.shape.iter().product();
            if prod != t.size {
                return Err(Error::artifact(format!(
                    "tensor {} size {} != shape product {prod}",
                    t.name, t.size
                )));
            }
            off += t.size;
        }
        if off != self.param_count {
            return Err(Error::artifact(format!(
                "tensor sizes sum to {off}, manifest says {}",
                self.param_count
            )));
        }
        for required in ["train_step", "eval_step", "sgd_update", "mix"] {
            if self.program(required).is_none() {
                return Err(Error::artifact(format!("missing program {required}")));
            }
        }
        Ok(())
    }

    /// Look up a program by name.
    pub fn program(&self, name: &str) -> Option<&ProgramSpec> {
        self.programs.iter().find(|p| p.name == name)
    }

    /// Path of a program's HLO text file.
    pub fn program_path(&self, name: &str) -> Result<PathBuf> {
        let p = self
            .program(name)
            .ok_or_else(|| Error::artifact(format!("no program {name}")))?;
        Ok(self.dir.join(&p.file))
    }

    /// Load the bit-exact jax initialization from `params_init.bin`.
    pub fn load_init_params(&self) -> Result<FlatVec> {
        let path = self.dir.join("params_init.bin");
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::artifact(format!("cannot read {}: {e}", path.display())))?;
        if bytes.len() != self.param_count * 4 {
            return Err(Error::artifact(format!(
                "params_init.bin has {} bytes, expected {}",
                bytes.len(),
                self.param_count * 4
            )));
        }
        let mut out = vec![0.0f32; self.param_count];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(FlatVec::from_vec(out))
    }

    /// Re-sample an initialization in Rust from the recorded per-tensor
    /// std (He-normal; biases have std 0 and stay zero).  Statistically
    /// equivalent to the jax init, not bit-identical.
    pub fn sample_init_params(&self, seed: u64) -> FlatVec {
        let mut flat = vec![0.0f32; self.param_count];
        let base = Rng::new(seed);
        for (i, t) in self.tensors.iter().enumerate() {
            if t.init_std > 0.0 {
                let mut rng = base.split(i as u64);
                rng.fill_normal(
                    &mut flat[t.offset..t.offset + t.size],
                    t.init_std as f32,
                );
            }
        }
        FlatVec::from_vec(flat)
    }

    /// Elements in one image (NHWC product).
    pub fn image_elems(&self) -> usize {
        self.image_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path, param_count: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = format!(
            r#"{{
              "version": 2, "model": "tiny", "batch": 4, "eval_batch": 8,
              "image_shape": [32, 32, 3], "num_classes": 10,
              "param_count": {param_count}, "init_seed": 0,
              "tensors": [
                {{"name": "w", "shape": [2, 3], "offset": 0, "size": 6, "init_std": 0.5}},
                {{"name": "b", "shape": [2], "offset": 6, "size": 2, "init_std": 0.0}}
              ],
              "programs": {{
                "train_step": {{"file": "train_step.hlo.txt", "inputs": [], "outputs": []}},
                "eval_step": {{"file": "eval_step.hlo.txt", "inputs": [], "outputs": []}},
                "sgd_update": {{"file": "sgd_update.hlo.txt", "inputs": [], "outputs": []}},
                "mix": {{"file": "mix.hlo.txt",
                  "inputs": [{{"name": "x_r", "shape": [{param_count}], "dtype": "f32"}}],
                  "outputs": [{{"name": "mixed", "shape": [{param_count}], "dtype": "f32"}}]}}
              }}
            }}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let data: Vec<u8> = (0..param_count)
            .flat_map(|i| (i as f32).to_le_bytes())
            .collect();
        std::fs::write(dir.join("params_init.bin"), data).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gosgd_model_test_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = tmpdir("ok");
        write_fixture(&dir, 8);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(m.param_count, 8);
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.tensors[1].offset, 6);
        assert!(m.program("mix").is_some());
        assert!(m.program("nope").is_none());
        assert_eq!(m.image_elems(), 3072);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn init_params_round_trip() {
        let dir = tmpdir("init");
        write_fixture(&dir, 8);
        let m = Manifest::load(&dir).unwrap();
        let p = m.load_init_params().unwrap();
        assert_eq!(p.len(), 8);
        assert_eq!(p.as_slice()[3], 3.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sampled_init_respects_stds() {
        let dir = tmpdir("sample");
        write_fixture(&dir, 8);
        let m = Manifest::load(&dir).unwrap();
        let p = m.sample_init_params(1);
        // bias (last 2) must be exactly zero; weights non-zero
        assert_eq!(&p.as_slice()[6..], &[0.0, 0.0]);
        assert!(p.as_slice()[..6].iter().any(|&v| v != 0.0));
        // deterministic
        let q = m.sample_init_params(1);
        assert_eq!(p.as_slice(), q.as_slice());
        assert_ne!(p.as_slice(), m.sample_init_params(2).as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_param_count_rejected() {
        let dir = tmpdir("bad");
        write_fixture(&dir, 9); // tensors sum to 8, manifest says 9
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_gives_artifact_hint() {
        let err = Manifest::load("/nonexistent/gosgd").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn truncated_init_bin_rejected() {
        let dir = tmpdir("trunc");
        write_fixture(&dir, 8);
        std::fs::write(dir.join("params_init.bin"), [0u8; 7]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.load_init_params().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
