//! Transport plumbing: loopback byte pipes and the per-peer outbox layer.
//!
//! Two jobs live here, both below the protocol and above raw sockets:
//!
//! * [`LoopbackPipe`] — an in-process unidirectional byte stream with the
//!   failure modes of a real socket built in: arbitrary read chunking,
//!   and a *sever* operation that cuts the stream at an exact byte
//!   position (mid-frame, if the test wants) the way a crashed peer cuts
//!   a TCP connection.  The loopback runtime and both test suites speak
//!   frames over these pipes; the socket runtime speaks the same frames
//!   over `TcpStream`s.
//! * [`ConnManager`] — a sender's view of its connections: one bounded
//!   outbox per peer (a [`MessageQueue`], so overflow *coalesces* — the
//!   same backpressure-without-mass-loss policy every other runtime
//!   uses), plus exactly-once delivery accounting.  A message is
//!   **delivered** only when the receiver has acknowledged the stream
//!   position past its frame's last byte; anything short of that on a
//!   dead connection is **reclaimed** and reabsorbed by the sender, so a
//!   crash can move mass back but never destroy it.  The receiver's half
//!   of the contract is symmetric: a torn frame prefix in a
//!   [`FrameReader`](crate::net::FrameReader) is discarded, never
//!   partially absorbed.
//!
//! `Σ (worker mass) + Σ (acked-but-unprocessed) == 1` holds across every
//! sever/reclaim interleaving — audited by `rust/tests/net_faults.rs`.

use crate::gossip::{Message, MessageQueue};
use crate::net::frame::{encode_frame, FrameKind};
use crate::sync::Mutex;
use std::collections::VecDeque;

/// A unidirectional in-process byte stream with socket-shaped faults.
///
/// Positions are absolute stream offsets (bytes since the pipe opened),
/// so sender-side bookkeeping survives buffer compaction.
#[derive(Debug, Default)]
pub struct LoopbackPipe {
    inner: Mutex<PipeInner>,
}

#[derive(Debug, Default)]
struct PipeInner {
    /// Bytes written but not yet read, starting at stream offset `read`.
    buf: VecDeque<u8>,
    /// Total bytes ever written.
    written: u64,
    /// Total bytes the receiver has pulled out.
    read: u64,
    /// Stream position the receiver has *processed* through (frame
    /// granularity — the receiver acks after absorbing each frame).
    acked: u64,
    /// If set, the stream is cut: reads stop at this position and writes
    /// after it are discarded (the peer is gone).
    cut: Option<u64>,
}

impl LoopbackPipe {
    pub fn new() -> Self {
        LoopbackPipe::default()
    }

    /// Append bytes; returns the absolute stream position after them.
    /// Writes to a severed pipe are silently discarded past the cut,
    /// like writes to a half-closed socket.
    pub fn write(&self, bytes: &[u8]) -> u64 {
        let mut g = self.inner.lock().expect("pipe poisoned");
        let end = g.written + bytes.len() as u64;
        match g.cut {
            Some(cut) if g.written >= cut => {}
            Some(cut) => {
                let keep = (cut - g.written) as usize;
                g.buf.extend(bytes[..keep.min(bytes.len())].iter().copied());
            }
            None => g.buf.extend(bytes.iter().copied()),
        }
        g.written = end;
        end
    }

    /// Pull up to `max` bytes into `out`; returns how many arrived.
    /// Never crosses a sever point.
    pub fn read_into(&self, out: &mut Vec<u8>, max: usize) -> usize {
        let mut g = self.inner.lock().expect("pipe poisoned");
        let readable = match g.cut {
            Some(cut) => (cut.saturating_sub(g.read) as usize).min(g.buf.len()),
            None => g.buf.len(),
        };
        let n = readable.min(max);
        for _ in 0..n {
            out.push(g.buf.pop_front().expect("readable bytes"));
        }
        g.read += n as u64;
        n
    }

    /// Receiver-side: mark `n` more stream bytes as fully processed
    /// (called once per absorbed frame with that frame's total size).
    pub fn ack(&self, n: u64) {
        let mut g = self.inner.lock().expect("pipe poisoned");
        g.acked += n;
        debug_assert!(g.acked <= g.read, "acked past read position");
    }

    /// Stream position processed through (sender prunes against this).
    pub fn acked(&self) -> u64 {
        self.inner.lock().expect("pipe poisoned").acked
    }

    /// Total bytes ever written (next write starts here).
    pub fn written(&self) -> u64 {
        self.inner.lock().expect("pipe poisoned").written
    }

    /// Bytes currently readable without crossing a sever point.
    pub fn readable(&self) -> usize {
        let g = self.inner.lock().expect("pipe poisoned");
        match g.cut {
            Some(cut) => (cut.saturating_sub(g.read) as usize).min(g.buf.len()),
            None => g.buf.len(),
        }
    }

    /// Cut the stream at absolute position `pos`: bytes at or past `pos`
    /// never reach the receiver.  Cutting mid-frame is the "peer died
    /// while a frame was in flight" fault.  The earliest cut wins.
    pub fn sever_at(&self, pos: u64) {
        let mut g = self.inner.lock().expect("pipe poisoned");
        let pos = match g.cut {
            Some(old) => old.min(pos),
            None => pos,
        };
        g.cut = Some(pos);
        // Drop already-buffered bytes past the cut.
        let keep = (pos.saturating_sub(g.read) as usize).min(g.buf.len());
        g.buf.truncate(keep);
    }

    /// Cut at the current write position (everything already written may
    /// still arrive; nothing new will).
    pub fn sever_now(&self) -> u64 {
        let pos = self.written();
        self.sever_at(pos);
        pos
    }

    pub fn is_severed(&self) -> bool {
        self.inner.lock().expect("pipe poisoned").cut.is_some()
    }

    /// Reopen for a rejoined peer: clears the cut and discards any
    /// unread bytes from the previous incarnation (they belong to a
    /// connection that no longer exists; their mass was reclaimed
    /// sender-side).  Positions keep counting — stream offsets stay
    /// unique across incarnations, and the ack position jumps to the
    /// current write position so old unacked entries read as dead.
    pub fn reopen(&self) {
        let mut g = self.inner.lock().expect("pipe poisoned");
        g.cut = None;
        g.buf.clear();
        g.read = g.written;
        g.acked = g.written;
    }
}

/// One sender's bounded per-peer outboxes plus delivery accounting.
///
/// Not itself thread-safe — each worker owns one (the queues inside are
/// concurrent, but the unacked log is single-owner by design: only the
/// sending worker flushes its own connections).
#[derive(Debug)]
pub struct ConnManager {
    outboxes: Vec<MessageQueue>,
    /// Per peer: (stream position after the frame's last byte, message)
    /// for every flushed-but-unacked message, in stream order.
    unacked: Vec<VecDeque<(u64, Message)>>,
    /// Scratch buffers reused across flushes.
    drain_buf: Vec<Message>,
    body_buf: Vec<u8>,
    frame_buf: Vec<u8>,
}

impl ConnManager {
    /// `workers` peers, each outbox bounded at `outbox_cap` messages
    /// (overflow coalesces per the [`MessageQueue`] policy — backpressure
    /// without mass loss).
    pub fn new(workers: usize, outbox_cap: usize) -> Self {
        ConnManager {
            outboxes: (0..workers).map(|_| MessageQueue::bounded(outbox_cap)).collect(),
            unacked: (0..workers).map(|_| VecDeque::new()).collect(),
            drain_buf: Vec::new(),
            body_buf: Vec::new(),
            frame_buf: Vec::new(),
        }
    }

    pub fn peers(&self) -> usize {
        self.outboxes.len()
    }

    /// Queue a gossip message for `to`.  Never blocks; a full outbox
    /// coalesces.
    pub fn enqueue(&self, to: usize, msg: Message) {
        self.outboxes[to].push(msg);
    }

    /// Messages queued but not yet flushed to `to`'s pipe.
    pub fn queued(&self, to: usize) -> usize {
        self.outboxes[to].len()
    }

    /// Encode and write every queued message for `to` as gossip frames
    /// stamped with `epoch`.  Returns the number of frames written.
    /// Each message moves to the unacked log keyed by its frame's end
    /// position; [`prune_acked`](ConnManager::prune_acked) retires it
    /// once the receiver acks past that position.
    pub fn flush(&mut self, to: usize, epoch: u64, pipe: &LoopbackPipe) -> usize {
        self.drain_buf.clear();
        self.outboxes[to].drain_into(&mut self.drain_buf);
        let mut frames = 0;
        for msg in self.drain_buf.drain(..) {
            self.body_buf.clear();
            msg.encode_body(&mut self.body_buf);
            self.frame_buf.clear();
            encode_frame(&mut self.frame_buf, FrameKind::Gossip, epoch, &self.body_buf);
            let end = pipe.write(&self.frame_buf);
            self.unacked[to].push_back((end, msg));
            frames += 1;
        }
        frames
    }

    /// Write one control frame (join/ack/start/done/leave) directly —
    /// control traffic carries no sum-weight mass, so it skips the
    /// outbox and the unacked log.
    pub fn send_control(&mut self, kind: FrameKind, epoch: u64, body: &[u8], pipe: &LoopbackPipe) {
        self.frame_buf.clear();
        encode_frame(&mut self.frame_buf, kind, epoch, body);
        pipe.write(&self.frame_buf);
    }

    /// Retire unacked messages the receiver has processed (ack position
    /// at or past their frame end).
    pub fn prune_acked(&mut self, to: usize, pipe: &LoopbackPipe) {
        let acked = pipe.acked();
        while matches!(self.unacked[to].front(), Some((end, _)) if *end <= acked) {
            self.unacked[to].pop_front();
        }
    }

    /// Messages flushed to `to` but never processed by it.
    pub fn unacked_len(&self, to: usize) -> usize {
        self.unacked[to].len()
    }

    /// The connection to `to` is dead: reclaim every message whose mass
    /// never reached it — both the unflushed outbox and the
    /// flushed-but-unacked log.  The caller reabsorbs these into its own
    /// core (mass moves home, never vanishes).  The receiver's mirror
    /// obligation: discard any torn frame prefix without absorbing it.
    pub fn reclaim_dead(&mut self, to: usize, pipe: &LoopbackPipe) -> Vec<Message> {
        self.prune_acked(to, pipe);
        let mut back: Vec<Message> = self.unacked[to].drain(..).map(|(_, m)| m).collect();
        self.drain_buf.clear();
        self.outboxes[to].drain_into(&mut self.drain_buf);
        back.append(&mut self.drain_buf);
        back
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::{EncodedPayload, SumWeight};
    use crate::net::frame::{FrameReader, FRAME_HEADER_BYTES};
    use crate::tensor::FlatVec;

    fn msg(weight: f64, vals: &[f32]) -> Message {
        Message::dense(
            FlatVec::from_vec(vals.to_vec()),
            SumWeight::from_value(weight),
            0,
            0,
        )
    }

    fn mass(msgs: &[Message]) -> f64 {
        msgs.iter().map(|m| m.weight.value()).sum()
    }

    #[test]
    fn pipe_delivers_bytes_in_order_across_chunked_reads() {
        let pipe = LoopbackPipe::new();
        pipe.write(b"hello ");
        pipe.write(b"world");
        let mut out = Vec::new();
        while pipe.read_into(&mut out, 3) > 0 {}
        assert_eq!(out, b"hello world");
        assert_eq!(pipe.written(), 11);
    }

    #[test]
    fn sever_mid_stream_stops_reads_at_the_cut() {
        let pipe = LoopbackPipe::new();
        pipe.write(b"0123456789");
        pipe.sever_at(4);
        let mut out = Vec::new();
        pipe.read_into(&mut out, 100);
        assert_eq!(out, b"0123");
        // Later writes are swallowed entirely.
        pipe.write(b"abc");
        assert_eq!(pipe.readable(), 0);
        assert!(pipe.is_severed());
        // The earliest cut wins.
        pipe.sever_at(100);
        assert_eq!(pipe.readable(), 0);
    }

    #[test]
    fn reopen_resets_the_stream_for_a_new_incarnation() {
        let pipe = LoopbackPipe::new();
        pipe.write(b"stale bytes");
        pipe.sever_now();
        pipe.reopen();
        assert!(!pipe.is_severed());
        assert_eq!(pipe.readable(), 0, "previous incarnation's bytes are gone");
        let pos = pipe.write(b"new");
        assert_eq!(pos, 11 + 3, "stream offsets keep counting across incarnations");
        assert_eq!(pipe.acked(), 11, "ack position jumped past the dead bytes");
    }

    #[test]
    fn flush_frames_messages_and_acks_retire_them() {
        let mut cm = ConnManager::new(2, 16);
        let pipe = LoopbackPipe::new();
        cm.enqueue(1, msg(0.25, &[1.0, 2.0]));
        cm.enqueue(1, msg(0.125, &[3.0, 4.0]));
        assert_eq!(cm.flush(1, 0, &pipe), 2);
        assert_eq!(cm.unacked_len(1), 2);

        // Receiver: read, decode both frames, ack each.
        let mut r = FrameReader::new();
        let mut chunk = Vec::new();
        pipe.read_into(&mut chunk, usize::MAX);
        r.feed(&chunk);
        let mut got = 0;
        while let Some(f) = r.try_next().expect("clean frames") {
            pipe.ack((FRAME_HEADER_BYTES + f.body.len()) as u64);
            let m = Message::decode_body(&f.body).expect("valid body");
            assert!(matches!(m.payload, EncodedPayload::Dense(_)));
            got += 1;
        }
        assert_eq!(got, 2);
        cm.prune_acked(1, &pipe);
        assert_eq!(cm.unacked_len(1), 0);
    }

    #[test]
    fn kill_mid_frame_reclaims_exactly_the_undelivered_mass() {
        let mut cm = ConnManager::new(2, 16);
        let pipe = LoopbackPipe::new();
        cm.enqueue(1, msg(0.25, &[1.0]));
        cm.flush(1, 0, &pipe);
        let first_end = pipe.written();
        cm.enqueue(1, msg(0.125, &[2.0]));
        cm.flush(1, 0, &pipe);

        // The peer dies with the second frame half-delivered.
        pipe.sever_at(first_end + 7);

        // Receiver drains what it can: exactly one complete frame, plus a
        // torn prefix it must discard.
        let mut r = FrameReader::new();
        let mut chunk = Vec::new();
        pipe.read_into(&mut chunk, usize::MAX);
        r.feed(&chunk);
        let f = r.try_next().expect("intact first frame").expect("one frame");
        pipe.ack((FRAME_HEADER_BYTES + f.body.len()) as u64);
        let absorbed = Message::decode_body(&f.body).expect("valid");
        assert_eq!(absorbed.weight.value(), 0.25);
        assert!(r.try_next().expect("prefix only").is_none());
        assert!(r.has_partial(), "torn second frame left a prefix");

        // Sender reclaims: exactly the second message's mass comes home.
        let back = cm.reclaim_dead(1, &pipe);
        assert_eq!(back.len(), 1);
        assert_eq!(mass(&back), 0.125);
        assert_eq!(cm.unacked_len(1), 0);
        // Delivered + reclaimed == everything sent: exactly once.
        assert_eq!(absorbed.weight.value() + mass(&back), 0.375);
    }

    #[test]
    fn reclaim_includes_the_unflushed_outbox() {
        let mut cm = ConnManager::new(2, 16);
        let pipe = LoopbackPipe::new();
        cm.enqueue(1, msg(0.25, &[1.0]));
        cm.flush(1, 0, &pipe);
        cm.enqueue(1, msg(0.0625, &[2.0])); // never flushed
        pipe.sever_at(0); // peer died before reading anything
        let back = cm.reclaim_dead(1, &pipe);
        assert_eq!(back.len(), 2);
        assert!((mass(&back) - 0.3125).abs() < 1e-15);
    }

    #[test]
    fn bounded_outbox_coalesces_instead_of_dropping() {
        let cm = ConnManager::new(2, 2);
        for _ in 0..10 {
            cm.enqueue(1, msg(0.01, &[1.0]));
        }
        assert!(cm.queued(1) <= 2, "outbox stayed bounded");
        // All ten messages' mass is still in the queue (folded).
        let drained = {
            let mut v = Vec::new();
            cm.outboxes[1].drain_into(&mut v);
            v
        };
        assert!((mass(&drained) - 0.1).abs() < 1e-12, "coalescing conserved mass");
    }

    #[test]
    fn control_frames_bypass_delivery_accounting() {
        let mut cm = ConnManager::new(2, 4);
        let pipe = LoopbackPipe::new();
        cm.send_control(FrameKind::Done, 3, &[], &pipe);
        assert_eq!(cm.unacked_len(1), 0);
        let mut r = FrameReader::new();
        let mut chunk = Vec::new();
        pipe.read_into(&mut chunk, usize::MAX);
        r.feed(&chunk);
        let f = r.try_next().expect("ok").expect("frame");
        assert_eq!(f.kind, FrameKind::Done);
        assert_eq!(f.epoch, 3);
    }
}
