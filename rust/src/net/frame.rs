//! The versioned length-prefixed frame codec.
//!
//! Everything that crosses a socket travels inside one frame:
//!
//! ```text
//! offset  size  field      notes
//! ------  ----  ---------  ------------------------------------------
//!      0     4  magic      b"GSGD"
//!      4     2  version    u16 LE, currently 1; future versions refused
//!      6     1  kind       FrameKind discriminant (gossip/join/...)
//!      7     1  reserved   must be 0 on the wire today
//!      8     8  epoch      u64 LE membership epoch of the sender
//!     16     4  body_len   u32 LE, bytes of body after the header
//!     20     4  crc        CRC-32 over header-with-crc-zeroed + body
//!     24     …  body       kind-dependent (gossip frames: message body)
//! ```
//!
//! The CRC deliberately covers the *header as well as* the body (with the
//! CRC field itself zeroed): a bit-flip in the epoch or kind field is
//! exactly as corrupting as one in the payload, and the fuzz suite flips
//! bits everywhere.  Decoding is strictly panic-free on arbitrary bytes —
//! every malformed input maps to a typed [`FrameError`].
//!
//! The reader is incremental ([`FrameReader`]): feed it whatever chunk the
//! socket produced, pop complete frames.  A connection that dies mid-frame
//! simply leaves a partial prefix in the reader; the receiver drops it and
//! the *sender-side* delivery accounting ([`crate::net::ConnManager`])
//! reclaims the undelivered message, so no sum-weight mass rides on a torn
//! frame.

use crate::gossip::message::WireError;
use std::fmt;

/// Wire magic: the first four bytes of every frame.
pub const FRAME_MAGIC: [u8; 4] = *b"GSGD";

/// Current wire protocol version.  Decoders refuse frames from the
/// future; bumping this is a deliberate compatibility break.
pub const WIRE_VERSION: u16 = 1;

/// Fixed header size in bytes (see the module-level layout table).
pub const FRAME_HEADER_BYTES: usize = 24;

/// Largest admissible frame body.  Far above any real gossip shard; the
/// bound exists so a corrupt `body_len` cannot ask the reader to buffer
/// gigabytes before the CRC would have caught the corruption anyway.
pub const MAX_FRAME_BODY: usize = 64 << 20;

/// What a frame carries.  Discriminants are the on-wire `kind` byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A gossip message body ([`Message::decode_body`]-shaped bytes).
    ///
    /// [`Message::decode_body`]: crate::gossip::Message::decode_body
    Gossip = 0,
    /// Join request from a newcomer (body: requested worker id hint, may
    /// be empty).
    Join = 1,
    /// Join acknowledgement from the seed (body: assigned id + the
    /// serialized [`FleetConfig`](crate::net::FleetConfig) + peer roster).
    JoinAck = 2,
    /// Graceful leave announcement (empty body).
    Leave = 3,
    /// End-of-run marker: the sender has taken its last local step and
    /// will emit no more gossip (empty body).  Receivers drain until they
    /// hold a `Done` from every live peer, which makes the cutoff exact:
    /// every emitted message is absorbed and mass sums to 1 at the end.
    Done = 4,
    /// Fleet start signal from the seed once the roster is complete
    /// (empty body).
    Start = 5,
}

impl FrameKind {
    fn from_wire(b: u8) -> Option<FrameKind> {
        match b {
            0 => Some(FrameKind::Gossip),
            1 => Some(FrameKind::Join),
            2 => Some(FrameKind::JoinAck),
            3 => Some(FrameKind::Leave),
            4 => Some(FrameKind::Done),
            5 => Some(FrameKind::Start),
            _ => None,
        }
    }
}

/// One decoded frame: the validated header fields plus the raw body.
/// Body *interpretation* (message decode, config decode) happens one
/// layer up so transport integrity and semantic validity fail separately.
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub epoch: u64,
    pub body: Vec<u8>,
}

/// Typed transport-level decode failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The first four bytes are not `b"GSGD"` — not our protocol, or a
    /// stream that lost framing.  Unrecoverable for the connection.
    BadMagic([u8; 4]),
    /// The frame announces a protocol version newer than this build.
    FutureVersion(u16),
    /// Unknown `kind` discriminant.
    BadKind(u8),
    /// Nonzero reserved byte.
    BadReserved(u8),
    /// `body_len` exceeds [`MAX_FRAME_BODY`].
    Oversize(u32),
    /// Header+body checksum mismatch: bytes were corrupted in flight.
    CrcMismatch { expected: u32, got: u32 },
    /// The frame was intact but its body failed message-level decoding.
    Body(WireError),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::FutureVersion(v) => {
                write!(f, "frame version {v} is newer than supported {WIRE_VERSION}")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k:#04x}"),
            FrameError::BadReserved(b) => write!(f, "nonzero reserved byte {b:#04x}"),
            FrameError::Oversize(n) => write!(f, "frame body of {n} bytes exceeds the maximum"),
            FrameError::CrcMismatch { expected, got } => {
                write!(
                    f,
                    "frame crc mismatch: header says {expected:#010x}, bytes hash to {got:#010x}"
                )
            }
            FrameError::Body(e) => write!(f, "frame body rejected: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<WireError> for FrameError {
    fn from(e: WireError) -> Self {
        FrameError::Body(e)
    }
}

impl From<FrameError> for crate::error::Error {
    fn from(e: FrameError) -> Self {
        crate::error::Error::net(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), hand-rolled like
// everything else in the crate.  Slicing-by-8: eight compile-time tables
// let the hot loop fold eight message bytes per iteration instead of one,
// with no data-dependent chain between the eight lookups — the checksum
// sits on every gossip frame's send *and* receive path, so at WAN message
// sizes the bytewise loop was the frame codec's dominant cost.
// ---------------------------------------------------------------------------

/// `CRC_TABLES[0]` is the classic bytewise table; `CRC_TABLES[k][i]` is
/// the CRC of byte `i` followed by `k` zero bytes, which is what lets a
/// `k`-byte-deep lookup jump the register forward eight bytes at once.
const CRC_TABLES: [[u32; 256]; 8] = {
    let mut tables = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        tables[0][i] = c;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = tables[k - 1][i];
            tables[k][i] = (prev >> 8) ^ tables[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    tables
};

/// Streaming CRC-32: `crc32_update(crc32_update(INIT, a), b)` equals
/// `crc32(a ++ b)`, which lets the check run over header and body without
/// concatenating them.
const CRC_INIT: u32 = 0xFFFF_FFFF;

/// The one-byte-per-step reference kernel — kept as the oracle the
/// equivalence test checks the sliced kernel against, and as the tail
/// loop for lengths under eight.
fn crc32_update_bytewise(mut crc: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        crc = CRC_TABLES[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc
}

fn crc32_update(mut crc: u32, bytes: &[u8]) -> u32 {
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        // XOR the register into the first four bytes, then eight
        // independent table lookups re-derive the register eight bytes
        // later.  Reflected CRC consumes the low byte first, so lookup
        // depth runs 7..0 across the chunk.
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        crc = CRC_TABLES[7][(lo & 0xFF) as usize]
            ^ CRC_TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[4][(lo >> 24) as usize]
            ^ CRC_TABLES[3][(hi & 0xFF) as usize]
            ^ CRC_TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[0][(hi >> 24) as usize];
    }
    crc32_update_bytewise(crc, chunks.remainder())
}

/// CRC-32 of one contiguous buffer.
pub fn crc32(bytes: &[u8]) -> u32 {
    !crc32_update(CRC_INIT, bytes)
}

fn frame_crc(header_sans_crc: &[u8; FRAME_HEADER_BYTES], body: &[u8]) -> u32 {
    !crc32_update(crc32_update(CRC_INIT, header_sans_crc), body)
}

// ---------------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------------

/// Serialize one frame (header + body) into `out`.
///
/// Panics only on a body larger than [`MAX_FRAME_BODY`] — a programmer
/// error on the *send* side (local, trusted data); the decode side never
/// panics.
pub fn encode_frame(out: &mut Vec<u8>, kind: FrameKind, epoch: u64, body: &[u8]) {
    assert!(
        body.len() <= MAX_FRAME_BODY,
        "frame body of {} bytes exceeds the wire maximum",
        body.len()
    );
    let mut header = [0u8; FRAME_HEADER_BYTES];
    header[0..4].copy_from_slice(&FRAME_MAGIC);
    header[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    header[6] = kind as u8;
    header[7] = 0; // reserved
    header[8..16].copy_from_slice(&epoch.to_le_bytes());
    header[16..20].copy_from_slice(&(body.len() as u32).to_le_bytes());
    // CRC over the header with the crc field still zeroed, then the body.
    let crc = frame_crc(&header, body);
    header[20..24].copy_from_slice(&crc.to_le_bytes());
    out.reserve(FRAME_HEADER_BYTES + body.len());
    out.extend_from_slice(&header);
    out.extend_from_slice(body);
}

/// Convenience: one frame as a fresh buffer.
pub fn frame_bytes(kind: FrameKind, epoch: u64, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + body.len());
    encode_frame(&mut out, kind, epoch, body);
    out
}

// ---------------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------------

/// Incremental frame reassembler.
///
/// Feed it byte chunks as the transport produces them (a socket read, a
/// loopback pipe take — chunk boundaries are arbitrary) and pop complete
/// frames with [`try_next`](FrameReader::try_next).  A decode error is
/// **sticky**: framing on a byte stream cannot be resynchronized after
/// corruption, so the caller must drop the connection (which is exactly
/// what the runtime does).
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed by returned frames.  Compacted
    /// lazily so feeding is O(chunk).
    consumed: usize,
    poisoned: Option<FrameError>,
}

impl FrameReader {
    pub fn new() -> Self {
        FrameReader::default()
    }

    /// Append transport bytes.
    pub fn feed(&mut self, chunk: &[u8]) {
        // Compact before growing: keeps the buffer bounded by one frame
        // plus one chunk in steady state.
        if self.consumed > 0 {
            self.buf.drain(..self.consumed);
            self.consumed = 0;
        }
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes buffered but not yet returned as a frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.consumed
    }

    /// True if a partial frame (or any unconsumed bytes) sit in the
    /// buffer — after a peer death this is the torn-frame prefix the
    /// receiver discards.
    pub fn has_partial(&self) -> bool {
        self.pending_bytes() > 0
    }

    /// Pop the next complete frame, if the buffered bytes contain one.
    ///
    /// `Ok(None)` means "need more bytes".  `Err` poisons the reader:
    /// every later call returns the same error.
    pub fn try_next(&mut self) -> Result<Option<Frame>, FrameError> {
        if let Some(e) = &self.poisoned {
            return Err(e.clone());
        }
        match self.parse_one() {
            Ok(f) => Ok(f),
            Err(e) => {
                self.poisoned = Some(e.clone());
                Err(e)
            }
        }
    }

    fn parse_one(&mut self) -> Result<Option<Frame>, FrameError> {
        let avail = &self.buf[self.consumed..];
        if avail.len() < FRAME_HEADER_BYTES {
            return Ok(None);
        }
        let header: &[u8; FRAME_HEADER_BYTES] =
            avail[..FRAME_HEADER_BYTES].try_into().expect("header slice");
        if header[0..4] != FRAME_MAGIC {
            return Err(FrameError::BadMagic(header[0..4].try_into().expect("4 bytes")));
        }
        let version = u16::from_le_bytes(header[4..6].try_into().expect("2 bytes"));
        if version > WIRE_VERSION {
            return Err(FrameError::FutureVersion(version));
        }
        let body_len = u32::from_le_bytes(header[16..20].try_into().expect("4 bytes"));
        if body_len as usize > MAX_FRAME_BODY {
            return Err(FrameError::Oversize(body_len));
        }
        let total = FRAME_HEADER_BYTES + body_len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        // Whole frame present: check integrity before interpreting kind,
        // so a corrupt kind byte reports as corruption, not "bad kind".
        let body = &avail[FRAME_HEADER_BYTES..total];
        let expected = u32::from_le_bytes(header[20..24].try_into().expect("4 bytes"));
        let mut zeroed = *header;
        zeroed[20..24].copy_from_slice(&[0; 4]);
        let got = frame_crc(&zeroed, body);
        if got != expected {
            return Err(FrameError::CrcMismatch { expected, got });
        }
        if header[7] != 0 {
            return Err(FrameError::BadReserved(header[7]));
        }
        let kind = FrameKind::from_wire(header[6]).ok_or(FrameError::BadKind(header[6]))?;
        let epoch = u64::from_le_bytes(header[8..16].try_into().expect("8 bytes"));
        let frame = Frame { kind, epoch, body: body.to_vec() };
        self.consumed += total;
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE CRC-32 check values.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Streaming split equals one-shot.
        let split = !crc32_update(crc32_update(CRC_INIT, b"1234"), b"56789");
        assert_eq!(split, 0xCBF4_3926);
    }

    #[test]
    fn sliced_crc_equals_the_bytewise_reference_property() {
        // The slicing-by-8 kernel against the one-byte oracle: every
        // length (covering all remainder classes mod 8), arbitrary
        // content, arbitrary split points, non-initial registers.
        crate::util::proptest::check("crc slicing-by-8 ≡ bytewise", 200, |rng| {
            let len = (rng.next_u64() % 300) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let start = rng.next_u64() as u32; // any register, not just INIT
            assert_eq!(
                crc32_update(start, &bytes),
                crc32_update_bytewise(start, &bytes),
                "len {len}"
            );
            // Streaming at an arbitrary split still matches.
            let cut = if len == 0 { 0 } else { (rng.next_u64() % (len as u64 + 1)) as usize };
            assert_eq!(
                crc32_update(crc32_update(start, &bytes[..cut]), &bytes[cut..]),
                crc32_update_bytewise(start, &bytes),
                "len {len} cut {cut}"
            );
        });
    }

    #[test]
    fn frame_round_trips() {
        let body = b"hello gossip".to_vec();
        let bytes = frame_bytes(FrameKind::Gossip, 7, &body);
        assert_eq!(bytes.len(), FRAME_HEADER_BYTES + body.len());
        let mut r = FrameReader::new();
        r.feed(&bytes);
        let f = r.try_next().expect("decode").expect("complete");
        assert_eq!(f.kind, FrameKind::Gossip);
        assert_eq!(f.epoch, 7);
        assert_eq!(f.body, body);
        assert!(!r.has_partial());
        assert!(r.try_next().expect("no error").is_none());
    }

    #[test]
    fn reader_reassembles_across_arbitrary_chunks() {
        let a = frame_bytes(FrameKind::Join, 1, b"one");
        let b = frame_bytes(FrameKind::Done, 2, b"");
        let stream: Vec<u8> = a.iter().chain(b.iter()).copied().collect();
        // Feed a byte at a time: two frames must still pop out intact.
        let mut r = FrameReader::new();
        let mut frames = Vec::new();
        for &byte in &stream {
            r.feed(&[byte]);
            while let Some(f) = r.try_next().expect("clean stream") {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].kind, FrameKind::Join);
        assert_eq!(frames[0].body, b"one");
        assert_eq!(frames[1].kind, FrameKind::Done);
        assert_eq!(frames[1].epoch, 2);
        assert!(!r.has_partial());
    }

    #[test]
    fn truncated_frame_is_just_pending() {
        let bytes = frame_bytes(FrameKind::Gossip, 0, &[9; 100]);
        let mut r = FrameReader::new();
        r.feed(&bytes[..bytes.len() - 1]);
        assert!(r.try_next().expect("no error yet").is_none());
        assert!(r.has_partial());
        assert_eq!(r.pending_bytes(), bytes.len() - 1);
    }

    #[test]
    fn bad_magic_is_fatal_and_sticky() {
        let mut bytes = frame_bytes(FrameKind::Gossip, 0, b"x");
        bytes[0] = b'X';
        let mut r = FrameReader::new();
        r.feed(&bytes);
        let e = r.try_next().unwrap_err();
        assert!(matches!(e, FrameError::BadMagic(_)));
        assert_eq!(r.try_next().unwrap_err(), e, "poisoned reader repeats");
    }

    #[test]
    fn future_version_refused() {
        let mut bytes = frame_bytes(FrameKind::Gossip, 0, b"x");
        bytes[4..6].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
        let mut r = FrameReader::new();
        r.feed(&bytes);
        assert!(matches!(r.try_next().unwrap_err(), FrameError::FutureVersion(_)));
    }

    #[test]
    fn every_single_bit_flip_is_caught() {
        // CRC-32 detects all 1-bit errors; flipping any bit in the frame
        // (header or body, except within pre-CRC-checked fields where a
        // different typed error fires first) must fail decoding.
        let bytes = frame_bytes(FrameKind::Gossip, 3, b"payload bytes!");
        for bit in 0..bytes.len() * 8 {
            let mut flipped = bytes.clone();
            flipped[bit / 8] ^= 1 << (bit % 8);
            let mut r = FrameReader::new();
            r.feed(&flipped);
            match r.try_next() {
                Err(_) => {}
                Ok(Some(_)) => panic!("bit flip {bit} decoded as a valid frame"),
                // A flip in body_len can make the frame look longer than
                // the bytes we have — that parks as "pending", which is
                // fine: the CRC still guards it when more bytes arrive.
                Ok(None) => assert!(bit / 8 >= 16 && bit / 8 < 20, "bit {bit} silently pending"),
            }
        }
    }

    #[test]
    fn oversize_body_len_refused_without_buffering() {
        let mut bytes = frame_bytes(FrameKind::Gossip, 0, b"x");
        bytes[16..20].copy_from_slice(&(MAX_FRAME_BODY as u32 + 1).to_le_bytes());
        let mut r = FrameReader::new();
        r.feed(&bytes[..FRAME_HEADER_BYTES]);
        assert!(matches!(r.try_next().unwrap_err(), FrameError::Oversize(_)));
    }

    #[test]
    fn corrupt_kind_reports_as_corruption_not_bad_kind() {
        // The kind byte is CRC-covered; flipping it must surface as
        // CrcMismatch (transport corruption), BadKind is reserved for
        // well-checksummed frames from a incompatible peer.
        let mut bytes = frame_bytes(FrameKind::Gossip, 0, b"x");
        bytes[6] = 0x7f;
        let mut r = FrameReader::new();
        r.feed(&bytes);
        assert!(matches!(r.try_next().unwrap_err(), FrameError::CrcMismatch { .. }));
    }

    #[test]
    fn genuinely_unknown_kind_with_valid_crc_reports_bad_kind() {
        // Re-checksum a frame after forging the kind byte: now the CRC
        // passes and the kind check fires.
        let body = b"x";
        let mut header = [0u8; FRAME_HEADER_BYTES];
        header[0..4].copy_from_slice(&FRAME_MAGIC);
        header[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
        header[6] = 0x7f;
        header[16..20].copy_from_slice(&(body.len() as u32).to_le_bytes());
        let crc = frame_crc(&header, body);
        header[20..24].copy_from_slice(&crc.to_le_bytes());
        let mut stream = header.to_vec();
        stream.extend_from_slice(body);
        let mut r = FrameReader::new();
        r.feed(&stream);
        assert_eq!(r.try_next().unwrap_err(), FrameError::BadKind(0x7f));
    }
}
