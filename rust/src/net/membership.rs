//! Epoch-based elastic membership.
//!
//! The DES already models churn: a crash flips a worker's alive bit and
//! the emit path repairs peer picks against the alive mask
//! ([`ProtocolCore::emit_alive`](crate::gossip::ProtocolCore::emit_alive)).
//! The networked runtime makes the same semantics real, with one new
//! ingredient: the **membership epoch**, a `u64` that bumps on every
//! join, leave or detected crash.  Every frame carries the sender's
//! epoch, and the [`Membership::admit`] rule decides what to do with a
//! frame from the past:
//!
//! * **Current** — sender is alive and the frame's epoch is at or after
//!   the epoch the sender last joined: absorb normally.  Note admission
//!   is *not* "epoch == ours": gossip is asynchronous, a frame sent just
//!   before an unrelated membership change is still perfectly good mass.
//! * **Stale** — one of two cases, both discarded without blending:
//!   a **zombie** frame (the sender is currently marked dead — its bytes
//!   were in flight when it died; its mass is reconciled sender-side,
//!   never receiver-side), or a **ghost** frame (the sender is alive but
//!   the frame predates the sender's own `joined_epoch`, i.e. it was
//!   emitted by the sender's *previous incarnation*).
//! * **Future** — epoch beyond ours: we are behind on membership; the
//!   caller refreshes its view before absorbing (the loopback runtime
//!   treats it as admit-after-catch-up; the socket runtime re-syncs its
//!   roster).
//!
//! Discarding a stale frame looks like it destroys sum-weight mass — it
//! would, if the sender had forgotten it.  It has not: the connection
//! layer ([`crate::net::ConnManager`]) counts a message as delivered only
//! when its frame's bytes fully left the pipe, and a dead peer's
//! undelivered messages are reclaimed and **reabsorbed by the sender**
//! (or its rejoining incarnation).  The fault suite
//! (`rust/tests/net_faults.rs`) audits `Σ mass == 1` through every such
//! transition.

use crate::error::{Error, Result};
use crate::gossip::{CodecSpec, TopologySpec};
use std::fmt;

/// Verdict for an incoming frame, from [`Membership::admit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Absorb normally.
    Current,
    /// Discard: zombie (dead sender) or ghost (pre-rejoin) traffic.
    Stale,
    /// Our membership view is behind the sender's; refresh, then retry.
    Future,
}

/// Who is in the fleet, and since when.
#[derive(Clone, Debug)]
pub struct Membership {
    epoch: u64,
    alive: Vec<bool>,
    /// Epoch at which each worker (most recently) joined.  A frame from
    /// worker `w` with `epoch < joined_epoch[w]` was emitted by a
    /// previous incarnation of `w` and must not blend into the fleet.
    joined_epoch: Vec<u64>,
}

impl Membership {
    /// A fresh fleet of `workers` members, all alive at epoch 0.
    pub fn new(workers: usize) -> Self {
        Membership { epoch: 0, alive: vec![true; workers], joined_epoch: vec![0; workers] }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn workers(&self) -> usize {
        self.alive.len()
    }

    pub fn is_alive(&self, w: usize) -> bool {
        self.alive.get(w).copied().unwrap_or(false)
    }

    pub fn live_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// The alive mask in the exact shape
    /// [`ProtocolCore::emit_alive`](crate::gossip::ProtocolCore::emit_alive)
    /// takes.
    pub fn alive_mask(&self) -> &[bool] {
        &self.alive
    }

    /// Classify a frame from `sender` stamped with `frame_epoch`.
    pub fn admit(&self, sender: usize, frame_epoch: u64) -> Admit {
        if frame_epoch > self.epoch {
            return Admit::Future;
        }
        if sender >= self.alive.len() || !self.alive[sender] {
            return Admit::Stale; // zombie
        }
        if frame_epoch < self.joined_epoch[sender] {
            return Admit::Stale; // ghost from a previous incarnation
        }
        Admit::Current
    }

    /// Record a death (crash or graceful leave).  Bumps the epoch; a
    /// no-op (no bump) if the worker is already dead or out of range.
    pub fn mark_dead(&mut self, w: usize) -> bool {
        if w < self.alive.len() && self.alive[w] {
            self.alive[w] = false;
            self.epoch += 1;
            true
        } else {
            false
        }
    }

    /// Revive a previously-dead worker slot.  Bumps the epoch and stamps
    /// the slot's `joined_epoch`, which is what turns that worker's
    /// pre-crash in-flight frames into ghosts.
    pub fn rejoin(&mut self, w: usize) -> bool {
        if w < self.alive.len() && !self.alive[w] {
            self.alive[w] = true;
            self.epoch += 1;
            self.joined_epoch[w] = self.epoch;
            true
        } else {
            false
        }
    }

    /// Admit a brand-new member; returns its assigned worker id.
    pub fn join_new(&mut self) -> usize {
        let id = self.alive.len();
        self.epoch += 1;
        self.alive.push(true);
        self.joined_epoch.push(self.epoch);
        id
    }
}

// ---------------------------------------------------------------------------
// FleetConfig: the shared run configuration the join handshake replays.
// ---------------------------------------------------------------------------

/// Everything a newcomer needs to run the same protocol as the fleet.
///
/// This is the serialized payload of a
/// [`FrameKind::JoinAck`](crate::net::FrameKind::JoinAck): the seed node
/// replays the exact configuration (topology, codec, sharding, learning
/// schedule, seed) so every process derives bit-identical protocol cores.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    pub workers: usize,
    /// Model dimension (a joiner allocates its vector from this; its
    /// *values* arrive through gossip — see the sponsor-seeding note in
    /// the module docs of [`crate::net`]).
    pub dim: usize,
    pub p: f64,
    pub steps_per_worker: u64,
    pub eta: f32,
    pub weight_decay: f32,
    pub seed: u64,
    pub topology: TopologySpec,
    pub shards: usize,
    pub codec: CodecSpec,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            workers: 2,
            dim: 16,
            p: 0.05,
            steps_per_worker: 100,
            eta: 0.1,
            weight_decay: 1e-4,
            seed: 0,
            topology: TopologySpec::UniformRandom,
            shards: 1,
            codec: CodecSpec::Dense,
        }
    }
}

const TOPO_UNIFORM: u8 = 0;
const TOPO_RING: u8 = 1;
const TOPO_HYPERCUBE: u8 = 2;
const TOPO_ROTATION: u8 = 3;
const TOPO_SMALL_WORLD: u8 = 4;

const CODEC_DENSE: u8 = 0;
const CODEC_TOPK: u8 = 1;
const CODEC_Q8: u8 = 2;

impl FleetConfig {
    /// Serialize for the wire (little-endian, fixed order — this is a
    /// frame body, so the frame CRC covers it).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.workers as u64).to_le_bytes());
        out.extend_from_slice(&(self.dim as u64).to_le_bytes());
        out.extend_from_slice(&self.p.to_le_bytes());
        out.extend_from_slice(&self.steps_per_worker.to_le_bytes());
        out.extend_from_slice(&self.eta.to_le_bytes());
        out.extend_from_slice(&self.weight_decay.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        match self.topology {
            TopologySpec::UniformRandom => {
                out.push(TOPO_UNIFORM);
                out.extend_from_slice(&0f64.to_le_bytes());
            }
            TopologySpec::Ring => {
                out.push(TOPO_RING);
                out.extend_from_slice(&0f64.to_le_bytes());
            }
            TopologySpec::Hypercube => {
                out.push(TOPO_HYPERCUBE);
                out.extend_from_slice(&0f64.to_le_bytes());
            }
            TopologySpec::PartnerRotation => {
                out.push(TOPO_ROTATION);
                out.extend_from_slice(&0f64.to_le_bytes());
            }
            TopologySpec::SmallWorld { q } => {
                out.push(TOPO_SMALL_WORLD);
                out.extend_from_slice(&q.to_le_bytes());
            }
        }
        out.extend_from_slice(&(self.shards as u64).to_le_bytes());
        match self.codec {
            CodecSpec::Dense => {
                out.push(CODEC_DENSE);
                out.extend_from_slice(&0u64.to_le_bytes());
            }
            CodecSpec::TopK { k } => {
                out.push(CODEC_TOPK);
                out.extend_from_slice(&(k as u64).to_le_bytes());
            }
            CodecSpec::QuantizeU8 => {
                out.push(CODEC_Q8);
                out.extend_from_slice(&0u64.to_le_bytes());
            }
        }
    }

    /// Decode from untrusted bytes.  Every malformed input maps to
    /// [`Error::Net`](crate::error::Error::Net); semantic nonsense (zero
    /// workers, NaN p, zero shards) is refused here so a hostile JoinAck
    /// cannot steer a node into the panicking constructors downstream.
    pub fn decode(bytes: &[u8]) -> Result<FleetConfig> {
        fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8]> {
            if b.len() < n {
                return Err(Error::net(format!("fleet config truncated at {what}")));
            }
            let (head, tail) = b.split_at(n);
            *b = tail;
            Ok(head)
        }
        fn u64f(b: &mut &[u8], what: &str) -> Result<u64> {
            Ok(u64::from_le_bytes(take(b, 8, what)?.try_into().expect("8 bytes")))
        }
        fn f64f(b: &mut &[u8], what: &str) -> Result<f64> {
            Ok(f64::from_le_bytes(take(b, 8, what)?.try_into().expect("8 bytes")))
        }
        fn f32f(b: &mut &[u8], what: &str) -> Result<f32> {
            Ok(f32::from_le_bytes(take(b, 4, what)?.try_into().expect("4 bytes")))
        }
        fn u8f(b: &mut &[u8], what: &str) -> Result<u8> {
            Ok(take(b, 1, what)?[0])
        }

        let mut b = bytes;
        let workers = u64f(&mut b, "workers")? as usize;
        let dim = u64f(&mut b, "dim")? as usize;
        let p = f64f(&mut b, "p")?;
        let steps_per_worker = u64f(&mut b, "steps")?;
        let eta = f32f(&mut b, "eta")?;
        let weight_decay = f32f(&mut b, "weight_decay")?;
        let seed = u64f(&mut b, "seed")?;
        let topo_tag = u8f(&mut b, "topology tag")?;
        let topo_q = f64f(&mut b, "topology param")?;
        let topology = match topo_tag {
            TOPO_UNIFORM => TopologySpec::UniformRandom,
            TOPO_RING => TopologySpec::Ring,
            TOPO_HYPERCUBE => TopologySpec::Hypercube,
            TOPO_ROTATION => TopologySpec::PartnerRotation,
            TOPO_SMALL_WORLD => {
                if !topo_q.is_finite() || !(0.0..=1.0).contains(&topo_q) {
                    return Err(Error::net(format!("bad small-world q {topo_q}")));
                }
                TopologySpec::SmallWorld { q: topo_q }
            }
            t => return Err(Error::net(format!("unknown topology tag {t}"))),
        };
        let shards = u64f(&mut b, "shards")? as usize;
        let codec_tag = u8f(&mut b, "codec tag")?;
        let codec_k = u64f(&mut b, "codec param")? as usize;
        let codec = match codec_tag {
            CODEC_DENSE => CodecSpec::Dense,
            CODEC_TOPK => {
                if codec_k == 0 {
                    return Err(Error::net("top-k codec with k = 0"));
                }
                CodecSpec::TopK { k: codec_k }
            }
            CODEC_Q8 => CodecSpec::QuantizeU8,
            t => return Err(Error::net(format!("unknown codec tag {t}"))),
        };
        if !b.is_empty() {
            return Err(Error::net(format!("{} trailing bytes after fleet config", b.len())));
        }
        let cfg = FleetConfig {
            workers,
            dim,
            p,
            steps_per_worker,
            eta,
            weight_decay,
            seed,
            topology,
            shards,
            codec,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Semantic validation shared by decode and the CLI.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::net("fleet config: zero workers"));
        }
        if self.dim == 0 {
            return Err(Error::net("fleet config: zero dimension"));
        }
        if !self.p.is_finite() || !(0.0..=1.0).contains(&self.p) {
            return Err(Error::net(format!("fleet config: bad exchange probability {}", self.p)));
        }
        if !self.eta.is_finite() || !self.weight_decay.is_finite() {
            return Err(Error::net("fleet config: non-finite learning parameters"));
        }
        if self.shards == 0 || (self.shards > 1 && self.dim < self.shards) {
            return Err(Error::net(format!(
                "fleet config: {} shards does not divide dim {}",
                self.shards, self.dim
            )));
        }
        Ok(())
    }

    /// The serialized form as a fresh buffer (a JoinAck body prefix).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(80);
        self.encode(&mut out);
        out
    }
}

// ---------------------------------------------------------------------------
// JoinHandshake: the client-side state machine.
// ---------------------------------------------------------------------------

/// Client states for joining a live fleet.
///
/// A joiner sends `Join`, then polls for the seed's `JoinAck`.  The ack
/// replays the [`FleetConfig`] plus the joiner's assigned id and the
/// fleet epoch; a dropped handshake (seed never answers) times out after
/// a bounded number of polls and the joiner reports failure without ever
/// having touched fleet state — the fault suite asserts fleet mass is
/// untouched by an abandoned join.
#[derive(Clone, Debug)]
pub enum JoinHandshake {
    /// Join sent, waiting for the ack; `polls_left` bounds the wait.
    AwaitingAck { polls_left: u32 },
    /// Ack received and validated.
    Admitted { id: usize, epoch: u64, config: FleetConfig },
    /// Handshake abandoned (timeout or malformed ack).
    Failed(String),
}

impl JoinHandshake {
    /// Start a handshake that tolerates `polls` empty polls.
    pub fn start(polls: u32) -> Self {
        JoinHandshake::AwaitingAck { polls_left: polls }
    }

    /// One empty poll elapsed (no ack bytes yet).
    pub fn poll_empty(&mut self) {
        if let JoinHandshake::AwaitingAck { polls_left } = self {
            if *polls_left == 0 {
                *self = JoinHandshake::Failed("join handshake timed out".into());
            } else {
                *polls_left -= 1;
            }
        }
    }

    /// A JoinAck body arrived: `[id u64][epoch u64][FleetConfig ...]`.
    pub fn on_ack(&mut self, body: &[u8]) {
        if !matches!(self, JoinHandshake::AwaitingAck { .. }) {
            return; // duplicate ack; first one wins
        }
        if body.len() < 16 {
            *self = JoinHandshake::Failed("short join ack".into());
            return;
        }
        let id = u64::from_le_bytes(body[0..8].try_into().expect("8 bytes")) as usize;
        let epoch = u64::from_le_bytes(body[8..16].try_into().expect("8 bytes"));
        match FleetConfig::decode(&body[16..]) {
            Ok(config) => {
                if id >= config.workers {
                    *self = JoinHandshake::Failed(format!(
                        "assigned id {id} outside fleet of {}",
                        config.workers
                    ));
                } else {
                    *self = JoinHandshake::Admitted { id, epoch, config };
                }
            }
            Err(e) => *self = JoinHandshake::Failed(format!("bad join ack: {e}")),
        }
    }

    pub fn is_terminal(&self) -> bool {
        !matches!(self, JoinHandshake::AwaitingAck { .. })
    }
}

/// Serialize a JoinAck body for [`JoinHandshake::on_ack`].
pub fn encode_join_ack(id: usize, epoch: u64, config: &FleetConfig) -> Vec<u8> {
    let mut out = Vec::with_capacity(96);
    out.extend_from_slice(&(id as u64).to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    config.encode(&mut out);
    out
}

impl fmt::Display for Admit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Admit::Current => write!(f, "current"),
            Admit::Stale => write!(f, "stale"),
            Admit::Future => write!(f, "future"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_fleet_admits_epoch_zero_traffic() {
        let m = Membership::new(4);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.live_count(), 4);
        for w in 0..4 {
            assert_eq!(m.admit(w, 0), Admit::Current);
        }
    }

    #[test]
    fn death_bumps_epoch_and_zombifies_sender() {
        let mut m = Membership::new(3);
        assert!(m.mark_dead(1));
        assert_eq!(m.epoch(), 1);
        assert!(!m.is_alive(1));
        // The dead worker's in-flight traffic is now stale...
        assert_eq!(m.admit(1, 0), Admit::Stale);
        // ...but survivors' pre-bump traffic is still perfectly good.
        assert_eq!(m.admit(0, 0), Admit::Current);
        assert_eq!(m.admit(2, 1), Admit::Current);
        // Double-death is a no-op.
        assert!(!m.mark_dead(1));
        assert_eq!(m.epoch(), 1);
    }

    #[test]
    fn rejoin_ghosts_the_previous_incarnation() {
        let mut m = Membership::new(3);
        m.mark_dead(2);
        assert!(m.rejoin(2));
        assert_eq!(m.epoch(), 2);
        assert!(m.is_alive(2));
        // Frames from before the rejoin are ghosts; new ones are current.
        assert_eq!(m.admit(2, 0), Admit::Stale);
        assert_eq!(m.admit(2, 1), Admit::Stale);
        assert_eq!(m.admit(2, 2), Admit::Current);
        // Rejoining an alive worker is refused.
        assert!(!m.rejoin(2));
    }

    #[test]
    fn future_epochs_are_flagged_not_absorbed() {
        let m = Membership::new(2);
        assert_eq!(m.admit(0, 5), Admit::Future);
    }

    #[test]
    fn out_of_range_senders_are_stale() {
        let m = Membership::new(2);
        assert_eq!(m.admit(7, 0), Admit::Stale);
    }

    #[test]
    fn join_new_grows_the_fleet_at_a_fresh_epoch() {
        let mut m = Membership::new(2);
        let id = m.join_new();
        assert_eq!(id, 2);
        assert_eq!(m.epoch(), 1);
        assert_eq!(m.workers(), 3);
        assert!(m.is_alive(2));
        // The newcomer's traffic is current only from its join epoch.
        assert_eq!(m.admit(2, 0), Admit::Stale);
        assert_eq!(m.admit(2, 1), Admit::Current);
    }

    #[test]
    fn alive_mask_tracks_membership() {
        let mut m = Membership::new(3);
        m.mark_dead(0);
        assert_eq!(m.alive_mask(), &[false, true, true]);
        assert_eq!(m.live_count(), 2);
    }

    #[test]
    fn fleet_config_round_trips() {
        let cfg = FleetConfig {
            workers: 4,
            dim: 64,
            p: 0.05,
            steps_per_worker: 200,
            eta: 0.25,
            weight_decay: 1e-4,
            seed: 42,
            topology: TopologySpec::SmallWorld { q: 0.3 },
            shards: 4,
            codec: CodecSpec::TopK { k: 8 },
        };
        let back = FleetConfig::decode(&cfg.to_bytes()).expect("round trip");
        assert_eq!(back, cfg);
    }

    #[test]
    fn fleet_config_rejects_malformed_bytes() {
        let good = FleetConfig::default().to_bytes();
        // Truncation at every prefix length.
        for cut in 0..good.len() {
            assert!(FleetConfig::decode(&good[..cut]).is_err(), "cut {cut} accepted");
        }
        // Trailing garbage.
        let mut long = good.clone();
        long.push(1);
        assert!(FleetConfig::decode(&long).is_err());
        // Unknown topology tag (offset: 7 u64/f64 fields + eta/wd f32s = 48, tag at 48).
        let mut bad = good.clone();
        bad[48] = 99;
        assert!(FleetConfig::decode(&bad).is_err());
        // Zero workers.
        let mut bad = good.clone();
        bad[0..8].copy_from_slice(&0u64.to_le_bytes());
        assert!(FleetConfig::decode(&bad).is_err());
        // NaN exchange probability.
        let mut bad = good;
        bad[16..24].copy_from_slice(&f64::NAN.to_le_bytes());
        assert!(FleetConfig::decode(&bad).is_err());
    }

    #[test]
    fn handshake_times_out_after_bounded_polls() {
        let mut h = JoinHandshake::start(2);
        assert!(!h.is_terminal());
        h.poll_empty();
        h.poll_empty();
        assert!(!h.is_terminal());
        h.poll_empty();
        assert!(matches!(h, JoinHandshake::Failed(_)));
    }

    #[test]
    fn handshake_admits_on_valid_ack() {
        let cfg = FleetConfig { workers: 3, ..FleetConfig::default() };
        let mut h = JoinHandshake::start(5);
        h.on_ack(&encode_join_ack(2, 9, &cfg));
        match &h {
            JoinHandshake::Admitted { id, epoch, config } => {
                assert_eq!(*id, 2);
                assert_eq!(*epoch, 9);
                assert_eq!(config, &cfg);
            }
            other => panic!("expected admitted, got {other:?}"),
        }
        // A duplicate ack is ignored.
        h.on_ack(&encode_join_ack(0, 1, &cfg));
        assert!(matches!(h, JoinHandshake::Admitted { id: 2, .. }));
    }

    #[test]
    fn handshake_fails_on_malformed_ack() {
        let mut h = JoinHandshake::start(5);
        h.on_ack(&[1, 2, 3]);
        assert!(matches!(h, JoinHandshake::Failed(_)));
        // Out-of-range assigned id.
        let cfg = FleetConfig { workers: 2, ..FleetConfig::default() };
        let mut h = JoinHandshake::start(5);
        h.on_ack(&encode_join_ack(7, 0, &cfg));
        assert!(matches!(h, JoinHandshake::Failed(_)));
    }
}
