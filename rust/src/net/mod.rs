//! The networked gossip runtime: `ProtocolCore`'s fourth driver.
//!
//! Three drivers already exercise the protocol core in one process — the
//! sequential engine, the OS-thread runtime and the discrete-event
//! simulator.  This module makes the paper's "fully asynchronous and
//! decentralized" claim literal: each worker is a *process*, messages are
//! *bytes* on a TCP socket, and membership is *elastic* — workers join a
//! live fleet via a config-replaying handshake and leave (or crash) under
//! an epoch bump that triggers the same alive-mask peer repair the DES
//! uses for churn.
//!
//! The layering, bottom up:
//!
//! * [`frame`] — the versioned length-prefixed frame codec: magic,
//!   version, frame kind, membership epoch, body length, CRC-32.  An
//!   incremental [`FrameReader`](frame::FrameReader) reassembles frames
//!   from arbitrary byte chunks and rejects corruption with typed
//!   [`FrameError`]s — never a panic, for any input bytes (pinned by the
//!   fuzz loop in `rust/tests/wire_framing.rs`).
//! * [`membership`] — the epoch-based membership state machine
//!   ([`Membership`](membership::Membership)): who is alive, at which
//!   epoch each worker joined, and the zombie/ghost admission rule that
//!   discards stale-epoch traffic without destroying sum-weight mass.
//!   Plus [`FleetConfig`](membership::FleetConfig), the shared run
//!   configuration a join handshake replays to newcomers, and the
//!   [`JoinHandshake`](membership::JoinHandshake) client state machine.
//! * [`conn`] — transport plumbing: [`LoopbackPipe`](conn::LoopbackPipe),
//!   an in-process byte stream with fault injection (sever mid-frame,
//!   reopen under a new epoch) used by the test suites, and
//!   [`ConnManager`](conn::ConnManager), the per-peer outbox layer with
//!   bounded backpressure and exactly-once delivery accounting
//!   (undelivered messages are reclaimed for sender-side reabsorption —
//!   mass is conserved through any crash).
//! * [`runtime`] — the real-socket node: `gosgd net --listen` seeds a
//!   fleet, `gosgd net --join` dials in, and the join handshake replays
//!   [`FleetConfig`](membership::FleetConfig) so every process runs the
//!   same protocol core.  This file is the **only** place in the crate
//!   allowed to touch `std::net` — `gosgd-lint`'s `net-isolation` rule
//!   enforces the boundary.
//!
//! The driver itself ([`NetGossip`](crate::worker::NetGossip), in
//! `worker/` beside its threaded sibling) mirrors `ThreadedGossip`'s
//! API, and its loopback mode is **bit-identical** to the threaded
//! runtime under the same seed — the frame codec is a transparent
//! transport, asserted across the codec/topology grid in
//! `rust/tests/runtime_equivalence.rs`.

pub mod conn;
pub mod frame;
pub mod membership;
pub mod runtime;

pub use conn::{ConnManager, LoopbackPipe};
pub use frame::{Frame, FrameError, FrameKind, FrameReader, FRAME_HEADER_BYTES, WIRE_VERSION};
pub use membership::{encode_join_ack, Admit, FleetConfig, JoinHandshake, Membership};
pub use runtime::{NetNodeConfig, NetNodeReport};
