//! The real-socket gossip node: `gosgd net --listen/--join`.
//!
//! Each worker is a **process**.  One node seeds the fleet
//! (`gosgd net --listen ADDR`): it owns worker id 0, accepts
//! `workers − 1` joiners, assigns their ids, and replays the shared
//! [`FleetConfig`] to each through the join handshake — so every process
//! derives a bit-identical protocol core from the same nine knobs.
//! Joiners (`gosgd net --join ADDR --listen OWN_ADDR`) dial the seed,
//! complete the handshake, mesh with the other joiners from the roster
//! the seed broadcasts at start, and run the same worker loop.
//!
//! The run protocol over each TCP stream is exactly the loopback
//! driver's ([`crate::worker::NetGossip`]): length-prefixed CRC'd frames,
//! a Bernoulli-gated gossip loop, and the **Done finale** — announce the
//! local cutoff, drain until every peer has announced theirs (FIFO
//! streams make the cutoff exact).  After Done, each joiner ships its
//! final per-shard sum weights to the seed in a `Leave` frame; the seed
//! folds them with its own and prints the fleet-wide audit line
//!
//! ```text
//! fleet mass 1.000000
//! ```
//!
//! which the CI `net` lane greps for after spawning a two-process fleet.
//!
//! This file is the **only** module in the crate allowed to name
//! `std::net` — `gosgd-lint`'s `net-isolation` rule keeps every other
//! layer socket-free, which is what keeps the loopback and TCP paths
//! honest about sharing all their protocol code.

use crate::error::{Error, Result};
use crate::gossip::{Message, ProtocolCore};
use crate::net::frame::{encode_frame, FrameKind, FrameReader, FRAME_HEADER_BYTES};
use crate::net::membership::{encode_join_ack, FleetConfig, JoinHandshake};
use crate::strategies::grad::{GradSource, QuadraticSource};
use crate::tensor::FlatVec;
use crate::util::rng::Rng;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// How a `gosgd net` process participates in a fleet.
#[derive(Clone, Debug)]
pub struct NetNodeConfig {
    /// Address to listen on (the seed's fleet port, or a joiner's
    /// peer-mesh port; empty for a joiner in a two-worker fleet, which
    /// needs no mesh links).
    pub listen: String,
    /// Seed address to dial; `None` makes this node the seed.
    pub join: Option<String>,
    /// The shared run configuration.  Authoritative on the seed; on a
    /// joiner only used as a placeholder until the handshake replays the
    /// seed's copy.
    pub config: FleetConfig,
    /// Gradient noise scale for the built-in quadratic source.
    pub sigma: f32,
}

/// Outcome of one node's run, for the caller to print or assert on.
#[derive(Clone, Debug)]
pub struct NetNodeReport {
    pub id: usize,
    /// This node's final per-shard sum weights.
    pub shard_weights: Vec<f64>,
    /// Seed only: the fleet-wide per-shard mass totals (own + every
    /// joiner's, from their Leave frames).  `None` on joiners.
    pub fleet_shard_mass: Option<Vec<f64>>,
    pub messages: u64,
    pub bytes: u64,
}

impl NetNodeConfig {
    /// Run this node to completion.
    pub fn run(&self) -> Result<NetNodeReport> {
        self.config.validate()?;
        match &self.join {
            None => run_seed(self),
            Some(addr) => run_joiner(self, addr),
        }
    }
}

// ---------------------------------------------------------------------------
// Blocking-ish I/O helpers over nonblocking streams.
// ---------------------------------------------------------------------------

/// Write all bytes, riding out `WouldBlock` on a nonblocking socket.
fn write_all(stream: &mut TcpStream, mut bytes: &[u8]) -> Result<()> {
    while !bytes.is_empty() {
        match stream.write(bytes) {
            Ok(0) => return Err(Error::net("peer closed the stream mid-write")),
            Ok(n) => bytes = &bytes[n..],
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                crate::sync::thread::yield_now();
            }
            Err(e) => return Err(Error::net(format!("socket write failed: {e}"))),
        }
    }
    Ok(())
}

/// Pull whatever the socket has into the frame reader.  Returns `false`
/// once the peer has closed the stream.
fn pump(stream: &mut TcpStream, reader: &mut FrameReader, buf: &mut [u8]) -> Result<bool> {
    loop {
        match stream.read(buf) {
            Ok(0) => return Ok(false),
            Ok(n) => reader.feed(&buf[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(true),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::net(format!("socket read failed: {e}"))),
        }
    }
}

/// Block until one frame arrives on a (blocking-mode) stream.
fn read_frame_blocking(
    stream: &mut TcpStream,
    reader: &mut FrameReader,
    buf: &mut [u8],
) -> Result<crate::net::frame::Frame> {
    loop {
        if let Some(f) = reader.try_next()? {
            return Ok(f);
        }
        match stream.read(buf) {
            Ok(0) => return Err(Error::net("peer closed the stream mid-handshake")),
            Ok(n) => reader.feed(&buf[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::net(format!("socket read failed: {e}"))),
        }
    }
}

fn send_frame(stream: &mut TcpStream, kind: FrameKind, epoch: u64, body: &[u8]) -> Result<()> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + body.len());
    encode_frame(&mut out, kind, epoch, body);
    write_all(stream, &out)
}

// ---------------------------------------------------------------------------
// Roster encoding (Start frame body): [count u32] then per joiner
// [id u64][addr_len u32][addr bytes].
// ---------------------------------------------------------------------------

fn encode_roster(roster: &[(usize, String)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(roster.len() as u32).to_le_bytes());
    for (id, addr) in roster {
        out.extend_from_slice(&(*id as u64).to_le_bytes());
        out.extend_from_slice(&(addr.len() as u32).to_le_bytes());
        out.extend_from_slice(addr.as_bytes());
    }
    out
}

fn decode_roster(body: &[u8]) -> Result<Vec<(usize, String)>> {
    let mut b = body;
    let take = |b: &mut &[u8], n: usize| -> Result<Vec<u8>> {
        if b.len() < n {
            return Err(Error::net("truncated roster"));
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Ok(head.to_vec())
    };
    let count = u32::from_le_bytes(take(&mut b, 4)?.try_into().expect("4 bytes")) as usize;
    if count > 4096 {
        return Err(Error::net(format!("implausible roster of {count} entries")));
    }
    let mut roster = Vec::with_capacity(count);
    for _ in 0..count {
        let id = u64::from_le_bytes(take(&mut b, 8)?.try_into().expect("8 bytes")) as usize;
        let len = u32::from_le_bytes(take(&mut b, 4)?.try_into().expect("4 bytes")) as usize;
        if len > 256 {
            return Err(Error::net("implausible roster address"));
        }
        let addr = String::from_utf8(take(&mut b, len)?)
            .map_err(|_| Error::net("non-utf8 roster address"))?;
        roster.push((id, addr));
    }
    if !b.is_empty() {
        return Err(Error::net("trailing bytes after roster"));
    }
    Ok(roster)
}

// ---------------------------------------------------------------------------
// Seed
// ---------------------------------------------------------------------------

fn run_seed(node: &NetNodeConfig) -> Result<NetNodeReport> {
    let cfg = &node.config;
    let m = cfg.workers;
    let listener = TcpListener::bind(&node.listen)
        .map_err(|e| Error::net(format!("cannot listen on {}: {e}", node.listen)))?;

    // Accept and admit m-1 joiners.  streams[id] is the link to that
    // worker; the seed is id 0.
    let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
    let mut roster: Vec<(usize, String)> = Vec::new();
    let mut buf = vec![0u8; 64 * 1024];
    for next_id in 1..m {
        let (mut stream, _) = listener
            .accept()
            .map_err(|e| Error::net(format!("accept failed: {e}")))?;
        stream.set_nodelay(true).ok();
        let mut reader = FrameReader::new();
        let frame = read_frame_blocking(&mut stream, &mut reader, &mut buf)?;
        if frame.kind != FrameKind::Join {
            return Err(Error::net(format!("expected a join, got {:?}", frame.kind)));
        }
        let peer_addr = String::from_utf8(frame.body.clone())
            .map_err(|_| Error::net("non-utf8 listen address in join"))?;
        send_frame(&mut stream, FrameKind::JoinAck, 0, &encode_join_ack(next_id, 0, cfg))?;
        if !peer_addr.is_empty() {
            roster.push((next_id, peer_addr));
        }
        if reader.has_partial() {
            return Err(Error::net("unexpected bytes after join"));
        }
        streams[next_id] = Some(stream);
    }
    if m > 2 && roster.len() != m - 1 {
        return Err(Error::net(
            "fleets larger than two processes need every joiner to pass --listen",
        ));
    }

    // Roster complete: broadcast Start and run.
    let roster_body = encode_roster(&roster);
    for s in streams.iter_mut().flatten() {
        send_frame(s, FrameKind::Start, 0, &roster_body)?;
    }
    let (core, mut readers, messages, bytes) = run_worker_loop(0, node, &mut streams)?;
    let shard_weights = core.weight_values();

    // Collect Leave frames: each joiner ships its final shard weights.
    // The worker loop's readers carry over — a fast joiner's Leave may
    // already be buffered behind its Done frame.
    let mut fleet: Vec<f64> = shard_weights.clone();
    for id in 1..m {
        let stream = streams[id].as_mut().expect("joiner stream");
        stream.set_nonblocking(false).map_err(|e| Error::net(format!("socket mode: {e}")))?;
        loop {
            let frame = read_frame_blocking(stream, &mut readers[id], &mut buf)?;
            match frame.kind {
                FrameKind::Leave => {
                    if frame.body.len() != fleet.len() * 8 {
                        return Err(Error::net("leave frame with wrong weight count"));
                    }
                    for (k, chunk) in frame.body.chunks_exact(8).enumerate() {
                        fleet[k] += f64::from_le_bytes(chunk.try_into().expect("8 bytes"));
                    }
                    break;
                }
                // Stragglers from the gossip phase already counted via
                // the Done protocol; anything else here is a bug.
                other => {
                    return Err(Error::net(format!("expected leave, got {other:?}")));
                }
            }
        }
    }
    let total: f64 = fleet.iter().sum::<f64>() / fleet.len() as f64;
    println!("fleet mass {total:.6}");
    println!("fleet messages {messages} bytes {bytes}");
    Ok(NetNodeReport {
        id: 0,
        shard_weights,
        fleet_shard_mass: Some(fleet),
        messages,
        bytes,
    })
}

// ---------------------------------------------------------------------------
// Joiner
// ---------------------------------------------------------------------------

fn run_joiner(node: &NetNodeConfig, seed_addr: &str) -> Result<NetNodeReport> {
    // Dial the seed with retries — the seed process may still be binding.
    let mut seed_stream = None;
    for _ in 0..100 {
        match TcpStream::connect(seed_addr) {
            Ok(s) => {
                seed_stream = Some(s);
                break;
            }
            Err(_) => crate::sync::thread::sleep(Duration::from_millis(100)),
        }
    }
    let mut seed_stream = seed_stream
        .ok_or_else(|| Error::net(format!("could not reach seed at {seed_addr}")))?;
    seed_stream.set_nodelay(true).ok();

    // Optional mesh listener (required for fleets of more than two).
    let listener = if node.listen.is_empty() {
        None
    } else {
        Some(
            TcpListener::bind(&node.listen)
                .map_err(|e| Error::net(format!("cannot listen on {}: {e}", node.listen)))?,
        )
    };

    // Join handshake: send our mesh address, await the config replay.
    send_frame(&mut seed_stream, FrameKind::Join, 0, node.listen.as_bytes())?;
    let mut reader = FrameReader::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut shake = JoinHandshake::start(1);
    let frame = read_frame_blocking(&mut seed_stream, &mut reader, &mut buf)?;
    if frame.kind != FrameKind::JoinAck {
        return Err(Error::net(format!("expected a join ack, got {:?}", frame.kind)));
    }
    shake.on_ack(&frame.body);
    let (id, cfg) = match shake {
        JoinHandshake::Admitted { id, config, .. } => (id, config),
        JoinHandshake::Failed(why) => return Err(Error::net(why)),
        JoinHandshake::AwaitingAck { .. } => unreachable!("ack was delivered"),
    };
    let m = cfg.workers;

    // Await Start + roster, then mesh: we dial every joiner with a
    // smaller id; joiners with larger ids dial us.
    let frame = read_frame_blocking(&mut seed_stream, &mut reader, &mut buf)?;
    if frame.kind != FrameKind::Start {
        return Err(Error::net(format!("expected start, got {:?}", frame.kind)));
    }
    let roster = decode_roster(&frame.body)?;
    let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
    for (peer_id, addr) in &roster {
        if *peer_id >= id || *peer_id == 0 {
            continue;
        }
        let mut s = TcpStream::connect(addr.as_str())
            .map_err(|e| Error::net(format!("cannot mesh with worker {peer_id} at {addr}: {e}")))?;
        s.set_nodelay(true).ok();
        send_frame(&mut s, FrameKind::Join, 0, &(id as u64).to_le_bytes())?;
        streams[*peer_id] = Some(s);
    }
    let expected_inbound = (id + 1..m).len();
    if let Some(listener) = &listener {
        for _ in 0..expected_inbound {
            let (mut s, _) = listener
                .accept()
                .map_err(|e| Error::net(format!("mesh accept failed: {e}")))?;
            s.set_nodelay(true).ok();
            let mut r = FrameReader::new();
            let hello = read_frame_blocking(&mut s, &mut r, &mut buf)?;
            if hello.kind != FrameKind::Join || hello.body.len() != 8 {
                return Err(Error::net("malformed mesh hello"));
            }
            let peer_id =
                u64::from_le_bytes(hello.body[..8].try_into().expect("8 bytes")) as usize;
            if peer_id <= id || peer_id >= m {
                return Err(Error::net(format!("mesh hello from implausible worker {peer_id}")));
            }
            streams[peer_id] = Some(s);
        }
    } else if expected_inbound > 0 {
        return Err(Error::net("this joiner needs --listen to accept mesh links"));
    }
    streams[0] = Some(seed_stream);

    let mut node_cfg = node.clone();
    node_cfg.config = cfg;
    let (core, _readers, messages, bytes) = run_worker_loop(id, &node_cfg, &mut streams)?;
    let shard_weights = core.weight_values();

    // Ship our final weights home and leave.
    let mut body = Vec::with_capacity(shard_weights.len() * 8);
    for w in &shard_weights {
        body.extend_from_slice(&w.to_le_bytes());
    }
    let seed = streams[0].as_mut().expect("seed stream");
    seed.set_nonblocking(false).map_err(|e| Error::net(format!("socket mode: {e}")))?;
    send_frame(seed, FrameKind::Leave, 0, &body)?;
    Ok(NetNodeReport { id, shard_weights, fleet_shard_mass: None, messages, bytes })
}

// ---------------------------------------------------------------------------
// The shared worker loop: the loopback driver's protocol over TCP.
// ---------------------------------------------------------------------------

fn run_worker_loop(
    id: usize,
    node: &NetNodeConfig,
    streams: &mut [Option<TcpStream>],
) -> Result<(ProtocolCore, Vec<FrameReader>, u64, u64)> {
    let cfg = &node.config;
    let m = cfg.workers;
    for s in streams.iter_mut().flatten() {
        s.set_nonblocking(true).map_err(|e| Error::net(format!("socket mode: {e}")))?;
    }
    let mut core = ProtocolCore::new(id, m, cfg.dim, cfg.p, cfg.topology, cfg.shards)?
        .with_codec(cfg.codec);
    let mut source: Box<dyn GradSource> =
        Box::new(QuadraticSource::new(cfg.dim, node.sigma, cfg.seed));
    let mut rng = Rng::new(cfg.seed).split(id as u64 + 1);
    let mut x = FlatVec::zeros(cfg.dim);
    let mut grad = FlatVec::zeros(cfg.dim);
    let mut readers: Vec<FrameReader> = (0..m).map(|_| FrameReader::new()).collect();
    let mut done_from = vec![false; m];
    done_from[id] = true;
    let mut open: Vec<bool> = streams.iter().map(|s| s.is_some()).collect();
    let mut buf = vec![0u8; 64 * 1024];
    let (mut messages, mut bytes) = (0u64, 0u64);
    let mut frame_out = Vec::new();
    let mut body_out = Vec::new();

    let mut drain = |streams: &mut [Option<TcpStream>],
                     readers: &mut [FrameReader],
                     done_from: &mut [bool],
                     open: &mut [bool],
                     core: &mut ProtocolCore,
                     x: &mut FlatVec|
     -> Result<()> {
        for v in 0..m {
            if v == id || !open[v] || done_from[v] {
                // A peer that announced Done sends nothing further for
                // this phase (FIFO stream): stop reading so its Leave
                // frame stays buffered for the collection phase.
                continue;
            }
            let Some(stream) = streams[v].as_mut() else { continue };
            let alive = pump(stream, &mut readers[v], &mut buf)?;
            while !done_from[v] {
                let Some(frame) = readers[v].try_next()? else { break };
                match frame.kind {
                    FrameKind::Gossip => {
                        let msg = Message::decode_body(&frame.body)?;
                        core.absorb_message(x, &msg)?;
                    }
                    FrameKind::Done => done_from[v] = true,
                    other => {
                        return Err(Error::net(format!("unexpected {other:?} during gossip")));
                    }
                }
            }
            if !alive {
                // Peer closed: a torn frame prefix is discarded (its
                // mass lives with the sender); a closed peer that never
                // sent Done cannot hold up the finale.
                open[v] = false;
                done_from[v] = true;
            }
        }
        Ok(())
    };

    for step in 0..cfg.steps_per_worker {
        drain(streams, &mut readers, &mut done_from, &mut open, &mut core, &mut x)?;
        let _loss = source.grad(id + 1, &x, step, &mut grad)?;
        core.local_step(&mut x, &grad, cfg.eta, cfg.weight_decay)?;
        if let Some(out) = core.emit(&x, m, &mut rng)? {
            let to = out.to;
            let msg = out.into_message(id, step);
            bytes += msg.wire_bytes() as u64;
            messages += 1;
            body_out.clear();
            msg.encode_body(&mut body_out);
            frame_out.clear();
            encode_frame(&mut frame_out, FrameKind::Gossip, 0, &body_out);
            if let Some(stream) = streams[to].as_mut() {
                write_all(stream, &frame_out)?;
            }
        }
    }
    // Done finale: announce, then drain until everyone announced.
    frame_out.clear();
    encode_frame(&mut frame_out, FrameKind::Done, 0, &[]);
    for v in 0..m {
        if v != id {
            if let Some(stream) = streams[v].as_mut() {
                write_all(stream, &frame_out)?;
            }
        }
    }
    while !done_from.iter().all(|&d| d) {
        drain(streams, &mut readers, &mut done_from, &mut open, &mut core, &mut x)?;
        crate::sync::thread::yield_now();
    }
    Ok((core, readers, messages, bytes))
}
