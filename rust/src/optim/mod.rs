//! Host-side optimizers and learning-rate schedules.
//!
//! The paper's experiments use plain SGD with lr 0.1 and weight decay 1e-4
//! (section 5.1).  [`Sgd`] mirrors the `sgd_update` HLO artifact exactly —
//! the integration tests assert both paths produce identical parameters —
//! and adds optional Polyak momentum for the extension benches.
//!
//! Schedules: the paper trains at constant lr; step decay is provided for
//! longer end-to-end runs.

use crate::error::Result;
use crate::tensor::FlatVec;

/// Learning-rate schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// Constant rate (the paper's setting).
    Constant(f32),
    /// Multiply by `gamma` every `every` steps.
    StepDecay { base: f32, gamma: f32, every: u64 },
}

impl LrSchedule {
    /// Learning rate at (local) step `t`.
    pub fn at(&self, t: u64) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::StepDecay { base, gamma, every } => {
                base * gamma.powi((t / every) as i32)
            }
        }
    }

    /// Parse `0.1` or `step:0.1:0.5:1000`.
    pub fn parse(text: &str) -> Option<LrSchedule> {
        if let Ok(lr) = text.parse::<f32>() {
            return Some(LrSchedule::Constant(lr));
        }
        let parts: Vec<&str> = text.split(':').collect();
        if parts.len() == 4 && parts[0] == "step" {
            return Some(LrSchedule::StepDecay {
                base: parts[1].parse().ok()?,
                gamma: parts[2].parse().ok()?,
                every: parts[3].parse().ok()?,
            });
        }
        None
    }
}

/// SGD with weight decay and optional momentum.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub schedule: LrSchedule,
    pub weight_decay: f32,
    pub momentum: f32,
    velocity: Option<FlatVec>,
}

impl Sgd {
    /// The paper's optimizer: `p ← p − lr·(g + wd·p)`.
    pub fn new(schedule: LrSchedule, weight_decay: f32) -> Self {
        Sgd { schedule, weight_decay, momentum: 0.0, velocity: None }
    }

    pub fn with_momentum(mut self, mu: f32) -> Self {
        assert!((0.0..1.0).contains(&mu));
        self.momentum = mu;
        self
    }

    /// Apply one update at local step `t`.
    pub fn step(&mut self, params: &mut FlatVec, grad: &FlatVec, t: u64) -> Result<()> {
        let lr = self.schedule.at(t);
        if self.momentum == 0.0 {
            return params.sgd_step(grad, lr, self.weight_decay);
        }
        // v ← mu·v + (g + wd·p); p ← p − lr·v
        let v = self
            .velocity
            .get_or_insert_with(|| FlatVec::zeros(params.len()));
        if v.len() != params.len() {
            return Err(crate::error::Error::shape("momentum buffer size mismatch"));
        }
        v.scale(self.momentum);
        v.axpy(1.0, grad)?;
        if self.weight_decay != 0.0 {
            let p_snapshot = params.clone();
            v.axpy(self.weight_decay, &p_snapshot)?;
        }
        let v_ref = self.velocity.as_ref().unwrap();
        params.axpy(-lr, v_ref)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::Constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn step_decay_halves() {
        let s = LrSchedule::StepDecay { base: 0.4, gamma: 0.5, every: 100 };
        assert_eq!(s.at(0), 0.4);
        assert_eq!(s.at(99), 0.4);
        assert_eq!(s.at(100), 0.2);
        assert_eq!(s.at(250), 0.1);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(LrSchedule::parse("0.1"), Some(LrSchedule::Constant(0.1)));
        assert_eq!(
            LrSchedule::parse("step:0.1:0.5:1000"),
            Some(LrSchedule::StepDecay { base: 0.1, gamma: 0.5, every: 1000 })
        );
        assert_eq!(LrSchedule::parse("cosine:1"), None);
    }

    #[test]
    fn plain_sgd_matches_flatvec_step() {
        let mut a = FlatVec::from_vec(vec![1.0, -2.0, 3.0]);
        let mut b = a.clone();
        let g = FlatVec::from_vec(vec![0.5, 0.5, -0.5]);
        let mut opt = Sgd::new(LrSchedule::Constant(0.1), 1e-4);
        opt.step(&mut a, &g, 0).unwrap();
        b.sgd_step(&g, 0.1, 1e-4).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn momentum_accelerates_constant_gradient() {
        // With a constant gradient, momentum accumulates: displacement
        // after k steps exceeds plain SGD's.
        let g = FlatVec::from_vec(vec![1.0; 4]);
        let mut plain = FlatVec::zeros(4);
        let mut heavy = FlatVec::zeros(4);
        let mut opt_p = Sgd::new(LrSchedule::Constant(0.1), 0.0);
        let mut opt_m = Sgd::new(LrSchedule::Constant(0.1), 0.0).with_momentum(0.9);
        for t in 0..20 {
            opt_p.step(&mut plain, &g, t).unwrap();
            opt_m.step(&mut heavy, &g, t).unwrap();
        }
        assert!(heavy.as_slice()[0] < plain.as_slice()[0] - 1.0);
    }

    #[test]
    fn momentum_buffer_tracks_dim() {
        let mut opt = Sgd::new(LrSchedule::Constant(0.1), 0.0).with_momentum(0.5);
        let mut p = FlatVec::zeros(4);
        let g = FlatVec::from_vec(vec![1.0; 4]);
        opt.step(&mut p, &g, 0).unwrap();
        let mut p2 = FlatVec::zeros(8);
        let g2 = FlatVec::zeros(8);
        assert!(opt.step(&mut p2, &g2, 0).is_err());
    }
}
