//! The PJRT executor: typed entry points over the four AOT programs.
//!
//! `ModelRuntime::load` parses the manifest, reads each program's HLO
//! *text* (the interchange format — see `python/compile/aot.py`), compiles
//! it once on the CPU PJRT client, and exposes:
//!
//! * [`ModelRuntime::train_step`] — `(params, batch) → (loss, grads)`
//! * [`ModelRuntime::eval_step`]  — `(params, batch) → (loss, #correct)`
//! * [`ModelRuntime::sgd_update`] — the fused optimizer artifact
//! * [`ModelRuntime::mix`]        — the Pallas gossip blend
//!
//! [`PjrtSource`] adapts the runtime + a [`BatchSampler`] into the
//! engine's [`GradSource`], putting the real Layer-2 CNN behind the same
//! interface as the synthetic sources.

use std::path::Path;

use crate::data::BatchSampler;
use crate::error::{Error, Result};
use crate::model::Manifest;
use crate::runtime::literal::{f32_literal, f32_scalar1, i32_literal, to_f32_scalar, to_flatvec};
use crate::strategies::grad::GradSource;
use crate::tensor::FlatVec;

/// A compiled model: PJRT client + the four loaded executables.
pub struct ModelRuntime {
    manifest: Manifest,
    // Field order matters: executables must drop before the client.
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    sgd: xla::PjRtLoadedExecutable,
    mix: xla::PjRtLoadedExecutable,
    #[allow(dead_code)]
    client: xla::PjRtClient,
}

impl ModelRuntime {
    /// Load and compile every program under `dir` (an artifact model dir,
    /// e.g. `artifacts/cnn`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.program_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::artifact("non-utf8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(ModelRuntime {
            train: compile("train_step")?,
            eval: compile("eval_step")?,
            sgd: compile("sgd_update")?,
            mix: compile("mix")?,
            manifest,
            client,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    fn check_params(&self, params: &FlatVec) -> Result<()> {
        if params.len() != self.manifest.param_count {
            return Err(Error::shape(format!(
                "params len {} vs model {}",
                params.len(),
                self.manifest.param_count
            )));
        }
        Ok(())
    }

    fn batch_shape(&self, n: usize) -> Vec<usize> {
        let mut s = vec![n];
        s.extend(&self.manifest.image_shape);
        s
    }

    /// One forward/backward pass: returns `(loss, flat_grads)`.
    pub fn train_step(
        &self,
        params: &FlatVec,
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f64, FlatVec)> {
        self.check_params(params)?;
        let b = self.manifest.batch;
        if labels.len() != b {
            return Err(Error::shape(format!("labels len {} vs batch {b}", labels.len())));
        }
        let args = [
            f32_literal(params.as_slice(), &[params.len()])?,
            f32_literal(images, &self.batch_shape(b))?,
            i32_literal(labels, &[b])?,
        ];
        let result = self.train.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss_lit, grads_lit) = result.to_tuple2()?;
        let loss = to_f32_scalar(&loss_lit)? as f64;
        let grads = to_flatvec(&grads_lit, params.len())?;
        Ok((loss, grads))
    }

    /// Validation pass: returns `(mean_loss, correct_count)`.
    pub fn eval_step(
        &self,
        params: &FlatVec,
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f64, f64)> {
        self.check_params(params)?;
        let b = self.manifest.eval_batch;
        if labels.len() != b {
            return Err(Error::shape(format!(
                "eval labels len {} vs eval_batch {b}",
                labels.len()
            )));
        }
        let args = [
            f32_literal(params.as_slice(), &[params.len()])?,
            f32_literal(images, &self.batch_shape(b))?,
            i32_literal(labels, &[b])?,
        ];
        let result = self.eval.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss_lit, correct_lit) = result.to_tuple2()?;
        Ok((to_f32_scalar(&loss_lit)? as f64, to_f32_scalar(&correct_lit)? as f64))
    }

    /// Fused optimizer artifact: `p − lr·(g + wd·p)`.
    pub fn sgd_update(
        &self,
        params: &FlatVec,
        grads: &FlatVec,
        lr: f32,
        wd: f32,
    ) -> Result<FlatVec> {
        self.check_params(params)?;
        self.check_params(grads)?;
        let args = [
            f32_literal(params.as_slice(), &[params.len()])?,
            f32_literal(grads.as_slice(), &[grads.len()])?,
            f32_scalar1(lr),
            f32_scalar1(wd),
        ];
        let result = self.sgd.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        to_flatvec(&result.to_tuple1()?, params.len())
    }

    /// The Pallas gossip blend artifact (paper Algorithm 4 line 9):
    /// `(w_r·x_r + w_s·x_s)/(w_r+w_s)`.
    pub fn mix(&self, x_r: &FlatVec, x_s: &FlatVec, w_r: f32, w_s: f32) -> Result<FlatVec> {
        self.check_params(x_r)?;
        self.check_params(x_s)?;
        let args = [
            f32_literal(x_r.as_slice(), &[x_r.len()])?,
            f32_literal(x_s.as_slice(), &[x_s.len()])?,
            f32_scalar1(w_r),
            f32_scalar1(w_s),
        ];
        let result = self.mix.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        to_flatvec(&result.to_tuple1()?, x_r.len())
    }

    /// Evaluate over `n_batches` validation batches: `(mean_loss, accuracy)`.
    pub fn evaluate(
        &self,
        params: &FlatVec,
        sampler: &BatchSampler,
        n_batches: u64,
    ) -> Result<(f64, f64)> {
        let b = self.manifest.eval_batch;
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        for i in 0..n_batches {
            let batch = sampler.val_batch(i, b);
            let (loss, c) = self.eval_step(params, &batch.images, &batch.labels)?;
            loss_sum += loss;
            correct += c;
        }
        Ok((
            loss_sum / n_batches as f64,
            correct / (n_batches as f64 * b as f64),
        ))
    }
}

/// [`GradSource`] over the real model: worker `m`'s gradient at engine
/// step `t` comes from its sharded synthetic-CIFAR batch through the
/// `train_step` artifact.
pub struct PjrtSource<'rt> {
    runtime: &'rt ModelRuntime,
    sampler: BatchSampler,
    /// Per-worker local step counters (engine ticks are global).
    local_steps: Vec<u64>,
}

impl<'rt> PjrtSource<'rt> {
    pub fn new(runtime: &'rt ModelRuntime, sampler: BatchSampler, workers: usize) -> Self {
        assert_eq!(sampler.batch_size(), runtime.manifest().batch);
        PjrtSource { runtime, sampler, local_steps: vec![0; workers + 1] }
    }

    pub fn sampler(&self) -> &BatchSampler {
        &self.sampler
    }
}

impl<'rt> GradSource for PjrtSource<'rt> {
    fn grad(&mut self, m: usize, params: &FlatVec, _step: u64, out: &mut FlatVec) -> Result<f64> {
        let local = self.local_steps[m];
        self.local_steps[m] += 1;
        let batch = self.sampler.train_batch(m, local);
        let (loss, grads) = self.runtime.train_step(params, &batch.images, &batch.labels)?;
        out.as_mut_slice().copy_from_slice(grads.as_slice());
        Ok(loss)
    }

    fn dim(&self) -> usize {
        self.runtime.param_count()
    }
}
