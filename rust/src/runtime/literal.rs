//! `FlatVec`/host-buffer ⇄ `xla::Literal` conversion helpers.
//!
//! The xla crate moves data as `Literal`s.  These helpers keep all shape
//! bookkeeping in one place and, for the hot path, avoid intermediate
//! copies where the API allows.

use crate::error::{Error, Result};
use crate::tensor::FlatVec;

/// f32 literal of arbitrary shape from a host slice.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    if elems != data.len() {
        return Err(Error::shape(format!(
            "literal shape {shape:?} ({elems}) vs data len {}",
            data.len()
        )));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 literal (labels).
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    if elems != data.len() {
        return Err(Error::shape(format!(
            "literal shape {shape:?} ({elems}) vs data len {}",
            data.len()
        )));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Scalar-or-[1] f32 literal (lr / weight arguments).
pub fn f32_scalar1(v: f32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// Extract an f32 vector from a literal into a `FlatVec`.
pub fn to_flatvec(lit: &xla::Literal, expect_len: usize) -> Result<FlatVec> {
    let v: Vec<f32> = lit.to_vec()?;
    if v.len() != expect_len {
        return Err(Error::shape(format!(
            "literal has {} elems, expected {expect_len}",
            v.len()
        )));
    }
    Ok(FlatVec::from_vec(v))
}

/// Extract a scalar f32 (shape `[]` or `[1]`).
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    let v: Vec<f32> = lit.to_vec()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::shape("empty literal where scalar expected"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip_1d() {
        let data = vec![1.0f32, 2.0, 3.0];
        let lit = f32_literal(&data, &[3]).unwrap();
        let back = to_flatvec(&lit, 3).unwrap();
        assert_eq!(back.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn f32_round_trip_4d() {
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let lit = f32_literal(&data, &[2, 2, 2, 3]).unwrap();
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1, 2], &[1]).is_err());
    }

    #[test]
    fn i32_labels() {
        let lit = i32_literal(&[3, 1, 4], &[3]).unwrap();
        let back: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![3, 1, 4]);
    }

    #[test]
    fn scalar_extraction() {
        let lit = f32_scalar1(2.5);
        assert_eq!(to_f32_scalar(&lit).unwrap(), 2.5);
    }

    #[test]
    fn to_flatvec_length_guard() {
        let lit = f32_literal(&[1.0, 2.0], &[2]).unwrap();
        assert!(to_flatvec(&lit, 3).is_err());
    }
}
