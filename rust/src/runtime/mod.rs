//! PJRT runtime: load and execute the AOT artifacts.
//!
//! With the `pjrt` cargo feature, wraps the `xla` crate
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`) behind typed entry points for the four exported programs.
//! Python never runs here — the HLO text was produced once by
//! `make artifacts`.
//!
//! Without the feature (the default, since the `xla` crate is not part of
//! the offline dependency set), an API-compatible stub is compiled whose
//! `ModelRuntime::load` returns a descriptive error.  Everything that does
//! not need the real Layer-2 model — the synthetic gradient sources, the
//! discrete-event simulator, the threaded runtime, all strategy logic —
//! works identically either way.

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
pub use executor::{ModelRuntime, PjrtSource};
#[cfg(not(feature = "pjrt"))]
pub use stub::{ModelRuntime, PjrtSource};
