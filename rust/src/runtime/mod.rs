//! PJRT runtime: load and execute the AOT artifacts.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`) behind typed entry points for
//! the four exported programs.  Python never runs here — the HLO text was
//! produced once by `make artifacts`.

pub mod executor;
pub mod literal;

pub use executor::{ModelRuntime, PjrtSource};
