//! API-compatible stand-in for the PJRT executor when the `pjrt` feature
//! is disabled (the `xla` crate is absent from the offline dependency
//! set).
//!
//! [`ModelRuntime::load`] always fails with an explanatory error, so no
//! instance of either type can ever be constructed — the remaining
//! methods exist purely so that callers (coordinator, figure harnesses,
//! benches) compile unchanged.  Artifact-gated tests and benches already
//! skip when `artifacts/<model>/manifest.json` is missing, which is
//! always the case in a stub build.

use std::path::Path;

use crate::data::BatchSampler;
use crate::error::{Error, Result};
use crate::model::Manifest;
use crate::strategies::grad::GradSource;
use crate::tensor::FlatVec;

fn unavailable() -> Error {
    Error::artifact(
        "PJRT runtime unavailable: this binary was built without the `pjrt` cargo feature \
         (the `xla` crate is not in the offline dependency set); use the synthetic backends \
         (quadratic/noise gradient sources, DES simulator, threaded runtime) or rebuild with \
         `--features pjrt` after vendoring the xla crate",
    )
}

/// Stub for the compiled model (see [`module docs`](self)).
pub struct ModelRuntime {
    manifest: Manifest,
}

impl ModelRuntime {
    /// Always fails in a stub build; see the crate's README for how to
    /// enable the real PJRT path.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(unavailable())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    /// One forward/backward pass: returns `(loss, flat_grads)`.
    pub fn train_step(
        &self,
        _params: &FlatVec,
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<(f64, FlatVec)> {
        Err(unavailable())
    }

    /// Validation pass: returns `(mean_loss, correct_count)`.
    pub fn eval_step(
        &self,
        _params: &FlatVec,
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<(f64, f64)> {
        Err(unavailable())
    }

    /// Fused optimizer artifact: `p − lr·(g + wd·p)`.
    pub fn sgd_update(
        &self,
        _params: &FlatVec,
        _grads: &FlatVec,
        _lr: f32,
        _wd: f32,
    ) -> Result<FlatVec> {
        Err(unavailable())
    }

    /// The Pallas gossip blend artifact (paper Algorithm 4 line 9).
    pub fn mix(&self, _x_r: &FlatVec, _x_s: &FlatVec, _w_r: f32, _w_s: f32) -> Result<FlatVec> {
        Err(unavailable())
    }

    /// Evaluate over `n_batches` validation batches: `(mean_loss, accuracy)`.
    pub fn evaluate(
        &self,
        _params: &FlatVec,
        _sampler: &BatchSampler,
        _n_batches: u64,
    ) -> Result<(f64, f64)> {
        Err(unavailable())
    }
}

/// Stub for the PJRT-backed [`GradSource`]; never constructible because
/// [`ModelRuntime::load`] always fails.
pub struct PjrtSource<'rt> {
    runtime: &'rt ModelRuntime,
    sampler: BatchSampler,
}

impl<'rt> PjrtSource<'rt> {
    pub fn new(runtime: &'rt ModelRuntime, sampler: BatchSampler, workers: usize) -> Self {
        let _ = workers;
        PjrtSource { runtime, sampler }
    }

    pub fn sampler(&self) -> &BatchSampler {
        &self.sampler
    }
}

impl<'rt> GradSource for PjrtSource<'rt> {
    fn grad(
        &mut self,
        _m: usize,
        _params: &FlatVec,
        _step: u64,
        _out: &mut FlatVec,
    ) -> Result<f64> {
        Err(unavailable())
    }

    fn dim(&self) -> usize {
        self.runtime.param_count()
    }
}
