//! Discrete-event engine for wall-clock experiments (paper Fig. 2).
//!
//! Time is simulated; gradients are real.  Every worker alternates
//! compute and (strategy-dependent) communication; the event queue orders
//! everything by simulated seconds.
//!
//! All gossip state transitions — blend, weight halving, shard cursor —
//! are delegated to the per-worker
//! [`ProtocolCore`](crate::gossip::ProtocolCore); this module owns only
//! what is genuinely simulation: the event queue, clocks, the latency
//! model, barrier bookkeeping for the synchronous baselines, and the
//! scenario-diversity knobs ([`ScenarioModel`]: heterogeneous per-worker
//! compute speeds and crash/rejoin worker churn).
//!
//! The engine is built to scale to million-worker fleets: events schedule
//! through a hierarchical timing wheel by default ([`SchedulerKind`];
//! amortized O(1), pop order bit-identical to the reference heap), worker
//! models materialize copy-on-write from one shared cold replica
//! ([`CowModel`]), churn state is sparse (per-*down*-worker, not
//! per-worker), and telemetry samples a strided subset of workers on
//! large fleets ([`DesEngine::with_telemetry_sample`]).

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::gossip::{
    wire_bytes_for, AliveSet, CodecSpec, CowModel, EncodedPayload, ProtocolCore, Shard, SumWeight,
    TopologySpec,
};
use crate::sim::fabric::{Delivery, Fabric, FabricParams, FabricSpec, FabricStats};
use crate::sim::wheel::TimingWheel;
use crate::strategies::grad::GradSource;
use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use crate::sync::{thread as sync_thread, Mutex as SyncMutex};
use crate::tensor::{BufferPool, FlatVec};
use crate::util::rng::{CounterRng, Draws};

/// What a gossip message carries while inside the network fabric.
type GossipMsg = (Shard, EncodedPayload, f64);

/// Cluster timing parameters (seconds).
#[derive(Clone, Debug)]
pub struct TimeModel {
    /// Mean gradient-step compute time per worker.
    pub compute: f64,
    /// Uniform jitter fraction on compute time (`±compute_jitter`).
    pub compute_jitter: f64,
    /// Probability a step hits a straggler event (OS jitter, allocator,
    /// ECC scrub, …) and takes `straggler_factor × compute` extra.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
    /// One-way network latency for a parameter message.
    pub latency: f64,
    /// Uniform jitter fraction on latency.
    pub latency_jitter: f64,
    /// Master service time per sync request (serialization point).
    pub master_service: f64,
}

impl TimeModel {
    /// Calibration used by the Fig. 2 harness, set to GPU-era ratios for
    /// the paper's CNN (~1.7M params ≈ 7 MB messages): a gradient step ≈
    /// 100 ms; shipping a model one-way ≈ 50 ms; master combine ≈ 20 ms
    /// per worker; a 5% heavy-tail straggler on compute (the cost global
    /// barriers actually pay in practice).
    pub fn paper_like() -> Self {
        TimeModel {
            compute: 0.100,
            compute_jitter: 0.15,
            straggler_prob: 0.05,
            straggler_factor: 3.0,
            latency: 0.050,
            latency_jitter: 0.25,
            master_service: 0.020,
        }
    }

    fn draw_compute(&self, rng: &mut dyn Draws) -> f64 {
        let base = self.compute * (1.0 + self.compute_jitter * (2.0 * rng.f64() - 1.0));
        if rng.bernoulli(self.straggler_prob) {
            base + self.straggler_factor * self.compute
        } else {
            base
        }
    }

    fn draw_latency(&self, rng: &mut dyn Draws) -> f64 {
        self.latency * (1.0 + self.latency_jitter * (2.0 * rng.f64() - 1.0))
    }
}

/// Scenario-diversity knobs layered on top of the [`TimeModel`]:
/// *persistent* heterogeneity (slow machines, not transient jitter) and
/// worker churn.  Both are things a decentralized protocol should shrug
/// off and a barrier-based one cannot — the `scenarios` harness
/// quantifies exactly that.
#[derive(Clone, Debug, Default)]
pub struct ScenarioModel {
    /// Per-worker compute-time multipliers; worker `w` uses entry
    /// `w % len` (empty = homogeneous, all 1.0).  `[1, 1, 1, 4]` makes
    /// every fourth worker a persistent 4× straggler.
    pub compute_scale: Vec<f64>,
    /// Mean simulated seconds between crashes per worker (exponential);
    /// 0 disables churn.
    pub crash_mtbf: f64,
    /// Mean downtime before a crashed worker rejoins (exponential).
    pub rejoin_mttr: f64,
}

impl ScenarioModel {
    /// The neutral scenario: homogeneous compute, no churn.
    pub fn none() -> Self {
        ScenarioModel::default()
    }

    /// Compute multiplier for worker `w`.
    pub fn scale(&self, w: usize) -> f64 {
        if self.compute_scale.is_empty() {
            1.0
        } else {
            self.compute_scale[w % self.compute_scale.len()]
        }
    }

    pub fn churn_enabled(&self) -> bool {
        self.crash_mtbf > 0.0
    }
}

/// Strategy semantics under simulated time.
#[derive(Clone, Debug)]
pub enum DesStrategy {
    GoSgd { p: f64 },
    /// Sharded GoSGD: each exchange ships one round-robin shard of the
    /// vector with its shard-local sum weight (see
    /// [`crate::gossip::shard`]).  Message latency scales with the payload
    /// fraction (the [`TimeModel::latency`] is bandwidth-dominated at
    /// paper-scale messages), so sharding directly cuts per-event latency
    /// and bytes.
    ShardedGoSgd { p: f64, shards: usize },
    /// Ablation (paper section 4, third paragraph): *symmetric* gossip —
    /// sender and receiver rendezvous and swap, so the sender blocks until
    /// the receiver is free.  The paper rejects this design because "local
    /// blocking waits can cause global synchronization issues"; this
    /// variant quantifies the cost it avoids.
    SymmetricGossip { p: f64 },
    Easgd { alpha: f64, tau: u64 },
    PerSyn { tau: u64 },
    Local,
}

impl DesStrategy {
    pub fn name(&self) -> String {
        match self {
            DesStrategy::GoSgd { p } => format!("gosgd(p={p})"),
            DesStrategy::ShardedGoSgd { p, shards } => {
                format!("gosgd(p={p},shards={shards})")
            }
            DesStrategy::SymmetricGossip { p } => format!("symgossip(p={p})"),
            DesStrategy::Easgd { alpha, tau } => format!("easgd(alpha={alpha:.3},tau={tau})"),
            DesStrategy::PerSyn { tau } => format!("persyn(tau={tau})"),
            DesStrategy::Local => "local".into(),
        }
    }

    /// The fire-and-forget strategies: every message they send is an
    /// asynchronous `Outbound` the engine can route through the network
    /// fabric, and a crashed peer never deadlocks them.  The barrier
    /// strategies (and the symmetric-gossip ablation) synchronize through
    /// rendezvous/master abstractions the fabric does not model.
    pub fn fire_and_forget(&self) -> bool {
        matches!(
            self,
            DesStrategy::GoSgd { .. } | DesStrategy::ShardedGoSgd { .. } | DesStrategy::Local
        )
    }

    /// Gossip (fire-and-forget) strategies tolerate churn; the barrier
    /// strategies would deadlock on a crashed member without membership
    /// logic the paper's baselines don't have.
    fn supports_churn(&self) -> bool {
        self.fire_and_forget()
    }

    /// The protocol core's exchange configuration for this strategy
    /// (`p = 0` for the non-core strategies — their cores stay silent).
    fn core_config(&self) -> (f64, usize) {
        match self {
            DesStrategy::GoSgd { p } => (*p, 1),
            DesStrategy::ShardedGoSgd { p, shards } => (*p, *shards),
            _ => (0.0, 1),
        }
    }
}

/// Priority-queue event.
#[derive(Debug)]
enum EventKind {
    /// Worker finished a compute step (or resumed from a block).  The
    /// epoch stamps the wake stream it belongs to: a crash bumps the
    /// worker's epoch, invalidating wakes scheduled before it died.
    Wake { w: usize, epoch: u32 },
    /// A gossip message lands in worker `to`'s mailbox; `shard` records
    /// which slice of the vector the (possibly codec-encoded) `payload`
    /// covers.
    Deliver { to: usize, payload: EncodedPayload, weight: f64, shard: Shard },
    /// Worker `w` crashes: it stops computing, its state freezes, its
    /// mailbox keeps accumulating (peers fire-and-forget as usual).
    Crash(usize),
    /// A crashed worker comes back with its preserved state (warm restart
    /// from local checkpoint) and drains its backlog at the next wake.
    Rejoin(usize),
    /// The finite-bandwidth fabric has an internal transition due (a
    /// message finishing a NIC, link, or switch hop).  The engine keeps
    /// exactly one *useful* tick pending: scheduled at the fabric's
    /// earliest transition, re-armed after every fire and after any
    /// inject that creates an earlier transition.
    FabricTick,
}

/// Bits of an event key reserved for the per-origin counter; the high
/// bits above carry the scheduling origin (see [`pack_key`]).
const KEY_ORIGIN_SHIFT: u32 = 40;

/// Origin-packed event key: the high 24 bits carry the *origin* — the
/// worker whose handler scheduled the event, or the fleet size `m` for
/// fabric ticks — and the low 40 bits a per-origin counter.  Keys break
/// time ties in the event queue, so they must be assigned identically by
/// the sequential and the sharded executor: a global counter would
/// depend on the (executor-specific) order handlers run in, while an
/// origin-packed counter depends only on each origin's own event
/// history, which both executors replay in the same relative order.
/// Two consequences are load-bearing: worker events at equal time sort
/// by origin id (deterministic, executor-independent), and fabric ticks
/// (origin `m`) sort *after* every worker event at the same instant —
/// the parallel merge thread advances the fabric at window barriers,
/// i.e. after the in-window worker events, and the key order makes the
/// sequential engine do the same.
fn pack_key(origin: usize, ctr: u64) -> u64 {
    debug_assert!(ctr < (1u64 << KEY_ORIGIN_SHIFT), "per-origin event counter overflow");
    ((origin as u64) << KEY_ORIGIN_SHIFT) | ctr
}

struct Event {
    time: f64,
    /// Origin-packed key ([`pack_key`]); the queue orders by
    /// `(time, seq)`.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; seq breaks ties deterministically
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Which scheduler backs the engine's event queue.  Both pop the exact
/// same order — ascending `(time, seq)` — and neither consumes RNG, so
/// every run is bit-identical under either backend (pinned by
/// `runtime_equivalence.rs`).  The wheel is the default: amortized O(1)
/// per event versus the heap's O(log n), which is the difference that
/// lets a million-worker fleet (a million pending wakes at all times)
/// simulate at full speed.
#[derive(Clone, Copy, Debug, Eq, PartialEq)]
pub enum SchedulerKind {
    /// Global binary heap — the reference implementation.
    Heap,
    /// Hierarchical timing wheel ([`crate::sim::wheel::TimingWheel`]).
    Wheel,
}

/// Which executor drives the event loop.  Both produce *bit-identical*
/// runs — same trace, same hash, same per-worker state — because the
/// sharded executor only reorders work that is provably independent
/// (events inside one conservative lookahead window, on disjoint worker
/// shards) and merges every observable effect back in `(time, key)`
/// order at window barriers (pinned by `runtime_equivalence.rs`).
#[derive(Clone, Copy, Debug, Eq, PartialEq)]
pub enum ParallelKind {
    /// Single-threaded reference executor.
    Sequential,
    /// `T` worker shards, each with its own event queue, executed
    /// window-by-window on a scoped thread pool.  Requires a
    /// fire-and-forget strategy and a forkable gradient source
    /// ([`GradSource::fork`]); rejected with a config error otherwise.
    Sharded(usize),
}

/// The engine's pending-event store, behind the [`SchedulerKind`] choice.
enum EventQueue {
    Heap(BinaryHeap<Event>),
    Wheel(TimingWheel<EventKind>),
}

impl EventQueue {
    fn new(kind: SchedulerKind, wheel_tick: f64) -> Self {
        match kind {
            SchedulerKind::Heap => EventQueue::Heap(BinaryHeap::new()),
            SchedulerKind::Wheel => EventQueue::Wheel(TimingWheel::new(wheel_tick)),
        }
    }

    fn push(&mut self, ev: Event) {
        match self {
            EventQueue::Heap(h) => h.push(ev),
            EventQueue::Wheel(wh) => wh.push(ev.time, ev.seq, ev.kind),
        }
    }

    fn pop(&mut self) -> Option<Event> {
        match self {
            EventQueue::Heap(h) => h.pop(),
            EventQueue::Wheel(wh) => {
                wh.pop().map(|e| Event { time: e.time, seq: e.seq, kind: e.item })
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            EventQueue::Heap(h) => h.len(),
            EventQueue::Wheel(wh) => wh.len(),
        }
    }

    /// Visit every pending event's kind (order unspecified) — the
    /// conservation audit sums in-flight `Deliver` mass this way.
    fn for_each_kind<F: FnMut(&EventKind)>(&self, mut f: F) {
        match self {
            EventQueue::Heap(h) => {
                for ev in h.iter() {
                    f(&ev.kind);
                }
            }
            EventQueue::Wheel(wh) => wh.for_each(|e| f(&e.item)),
        }
    }

    fn approx_bytes(&self) -> usize {
        match self {
            EventQueue::Heap(h) => h.capacity() * std::mem::size_of::<Event>(),
            EventQueue::Wheel(wh) => wh.approx_bytes(),
        }
    }
}

/// Wheel bucket width for a time model: an eighth of the mean compute
/// time spreads each worker's wake stream across ~8 ticks — fine enough
/// that per-slot sorts stay short, coarse enough that the wheel's
/// two-level span (65,536 ticks) covers hours of simulated time before
/// the overflow list is consulted.
fn wheel_tick(tm: &TimeModel) -> f64 {
    let tick = tm.compute / 8.0;
    if tick.is_finite() && tick > 0.0 {
        tick
    } else {
        1e-3
    }
}

/// A `(sim_time_seconds, loss)` training trace plus accounting.
#[derive(Debug, Default)]
pub struct DesReport {
    pub trace: Vec<(f64, f64)>,
    pub messages: u64,
    /// Wire bytes carried by gossip messages in their encoded form
    /// (sharded messages are proportionally smaller, codecs shrink the
    /// body further; barrier strategies count full dense models).
    pub bytes: u64,
    /// Bytes the same messages would have cost uncompressed (dense f32);
    /// equals `bytes` when no codec is active.
    pub raw_bytes: u64,
    /// Total seconds workers spent blocked on synchronization.
    pub blocked_secs: f64,
    /// Total local gradient steps executed.
    pub steps: u64,
    /// Crash events that fired (churn scenarios).
    pub crashes: u64,
    /// Total simulated seconds workers spent offline.
    pub downtime_secs: f64,
    /// Final simulated time.
    pub end_time: f64,
    /// Per-worker queueing-delay and link-utilization accounting when a
    /// finite-bandwidth fabric is active (`None` under the ideal scalar
    /// model).
    pub fabric: Option<FabricStats>,
}

/// FNV-1a over one little-endian `u64`.
fn fnv(h: &mut u64, x: u64) {
    for b in x.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

impl DesReport {
    /// Order-sensitive hash of the full event outcome: every trace point
    /// (time and loss at f64 bit precision), the message/byte/step
    /// counters, and the fabric accounting.  Two runs with the same seed
    /// and configuration must produce the same hash — the determinism
    /// contract the fabric-invariants suite pins, including under
    /// jittered latency distributions.
    pub fn trace_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        fnv(&mut h, self.messages);
        fnv(&mut h, self.bytes);
        fnv(&mut h, self.raw_bytes);
        fnv(&mut h, self.steps);
        fnv(&mut h, self.crashes);
        fnv(&mut h, self.blocked_secs.to_bits());
        fnv(&mut h, self.downtime_secs.to_bits());
        fnv(&mut h, self.end_time.to_bits());
        for (t, loss) in &self.trace {
            fnv(&mut h, t.to_bits());
            fnv(&mut h, loss.to_bits());
        }
        if let Some(stats) = &self.fabric {
            fnv(&mut h, stats.injected);
            fnv(&mut h, stats.delivered);
            fnv(&mut h, stats.switch_queue_secs.to_bits());
            fnv(&mut h, stats.switch_busy_secs.to_bits());
            for xs in [&stats.nic_queue_secs, &stats.nic_busy_secs, &stats.rx_queue_secs] {
                for x in xs {
                    fnv(&mut h, x.to_bits());
                }
            }
        }
        h
    }
}

struct WorkerState {
    /// The worker's model, copy-on-write against the engine's shared
    /// cold replica: `Cold` until the first local step or absorb
    /// materializes a private copy through the buffer pool.  Idle
    /// workers on a million-worker fleet cost bytes, not a model clone.
    x: CowModel,
    /// The worker's protocol state machine (per-shard sum weights, shard
    /// cursor, exchange policy, local step counter).
    core: ProtocolCore,
    mailbox: Vec<(Shard, EncodedPayload, f64)>,
    /// PerSyn/EASGD: parked at the barrier.
    at_barrier: bool,
    /// The worker's private randomness stream, keyed `(seed, w)`: every
    /// draw a worker's handlers make comes from here, so a draw sequence
    /// depends only on that worker's own event history — the property
    /// that lets the sharded executor replay the exact sequential draws.
    rng: CounterRng,
    /// Per-worker event-key counter (see [`pack_key`]).
    key_ctr: u64,
}

impl WorkerState {
    /// Next origin-packed event key for an event scheduled by worker
    /// `w`'s handler (`w` must be this worker's own id).
    fn next_key(&mut self, w: usize) -> u64 {
        let k = pack_key(w, self.key_ctr);
        self.key_ctr += 1;
        k
    }
}

/// Sparse churn state, allocated only when the scenario enables churn.
/// Everything keys by worker id in *ordered* maps so accounting sweeps
/// (e.g. the end-of-run downtime pass) visit workers in ascending id —
/// the same order the old dense per-worker arrays walked, keeping f64
/// summation order (and thus the trace hash) bit-identical.
#[derive(Debug, Default)]
struct ChurnState {
    /// Ids of the workers currently down.  Offline workers swallow wakes
    /// and let mail accumulate; `AliveSet::Down` hands this to the
    /// emit path so deterministic schedules repair around dead peers.
    down: BTreeSet<usize>,
    /// Wake-stream epochs of workers that have crashed at least once
    /// (absent = epoch 0).  A crash bumps the epoch, invalidating wakes
    /// scheduled before the worker died.
    epochs: BTreeMap<usize, u32>,
    /// When each down worker's current outage began; downtime is
    /// accounted on rejoin / at the horizon, so the report never counts
    /// offline seconds that fall outside the run.
    down_since: BTreeMap<usize, f64>,
}

/// Rendezvous bookkeeping for the symmetric-gossip ablation — the only
/// strategy that reads it, and the only one that pays its two O(workers)
/// vectors.
#[derive(Debug)]
struct SymState {
    /// When each worker's current compute finishes (earliest rendezvous
    /// point).
    busy_until: Vec<f64>,
    /// Handshake delays owed at next wake.
    pending_delay: Vec<f64>,
}

/// Exponential deviate with the given mean (churn inter-arrivals).
fn draw_exp(rng: &mut dyn Draws, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// A priced outbound gossip message produced by a fire-and-forget wake,
/// with every random draw (peer pick, codec, latency or up-link jitter)
/// already taken from the *sender's* stream.  The executor only
/// accounts and routes it — sequentially in place, or replayed on the
/// parallel merge thread in global `(time, key)` order.
struct SendOut {
    to: usize,
    payload: EncodedPayload,
    weight: f64,
    shard: Shard,
    /// Encoded wire bytes.
    encoded: usize,
    /// Dense-equivalent wire bytes of the same message.
    raw: usize,
    /// Ideal model: the drawn one-way latency.  Finite fabric: the
    /// pre-drawn up-link jitter to hand to [`Fabric::inject_delayed`].
    delay: f64,
}

/// The read-only context a fire-and-forget wake needs, shared by the
/// sequential executor and every parallel lane.
#[derive(Clone, Copy)]
struct FireCtx<'a> {
    time_model: &'a TimeModel,
    scenario: &'a ScenarioModel,
    cold: &'a Arc<FlatVec>,
    /// `Some` when a finite fabric is active (latency is then priced by
    /// the fabric; the wake pre-draws only the up-link jitter).
    fab_params: Option<FabricParams>,
    dim: usize,
    workers: usize,
    eta: f32,
    weight_decay: f32,
    /// `false` for `Local` (no emit, no send draws).
    gossip: bool,
}

/// One fire-and-forget wake for worker `w`: mailbox absorb → gradient
/// step → gated emit → latency/up-link draw → next-compute draw, every
/// draw from `ws.rng`.  This is the *shared* transition both executors
/// run verbatim, which is what makes a worker's draw sequence depend
/// only on its own event history: the parallel executor replays each
/// worker's events in the same relative order as the sequential one, so
/// the streams — and the run — are bit-identical.
fn fire_and_forget_wake(
    ctx: FireCtx<'_>,
    ws: &mut WorkerState,
    w: usize,
    grad: &mut dyn GradSource,
    grad_buf: &mut FlatVec,
    mail_scratch: &mut Vec<GossipMsg>,
    down: Option<&BTreeSet<usize>>,
) -> Result<(f64, Option<SendOut>, f64)> {
    // 1. Process pending messages (GoSGD ProcessMessages): the core
    //    blends each shard range against that shard's sum weight.  The
    //    mailbox is swapped against a reusable scratch buffer — no fresh
    //    Vec per wake — and each absorbed payload's pooled storage
    //    retires for the next emit.
    debug_assert!(mail_scratch.is_empty());
    std::mem::swap(mail_scratch, &mut ws.mailbox);
    let WorkerState { x, core, rng, .. } = ws;
    for (shard, payload, weight) in mail_scratch.drain(..) {
        core.absorb_cow(x, ctx.cold, shard, &payload, SumWeight::from_value(weight))?;
    }
    // 2. Local gradient step (through the core's step transition).
    let step = core.steps();
    let loss = grad.grad(w + 1, x.read(ctx.cold), step, grad_buf)?;
    core.local_step_cow(x, ctx.cold, grad_buf, ctx.eta, ctx.weight_decay)?;
    // 3. Gated emit + message pricing.  Under churn the down-set gate
    //    repairs deterministic schedules around dead peers; the sparse
    //    gate draws the same RNG stream the old dense mask did.
    let send = if ctx.gossip {
        let gate = down.map(AliveSet::Down);
        match core.emit_gated(x.read(ctx.cold), ctx.workers, rng, gate.as_ref())? {
            Some(out) => {
                let encoded = out.wire_bytes();
                let raw = out.raw_wire_bytes();
                let delay = match &ctx.fab_params {
                    // Finite fabric: pre-draw the up-link jitter from the
                    // sender's stream so the merge thread can replay the
                    // injection without consuming any randomness.
                    Some(p) => p.sample_delay(rng),
                    // Ideal model — bandwidth-dominated latency at
                    // paper-scale messages: shipping a fraction of the
                    // full dense message's bytes takes the same fraction
                    // of the one-way latency.
                    None => {
                        let frac = encoded as f64 / wire_bytes_for(ctx.dim, false) as f64;
                        ctx.time_model.draw_latency(rng) * frac
                    }
                };
                Some(SendOut {
                    to: out.to,
                    payload: out.payload,
                    weight: out.weight.value(),
                    shard: out.shard,
                    encoded,
                    raw,
                    delay,
                })
            }
            None => None,
        }
    } else {
        None
    };
    // 4. Fire-and-forget: compute continues immediately.
    let dt = ctx.time_model.draw_compute(rng) * ctx.scenario.scale(w);
    Ok((loss, send, dt))
}

/// Contiguous worker spans for `t` lanes: the first `workers % t` lanes
/// take one extra worker.
fn lane_spans(workers: usize, t: usize) -> Vec<(usize, usize)> {
    let base = workers / t;
    let rem = workers % t;
    let mut spans = Vec::with_capacity(t);
    let mut lo = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        spans.push((lo, lo + len));
        lo += len;
    }
    spans
}

/// Index of the lane owning worker `w` (spans are contiguous ascending).
fn lane_of(spans: &[(usize, usize)], w: usize) -> usize {
    spans.partition_point(|&(_, hi)| hi <= w)
}

/// `(time, key)` of the queue's earliest event without consuming it — a
/// pop/push-back peek.  The wheel re-accepts a just-popped entry at its
/// exact position (pinned by its push-back tests); `(∞, MAX)` = empty.
fn peek_next(q: &mut EventQueue) -> (f64, u64) {
    match q.pop() {
        Some(ev) => {
            let n = (ev.time, ev.seq);
            q.push(ev);
            n
        }
        None => (f64::INFINITY, u64::MAX),
    }
}

/// Merge-thread → lane-thread window handoff: the merge thread publishes
/// the bound under the mutex, then bumps the generation counter to
/// release the lanes; each lane increments the done counter when its
/// window completes.  A spin-yield gate instead of a condvar on purpose:
/// the sync shim swaps `Mutex` for the model-checked type under
/// `--cfg loom`, and pairing a std condvar with a model mutex would be
/// unsound.
struct WindowCtrl {
    bound_time: f64,
    bound_key: u64,
    exit: bool,
}

/// One worker shard of the parallel executor: a contiguous id range
/// `lo..lo+workers.len()` with its own event queue, gradient-source
/// fork, scratch buffers, and window-output staging — all behind one
/// `Mutex` the lane thread holds while a window runs and the merge
/// thread holds at the barrier.
struct Lane {
    lo: usize,
    workers: Vec<WorkerState>,
    events: EventQueue,
    grad: Box<dyn GradSource + Send>,
    grad_buf: FlatVec,
    mail_scratch: Vec<GossipMsg>,
    trace_stride: usize,
    /// Churn snapshots, refreshed by the merge thread whenever a churn
    /// event fires.  Accurate for a whole window because crash/rejoin
    /// events only ever execute at window barriers.
    down: Option<BTreeSet<usize>>,
    epochs: BTreeMap<usize, u32>,
    // --- window output, drained by the merge thread at the barrier ---
    steps: u64,
    msgs: u64,
    bytes: u64,
    raw: u64,
    /// `(time, key, loss)` trace points, in processing order.
    trace: Vec<(f64, u64, f64)>,
    /// Finite fabric: priced sends to replay as injections, tagged with
    /// the emitting wake's `(time, key)` and the sender id.
    injects: Vec<(f64, u64, usize, SendOut)>,
    /// Ideal model: deliveries addressed to other lanes.
    egress: Vec<Event>,
    /// Latest event time processed (end-time accounting).
    hi_t: f64,
    error: Option<Error>,
}

impl Lane {
    fn hi(&self) -> usize {
        self.lo + self.workers.len()
    }

    /// Process every pending event strictly below `(bound_time,
    /// bound_key)` — the conservative window the merge thread proved
    /// free of incoming cross-lane effects.
    fn run_window(&mut self, ctx: FireCtx<'_>, bound_time: f64, bound_key: u64) {
        if self.error.is_some() {
            return;
        }
        while let Some(ev) = self.events.pop() {
            if (ev.time, ev.seq) >= (bound_time, bound_key) {
                self.events.push(ev);
                break;
            }
            self.hi_t = ev.time;
            match ev.kind {
                EventKind::Deliver { to, payload, weight, shard } => {
                    // Delivered even while `to` is down: the mailbox
                    // accumulates and the backlog blends at rejoin.
                    self.workers[to - self.lo].mailbox.push((shard, payload, weight));
                }
                EventKind::Wake { w, epoch } => {
                    let alive = self.down.as_ref().map_or(true, |d| !d.contains(&w));
                    if alive && epoch == self.epochs.get(&w).copied().unwrap_or(0) {
                        if let Err(e) = self.wake(ctx, w, ev.time, ev.seq) {
                            self.error = Some(e);
                            return;
                        }
                    }
                }
                // Crash/rejoin candidates and fabric ticks live on the
                // merge thread, never in a lane queue.
                EventKind::Crash(_) | EventKind::Rejoin(_) | EventKind::FabricTick => {
                    unreachable!("merge-thread event routed into a lane queue")
                }
            }
        }
    }

    /// One in-window wake: the shared transition plus lane-local
    /// accounting and routing (the merge thread finishes both at the
    /// barrier, in global `(time, key)` order).
    fn wake(&mut self, ctx: FireCtx<'_>, w: usize, now: f64, key: u64) -> Result<()> {
        let i = w - self.lo;
        let (loss, send, dt) = fire_and_forget_wake(
            ctx,
            &mut self.workers[i],
            w,
            &mut *self.grad,
            &mut self.grad_buf,
            &mut self.mail_scratch,
            self.down.as_ref(),
        )?;
        self.steps += 1;
        if w % self.trace_stride == 0 {
            self.trace.push((now, key, loss));
        }
        if let Some(s) = send {
            self.msgs += 1;
            self.bytes += s.encoded as u64;
            self.raw += s.raw as u64;
            if ctx.fab_params.is_some() {
                self.injects.push((now, key, w, s));
            } else {
                // Mint the delivery key *before* the wake key — the
                // order the sequential executor assigns them.
                let dkey = self.workers[i].next_key(w);
                let ev = Event {
                    time: now + s.delay,
                    seq: dkey,
                    kind: EventKind::Deliver {
                        to: s.to,
                        payload: s.payload,
                        weight: s.weight,
                        shard: s.shard,
                    },
                };
                if (self.lo..self.hi()).contains(&s.to) {
                    self.events.push(ev);
                } else {
                    self.egress.push(ev);
                }
            }
        }
        let epoch = self.epochs.get(&w).copied().unwrap_or(0);
        let wkey = self.workers[i].next_key(w);
        self.events.push(Event { time: now + dt, seq: wkey, kind: EventKind::Wake { w, epoch } });
        Ok(())
    }
}

/// The discrete-event engine.
pub struct DesEngine {
    strategy: DesStrategy,
    time_model: TimeModel,
    scenario: ScenarioModel,
    /// Receiver-selection topology for the gossip strategies (uniform
    /// random by default); applied to every worker's core at `start`.
    topology: TopologySpec,
    /// Network model selection (`Ideal` = the scalar latency function).
    fabric_spec: FabricSpec,
    /// The finite-bandwidth fabric, instantiated at `start` when the spec
    /// is not `Ideal`.  `None` keeps the pre-fabric scalar path —
    /// bit-identical, same RNG draw order.
    fabric: Option<Fabric<GossipMsg>>,
    /// Time of the earliest pending `FabricTick` (`INFINITY` = none).
    fabric_tick_at: f64,
    /// Reusable delivery buffer for fabric ticks.
    fabric_out: Vec<Delivery<GossipMsg>>,
    workers: Vec<WorkerState>,
    /// The shared cold model replica every `CowModel::Cold` worker reads.
    cold: Arc<FlatVec>,
    master: FlatVec,

    /// PerSyn/EASGD barrier bookkeeping.
    barrier_arrivals: Vec<f64>,
    /// Symmetric-gossip rendezvous state; `None` for every other
    /// strategy (which never reads it).
    sym: Option<Box<SymState>>,
    /// Sparse crash/rejoin state; `None` until a churn scenario starts.
    churn: Option<Box<ChurnState>>,
    events: EventQueue,
    scheduler: SchedulerKind,
    /// Executor selection (see [`ParallelKind`]); sequential by default.
    parallel: ParallelKind,
    /// The active codec's spec, kept alongside the built codec object so
    /// the parallel executor can compute its lookahead from the smallest
    /// possible wire payload.
    codec_spec: CodecSpec,
    /// Telemetry stride: worker `w` contributes to the loss trace and
    /// the consensus computations iff `w % trace_stride == 0`.  1 (full
    /// telemetry) up to 4096 workers; a ~1024-worker sample beyond.
    trace_stride: usize,
    /// Per-origin event-key counter for fabric ticks (origin = fleet
    /// size, sorting after all worker events at equal time).
    fabric_key_ctr: u64,
    /// Initial wakes (and crash schedules) are laid down lazily on the
    /// first `run` call so `with_scenario` can still adjust the model.
    started: bool,
    eta: f32,
    weight_decay: f32,
    /// Randomness consumed by the fabric's *receive side* (down-link
    /// jitter drawn inside `advance_into`): a dedicated stream keyed
    /// `(seed, m)` so fabric draws never interleave with worker streams —
    /// the merge thread owns it in a parallel run.
    fabric_rng: CounterRng,
    grad_buf: FlatVec,
    /// Reusable drain buffer for mailbox processing: swapped with the
    /// awake worker's mailbox each wake so neither side allocates once
    /// capacities are warm (absorbed payloads retire to the cores' shared
    /// buffer pool).
    mail_scratch: Vec<(Shard, EncodedPayload, f64)>,
    report: DesReport,
}

impl DesEngine {
    /// Build the engine.  Fails with a config error (rather than
    /// panicking) when a sharded strategy's shard count is 0 or exceeds
    /// the model dimension — the two places where user input meets the
    /// dimension for the first time.
    pub fn new(
        strategy: DesStrategy,
        time_model: TimeModel,
        workers: usize,
        init: &FlatVec,
        eta: f32,
        weight_decay: f32,
        seed: u64,
    ) -> Result<Self> {
        assert!(workers >= 2);
        // Event keys pack the origin into the high 24 bits (see
        // `pack_key`); the fleet-size sentinel origin for fabric ticks
        // must fit too.
        assert!(workers < (1 << 24) - 1, "fleet size exceeds the event-key origin space");
        let (p, shards) = strategy.core_config();
        // One shared pool: a payload acquired at any worker's emit is
        // recycled when the receiving worker absorbs it.
        let pool = BufferPool::shared();
        // One fully validated template core; every worker forks it,
        // sharing the topology/codec objects behind `Arc`s — O(shards)
        // state per worker instead of per-worker rebuilds.
        let template =
            ProtocolCore::new(0, workers, init.len(), p, TopologySpec::UniformRandom, shards)?
                .with_pool(pool);
        let ws = (0..workers)
            .map(|w| WorkerState {
                x: CowModel::Cold,
                core: template.fork(w),
                mailbox: Vec::new(),
                at_barrier: false,
                rng: CounterRng::new(seed, w as u64),
                key_ctr: 0,
            })
            .collect::<Vec<WorkerState>>();
        let sym = matches!(strategy, DesStrategy::SymmetricGossip { .. }).then(|| {
            Box::new(SymState {
                busy_until: vec![0.0; workers],
                pending_delay: vec![0.0; workers],
            })
        });
        let trace_stride = if workers <= 4096 { 1 } else { workers / 1024 };
        Ok(DesEngine {
            strategy,
            scenario: ScenarioModel::none(),
            topology: TopologySpec::UniformRandom,
            fabric_spec: FabricSpec::Ideal,
            fabric: None,
            fabric_tick_at: f64::INFINITY,
            fabric_out: Vec::new(),
            workers: ws,
            cold: Arc::new(init.clone()),
            master: init.clone(),
            barrier_arrivals: Vec::new(),
            sym,
            churn: None,
            events: EventQueue::new(SchedulerKind::Wheel, wheel_tick(&time_model)),
            scheduler: SchedulerKind::Wheel,
            parallel: ParallelKind::Sequential,
            codec_spec: CodecSpec::default(),
            trace_stride,
            fabric_key_ctr: 0,
            started: false,
            eta,
            weight_decay,
            fabric_rng: CounterRng::new(seed, workers as u64),
            grad_buf: FlatVec::zeros(init.len()),
            mail_scratch: Vec::new(),
            report: DesReport::default(),
            time_model,
        })
    }

    /// Attach a scenario (heterogeneous compute and/or churn).  Must be
    /// called before the first [`DesEngine::run`].
    pub fn with_scenario(mut self, scenario: ScenarioModel) -> Self {
        assert!(!self.started, "with_scenario must precede run");
        self.scenario = scenario;
        self
    }

    /// Select the gossip topology (see [`crate::gossip::topology`]);
    /// uniform random by default.  Validated against the fleet size (and
    /// applied to every worker core) at the first [`DesEngine::run`].
    /// Must be called before that run.
    pub fn with_topology(mut self, topology: TopologySpec) -> Self {
        assert!(!self.started, "with_topology must precede run");
        self.topology = topology;
        self
    }

    /// Select the network model (see [`crate::sim::fabric`]).  The
    /// default [`FabricSpec::Ideal`] keeps the scalar latency function —
    /// bit-identical to the pre-fabric engine — while the finite presets
    /// route every gossip `Outbound` through NIC serialization queues,
    /// jittered links, and the oversubscribed-switch arbiter.  Finite
    /// fabrics are validated against the strategy (fire-and-forget only)
    /// at the first [`DesEngine::run`].  Must be called before that run.
    pub fn with_fabric(mut self, spec: FabricSpec) -> Self {
        assert!(!self.started, "with_fabric must precede run");
        self.fabric_spec = spec;
        self
    }

    /// Compress gossip payloads with a codec (gossip strategies only —
    /// the barrier baselines ship dense models).  Message latency is
    /// bandwidth-dominated at paper-scale payloads, so the encoded form
    /// proportionally cuts per-message latency as well as bytes.  Must be
    /// called before the first [`DesEngine::run`].
    pub fn with_codec(mut self, codec: CodecSpec) -> Self {
        assert!(!self.started, "with_codec must precede run");
        // One codec object serves the whole fleet (codecs are stateless;
        // per-worker codec *state* like error feedback lives in the core).
        let shared = codec.build();
        for ws in &mut self.workers {
            ws.core.set_codec_shared(&shared);
        }
        self.codec_spec = codec;
        self
    }

    /// Select the executor (see [`ParallelKind`]); sequential by default.
    /// `Sharded(T)` runs the fire-and-forget strategies on `T` threads
    /// with bit-identical results; validated against the strategy and the
    /// gradient source at the first [`DesEngine::run`].  Must be called
    /// before that run.
    pub fn with_parallel(mut self, kind: ParallelKind) -> Self {
        assert!(!self.started, "with_parallel must precede run");
        self.parallel = kind;
        self
    }

    /// Select the event-queue backend (see [`SchedulerKind`]); the timing
    /// wheel by default.  Pop order — and therefore every run — is
    /// bit-identical under either, so this is a performance knob and an
    /// equivalence-testing hook, not a semantics switch.  Must be called
    /// before the first [`DesEngine::run`].
    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        assert!(!self.started, "with_scheduler must precede run");
        if kind != self.scheduler {
            debug_assert_eq!(self.events.len(), 0, "events precede start");
            self.scheduler = kind;
            self.events = EventQueue::new(kind, wheel_tick(&self.time_model));
        }
        self
    }

    /// Cap telemetry at roughly `samples` workers: the loss trace and the
    /// consensus computations use every `stride`-th worker, with
    /// `stride = max(1, workers / samples)`.  Fleets of ≤ 4096 workers
    /// default to full telemetry (stride 1); larger fleets default to a
    /// ~1024-worker sample.  `report.steps` still counts every worker's
    /// steps — only the per-step trace is sampled.  Must be called before
    /// the first [`DesEngine::run`].
    pub fn with_telemetry_sample(mut self, samples: usize) -> Self {
        assert!(!self.started, "with_telemetry_sample must precede run");
        self.trace_stride = (self.workers.len() / samples.max(1)).max(1);
        self
    }

    /// Schedule an event keyed to worker `origin`'s key stream — the
    /// worker whose handler is doing the scheduling (see [`pack_key`]).
    fn schedule_from(&mut self, origin: usize, at: f64, kind: EventKind) {
        let key = self.workers[origin].next_key(origin);
        self.events.push(Event { time: at, seq: key, kind });
    }

    /// Schedule a wake stamped with `w`'s current epoch.
    fn schedule_wake(&mut self, at: f64, w: usize) {
        let epoch = self.epoch_of(w);
        self.schedule_from(w, at, EventKind::Wake { w, epoch });
    }

    /// Schedule a fabric tick.  Its key origin is the fleet size, so at
    /// equal times it sorts *after* every worker event — the order the
    /// parallel merge thread reproduces by advancing the fabric at
    /// window barriers.
    fn schedule_fabric_tick(&mut self, at: f64) {
        let key = pack_key(self.workers.len(), self.fabric_key_ctr);
        self.fabric_key_ctr += 1;
        self.events.push(Event { time: at, seq: key, kind: EventKind::FabricTick });
    }

    /// Whether worker `w` is currently up (always true without churn).
    fn is_alive(&self, w: usize) -> bool {
        self.churn.as_ref().map_or(true, |c| !c.down.contains(&w))
    }

    /// `w`'s current wake-stream epoch (0 until its first crash).
    fn epoch_of(&self, w: usize) -> u32 {
        self.churn.as_ref().and_then(|c| c.epochs.get(&w).copied()).unwrap_or(0)
    }

    /// Per-worker compute draw: base jittered time × the scenario's
    /// persistent multiplier, from the worker's own stream.
    fn draw_compute_for(&mut self, w: usize) -> f64 {
        self.time_model.draw_compute(&mut self.workers[w].rng) * self.scenario.scale(w)
    }

    /// Lay down the initial wake (and crash) schedule; validates the
    /// scenario against the strategy.
    fn start(&mut self) -> Result<()> {
        if self.started {
            return Ok(());
        }
        for s in &self.scenario.compute_scale {
            if !(s.is_finite() && *s > 0.0) {
                return Err(Error::config(format!(
                    "compute multipliers must be positive and finite, got {s}"
                )));
            }
        }
        if self.scenario.churn_enabled() {
            if !self.strategy.supports_churn() {
                return Err(Error::config(format!(
                    "worker churn requires a decentralized strategy, not {}",
                    self.strategy.name()
                )));
            }
            if !self.scenario.crash_mtbf.is_finite() {
                return Err(Error::config(
                    "crash_mtbf must be finite when churn is enabled",
                ));
            }
            if !(self.scenario.rejoin_mttr > 0.0 && self.scenario.rejoin_mttr.is_finite()) {
                return Err(Error::config("rejoin_mttr must be > 0 when churn is enabled"));
            }
        }
        self.topology.validate_for(self.workers.len())?;
        if self.fabric_spec != FabricSpec::Ideal && !self.strategy.fire_and_forget() {
            return Err(Error::config(format!(
                "a finite fabric routes asynchronous gossip messages; {} synchronizes \
                 through rendezvous/master paths the fabric does not model — use \
                 --fabric ideal for the barrier baselines",
                self.strategy.name()
            )));
        }
        // Only latch after validation: a rejected scenario must keep
        // rejecting on a retried run, not fall through to an empty heap.
        self.started = true;
        if let Some(params) = self.fabric_spec.params() {
            self.fabric = Some(Fabric::new(self.workers.len(), params));
        }
        if self.scenario.churn_enabled() {
            self.churn = Some(Box::default());
        }
        if self.topology != TopologySpec::UniformRandom {
            // One topology object serves the whole fleet; per-worker
            // position (rotation cursor) lives in the core.
            let shared = self.topology.build();
            for ws in &mut self.workers {
                ws.core.set_topology_shared(&shared);
            }
        }
        // Stagger initial wakes slightly so workers don't tick in lockstep.
        for w in 0..self.workers.len() {
            let dt = self.draw_compute_for(w);
            self.schedule_wake(dt, w);
        }
        if self.scenario.churn_enabled() {
            for w in 0..self.workers.len() {
                let at = draw_exp(&mut self.workers[w].rng, self.scenario.crash_mtbf);
                self.schedule_from(w, at, EventKind::Crash(w));
            }
        }
        Ok(())
    }

    /// Run until simulated `horizon` seconds (or the event queue drains).
    pub fn run(&mut self, grad: &mut dyn GradSource, horizon: f64) -> Result<&DesReport> {
        self.start()?;
        if let ParallelKind::Sharded(t) = self.parallel {
            self.run_parallel(grad, horizon, t)?;
            return Ok(&self.report);
        }
        while let Some(ev) = self.events.pop() {
            if ev.time > horizon {
                // Leave the event for a later run with a longer horizon —
                // in-flight deliveries keep their weight mass.
                self.events.push(ev);
                self.report.end_time = horizon;
                break;
            }
            self.report.end_time = ev.time;
            match ev.kind {
                EventKind::Deliver { to, payload, weight, shard } => {
                    // Delivered even while `to` is down: the mailbox
                    // accumulates and the backlog blends at rejoin.
                    self.workers[to].mailbox.push((shard, payload, weight));
                }
                EventKind::Wake { w, epoch } => {
                    if self.is_alive(w) && epoch == self.epoch_of(w) {
                        self.wake(w, ev.time, grad)?;
                    }
                }
                EventKind::Crash(w) => self.crash(w, ev.time),
                EventKind::Rejoin(w) => self.rejoin(w, ev.time),
                EventKind::FabricTick => {
                    // This tick may be stale (a later duplicate of one
                    // that already advanced the fabric); advancing to
                    // `ev.time` is idempotent, so firing it is harmless.
                    self.fabric_tick_at = f64::INFINITY;
                    let mut out = std::mem::take(&mut self.fabric_out);
                    if let Some(fab) = self.fabric.as_mut() {
                        fab.advance_into(ev.time, &mut self.fabric_rng, &mut out);
                    }
                    for d in out.drain(..) {
                        // Delivered even while `dst` is down — mailbox
                        // semantics are identical to the ideal path.
                        let (shard, payload, weight) = d.item;
                        self.workers[d.dst].mailbox.push((shard, payload, weight));
                    }
                    self.fabric_out = out;
                    self.arm_fabric_tick();
                }
            }
        }
        self.finish_run();
        Ok(&self.report)
    }

    /// Post-loop accounting shared by both executors: sweep the
    /// in-progress outages up to the point the run stopped (resetting
    /// `down_since` keeps a longer-horizon resume exact; the BTreeMap
    /// sweeps in ascending worker id — the summation order the dense
    /// representation used) and snapshot the fabric stats.
    fn finish_run(&mut self) {
        let end = self.report.end_time;
        if let Some(churn) = self.churn.as_mut() {
            for since in churn.down_since.values_mut() {
                if *since < end {
                    self.report.downtime_secs += end - *since;
                    *since = end;
                }
            }
        }
        if let Some(fab) = &self.fabric {
            self.report.fabric = Some(fab.stats().clone());
        }
    }

    /// Keep a `FabricTick` pending at the fabric's earliest internal
    /// transition.  Transitions are only created by `inject` (strictly
    /// later than `now`) and by firing hops (strictly later than the hop:
    /// bytes are positive and bandwidth finite), so scheduling whenever
    /// the earliest transition moves *earlier* than the pending tick
    /// guarantees no transition is ever reached late.
    fn arm_fabric_tick(&mut self) {
        let next = self.fabric.as_ref().and_then(|f| f.next_transition());
        if let Some(t) = next {
            if t < self.fabric_tick_at {
                self.fabric_tick_at = t;
                self.schedule_fabric_tick(t);
            }
        }
    }

    fn crash(&mut self, w: usize, now: f64) {
        // A worker parked at a barrier never crashes in this model (churn
        // is gated to the decentralized strategies, which have no barrier).
        if !self.is_alive(w) || self.workers[w].at_barrier {
            return;
        }
        {
            let churn = self.churn.as_mut().expect("crash events exist only under churn");
            churn.down.insert(w);
            churn.down_since.insert(w, now);
            // Invalidate the in-flight wake of the interrupted step.
            let epoch = churn.epochs.entry(w).or_insert(0);
            *epoch = epoch.wrapping_add(1);
        }
        self.report.crashes += 1;
        let down = draw_exp(&mut self.workers[w].rng, self.scenario.rejoin_mttr);
        self.schedule_from(w, now + down, EventKind::Rejoin(w));
    }

    fn rejoin(&mut self, w: usize, now: f64) {
        {
            let churn = self.churn.as_mut().expect("rejoin events exist only under churn");
            let since = churn.down_since.remove(&w).expect("rejoining worker was down");
            churn.down.remove(&w);
            self.report.downtime_secs += now - since;
        }
        let dt = self.draw_compute_for(w);
        self.schedule_wake(now + dt, w);
        // Next failure of this worker.
        let next = draw_exp(&mut self.workers[w].rng, self.scenario.crash_mtbf);
        self.schedule_from(w, now + next, EventKind::Crash(w));
    }

    fn wake(&mut self, w: usize, now: f64, grad: &mut dyn GradSource) -> Result<()> {
        if self.strategy.fire_and_forget() {
            return self.wake_fire_and_forget(w, now, grad);
        }
        let cold = Arc::clone(&self.cold);
        // 0. Pay any handshake delay owed from a symmetric rendezvous the
        //    worker was dragged into while computing.
        if let Some(sym) = self.sym.as_mut() {
            if sym.pending_delay[w] > 0.0 {
                let d = std::mem::take(&mut sym.pending_delay[w]);
                sym.busy_until[w] = now + d;
                self.report.blocked_secs += d;
                self.schedule_wake(now + d, w);
                return Ok(());
            }
        }
        // 1. Process pending messages (GoSGD ProcessMessages): the core
        //    blends each shard range against that shard's sum weight.
        //    The mailbox is swapped against a reusable scratch buffer —
        //    no fresh Vec per wake — and each absorbed payload's pooled
        //    storage retires for the next emit.  (No delivery can land in
        //    `w`'s mailbox mid-wake: deliveries are queue events.)
        debug_assert!(self.mail_scratch.is_empty());
        std::mem::swap(&mut self.mail_scratch, &mut self.workers[w].mailbox);
        {
            let WorkerState { x, core, .. } = &mut self.workers[w];
            for (shard, payload, weight) in self.mail_scratch.drain(..) {
                core.absorb_cow(x, &cold, shard, &payload, SumWeight::from_value(weight))?;
            }
        }

        // 2. Local gradient step (through the core's step transition).
        let loss = {
            let WorkerState { x, core, .. } = &mut self.workers[w];
            let step = core.steps();
            let loss = grad.grad(w + 1, x.read(&cold), step, &mut self.grad_buf)?;
            core.local_step_cow(x, &cold, &self.grad_buf, self.eta, self.weight_decay)?;
            loss
        };
        self.report.steps += 1;
        if w % self.trace_stride == 0 {
            self.report.trace.push((now, loss));
        }

        // 3. Strategy-specific communication + next wake.
        match self.strategy.clone() {
            DesStrategy::Local | DesStrategy::GoSgd { .. } | DesStrategy::ShardedGoSgd { .. } => {
                unreachable!("fire-and-forget strategies wake through wake_fire_and_forget")
            }
            DesStrategy::SymmetricGossip { p } => {
                let mut resume = now;
                if self.workers[w].rng.bernoulli(p) {
                    let m = self.workers.len();
                    let r = self.workers[w].rng.peer(m, w);
                    // Rendezvous: wait for r to finish its current step,
                    // then a two-way swap (2 messages, 2 latencies).
                    let wait = {
                        let sym = self.sym.as_ref().expect("symmetric state");
                        (sym.busy_until[r] - now).max(0.0)
                    };
                    let lat = self.time_model.draw_latency(&mut self.workers[w].rng)
                        + self.time_model.draw_latency(&mut self.workers[w].rng);
                    // Pairwise average both models (symmetric exchange).
                    let xr = self.workers[r].x.read(&cold).clone();
                    {
                        let WorkerState { x, core, .. } = &mut self.workers[w];
                        x.make_hot(&cold, core.pool()).mix_from(&xr, 0.5, 0.5)?;
                    }
                    let xw = self.workers[w].x.read(&cold).clone();
                    self.workers[r].x = CowModel::Hot(xw);
                    self.report.messages += 2;
                    let b = 2 * wire_bytes_for(xr.len(), false) as u64;
                    self.report.bytes += b;
                    self.report.raw_bytes += b;
                    // Sender blocks for the wait + handshake; receiver owes
                    // the handshake at its next wake.
                    self.report.blocked_secs += wait + lat;
                    self.sym.as_mut().expect("symmetric state").pending_delay[r] += lat;
                    resume = now + wait + lat;
                }
                let dt = self.draw_compute_for(w);
                if let Some(sym) = self.sym.as_mut() {
                    sym.busy_until[w] = resume + dt;
                }
                self.schedule_wake(resume + dt, w);
            }
            DesStrategy::Easgd { alpha, tau } => {
                if self.workers[w].core.steps() % tau == 0 {
                    // Paper section 3.2: "a global synchronization is still
                    // required as the master has to [combine] local models
                    // that have been updated the same number of times."
                    // Workers park at the barrier; when the last arrives,
                    // each ships its model (latency), the master services
                    // the elastic updates serially, then broadcasts back.
                    self.workers[w].at_barrier = true;
                    self.barrier_arrivals.push(now);
                    let m = self.workers.len();
                    if self.barrier_arrivals.len() == m {
                        let last = self
                            .barrier_arrivals
                            .iter()
                            .cloned()
                            .fold(0.0f64, f64::max);
                        let up = self.time_model.draw_latency(&mut self.workers[w].rng);
                        let service = self.time_model.master_service * m as f64;
                        let down = self.time_model.draw_latency(&mut self.workers[w].rng);
                        let resume = last + up + service + down;
                        // Elastic move (x̃ uses pre-sync worker states).
                        let a = alpha as f32;
                        let old_master = self.master.clone();
                        let mut sum_delta = FlatVec::zeros(old_master.len());
                        for ws in &self.workers {
                            let mut d = ws.x.read(&cold).clone();
                            d.axpy(-1.0, &old_master)?;
                            sum_delta.add_assign(&d)?;
                        }
                        self.master.axpy(a, &sum_delta)?;
                        for i in 0..m {
                            let WorkerState { x, core, at_barrier, .. } =
                                &mut self.workers[i];
                            let xw = x.make_hot(&cold, core.pool());
                            xw.scale(1.0 - a);
                            xw.axpy(a, &old_master)?;
                            *at_barrier = false;
                        }
                        self.report.messages += 2 * m as u64;
                        let b = 2 * m as u64 * wire_bytes_for(old_master.len(), false) as u64;
                        self.report.bytes += b;
                        self.report.raw_bytes += b;
                        for arrival in self.barrier_arrivals.clone() {
                            self.report.blocked_secs += resume - arrival;
                        }
                        for i in 0..m {
                            let dt = self.draw_compute_for(i);
                            self.schedule_wake(resume + dt, i);
                        }
                        self.barrier_arrivals.clear();
                    }
                    // else: parked until the barrier releases
                } else {
                    let dt = self.draw_compute_for(w);
                    self.schedule_wake(now + dt, w);
                }
            }
            DesStrategy::PerSyn { tau } => {
                if self.workers[w].core.steps() % tau == 0 {
                    // Park at the barrier.
                    self.workers[w].at_barrier = true;
                    self.barrier_arrivals.push(now);
                    let m = self.workers.len();
                    if self.barrier_arrivals.len() == m {
                        // Everyone arrived: average, pay gather+broadcast.
                        let refs: Vec<&FlatVec> =
                            self.workers.iter().map(|s| s.x.read(&cold)).collect();
                        let mean = FlatVec::mean_of(&refs)?;
                        let last = self
                            .barrier_arrivals
                            .iter()
                            .cloned()
                            .fold(0.0f64, f64::max);
                        let gather = self.time_model.draw_latency(&mut self.workers[w].rng);
                        let service = self.time_model.master_service * m as f64;
                        let bcast = self.time_model.draw_latency(&mut self.workers[w].rng);
                        let resume = last + gather + service + bcast;
                        self.report.messages += 2 * m as u64;
                        let b = 2 * m as u64 * wire_bytes_for(mean.len(), false) as u64;
                        self.report.bytes += b;
                        self.report.raw_bytes += b;
                        for (i, arrival) in self.barrier_arrivals.clone().iter().enumerate() {
                            self.report.blocked_secs += resume - arrival;
                            self.workers[i].x = CowModel::Hot(mean.clone());
                            self.workers[i].at_barrier = false;
                            let dt = self.draw_compute_for(i);
                            self.schedule_wake(resume + dt, i);
                        }
                        self.master = mean;
                        self.barrier_arrivals.clear();
                    }
                    // else: stay parked (no wake scheduled until release)
                } else {
                    let dt = self.draw_compute_for(w);
                    self.schedule_wake(now + dt, w);
                }
            }
        }
        Ok(())
    }

    /// Sequential fire-and-forget wake: run the shared transition, then
    /// account and route its outputs — the same bookkeeping the parallel
    /// merge thread performs at window barriers.
    fn wake_fire_and_forget(
        &mut self,
        w: usize,
        now: f64,
        grad: &mut dyn GradSource,
    ) -> Result<()> {
        let DesEngine {
            time_model,
            scenario,
            cold,
            workers,
            churn,
            grad_buf,
            mail_scratch,
            eta,
            weight_decay,
            fabric_spec,
            strategy,
            ..
        } = self;
        let ctx = FireCtx {
            time_model,
            scenario,
            cold,
            fab_params: fabric_spec.params(),
            dim: cold.len(),
            workers: workers.len(),
            eta: *eta,
            weight_decay: *weight_decay,
            gossip: !matches!(strategy, DesStrategy::Local),
        };
        let down = churn.as_deref().map(|c| &c.down);
        let (loss, send, dt) =
            fire_and_forget_wake(ctx, &mut workers[w], w, grad, grad_buf, mail_scratch, down)?;
        self.report.steps += 1;
        if w % self.trace_stride == 0 {
            self.report.trace.push((now, loss));
        }
        if let Some(s) = send {
            self.report.messages += 1;
            self.report.bytes += s.encoded as u64;
            self.report.raw_bytes += s.raw as u64;
            if self.fabric.is_some() {
                // Finite fabric: the message's cost is its actual byte
                // count through NIC queues, jittered links, and the
                // switch arbiter — contention emerges instead of being
                // priced by a scalar.
                let fab = self.fabric.as_mut().expect("checked");
                fab.inject_delayed(w, s.to, s.encoded, now, s.delay, (s.shard, s.payload, s.weight));
                self.arm_fabric_tick();
            } else {
                self.schedule_from(
                    w,
                    now + s.delay,
                    EventKind::Deliver {
                        to: s.to,
                        payload: s.payload,
                        weight: s.weight,
                        shard: s.shard,
                    },
                );
            }
        }
        self.schedule_wake(now + dt, w);
        Ok(())
    }

    /// The parallel executor's lookahead `δ`: a message emitted at time
    /// `s` cannot become visible to another worker before `s + δ`.  `δ`
    /// prices the smallest wire message the configuration can produce —
    /// the smallest shard under the tightest codec encoding, *including*
    /// the dense fallback degenerate inputs can force — over the fastest
    /// possible link.
    ///
    /// Ideal model: the latency-jitter lower bound scaled by the minimal
    /// payload fraction.  Finite fabric: an injection at `s` creates its
    /// first internal transition (the up-link arrival) no earlier than
    /// `s + bytes/bandwidth + min_delay`; windows are additionally
    /// capped at the fabric's current next transition, so in-flight
    /// messages need no lookahead of their own.
    fn lookahead(&self) -> Result<f64> {
        if matches!(self.strategy, DesStrategy::Local) {
            // No worker ever sends: lanes are fully independent.
            return Ok(f64::INFINITY);
        }
        let dim = self.cold.len();
        let (_, shards) = self.strategy.core_config();
        let sharded = shards > 1;
        // Smallest shard the plan can produce (`ShardPlan` floors).
        let lmin = if sharded { dim / shards } else { dim };
        let payload = self.codec_spec.payload_wire_bytes(lmin).min(4 * lmin);
        let b_min = (payload + 8 + 16 + if sharded { 8 } else { 0 }) as f64;
        if let Some(p) = self.fabric_spec.params() {
            return Ok(b_min / p.bandwidth + p.min_delay());
        }
        let full = wire_bytes_for(dim, false) as f64;
        let d = self.time_model.latency * (1.0 - self.time_model.latency_jitter) * (b_min / full);
        if !(d > 0.0 && d.is_finite()) {
            return Err(Error::config(format!(
                "the parallel executor needs a positive latency lower bound; latency {} \
                 with jitter {} leaves none — lower the jitter below 1 or use the \
                 sequential executor",
                self.time_model.latency, self.time_model.latency_jitter
            )));
        }
        Ok(d)
    }

    /// The deterministic sharded executor: workers partition into `t`
    /// contiguous lanes, each with its own event queue; events execute
    /// window-by-window under the conservative [`DesEngine::lookahead`]
    /// bound, lanes running concurrently on scoped threads, and every
    /// cross-lane effect (fabric injections, trace points, deliveries,
    /// churn) merges at the window barrier in global `(time, key)`
    /// order.  Bit-identical to the sequential executor — pinned by
    /// `runtime_equivalence.rs`, argued in ARCHITECTURE.md ch. 7f.
    fn run_parallel(&mut self, grad: &mut dyn GradSource, horizon: f64, t: usize) -> Result<()> {
        let m = self.workers.len();
        let t = t.clamp(1, m);
        if !self.strategy.fire_and_forget() {
            return Err(Error::config(format!(
                "the parallel executor runs the fire-and-forget strategies; {} synchronizes \
                 through rendezvous/master paths that need the sequential engine",
                self.strategy.name()
            )));
        }
        let delta = self.lookahead()?;
        let spans = lane_spans(m, t);
        let mut forks = Vec::with_capacity(t);
        for _ in 0..t {
            match grad.fork() {
                Some(f) => forks.push(f),
                None => {
                    return Err(Error::config(
                        "this gradient source does not support parallel execution \
                         (GradSource::fork returned None); use the sequential executor",
                    ))
                }
            }
        }
        let sched = self.scheduler;
        let wheel_dt = wheel_tick(&self.time_model);
        let stride = self.trace_stride;
        let dim = self.cold.len();

        // ---- disassemble engine state ----
        // Crash/rejoin candidates move to a merge-side heap; stale
        // fabric ticks are dropped (the merge thread polls the fabric
        // directly and a fresh tick is re-armed on reassembly).
        let mut churn_heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut routed: Vec<Vec<Event>> = (0..t).map(|_| Vec::new()).collect();
        while let Some(ev) = self.events.pop() {
            match &ev.kind {
                EventKind::Wake { w, .. } => routed[lane_of(&spans, *w)].push(ev),
                EventKind::Deliver { to, .. } => routed[lane_of(&spans, *to)].push(ev),
                EventKind::Crash(_) | EventKind::Rejoin(_) => churn_heap.push(ev),
                EventKind::FabricTick => {}
            }
        }
        let mut churn = self.churn.take();
        let mut rest = std::mem::take(&mut self.workers);
        let mut forks = forks.into_iter();
        let lanes: Vec<SyncMutex<Lane>> = spans
            .iter()
            .zip(routed.iter_mut())
            .map(|(&(lo, hi), pending)| {
                let tail = rest.split_off(hi - lo);
                let lane_workers = std::mem::replace(&mut rest, tail);
                let mut events = EventQueue::new(sched, wheel_dt);
                for ev in pending.drain(..) {
                    events.push(ev);
                }
                SyncMutex::new(Lane {
                    lo,
                    workers: lane_workers,
                    events,
                    grad: forks.next().expect("one fork per lane"),
                    grad_buf: FlatVec::zeros(dim),
                    mail_scratch: Vec::new(),
                    trace_stride: stride,
                    down: churn.as_deref().map(|c| c.down.clone()),
                    epochs: churn.as_deref().map(|c| c.epochs.clone()).unwrap_or_default(),
                    steps: 0,
                    msgs: 0,
                    bytes: 0,
                    raw: 0,
                    trace: Vec::new(),
                    injects: Vec::new(),
                    egress: Vec::new(),
                    hi_t: 0.0,
                    error: None,
                })
            })
            .collect();

        let DesEngine {
            time_model,
            scenario,
            cold,
            fabric,
            fabric_rng,
            fabric_out,
            report,
            fabric_spec,
            strategy,
            eta,
            weight_decay,
            ..
        } = self;
        // Rebind the field borrows as shared so both the lane context
        // and the merge loop can read them.
        let time_model: &TimeModel = time_model;
        let scenario: &ScenarioModel = scenario;
        let cold: &Arc<FlatVec> = cold;
        let ctx = FireCtx {
            time_model,
            scenario,
            cold,
            fab_params: fabric_spec.params(),
            dim,
            workers: m,
            eta: *eta,
            weight_decay: *weight_decay,
            gossip: !matches!(strategy, DesStrategy::Local),
        };

        let gen = AtomicU64::new(0);
        let done = AtomicUsize::new(0);
        let ctrl = SyncMutex::new(WindowCtrl { bound_time: 0.0, bound_key: 0, exit: false });
        let mut run_err: Option<Error> = None;
        let mut max_t = report.end_time;
        let mut pending_beyond = false;
        // Reused merge buffers.
        let mut injects: Vec<(f64, u64, usize, SendOut)> = Vec::new();
        let mut trace_buf: Vec<(f64, u64, f64)> = Vec::new();
        let mut egress_buf: Vec<Event> = Vec::new();

        sync_thread::scope(|scope| {
            for i in 0..t {
                let (lanes, ctrl, gen, done) = (&lanes, &ctrl, &gen, &done);
                scope.spawn(move || {
                    let mut seen = 0u64;
                    loop {
                        while gen.load(AtomicOrdering::Acquire) == seen {
                            sync_thread::yield_now();
                        }
                        seen = gen.load(AtomicOrdering::Acquire);
                        let (bt, bk, exit) = {
                            let c = ctrl.lock().unwrap();
                            (c.bound_time, c.bound_key, c.exit)
                        };
                        if exit {
                            break;
                        }
                        lanes[i].lock().unwrap().run_window(ctx, bt, bk);
                        done.fetch_add(1, AtomicOrdering::Release);
                    }
                });
            }

            // ---- merge thread: the window loop ----
            let inf = (f64::INFINITY, u64::MAX);
            let key_order = |a: &(f64, u64), b: &(f64, u64)| {
                a.0.partial_cmp(&b.0).unwrap_or(Ordering::Equal).then(a.1.cmp(&b.1))
            };
            let mut nexts: Vec<(f64, u64)> = lanes
                .iter()
                .map(|lane| peek_next(&mut lane.lock().unwrap().events))
                .collect();
            loop {
                // Candidates: earliest lane event, earliest churn event,
                // earliest fabric transition (fabric keys sort after all
                // worker keys at equal time, matching the sequential
                // tick order).
                let mut t0 = inf;
                for &n in &nexts {
                    if n < t0 {
                        t0 = n;
                    }
                }
                let churn_next =
                    churn_heap.peek().map_or(inf, |e| (e.time, e.seq));
                if churn_next < t0 {
                    t0 = churn_next;
                }
                let fab_next = fabric
                    .as_ref()
                    .and_then(|f| f.next_transition())
                    .map_or(inf, |ft| (ft, (m as u64) << KEY_ORIGIN_SHIFT));
                if fab_next < t0 {
                    t0 = fab_next;
                }
                if t0.0 > horizon {
                    pending_beyond = t0.0.is_finite();
                    break;
                }

                // The window bound: conservative lookahead from the
                // earliest runnable event, capped by the boundary
                // candidates the merge thread itself must execute.
                let mut bound = (t0.0 + delta, 0u64);
                if churn_next < bound {
                    bound = churn_next;
                }
                if fab_next < bound {
                    bound = fab_next;
                }
                if (horizon, u64::MAX) < bound {
                    bound = (horizon, u64::MAX);
                }

                // Release the lanes on this window and wait them out.
                {
                    let mut c = ctrl.lock().unwrap();
                    c.bound_time = bound.0;
                    c.bound_key = bound.1;
                }
                done.store(0, AtomicOrdering::Release);
                gen.fetch_add(1, AtomicOrdering::Release);
                while done.load(AtomicOrdering::Acquire) < t {
                    sync_thread::yield_now();
                }

                // ---- barrier: merge the window's outputs ----
                for lane in &lanes {
                    let mut l = lane.lock().unwrap();
                    if let Some(e) = l.error.take() {
                        run_err = Some(e);
                    }
                    report.steps += l.steps;
                    report.messages += l.msgs;
                    report.bytes += l.bytes;
                    report.raw_bytes += l.raw;
                    (l.steps, l.msgs, l.bytes, l.raw) = (0, 0, 0, 0);
                    if l.hi_t > max_t {
                        max_t = l.hi_t;
                    }
                    injects.append(&mut l.injects);
                    trace_buf.append(&mut l.trace);
                    egress_buf.append(&mut l.egress);
                }
                if run_err.is_some() {
                    break;
                }
                // (1) Replay this window's fabric injections in global
                // (time, key) order — the order the sequential engine
                // injected them, reproducing the fabric's internal
                // sequence numbers and f64 accounting exactly.
                injects.sort_by(|a, b| key_order(&(a.0, a.1), &(b.0, b.1)));
                if let Some(fab) = fabric.as_mut() {
                    for (at, _key, src, s) in injects.drain(..) {
                        fab.inject_delayed(
                            src,
                            s.to,
                            s.encoded,
                            at,
                            s.delay,
                            (s.shard, s.payload, s.weight),
                        );
                    }
                } else {
                    debug_assert!(injects.is_empty());
                }
                // (2) Trace points in global order.  Windows are
                // time-disjoint, so per-window sorted appends produce
                // the exact sequential trace.
                trace_buf.sort_by(|a, b| key_order(&(a.0, a.1), &(b.0, b.1)));
                for (at, _key, loss) in trace_buf.drain(..) {
                    report.trace.push((at, loss));
                }
                // (3) Cross-lane deliveries into their destination
                // queues (push order is irrelevant: queues order by
                // (time, key), and every delivery lands at or beyond the
                // bound — the lookahead guarantee).
                for ev in egress_buf.drain(..) {
                    let to = match &ev.kind {
                        EventKind::Deliver { to, .. } => *to,
                        _ => unreachable!("egress carries deliveries only"),
                    };
                    lanes[lane_of(&spans, to)].lock().unwrap().events.push(ev);
                }
                // (4) At most one churn event sits exactly at the bound;
                // execute it here, where every lane event below it has
                // already run — its position in the sequential order.
                let mut churn_fired = false;
                if churn_next == bound {
                    let ev = churn_heap.pop().expect("bound candidate");
                    if ev.time > max_t {
                        max_t = ev.time;
                    }
                    let c = churn.as_deref_mut().expect("churn events exist only under churn");
                    match ev.kind {
                        EventKind::Crash(w) => {
                            let mut l = lanes[lane_of(&spans, w)].lock().unwrap();
                            let li = w - l.lo;
                            if !c.down.contains(&w) && !l.workers[li].at_barrier {
                                c.down.insert(w);
                                c.down_since.insert(w, ev.time);
                                let e = c.epochs.entry(w).or_insert(0);
                                *e = e.wrapping_add(1);
                                report.crashes += 1;
                                let dn = draw_exp(&mut l.workers[li].rng, scenario.rejoin_mttr);
                                let key = l.workers[li].next_key(w);
                                churn_heap.push(Event {
                                    time: ev.time + dn,
                                    seq: key,
                                    kind: EventKind::Rejoin(w),
                                });
                            }
                        }
                        EventKind::Rejoin(w) => {
                            let since =
                                c.down_since.remove(&w).expect("rejoining worker was down");
                            c.down.remove(&w);
                            report.downtime_secs += ev.time - since;
                            let mut l = lanes[lane_of(&spans, w)].lock().unwrap();
                            let li = w - l.lo;
                            let dt = time_model.draw_compute(&mut l.workers[li].rng)
                                * scenario.scale(w);
                            let epoch = c.epochs.get(&w).copied().unwrap_or(0);
                            let wkey = l.workers[li].next_key(w);
                            l.events.push(Event {
                                time: ev.time + dt,
                                seq: wkey,
                                kind: EventKind::Wake { w, epoch },
                            });
                            let nxt = draw_exp(&mut l.workers[li].rng, scenario.crash_mtbf);
                            let ckey = l.workers[li].next_key(w);
                            churn_heap.push(Event {
                                time: ev.time + nxt,
                                seq: ckey,
                                kind: EventKind::Crash(w),
                            });
                        }
                        _ => unreachable!("churn heap holds crash/rejoin only"),
                    }
                    churn_fired = true;
                }
                // (5) Advance the fabric when its next transition is the
                // bound, delivering into mailboxes in the fabric's own
                // deterministic order.
                if fab_next == bound {
                    if let Some(fab) = fabric.as_mut() {
                        let mut out = std::mem::take(fabric_out);
                        fab.advance_into(bound.0, fabric_rng, &mut out);
                        for d in out.drain(..) {
                            let mut l = lanes[lane_of(&spans, d.dst)].lock().unwrap();
                            let li = d.dst - l.lo;
                            let (shard, payload, weight) = d.item;
                            l.workers[li].mailbox.push((shard, payload, weight));
                        }
                        *fabric_out = out;
                        if bound.0 > max_t {
                            max_t = bound.0;
                        }
                    }
                }
                // (6) Refresh churn snapshots if they changed and
                // recompute every lane's earliest pending event (egress
                // and churn pushes above may have changed them).
                for (i, lane) in lanes.iter().enumerate() {
                    let mut l = lane.lock().unwrap();
                    if churn_fired {
                        if let Some(c) = churn.as_deref() {
                            l.down = Some(c.down.clone());
                            l.epochs = c.epochs.clone();
                        }
                    }
                    nexts[i] = peek_next(&mut l.events);
                }
            }

            // Release the lanes from the gate for good.
            {
                let mut c = ctrl.lock().unwrap();
                c.exit = true;
            }
            gen.fetch_add(1, AtomicOrdering::Release);
        });

        // ---- reassemble engine state ----
        let mut workers_back: Vec<WorkerState> = Vec::with_capacity(m);
        let mut leftover: Vec<Event> = Vec::new();
        for lane in &lanes {
            let mut l = lane.lock().unwrap();
            workers_back.append(&mut l.workers);
            while let Some(ev) = l.events.pop() {
                leftover.push(ev);
            }
        }
        drop(lanes);
        self.workers = workers_back;
        // A fresh queue: the old one's wheel cursor sits past the events
        // we are putting back.
        self.events = EventQueue::new(self.scheduler, wheel_tick(&self.time_model));
        for ev in leftover {
            self.events.push(ev);
        }
        for ev in churn_heap {
            self.events.push(ev);
        }
        self.churn = churn;
        self.fabric_tick_at = f64::INFINITY;
        self.arm_fabric_tick();
        if let Some(e) = run_err {
            return Err(e);
        }
        self.report.end_time = if pending_beyond { horizon } else { max_t };
        self.finish_run();
        Ok(())
    }

    /// Mean worker model over the telemetry sample (every worker when the
    /// stride is 1 — the default up to 4096 workers).  Cold workers
    /// contribute the shared replica by reference: no per-worker clones.
    pub fn consensus_model(&self) -> Result<FlatVec> {
        Ok(self.consensus_over_sample()?.0)
    }

    /// Consensus error `Σ_m ‖x_m − x̄‖²` over the sampled worker models —
    /// the accuracy side of the codec bandwidth/accuracy tradeoff.
    pub fn consensus_error(&self) -> Result<f64> {
        Ok(self.consensus_over_sample()?.1)
    }

    /// One pass over the telemetry sample: the sample-mean model and the
    /// consensus error around it.  Strided sampling keeps this
    /// O(sample · dim) instead of O(workers · dim) at megafleet scale; at
    /// stride 1 it visits every worker in id order — the exact summation
    /// order (and therefore the exact bits) of the unsampled computation.
    pub fn consensus_over_sample(&self) -> Result<(FlatVec, f64)> {
        let refs: Vec<&FlatVec> = self
            .workers
            .iter()
            .step_by(self.trace_stride)
            .map(|s| s.x.read(&self.cold))
            .collect();
        let mean = FlatVec::mean_of(&refs)?;
        let mut eps = 0.0;
        for x in &refs {
            eps += x.dist_sq(&mean)?;
        }
        Ok((mean, eps))
    }

    /// Per-worker local step counts (scenario diagnostics).
    pub fn worker_steps(&self) -> Vec<u64> {
        self.workers.iter().map(|s| s.core.steps()).collect()
    }

    /// Per-worker, per-shard sum weights (conservation diagnostics).
    pub fn worker_weights(&self) -> Vec<Vec<f64>> {
        self.workers.iter().map(|s| s.core.weight_values()).collect()
    }

    /// Per-shard sum-weight mass currently *in flight*: mailboxes,
    /// undelivered `Deliver` events, and messages inside the fabric.
    /// Adding [`DesEngine::worker_weights`] must give exactly 1 per shard
    /// at any instant — the conservation invariant the fabric test suite
    /// audits under churn.
    pub fn pending_shard_mass(&self) -> Vec<f64> {
        let shards = self.workers[0].core.weight_values().len();
        let mut totals = vec![0.0f64; shards];
        for ws in &self.workers {
            for (shard, _, weight) in &ws.mailbox {
                totals[shard.index] += weight;
            }
        }
        self.events.for_each_kind(|kind| {
            if let EventKind::Deliver { weight, shard, .. } = kind {
                totals[shard.index] += weight;
            }
        });
        if let Some(fab) = &self.fabric {
            fab.for_each_in_flight(|(shard, _, weight)| totals[shard.index] += weight);
        }
        totals
    }

    /// Workers still reading the shared cold replica (never stepped,
    /// never absorbed): each costs O(bytes), not a model copy.
    pub fn cold_workers(&self) -> usize {
        self.workers.iter().filter(|ws| ws.x.is_cold()).count()
    }

    /// Estimated resident bytes of the engine's per-run state: worker
    /// models (hot copies only — cold workers share one replica), core
    /// state, mailboxes, event queue, churn/symmetric bookkeeping, and
    /// the telemetry trace.  An estimate (capacities × element sizes),
    /// not an allocator audit — `benches/des_scale.rs` asserts a
    /// bytes-per-worker ceiling on top of it.
    pub fn state_bytes(&self) -> usize {
        let mut bytes = std::mem::size_of::<Self>();
        bytes += self.workers.capacity() * std::mem::size_of::<WorkerState>();
        for ws in &self.workers {
            if let Some(x) = ws.x.hot() {
                bytes += x.len() * 4;
            }
            bytes += ws.core.state_bytes();
            bytes += ws.mailbox.capacity() * std::mem::size_of::<(Shard, EncodedPayload, f64)>();
            for (_, payload, _) in &ws.mailbox {
                bytes += payload.payload_wire_bytes();
            }
        }
        bytes += (self.master.len() + self.grad_buf.len() + self.cold.len()) * 4;
        bytes += self.barrier_arrivals.capacity() * 8;
        if let Some(sym) = &self.sym {
            bytes += (sym.busy_until.capacity() + sym.pending_delay.capacity()) * 8;
        }
        if let Some(churn) = &self.churn {
            // BTree nodes: ~3 words of overhead per entry is a fair
            // estimate for the audit's purposes.
            let per_entry = 48;
            bytes += (churn.down.len() + churn.epochs.len() + churn.down_since.len()) * per_entry;
        }
        bytes += self.report.trace.capacity() * 16;
        bytes += self.events.approx_bytes();
        bytes += self.mail_scratch.capacity() * std::mem::size_of::<(Shard, EncodedPayload, f64)>();
        bytes
    }

    pub fn report(&self) -> &DesReport {
        &self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::grad::QuadraticSource;

    fn run(strategy: DesStrategy, horizon: f64, seed: u64) -> (DesReport, FlatVec) {
        let dim = 32;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            strategy,
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap();
        eng.run(&mut grad, horizon).unwrap();
        let model = eng.consensus_model().unwrap();
        (std::mem::take(&mut eng.report), model)
    }

    fn run_scenario(
        strategy: DesStrategy,
        scenario: ScenarioModel,
        horizon: f64,
        seed: u64,
    ) -> DesEngine {
        let dim = 32;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            strategy,
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap()
        .with_scenario(scenario);
        eng.run(&mut grad, horizon).unwrap();
        eng
    }

    #[test]
    fn gosgd_never_blocks() {
        let (rep, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 1);
        assert_eq!(rep.blocked_secs, 0.0);
        assert!(rep.messages > 0);
        // 8 workers, ~0.1 s/step, 30 s -> ~2400 steps
        assert!(rep.steps > 2000, "{}", rep.steps);
    }

    #[test]
    fn easgd_blocks_and_loses_throughput() {
        let (gossip, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 2);
        let (easgd, _) = run(
            DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 10 },
            30.0,
            2,
        );
        assert!(easgd.blocked_secs > 0.0);
        assert!(
            easgd.steps < gossip.steps,
            "easgd {} vs gossip {}",
            easgd.steps,
            gossip.steps
        );
    }

    #[test]
    fn sync_strategies_block_gossip_does_not() {
        let (easgd, _) = run(DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 10 }, 30.0, 3);
        let (persyn, _) = run(DesStrategy::PerSyn { tau: 10 }, 30.0, 3);
        let (gossip, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 3);
        assert!(easgd.blocked_secs > 1.0, "easgd blocked {}", easgd.blocked_secs);
        assert!(persyn.blocked_secs > 1.0, "persyn blocked {}", persyn.blocked_secs);
        assert_eq!(gossip.blocked_secs, 0.0);
    }

    #[test]
    fn all_strategies_descend_in_sim_time() {
        for s in [
            DesStrategy::GoSgd { p: 0.05 },
            DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 20 },
            DesStrategy::PerSyn { tau: 20 },
            DesStrategy::Local,
        ] {
            let name = s.name();
            let (rep, _) = run(s, 60.0, 4);
            let early: f64 =
                rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
            let n = rep.trace.len();
            let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
            assert!(late < early * 0.7, "{name}: {early} -> {late}");
        }
    }

    #[test]
    fn trace_times_are_monotone() {
        let (rep, _) = run(DesStrategy::GoSgd { p: 0.2 }, 10.0, 5);
        for pair in rep.trace.windows(2) {
            assert!(pair[0].0 <= pair[1].0);
        }
        assert!(rep.end_time <= 10.0 + 1e-9);
    }

    #[test]
    fn symmetric_gossip_pays_blocking_asymmetric_does_not() {
        // The paper's section-4 design argument, quantified: at the same
        // exchange rate the symmetric variant blocks (rendezvous + two-way
        // handshake) while GoSGD never does, so GoSGD sustains more steps.
        let (asym, _) = run(DesStrategy::GoSgd { p: 0.3 }, 40.0, 21);
        let (sym, _) = run(DesStrategy::SymmetricGossip { p: 0.3 }, 40.0, 21);
        assert_eq!(asym.blocked_secs, 0.0);
        assert!(sym.blocked_secs > 1.0, "sym blocked {}", sym.blocked_secs);
        assert!(
            asym.steps as f64 > sym.steps as f64 * 1.05,
            "asym {} vs sym {}",
            asym.steps,
            sym.steps
        );
    }

    #[test]
    fn sharded_gossip_never_blocks_and_ships_fewer_bytes() {
        let (full, _) = run(DesStrategy::GoSgd { p: 0.2 }, 30.0, 6);
        let (sharded, _) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 30.0, 6);
        assert_eq!(sharded.blocked_secs, 0.0, "sharded gossip is still fire-and-forget");
        assert!(sharded.messages > 0);
        let full_per_msg = full.bytes as f64 / full.messages as f64;
        let sharded_per_msg = sharded.bytes as f64 / sharded.messages as f64;
        let ratio = sharded_per_msg / full_per_msg;
        // dim 32, 4 shards: (8*4 + 32) / (32*4 + 24) = 0.42 with headers.
        assert!(
            ratio < 0.5,
            "bytes/msg ratio {ratio} (full {full_per_msg}, sharded {sharded_per_msg})"
        );
    }

    #[test]
    fn sharded_gossip_still_descends() {
        let (rep, _) = run(DesStrategy::ShardedGoSgd { p: 0.1, shards: 4 }, 60.0, 8);
        let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
        let n = rep.trace.len();
        let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
        assert!(late < early * 0.7, "{early} -> {late}");
    }

    #[test]
    fn oversized_or_zero_shard_count_is_a_config_error() {
        let init = FlatVec::zeros(16);
        for shards in [0usize, 64] {
            let r = DesEngine::new(
                DesStrategy::ShardedGoSgd { p: 0.1, shards },
                TimeModel::paper_like(),
                4,
                &init,
                1.0,
                0.0,
                1,
            );
            assert!(r.is_err(), "shards = {shards} must be rejected");
        }
    }

    #[test]
    fn sharded_deterministic_given_seed() {
        let (a, ma) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 15.0, 12);
        let (b, mb) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 15.0, 12);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(ma.as_slice(), mb.as_slice());
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, ma) = run(DesStrategy::GoSgd { p: 0.1 }, 15.0, 9);
        let (b, mb) = run(DesStrategy::GoSgd { p: 0.1 }, 15.0, 9);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.messages, b.messages);
        assert_eq!(ma.as_slice(), mb.as_slice());
    }

    #[test]
    fn persyn_workers_all_park_and_release() {
        // With tau=5 over a long horizon, steps must be shared evenly:
        // the barrier forces lockstep progress.
        let (rep, _) = run(DesStrategy::PerSyn { tau: 5 }, 40.0, 11);
        assert!(rep.steps > 0);
        // Every completed barrier costs exactly 2M = 16 messages, so the
        // total must be a multiple of 16.
        assert_eq!(rep.messages % 16, 0);
    }

    // ---- scenario diversity: heterogeneous compute + churn -------------

    #[test]
    fn hetero_compute_slows_the_scaled_worker_only() {
        let scenario = ScenarioModel {
            compute_scale: vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 4.0],
            ..ScenarioModel::none()
        };
        let eng = run_scenario(DesStrategy::GoSgd { p: 0.1 }, scenario, 40.0, 31);
        let steps = eng.worker_steps();
        // The 4× straggler takes ~1/4 the steps of a normal worker; gossip
        // never blocks, so the fast workers are unaffected.
        assert!(
            (steps[7] as f64) < steps[0] as f64 * 0.5,
            "straggler {} vs fast {}",
            steps[7],
            steps[0]
        );
        assert_eq!(eng.report().blocked_secs, 0.0);
    }

    #[test]
    fn hetero_hurts_barrier_strategies_more_than_gossip() {
        let hetero = || ScenarioModel {
            compute_scale: vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 4.0],
            ..ScenarioModel::none()
        };
        let persyn_uniform = {
            let (rep, _) = run(DesStrategy::PerSyn { tau: 10 }, 40.0, 33);
            rep.blocked_secs
        };
        let persyn_hetero = run_scenario(DesStrategy::PerSyn { tau: 10 }, hetero(), 40.0, 33)
            .report()
            .blocked_secs;
        // Every barrier now waits for the persistent straggler.
        assert!(
            persyn_hetero > persyn_uniform * 1.5,
            "hetero blocked {persyn_hetero} vs uniform {persyn_uniform}"
        );
    }

    #[test]
    fn churn_crashes_rejoin_and_conserve_mass_per_shard() {
        let scenario = ScenarioModel {
            compute_scale: Vec::new(),
            crash_mtbf: 6.0,
            rejoin_mttr: 2.0,
        };
        let shards = 4;
        let eng = run_scenario(
            DesStrategy::ShardedGoSgd { p: 0.3, shards },
            scenario,
            60.0,
            35,
        );
        let rep = eng.report();
        assert!(rep.crashes > 0, "expected crashes over a 60 s horizon");
        assert!(rep.downtime_secs > 0.0);
        assert!(rep.steps > 0);
        // Per-shard conservation including every in-flight location:
        // worker cores + mailboxes + undelivered Deliver events.
        let mut totals = eng.pending_shard_mass();
        assert_eq!(totals.len(), shards);
        for ws in eng.worker_weights() {
            for (k, v) in ws.iter().enumerate() {
                totals[k] += v;
            }
        }
        for (k, total) in totals.iter().enumerate() {
            assert!((total - 1.0).abs() < 1e-9, "shard {k} mass {total}");
        }
    }

    #[test]
    fn churn_reduces_throughput_but_training_continues() {
        let uniform = run_scenario(
            DesStrategy::GoSgd { p: 0.1 },
            ScenarioModel::none(),
            60.0,
            37,
        );
        let churned = run_scenario(
            DesStrategy::GoSgd { p: 0.1 },
            ScenarioModel { compute_scale: Vec::new(), crash_mtbf: 8.0, rejoin_mttr: 4.0 },
            60.0,
            37,
        );
        assert!(churned.report().steps < uniform.report().steps);
        // Loss still descends through the crashes.
        let rep = churned.report();
        let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
        let n = rep.trace.len();
        let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
        assert!(late < early * 0.7, "{early} -> {late}");
    }

    #[test]
    fn churn_with_barrier_strategy_is_a_config_error() {
        let dim = 16;
        let mut grad = QuadraticSource::new(dim, 0.1, 1);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::PerSyn { tau: 5 },
            TimeModel::paper_like(),
            4,
            &init,
            1.0,
            0.0,
            1,
        )
        .unwrap()
        .with_scenario(ScenarioModel {
            compute_scale: Vec::new(),
            crash_mtbf: 5.0,
            rejoin_mttr: 1.0,
        });
        assert!(eng.run(&mut grad, 10.0).is_err());
    }

    #[test]
    fn bad_compute_scale_is_a_config_error() {
        let dim = 16;
        let mut grad = QuadraticSource::new(dim, 0.1, 1);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::GoSgd { p: 0.1 },
            TimeModel::paper_like(),
            4,
            &init,
            1.0,
            0.0,
            1,
        )
        .unwrap()
        .with_scenario(ScenarioModel {
            compute_scale: vec![1.0, 0.0],
            ..ScenarioModel::none()
        });
        assert!(eng.run(&mut grad, 10.0).is_err());
    }

    // ---- payload codecs under simulated time ---------------------------

    fn run_codec(codec: CodecSpec, horizon: f64, seed: u64) -> DesEngine {
        let dim = 2048;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 },
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap()
        .with_codec(codec);
        eng.run(&mut grad, horizon).unwrap();
        eng
    }

    #[test]
    fn q8_codec_compresses_bytes_and_latency_in_sim() {
        let dense = run_codec(CodecSpec::Dense, 30.0, 61);
        let q8 = run_codec(CodecSpec::QuantizeU8, 30.0, 61);
        assert_eq!(dense.report().bytes, dense.report().raw_bytes);
        let q8_rep = q8.report();
        assert!(q8_rep.messages > 0);
        assert!(
            q8_rep.raw_bytes >= 3 * q8_rep.bytes,
            "encoded {} vs raw {}",
            q8_rep.bytes,
            q8_rep.raw_bytes
        );
        // Fire-and-forget is untouched by the codec.
        assert_eq!(q8_rep.blocked_secs, 0.0);
        // Training still descends through the quantized exchanges.
        let early: f64 = q8_rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
        let n = q8_rep.trace.len();
        let late: f64 = q8_rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
        assert!(late < early * 0.7, "{early} -> {late}");
    }

    #[test]
    fn codec_runs_conserve_mass_per_shard_in_sim() {
        for codec in [CodecSpec::QuantizeU8, CodecSpec::TopK { k: 64 }] {
            let eng = run_codec(codec, 20.0, 63);
            let mut totals = eng.pending_shard_mass();
            assert_eq!(totals.len(), 4);
            for ws in eng.worker_weights() {
                for (k, v) in ws.iter().enumerate() {
                    totals[k] += v;
                }
            }
            for (k, total) in totals.iter().enumerate() {
                assert!(
                    (total - 1.0).abs() < 1e-9,
                    "codec {codec:?}: shard {k} mass {total}"
                );
            }
        }
    }

    #[test]
    fn codec_deterministic_given_seed() {
        let a = run_codec(CodecSpec::QuantizeU8, 15.0, 67);
        let b = run_codec(CodecSpec::QuantizeU8, 15.0, 67);
        assert_eq!(a.report().steps, b.report().steps);
        assert_eq!(a.report().bytes, b.report().bytes);
        assert_eq!(
            a.consensus_model().unwrap().as_slice(),
            b.consensus_model().unwrap().as_slice()
        );
    }

    // ---- gossip topologies under simulated time ------------------------

    fn run_topo(
        topology: TopologySpec,
        scenario: ScenarioModel,
        horizon: f64,
        seed: u64,
    ) -> DesEngine {
        let dim = 32;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::ShardedGoSgd { p: 0.3, shards: 4 },
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap()
        .with_scenario(scenario)
        .with_topology(topology);
        eng.run(&mut grad, horizon).unwrap();
        eng
    }

    #[test]
    fn structured_topologies_descend_and_never_block() {
        for topology in [
            TopologySpec::Ring,
            TopologySpec::Hypercube, // 8 workers: a 3-cube
            TopologySpec::PartnerRotation,
        ] {
            let eng = run_topo(topology, ScenarioModel::none(), 60.0, 81);
            let rep = eng.report();
            assert_eq!(rep.blocked_secs, 0.0, "{topology:?} must stay fire-and-forget");
            assert!(rep.messages > 0);
            let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
            let n = rep.trace.len();
            let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
            assert!(late < early * 0.7, "{topology:?}: {early} -> {late}");
        }
    }

    #[test]
    fn churn_with_rotation_topology_repairs_and_conserves_mass() {
        // Crashes remove workers from the schedule; the rotation repairs
        // around them (next alive peer) and per-shard mass — including
        // mailboxes and in-flight deliveries — stays exactly 1.
        let scenario = ScenarioModel {
            compute_scale: Vec::new(),
            crash_mtbf: 6.0,
            rejoin_mttr: 2.0,
        };
        let shards = 4;
        let eng = run_topo(TopologySpec::PartnerRotation, scenario, 60.0, 83);
        let rep = eng.report();
        assert!(rep.crashes > 0, "expected crashes over a 60 s horizon");
        assert!(rep.steps > 0);
        let mut totals = eng.pending_shard_mass();
        assert_eq!(totals.len(), shards);
        for ws in eng.worker_weights() {
            for (k, v) in ws.iter().enumerate() {
                totals[k] += v;
            }
        }
        for (k, total) in totals.iter().enumerate() {
            assert!((total - 1.0).abs() < 1e-9, "shard {k} mass {total}");
        }
        // Training continues through the repaired schedule.
        let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
        let n = rep.trace.len();
        let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
        assert!(late < early * 0.7, "{early} -> {late}");
    }

    #[test]
    fn topology_deterministic_given_seed() {
        let a = run_topo(TopologySpec::Hypercube, ScenarioModel::none(), 15.0, 85);
        let b = run_topo(TopologySpec::Hypercube, ScenarioModel::none(), 15.0, 85);
        assert_eq!(a.report().steps, b.report().steps);
        assert_eq!(a.report().messages, b.report().messages);
        assert_eq!(
            a.consensus_model().unwrap().as_slice(),
            b.consensus_model().unwrap().as_slice()
        );
    }

    #[test]
    fn hypercube_with_wrong_fleet_size_is_a_config_error() {
        let dim = 16;
        let mut grad = QuadraticSource::new(dim, 0.1, 1);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::GoSgd { p: 0.1 },
            TimeModel::paper_like(),
            6, // not a power of two
            &init,
            1.0,
            0.0,
            1,
        )
        .unwrap()
        .with_topology(TopologySpec::Hypercube);
        let err = eng.run(&mut grad, 10.0).unwrap_err();
        assert!(err.to_string().contains("hypercube"), "{err}");
        // A rejected topology keeps rejecting on a retried run.
        assert!(eng.run(&mut grad, 10.0).is_err());
    }

    // ---- finite-bandwidth fabric under simulated time -------------------

    fn run_fabric(spec: FabricSpec, horizon: f64, seed: u64) -> DesEngine {
        let dim = 64;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::ShardedGoSgd { p: 0.3, shards: 4 },
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap()
        .with_fabric(spec);
        eng.run(&mut grad, horizon).unwrap();
        eng
    }

    #[test]
    fn finite_fabric_conserves_mass_and_descends() {
        for spec in [FabricSpec::Rack, FabricSpec::Wan, FabricSpec::Edge] {
            let eng = run_fabric(spec, 40.0, 91);
            let rep = eng.report();
            assert!(rep.messages > 0, "{}", spec.label());
            assert_eq!(rep.blocked_secs, 0.0, "fabric queueing is not blocking");
            // Core + in-flight (mailboxes, heap, fabric) mass ≡ 1/shard.
            let mut totals = eng.pending_shard_mass();
            for ws in eng.worker_weights() {
                for (k, v) in ws.iter().enumerate() {
                    totals[k] += v;
                }
            }
            for (k, total) in totals.iter().enumerate() {
                assert!(
                    (total - 1.0).abs() < 1e-9,
                    "{}: shard {k} mass {total}",
                    spec.label()
                );
            }
            let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
            let n = rep.trace.len();
            let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
            assert!(late < early * 0.7, "{}: {early} -> {late}", spec.label());
        }
    }

    #[test]
    fn ideal_fabric_spec_is_identical_to_default() {
        let dim = 32;
        let mut results = Vec::new();
        for explicit in [false, true] {
            let mut grad = QuadraticSource::new(dim, 0.1, 93);
            let init = FlatVec::zeros(dim);
            let mut eng = DesEngine::new(
                DesStrategy::GoSgd { p: 0.2 },
                TimeModel::paper_like(),
                8,
                &init,
                1.0,
                0.0,
                93 ^ 0xD5,
            )
            .unwrap();
            if explicit {
                eng = eng.with_fabric(FabricSpec::Ideal);
            }
            eng.run(&mut grad, 20.0).unwrap();
            assert!(eng.report().fabric.is_none(), "ideal = no fabric accounting");
            results.push((eng.report().trace_hash(), eng.consensus_model().unwrap()));
        }
        assert_eq!(results[0].0, results[1].0);
        assert_eq!(results[0].1.as_slice(), results[1].1.as_slice());
    }

    #[test]
    fn fabric_report_exposes_queueing_and_utilization_stats() {
        let eng = run_fabric(FabricSpec::Edge, 30.0, 95);
        let rep = eng.report();
        let stats = rep.fabric.as_ref().expect("finite fabric must report stats");
        assert_eq!(stats.injected, rep.messages);
        assert!(stats.delivered <= stats.injected);
        assert!(stats.delivered > 0);
        assert_eq!(stats.nic_busy_secs.len(), 8);
        let util = stats.nic_utilization(rep.end_time);
        assert!(util.iter().all(|u| (0.0..1.0).contains(u)), "{util:?}");
        assert!(stats.nic_busy_secs.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn fabric_deterministic_given_seed_including_jitter() {
        // Edge has an exponential-tail jitter on every link sample; the
        // full report must still be bit-identical across reruns.
        let a = run_fabric(FabricSpec::Edge, 20.0, 97);
        let b = run_fabric(FabricSpec::Edge, 20.0, 97);
        assert_eq!(a.report().trace_hash(), b.report().trace_hash());
        assert_eq!(
            a.consensus_model().unwrap().as_slice(),
            b.consensus_model().unwrap().as_slice()
        );
    }

    #[test]
    fn finite_fabric_with_barrier_strategy_is_a_config_error() {
        let dim = 16;
        let mut grad = QuadraticSource::new(dim, 0.1, 1);
        let init = FlatVec::zeros(dim);
        for strategy in [
            DesStrategy::PerSyn { tau: 5 },
            DesStrategy::Easgd { alpha: 0.1, tau: 5 },
            DesStrategy::SymmetricGossip { p: 0.1 },
        ] {
            let mut eng = DesEngine::new(
                strategy.clone(),
                TimeModel::paper_like(),
                4,
                &init,
                1.0,
                0.0,
                1,
            )
            .unwrap()
            .with_fabric(FabricSpec::Rack);
            let err = eng.run(&mut grad, 10.0).unwrap_err();
            assert!(
                err.to_string().contains("config"),
                "{}: {err}",
                strategy.name()
            );
        }
    }

    #[test]
    fn fabric_resume_across_horizons_matches_single_run() {
        // The fabric tick must survive a horizon pause: running 10 s then
        // resuming to 30 s lands on the same final state as one 30 s run.
        let whole = run_fabric(FabricSpec::Rack, 30.0, 99);
        let dim = 64;
        let mut grad = QuadraticSource::new(dim, 0.1, 99);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            DesStrategy::ShardedGoSgd { p: 0.3, shards: 4 },
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            99 ^ 0xD5,
        )
        .unwrap()
        .with_fabric(FabricSpec::Rack);
        eng.run(&mut grad, 10.0).unwrap();
        eng.run(&mut grad, 30.0).unwrap();
        assert_eq!(eng.report().steps, whole.report().steps);
        assert_eq!(eng.report().messages, whole.report().messages);
        assert_eq!(
            eng.consensus_model().unwrap().as_slice(),
            whole.consensus_model().unwrap().as_slice()
        );
    }

    // ---- million-worker scaling machinery --------------------------------

    #[test]
    fn heap_scheduler_is_bit_identical_to_the_default_wheel() {
        let dim = 48;
        let mut results = Vec::new();
        for kind in [SchedulerKind::Wheel, SchedulerKind::Heap] {
            let mut grad = QuadraticSource::new(dim, 0.1, 113);
            let init = FlatVec::zeros(dim);
            let mut eng = DesEngine::new(
                DesStrategy::ShardedGoSgd { p: 0.3, shards: 4 },
                TimeModel::paper_like(),
                8,
                &init,
                1.0,
                0.0,
                113 ^ 0xD5,
            )
            .unwrap()
            .with_scheduler(kind)
            .with_scenario(ScenarioModel {
                compute_scale: Vec::new(),
                crash_mtbf: 8.0,
                rejoin_mttr: 2.0,
            });
            eng.run(&mut grad, 40.0).unwrap();
            results.push((eng.report().trace_hash(), eng.consensus_model().unwrap()));
        }
        assert_eq!(results[0].0, results[1].0, "trace hash must not depend on the scheduler");
        assert_eq!(results[0].1.as_slice(), results[1].1.as_slice());
    }

    #[test]
    fn workers_stay_cold_until_their_first_wake() {
        let dim = 32;
        let init = FlatVec::zeros(dim);
        let mut grad = QuadraticSource::new(dim, 0.1, 117);
        let mut eng = DesEngine::new(
            DesStrategy::GoSgd { p: 0.1 },
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            117,
        )
        .unwrap();
        // start() only lays down wakes strictly after t = 0: running to a
        // zero horizon starts the engine without materializing anyone.
        eng.run(&mut grad, 0.0).unwrap();
        assert_eq!(eng.cold_workers(), 8, "no worker may materialize before its first wake");
        // After a real horizon every worker has stepped, so all are hot —
        // and the consensus path reads hot and cold workers uniformly.
        eng.run(&mut grad, 5.0).unwrap();
        assert_eq!(eng.cold_workers(), 0);
        assert!(eng.state_bytes() > 0);
    }

    #[test]
    fn telemetry_sampling_thins_the_trace_but_not_the_steps() {
        let dim = 32;
        let init = FlatVec::zeros(dim);
        let run_sampled = |samples: Option<usize>| {
            let mut grad = QuadraticSource::new(dim, 0.1, 119);
            let mut eng = DesEngine::new(
                DesStrategy::GoSgd { p: 0.1 },
                TimeModel::paper_like(),
                8,
                &init,
                1.0,
                0.0,
                119,
            )
            .unwrap();
            if let Some(s) = samples {
                eng = eng.with_telemetry_sample(s);
            }
            eng.run(&mut grad, 20.0).unwrap();
            (eng.report().steps, eng.report().trace.len())
        };
        let (full_steps, full_trace) = run_sampled(None);
        let (sampled_steps, sampled_trace) = run_sampled(Some(2));
        // Same simulation — sampling only filters which wakes get traced.
        assert_eq!(full_steps, sampled_steps);
        assert!(
            sampled_trace * 3 < full_trace,
            "stride 4 must thin the trace: {sampled_trace} vs {full_trace}"
        );
        assert!(sampled_trace > 0);
    }
}
