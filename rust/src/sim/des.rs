//! Discrete-event engine for wall-clock experiments (paper Fig. 2).
//!
//! Time is simulated; gradients are real.  Every worker alternates
//! compute and (strategy-dependent) communication; the event queue orders
//! everything by simulated seconds.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::error::Result;
use crate::gossip::{wire_bytes_for, Shard, ShardPlan, SumWeight};
use crate::strategies::grad::GradSource;
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// Cluster timing parameters (seconds).
#[derive(Clone, Debug)]
pub struct TimeModel {
    /// Mean gradient-step compute time per worker.
    pub compute: f64,
    /// Uniform jitter fraction on compute time (`±compute_jitter`).
    pub compute_jitter: f64,
    /// Probability a step hits a straggler event (OS jitter, allocator,
    /// ECC scrub, …) and takes `straggler_factor × compute` extra.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
    /// One-way network latency for a parameter message.
    pub latency: f64,
    /// Uniform jitter fraction on latency.
    pub latency_jitter: f64,
    /// Master service time per sync request (serialization point).
    pub master_service: f64,
}

impl TimeModel {
    /// Calibration used by the Fig. 2 harness, set to GPU-era ratios for
    /// the paper's CNN (~1.7M params ≈ 7 MB messages): a gradient step ≈
    /// 100 ms; shipping a model one-way ≈ 50 ms; master combine ≈ 20 ms
    /// per worker; a 5% heavy-tail straggler on compute (the cost global
    /// barriers actually pay in practice).
    pub fn paper_like() -> Self {
        TimeModel {
            compute: 0.100,
            compute_jitter: 0.15,
            straggler_prob: 0.05,
            straggler_factor: 3.0,
            latency: 0.050,
            latency_jitter: 0.25,
            master_service: 0.020,
        }
    }

    fn draw_compute(&self, rng: &mut Rng) -> f64 {
        let base = self.compute * (1.0 + self.compute_jitter * (2.0 * rng.f64() - 1.0));
        if rng.bernoulli(self.straggler_prob) {
            base + self.straggler_factor * self.compute
        } else {
            base
        }
    }

    fn draw_latency(&self, rng: &mut Rng) -> f64 {
        self.latency * (1.0 + self.latency_jitter * (2.0 * rng.f64() - 1.0))
    }
}

/// Strategy semantics under simulated time.
#[derive(Clone, Debug)]
pub enum DesStrategy {
    GoSgd { p: f64 },
    /// Sharded GoSGD: each exchange ships one round-robin shard of the
    /// vector with its shard-local sum weight (see
    /// [`crate::gossip::shard`]).  Message latency scales with the payload
    /// fraction (the [`TimeModel::latency`] is bandwidth-dominated at
    /// paper-scale messages), so sharding directly cuts per-event latency
    /// and bytes.
    ShardedGoSgd { p: f64, shards: usize },
    /// Ablation (paper section 4, third paragraph): *symmetric* gossip —
    /// sender and receiver rendezvous and swap, so the sender blocks until
    /// the receiver is free.  The paper rejects this design because "local
    /// blocking waits can cause global synchronization issues"; this
    /// variant quantifies the cost it avoids.
    SymmetricGossip { p: f64 },
    Easgd { alpha: f64, tau: u64 },
    PerSyn { tau: u64 },
    Local,
}

impl DesStrategy {
    pub fn name(&self) -> String {
        match self {
            DesStrategy::GoSgd { p } => format!("gosgd(p={p})"),
            DesStrategy::ShardedGoSgd { p, shards } => {
                format!("gosgd(p={p},shards={shards})")
            }
            DesStrategy::SymmetricGossip { p } => format!("symgossip(p={p})"),
            DesStrategy::Easgd { alpha, tau } => format!("easgd(alpha={alpha:.3},tau={tau})"),
            DesStrategy::PerSyn { tau } => format!("persyn(tau={tau})"),
            DesStrategy::Local => "local".into(),
        }
    }
}

/// Priority-queue event.
#[derive(Debug)]
enum EventKind {
    /// Worker finished a compute step (or resumed from a block).
    Wake(usize),
    /// A gossip message lands in worker `to`'s mailbox; `shard` records
    /// which slice of the vector `params` covers.
    Deliver { to: usize, params: FlatVec, weight: f64, shard: Shard },
}

struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; seq breaks ties deterministically
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// A `(sim_time_seconds, loss)` training trace plus accounting.
#[derive(Debug, Default)]
pub struct DesReport {
    pub trace: Vec<(f64, f64)>,
    pub messages: u64,
    /// Wire bytes carried by gossip messages (sharded messages are
    /// proportionally smaller; barrier strategies count full models).
    pub bytes: u64,
    /// Total seconds workers spent blocked on synchronization.
    pub blocked_secs: f64,
    /// Total local gradient steps executed.
    pub steps: u64,
    /// Final simulated time.
    pub end_time: f64,
}

struct WorkerState {
    x: FlatVec,
    /// One sum weight per shard (a single entry when unsharded).
    weights: Vec<SumWeight>,
    mailbox: Vec<(Shard, FlatVec, f64)>,
    local_step: u64,
    /// PerSyn: parked at the barrier.
    at_barrier: bool,
}

/// The discrete-event engine.
pub struct DesEngine {
    strategy: DesStrategy,
    time_model: TimeModel,
    workers: Vec<WorkerState>,
    master: FlatVec,

    /// PerSyn/EASGD barrier bookkeeping.
    barrier_arrivals: Vec<f64>,
    /// Symmetric gossip: when each worker's current compute finishes
    /// (earliest rendezvous point) and handshake delays owed at next wake.
    busy_until: Vec<f64>,
    pending_delay: Vec<f64>,
    /// Sharded gossip: the vector partition and per-worker round-robin
    /// cursors (plan has one shard when unsharded).
    plan: ShardPlan,
    next_shard: Vec<usize>,
    events: BinaryHeap<Event>,
    seq: u64,
    eta: f32,
    weight_decay: f32,
    rng: Rng,
    grad_buf: FlatVec,
    report: DesReport,
}

impl DesEngine {
    /// Build the engine.  Fails with a config error (rather than
    /// panicking) when a sharded strategy's shard count is 0 or exceeds
    /// the model dimension — the two places where user input meets the
    /// dimension for the first time.
    pub fn new(
        strategy: DesStrategy,
        time_model: TimeModel,
        workers: usize,
        init: &FlatVec,
        eta: f32,
        weight_decay: f32,
        seed: u64,
    ) -> Result<Self> {
        assert!(workers >= 2);
        let shards = match &strategy {
            DesStrategy::ShardedGoSgd { shards, .. } => {
                if *shards == 0 {
                    return Err(crate::error::Error::config("shards must be >= 1"));
                }
                if *shards > init.len() {
                    return Err(crate::error::Error::config(format!(
                        "cannot cut {} parameters into {shards} shards",
                        init.len()
                    )));
                }
                *shards
            }
            _ => 1,
        };
        let plan = ShardPlan::new(init.len(), shards);
        let ws = (0..workers)
            .map(|_| WorkerState {
                x: init.clone(),
                weights: (0..shards).map(|_| SumWeight::init(workers)).collect(),
                mailbox: Vec::new(),
                local_step: 0,
                at_barrier: false,
            })
            .collect();
        let mut eng = DesEngine {
            strategy,
            time_model,
            workers: ws,
            master: init.clone(),
            barrier_arrivals: Vec::new(),
            busy_until: vec![0.0; workers],
            pending_delay: vec![0.0; workers],
            plan,
            next_shard: (0..workers).map(|w| w % shards).collect(),
            events: BinaryHeap::new(),
            seq: 0,
            eta,
            weight_decay,
            rng: Rng::new(seed),
            grad_buf: FlatVec::zeros(init.len()),
            report: DesReport::default(),
        };
        // Stagger initial wakes slightly so workers don't tick in lockstep.
        for w in 0..workers {
            let dt = eng.time_model.draw_compute(&mut eng.rng);
            eng.schedule(dt, EventKind::Wake(w));
        }
        Ok(eng)
    }

    fn schedule(&mut self, at: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Event { time: at, seq: self.seq, kind });
    }

    /// Run until simulated `horizon` seconds (or the event queue drains).
    pub fn run(&mut self, grad: &mut dyn GradSource, horizon: f64) -> Result<&DesReport> {
        while let Some(ev) = self.events.pop() {
            if ev.time > horizon {
                self.report.end_time = horizon;
                break;
            }
            self.report.end_time = ev.time;
            match ev.kind {
                EventKind::Deliver { to, params, weight, shard } => {
                    self.workers[to].mailbox.push((shard, params, weight));
                }
                EventKind::Wake(w) => self.wake(w, ev.time, grad)?,
            }
        }
        Ok(&self.report)
    }

    fn wake(&mut self, w: usize, now: f64, grad: &mut dyn GradSource) -> Result<()> {
        // 0. Pay any handshake delay owed from a symmetric rendezvous the
        //    worker was dragged into while computing.
        if self.pending_delay[w] > 0.0 {
            let d = std::mem::take(&mut self.pending_delay[w]);
            self.report.blocked_secs += d;
            self.busy_until[w] = now + d;
            self.schedule(now + d, EventKind::Wake(w));
            return Ok(());
        }
        // 1. Process pending messages (GoSGD ProcessMessages): each blends
        //    its shard range against that shard's sum weight.
        let pending = std::mem::take(&mut self.workers[w].mailbox);
        for (shard, params, weight) in pending {
            let t =
                self.workers[w].weights[shard.index].absorb(SumWeight::from_value(weight));
            if shard.is_full() {
                self.workers[w].x.mix_from(&params, 1.0 - t, t)?;
            } else {
                self.workers[w].x.mix_range_from(&params, shard.offset, 1.0 - t, t)?;
            }
        }

        // 2. Local gradient step.
        let step = self.workers[w].local_step;
        let loss = grad.grad(w + 1, &self.workers[w].x, step, &mut self.grad_buf)?;
        self.workers[w]
            .x
            .sgd_step(&self.grad_buf, self.eta, self.weight_decay)?;
        self.workers[w].local_step += 1;
        self.report.steps += 1;
        self.report.trace.push((now, loss));

        // 3. Strategy-specific communication + next wake.
        match self.strategy.clone() {
            DesStrategy::Local => {
                let dt = self.time_model.draw_compute(&mut self.rng);
                self.schedule(now + dt, EventKind::Wake(w));
            }
            DesStrategy::GoSgd { p } => {
                if self.rng.bernoulli(p) {
                    let m = self.workers.len();
                    let r = self.rng.peer(m, w);
                    let shipped = self.workers[w].weights[0].halve_for_send();
                    let latency = self.time_model.draw_latency(&mut self.rng);
                    let params = self.workers[w].x.clone();
                    let shard = Shard::full(params.len());
                    self.report.messages += 1;
                    self.report.bytes += wire_bytes_for(params.len(), false) as u64;
                    self.schedule(
                        now + latency,
                        EventKind::Deliver { to: r, params, weight: shipped.value(), shard },
                    );
                }
                // Fire-and-forget: compute continues immediately.
                let dt = self.time_model.draw_compute(&mut self.rng);
                self.busy_until[w] = now + dt;
                self.schedule(now + dt, EventKind::Wake(w));
            }
            DesStrategy::ShardedGoSgd { p, shards } => {
                if self.rng.bernoulli(p) {
                    let m = self.workers.len();
                    let r = self.rng.peer(m, w);
                    let shard = self.plan.shard(self.next_shard[w]);
                    self.next_shard[w] = (self.next_shard[w] + 1) % shards;
                    let shipped =
                        self.workers[w].weights[shard.index].halve_for_send();
                    // Bandwidth-dominated latency at paper-scale messages:
                    // shipping 1/shards of the vector takes ~1/shards of
                    // the one-way latency.
                    let dim = self.workers[w].x.len();
                    let frac = shard.len as f64 / dim as f64;
                    let latency = self.time_model.draw_latency(&mut self.rng) * frac;
                    let params = FlatVec::from_vec(
                        self.workers[w].x.as_slice()[shard.offset..shard.offset + shard.len]
                            .to_vec(),
                    );
                    self.report.messages += 1;
                    self.report.bytes += wire_bytes_for(shard.len, true) as u64;
                    self.schedule(
                        now + latency,
                        EventKind::Deliver { to: r, params, weight: shipped.value(), shard },
                    );
                }
                // Fire-and-forget, exactly like unsharded GoSGD.
                let dt = self.time_model.draw_compute(&mut self.rng);
                self.busy_until[w] = now + dt;
                self.schedule(now + dt, EventKind::Wake(w));
            }
            DesStrategy::SymmetricGossip { p } => {
                let mut resume = now;
                if self.rng.bernoulli(p) {
                    let m = self.workers.len();
                    let r = self.rng.peer(m, w);
                    // Rendezvous: wait for r to finish its current step,
                    // then a two-way swap (2 messages, 2 latencies).
                    let wait = (self.busy_until[r] - now).max(0.0);
                    let lat = self.time_model.draw_latency(&mut self.rng)
                        + self.time_model.draw_latency(&mut self.rng);
                    // Pairwise average both models (symmetric exchange).
                    let xr = self.workers[r].x.clone();
                    self.workers[w].x.mix_from(&xr, 0.5, 0.5)?;
                    self.workers[r].x = self.workers[w].x.clone();
                    self.report.messages += 2;
                    self.report.bytes += 2 * wire_bytes_for(xr.len(), false) as u64;
                    // Sender blocks for the wait + handshake; receiver owes
                    // the handshake at its next wake.
                    self.report.blocked_secs += wait + lat;
                    self.pending_delay[r] += lat;
                    resume = now + wait + lat;
                }
                let dt = self.time_model.draw_compute(&mut self.rng);
                self.busy_until[w] = resume + dt;
                self.schedule(resume + dt, EventKind::Wake(w));
            }
            DesStrategy::Easgd { alpha, tau } => {
                if self.workers[w].local_step % tau == 0 {
                    // Paper section 3.2: "a global synchronization is still
                    // required as the master has to [combine] local models
                    // that have been updated the same number of times."
                    // Workers park at the barrier; when the last arrives,
                    // each ships its model (latency), the master services
                    // the elastic updates serially, then broadcasts back.
                    self.workers[w].at_barrier = true;
                    self.barrier_arrivals.push(now);
                    let m = self.workers.len();
                    if self.barrier_arrivals.len() == m {
                        let last = self
                            .barrier_arrivals
                            .iter()
                            .cloned()
                            .fold(0.0f64, f64::max);
                        let up = self.time_model.draw_latency(&mut self.rng);
                        let service = self.time_model.master_service * m as f64;
                        let down = self.time_model.draw_latency(&mut self.rng);
                        let resume = last + up + service + down;
                        // Elastic move (x̃ uses pre-sync worker states).
                        let a = alpha as f32;
                        let old_master = self.master.clone();
                        let mut sum_delta = FlatVec::zeros(old_master.len());
                        for ws in &self.workers {
                            let mut d = ws.x.clone();
                            d.axpy(-1.0, &old_master)?;
                            sum_delta.add_assign(&d)?;
                        }
                        self.master.axpy(a, &sum_delta)?;
                        for i in 0..m {
                            let xw = &mut self.workers[i].x;
                            xw.scale(1.0 - a);
                            xw.axpy(a, &old_master)?;
                            self.workers[i].at_barrier = false;
                        }
                        self.report.messages += 2 * m as u64;
                        self.report.bytes += 2 * m as u64 * wire_bytes_for(old_master.len(), false) as u64;
                        for arrival in self.barrier_arrivals.clone() {
                            self.report.blocked_secs += resume - arrival;
                        }
                        for i in 0..m {
                            let dt = self.time_model.draw_compute(&mut self.rng);
                            self.schedule(resume + dt, EventKind::Wake(i));
                        }
                        self.barrier_arrivals.clear();
                    }
                    // else: parked until the barrier releases
                } else {
                    let dt = self.time_model.draw_compute(&mut self.rng);
                    self.schedule(now + dt, EventKind::Wake(w));
                }
            }
            DesStrategy::PerSyn { tau } => {
                if self.workers[w].local_step % tau == 0 {
                    // Park at the barrier.
                    self.workers[w].at_barrier = true;
                    self.barrier_arrivals.push(now);
                    let m = self.workers.len();
                    if self.barrier_arrivals.len() == m {
                        // Everyone arrived: average, pay gather+broadcast.
                        let refs: Vec<&FlatVec> = self.workers.iter().map(|s| &s.x).collect();
                        let mean = FlatVec::mean_of(&refs)?;
                        let last = self
                            .barrier_arrivals
                            .iter()
                            .cloned()
                            .fold(0.0f64, f64::max);
                        let gather = self.time_model.draw_latency(&mut self.rng);
                        let service = self.time_model.master_service * m as f64;
                        let bcast = self.time_model.draw_latency(&mut self.rng);
                        let resume = last + gather + service + bcast;
                        self.report.messages += 2 * m as u64;
                        self.report.bytes += 2 * m as u64 * wire_bytes_for(mean.len(), false) as u64;
                        for (i, arrival) in self.barrier_arrivals.clone().iter().enumerate() {
                            self.report.blocked_secs += resume - arrival;
                            self.workers[i].x = mean.clone();
                            self.workers[i].at_barrier = false;
                            let dt = self.time_model.draw_compute(&mut self.rng);
                            self.schedule(resume + dt, EventKind::Wake(i));
                        }
                        self.master = mean;
                        self.barrier_arrivals.clear();
                    }
                    // else: stay parked (no wake scheduled until release)
                } else {
                    let dt = self.time_model.draw_compute(&mut self.rng);
                    self.schedule(now + dt, EventKind::Wake(w));
                }
            }
        }
        Ok(())
    }

    /// Mean worker model at the end of the run.
    pub fn consensus_model(&self) -> Result<FlatVec> {
        let refs: Vec<&FlatVec> = self.workers.iter().map(|s| &s.x).collect();
        FlatVec::mean_of(&refs)
    }

    pub fn report(&self) -> &DesReport {
        &self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::grad::QuadraticSource;

    fn run(strategy: DesStrategy, horizon: f64, seed: u64) -> (DesReport, FlatVec) {
        let dim = 32;
        let mut grad = QuadraticSource::new(dim, 0.1, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = DesEngine::new(
            strategy,
            TimeModel::paper_like(),
            8,
            &init,
            1.0,
            0.0,
            seed ^ 0xD5,
        )
        .unwrap();
        eng.run(&mut grad, horizon).unwrap();
        let model = eng.consensus_model().unwrap();
        (std::mem::take(&mut eng.report), model)
    }

    #[test]
    fn gosgd_never_blocks() {
        let (rep, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 1);
        assert_eq!(rep.blocked_secs, 0.0);
        assert!(rep.messages > 0);
        // 8 workers, ~0.1 s/step, 30 s -> ~2400 steps
        assert!(rep.steps > 2000, "{}", rep.steps);
    }

    #[test]
    fn easgd_blocks_and_loses_throughput() {
        let (gossip, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 2);
        let (easgd, _) = run(
            DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 10 },
            30.0,
            2,
        );
        assert!(easgd.blocked_secs > 0.0);
        assert!(
            easgd.steps < gossip.steps,
            "easgd {} vs gossip {}",
            easgd.steps,
            gossip.steps
        );
    }

    #[test]
    fn sync_strategies_block_gossip_does_not() {
        let (easgd, _) = run(DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 10 }, 30.0, 3);
        let (persyn, _) = run(DesStrategy::PerSyn { tau: 10 }, 30.0, 3);
        let (gossip, _) = run(DesStrategy::GoSgd { p: 0.1 }, 30.0, 3);
        assert!(easgd.blocked_secs > 1.0, "easgd blocked {}", easgd.blocked_secs);
        assert!(persyn.blocked_secs > 1.0, "persyn blocked {}", persyn.blocked_secs);
        assert_eq!(gossip.blocked_secs, 0.0);
    }

    #[test]
    fn all_strategies_descend_in_sim_time() {
        for s in [
            DesStrategy::GoSgd { p: 0.05 },
            DesStrategy::Easgd { alpha: 0.9 / 8.0, tau: 20 },
            DesStrategy::PerSyn { tau: 20 },
            DesStrategy::Local,
        ] {
            let name = s.name();
            let (rep, _) = run(s, 60.0, 4);
            let early: f64 =
                rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
            let n = rep.trace.len();
            let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
            assert!(late < early * 0.7, "{name}: {early} -> {late}");
        }
    }

    #[test]
    fn trace_times_are_monotone() {
        let (rep, _) = run(DesStrategy::GoSgd { p: 0.2 }, 10.0, 5);
        for pair in rep.trace.windows(2) {
            assert!(pair[0].0 <= pair[1].0);
        }
        assert!(rep.end_time <= 10.0 + 1e-9);
    }

    #[test]
    fn symmetric_gossip_pays_blocking_asymmetric_does_not() {
        // The paper's section-4 design argument, quantified: at the same
        // exchange rate the symmetric variant blocks (rendezvous + two-way
        // handshake) while GoSGD never does, so GoSGD sustains more steps.
        let (asym, _) = run(DesStrategy::GoSgd { p: 0.3 }, 40.0, 21);
        let (sym, _) = run(DesStrategy::SymmetricGossip { p: 0.3 }, 40.0, 21);
        assert_eq!(asym.blocked_secs, 0.0);
        assert!(sym.blocked_secs > 1.0, "sym blocked {}", sym.blocked_secs);
        assert!(
            asym.steps as f64 > sym.steps as f64 * 1.05,
            "asym {} vs sym {}",
            asym.steps,
            sym.steps
        );
    }

    #[test]
    fn sharded_gossip_never_blocks_and_ships_fewer_bytes() {
        let (full, _) = run(DesStrategy::GoSgd { p: 0.2 }, 30.0, 6);
        let (sharded, _) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 30.0, 6);
        assert_eq!(sharded.blocked_secs, 0.0, "sharded gossip is still fire-and-forget");
        assert!(sharded.messages > 0);
        let full_per_msg = full.bytes as f64 / full.messages as f64;
        let sharded_per_msg = sharded.bytes as f64 / sharded.messages as f64;
        let ratio = sharded_per_msg / full_per_msg;
        // dim 32, 4 shards: (8*4 + 32) / (32*4 + 24) = 0.42 with headers.
        assert!(
            ratio < 0.5,
            "bytes/msg ratio {ratio} (full {full_per_msg}, sharded {sharded_per_msg})"
        );
    }

    #[test]
    fn sharded_gossip_still_descends() {
        let (rep, _) = run(DesStrategy::ShardedGoSgd { p: 0.1, shards: 4 }, 60.0, 8);
        let early: f64 = rep.trace.iter().take(50).map(|(_, l)| l).sum::<f64>() / 50.0;
        let n = rep.trace.len();
        let late: f64 = rep.trace[n - 50..].iter().map(|(_, l)| l).sum::<f64>() / 50.0;
        assert!(late < early * 0.7, "{early} -> {late}");
    }

    #[test]
    fn oversized_or_zero_shard_count_is_a_config_error() {
        let init = FlatVec::zeros(16);
        for shards in [0usize, 64] {
            let r = DesEngine::new(
                DesStrategy::ShardedGoSgd { p: 0.1, shards },
                TimeModel::paper_like(),
                4,
                &init,
                1.0,
                0.0,
                1,
            );
            assert!(r.is_err(), "shards = {shards} must be rejected");
        }
    }

    #[test]
    fn sharded_deterministic_given_seed() {
        let (a, ma) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 15.0, 12);
        let (b, mb) = run(DesStrategy::ShardedGoSgd { p: 0.2, shards: 4 }, 15.0, 12);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(ma.as_slice(), mb.as_slice());
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, ma) = run(DesStrategy::GoSgd { p: 0.1 }, 15.0, 9);
        let (b, mb) = run(DesStrategy::GoSgd { p: 0.1 }, 15.0, 9);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.messages, b.messages);
        assert_eq!(ma.as_slice(), mb.as_slice());
    }

    #[test]
    fn persyn_workers_all_park_and_release() {
        // With tau=5 over a long horizon, steps must be shared evenly:
        // the barrier forces lockstep progress.
        let (rep, _) = run(DesStrategy::PerSyn { tau: 5 }, 40.0, 11);
        assert!(rep.steps > 0);
        // Every completed barrier costs exactly 2M = 16 messages, so the
        // total must be a multiple of 16.
        assert_eq!(rep.messages % 16, 0);
    }
}
