//! Finite-bandwidth network fabric for the discrete-event simulator.
//!
//! The pre-fabric DES charged a scalar latency per encoded byte — a pure
//! propagation model.  Real gossip fleets lose time to **contention at
//! shared resources** instead: a NIC serializes one message at a time, an
//! oversubscribed top-of-rack switch throttles aggregate throughput, and
//! queueing behind both dominates raw latency (GossipGraD, Daily et al.
//! 2018; Jin et al. 2016 make the same point for the gossip-vs-all-reduce
//! decision).  This module models that pipeline as a composable component
//! chain in the spirit of the STEAM simulator's clock/rate-limiter kit
//! (SNIPPETS.md §1):
//!
//! ```text
//!  sender NIC queue ──▶ up link ──▶ switch arbiter ──▶ down link ──▶ receiver NIC queue
//!  (serialize at      (delay +    (round-robin over   (delay +     (serialize at
//!   `bandwidth`,       jitter)     flows, aggregate    jitter)      `bandwidth`,
//!   FIFO per worker)               capacity =                       FIFO per worker)
//!                                  M·bw / oversub)
//! ```
//!
//! * **NIC serialization** — a message of `B` bytes occupies its worker's
//!   NIC for `B / bandwidth` seconds; a second send issued while the first
//!   is still transmitting queues behind it (FIFO per worker).
//! * **Links** — each NIC↔switch hop adds a propagation `delay`, jittered
//!   by an optional [`Jitter`] distribution.  Delivery is in-order per
//!   flow (a jitter draw can never reorder two messages on the same link),
//!   matching a reliable transport.
//! * **Switch arbiter** — a shared uplink of aggregate capacity
//!   `workers × bandwidth / oversub`.  Contending flows hold per-sender
//!   FIFO queues and are served **fair round-robin**: when a transfer
//!   completes, the arbiter resumes scanning from the flow after the one
//!   it last served.  `oversub = 1` is a non-blocking switch; `oversub =
//!   4` is the classic 4:1 oversubscribed ToR uplink.
//!
//! [`Fabric`] is generic over the payload it carries (`T`) and knows only
//! `(src, dst, bytes, time)` — the DES threads gossip payloads through it,
//! the invariants suite threads plain ids.  It advances on its own small
//! event heap: [`Fabric::inject`] enqueues a message,
//! [`Fabric::next_transition`] exposes the earliest pending internal hop,
//! and [`Fabric::advance_into`] processes every hop due by `now`,
//! yielding completed [`Delivery`]s.  Every random draw flows through the
//! caller's [`Draws`] source, so a seeded run is exactly reproducible.
//!
//! [`FabricSpec`] is the plain-data configuration surface (`--fabric` on
//! the CLI): the `ideal` scalar-latency model (byte-identical to the
//! pre-fabric DES) plus `rack` / `wan` / `edge` presets and a fully
//! custom form, with [`FabricSpec::parse`] rejecting nonsense (zero or
//! negative bandwidth, NaN delay, oversubscription below 1) the same way
//! [`PeerSelector::parse`](crate::gossip::PeerSelector::parse) does.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};
use std::ops::Bound;

use crate::error::{Error, Result};
use crate::util::rng::Draws;

/// Per-link latency jitter distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Jitter {
    /// Deterministic links: every delay sample equals the base delay.
    None,
    /// Multiplicative uniform jitter: `delay × (1 ± frac)`.
    Uniform { frac: f64 },
    /// Additive exponential tail with the given mean (seconds) on top of
    /// the base delay — the heavy-tailed WAN/edge shape.
    ExpTail { mean: f64 },
}

/// The finite-bandwidth fabric's knobs (all links share them; per-link
/// heterogeneity composes on top by splitting fleets, not needed yet).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricParams {
    /// Per-NIC line rate, bytes/second (paid once to serialize onto the
    /// up link and once to serialize into the receiver).
    pub bandwidth: f64,
    /// One-way propagation delay per link hop, seconds (paid on the up
    /// link and again on the down link).
    pub delay: f64,
    /// Jitter applied to every link-delay sample.
    pub jitter: Jitter,
    /// Switch oversubscription ratio (≥ 1): the shared uplink's aggregate
    /// capacity is `workers × bandwidth / oversub`.
    pub oversub: f64,
}

impl FabricParams {
    /// One jittered link-delay sample.  Public so the parallel DES can
    /// pre-draw a message's up-link jitter from the *sender's* stream at
    /// emit time ([`Fabric::inject_delayed`]) while the sequential path
    /// keeps sampling inside [`Fabric::inject`].
    pub fn sample_delay(&self, rng: &mut dyn Draws) -> f64 {
        match self.jitter {
            Jitter::None => self.delay,
            Jitter::Uniform { frac } => self.delay * (1.0 + frac * (2.0 * rng.f64() - 1.0)),
            Jitter::ExpTail { mean } => self.delay - mean * (1.0 - rng.f64()).ln(),
        }
    }

    /// The smallest delay a link can ever sample — the propagation term
    /// of the ideal-latency lower bound.
    pub fn min_delay(&self) -> f64 {
        match self.jitter {
            Jitter::None | Jitter::ExpTail { .. } => self.delay,
            Jitter::Uniform { frac } => self.delay * (1.0 - frac),
        }
    }
}

/// The `--fabric` configuration surface: the ideal (scalar-latency) model
/// or a finite-bandwidth preset/custom parameter set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FabricSpec {
    /// Scalar latency per encoded byte — byte-identical to the pre-fabric
    /// DES, so every PR 3–5 figure stays reproducible.
    Ideal,
    /// Single rack behind a non-blocking ToR switch: 1 Gb/s NICs, 0.2 ms
    /// links with mild uniform jitter.
    Rack,
    /// Cross-region WAN: 200 Mb/s effective per worker, 30 ms links with
    /// a 10 ms exponential tail, 4:1 oversubscribed shared uplink.
    Wan,
    /// Edge/mobile: 20 Mb/s, 80 ms links with a 40 ms exponential tail,
    /// 8:1 oversubscription — high-variance, contention-dominated.
    Edge,
    /// Fully custom parameters (`custom:BW_MBS:DELAY_MS:OVERSUB[:JFRAC]`).
    Custom(FabricParams),
}

impl FabricSpec {
    /// The finite-fabric parameters, or `None` for the ideal model.
    pub fn params(&self) -> Option<FabricParams> {
        match self {
            FabricSpec::Ideal => None,
            FabricSpec::Rack => Some(FabricParams {
                bandwidth: 125.0e6,
                delay: 0.2e-3,
                jitter: Jitter::Uniform { frac: 0.1 },
                oversub: 1.0,
            }),
            FabricSpec::Wan => Some(FabricParams {
                bandwidth: 25.0e6,
                delay: 30.0e-3,
                jitter: Jitter::ExpTail { mean: 10.0e-3 },
                oversub: 4.0,
            }),
            FabricSpec::Edge => Some(FabricParams {
                bandwidth: 2.5e6,
                delay: 80.0e-3,
                jitter: Jitter::ExpTail { mean: 40.0e-3 },
                oversub: 8.0,
            }),
            FabricSpec::Custom(p) => Some(*p),
        }
    }

    /// Series label for figures and CSV tags.
    pub fn label(&self) -> String {
        match self {
            FabricSpec::Ideal => "ideal".into(),
            FabricSpec::Rack => "rack".into(),
            FabricSpec::Wan => "wan".into(),
            FabricSpec::Edge => "edge".into(),
            FabricSpec::Custom(p) => format!(
                "custom:{:.0}:{:.1}:{:.0}",
                p.bandwidth / 1.0e6,
                p.delay * 1.0e3,
                p.oversub
            ),
        }
    }

    /// Parse from a CLI string: `ideal`, `rack`, `wan`, `edge`, or
    /// `custom:BW_MBS:DELAY_MS:OVERSUB[:JFRAC]` (bandwidth in MB/s, delay
    /// in milliseconds, optional uniform jitter fraction).
    ///
    /// Garbage is a config error, not a panic or a silent default:
    /// bandwidth must be finite and positive, delay finite and
    /// non-negative (NaN rejected explicitly), oversubscription finite
    /// and at least 1, and the jitter fraction inside `[0, 1)`.
    ///
    /// ```
    /// use gosgd::sim::FabricSpec;
    ///
    /// assert_eq!(FabricSpec::parse("ideal").unwrap(), FabricSpec::Ideal);
    /// assert_eq!(FabricSpec::parse("wan").unwrap(), FabricSpec::Wan);
    /// let custom = FabricSpec::parse("custom:100:5:2:0.25").unwrap();
    /// assert!(custom.params().unwrap().bandwidth == 100.0e6);
    /// assert!(FabricSpec::parse("custom:0:5:1").is_err());      // zero bandwidth
    /// assert!(FabricSpec::parse("custom:100:NaN:1").is_err());  // NaN delay
    /// assert!(FabricSpec::parse("custom:100:5:0.5").is_err());  // oversub < 1
    /// assert!(FabricSpec::parse("infiniband").is_err());
    /// ```
    pub fn parse(text: &str) -> Result<FabricSpec> {
        match text {
            "ideal" => return Ok(FabricSpec::Ideal),
            "rack" => return Ok(FabricSpec::Rack),
            "wan" => return Ok(FabricSpec::Wan),
            "edge" => return Ok(FabricSpec::Edge),
            _ => {}
        }
        let body = text.strip_prefix("custom:").ok_or_else(|| {
            Error::config(format!(
                "unknown fabric {text:?} (expected ideal | rack | wan | edge | \
                 custom:BW_MBS:DELAY_MS:OVERSUB[:JFRAC])"
            ))
        })?;
        let parts: Vec<&str> = body.split(':').collect();
        if parts.len() != 3 && parts.len() != 4 {
            return Err(Error::config(format!(
                "custom fabric needs BW_MBS:DELAY_MS:OVERSUB[:JFRAC], got {body:?}"
            )));
        }
        let num = |name: &str, s: &str| -> Result<f64> {
            s.parse::<f64>()
                .map_err(|_| Error::config(format!("fabric {name} is not a number: {s:?}")))
        };
        let bandwidth = num("bandwidth", parts[0])? * 1.0e6;
        let delay = num("delay", parts[1])? * 1.0e-3;
        let oversub = num("oversubscription", parts[2])?;
        if !(bandwidth.is_finite() && bandwidth > 0.0) {
            return Err(Error::config(format!(
                "fabric bandwidth must be positive and finite, got {} MB/s",
                bandwidth / 1.0e6
            )));
        }
        if !(delay.is_finite() && delay >= 0.0) {
            // The NaN case matters: every comparison with NaN is false, so
            // an unchecked NaN delay would silently pass `delay < 0` style
            // guards and poison every event timestamp downstream.
            return Err(Error::config(format!(
                "fabric delay must be non-negative and finite, got {} ms",
                delay * 1.0e3
            )));
        }
        if !(oversub.is_finite() && oversub >= 1.0) {
            return Err(Error::config(format!(
                "fabric oversubscription must be >= 1 (1 = non-blocking), got {oversub}"
            )));
        }
        let jitter = if parts.len() == 4 {
            let frac = num("jitter fraction", parts[3])?;
            if !(frac.is_finite() && (0.0..1.0).contains(&frac)) {
                return Err(Error::config(format!(
                    "fabric jitter fraction must be in [0, 1), got {frac}"
                )));
            }
            if frac == 0.0 {
                Jitter::None
            } else {
                Jitter::Uniform { frac }
            }
        } else {
            Jitter::None
        };
        Ok(FabricSpec::Custom(FabricParams { bandwidth, delay, jitter, oversub }))
    }
}

/// Aggregate fabric accounting, exposed through
/// [`DesReport`](crate::sim::DesReport)`.fabric`.
#[derive(Clone, Debug, Default)]
pub struct FabricStats {
    /// Messages injected / delivered (equal once the fabric drains).
    pub injected: u64,
    pub delivered: u64,
    /// Per-worker seconds messages spent queued behind the sender's NIC.
    pub nic_queue_secs: Vec<f64>,
    /// Per-worker seconds the sender's NIC spent transmitting.
    pub nic_busy_secs: Vec<f64>,
    /// Per-worker seconds messages spent queued at the receiver's NIC.
    pub rx_queue_secs: Vec<f64>,
    /// Seconds messages waited in the switch's flow queues.
    pub switch_queue_secs: f64,
    /// Seconds the switch uplink spent serving.
    pub switch_busy_secs: f64,
}

impl FabricStats {
    /// Per-worker transmit-side link utilization over a run of
    /// `end_time` simulated seconds.
    pub fn nic_utilization(&self, end_time: f64) -> Vec<f64> {
        self.nic_busy_secs
            .iter()
            .map(|b| if end_time > 0.0 { b / end_time } else { 0.0 })
            .collect()
    }

    /// Total queueing delay absorbed anywhere in the fabric (sender NICs,
    /// switch, receiver NICs).
    pub fn queued_secs(&self) -> f64 {
        self.nic_queue_secs.iter().sum::<f64>()
            + self.rx_queue_secs.iter().sum::<f64>()
            + self.switch_queue_secs
    }
}

/// A message completing its last hop: delivered to `dst` at time `at`.
#[derive(Debug)]
pub struct Delivery<T> {
    pub at: f64,
    pub src: usize,
    pub dst: usize,
    /// When [`Fabric::inject`] accepted the message (transit time is
    /// `at - injected_at`).
    pub injected_at: f64,
    pub item: T,
}

/// One message in flight.
#[derive(Debug)]
struct Msg<T> {
    src: usize,
    dst: usize,
    bytes: f64,
    injected_at: f64,
    /// When the message reached the switch's flow queue (switch-queueing
    /// accounting; set by the arrive transition).
    switch_arrive: f64,
    item: T,
}

/// Internal fabric transitions, ordered by time on the fabric's own heap.
#[derive(Debug)]
enum Hop<T> {
    /// The message finishes its up link and joins its flow queue.
    ArriveSwitch(Msg<T>),
    /// The switch uplink finishes serving the message.
    SwitchDone(Msg<T>),
    /// The receiver's NIC finishes deserializing the message.
    Deliver(Msg<T>),
}

struct FabEvent<T> {
    time: f64,
    seq: u64,
    hop: Hop<T>,
}

impl<T> PartialEq for FabEvent<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for FabEvent<T> {}
impl<T> PartialOrd for FabEvent<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for FabEvent<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; seq breaks ties deterministically
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The finite-bandwidth fabric: NIC queues, jittered links, and the
/// round-robin switch arbiter, advanced on an internal event heap.
///
/// Generic over the carried payload `T` — the fabric reads only
/// `(src, dst, bytes, time)`.
pub struct Fabric<T> {
    params: FabricParams,
    /// Aggregate switch-uplink capacity, bytes/second.
    capacity: f64,
    /// When each worker's transmit NIC frees up.
    nic_free: Vec<f64>,
    /// Latest switch-arrival per source flow (in-order link delivery: a
    /// jitter draw can never reorder two messages on the same link).
    up_inorder: Vec<f64>,
    /// Latest receiver-side link arrival per destination, same contract.
    down_inorder: Vec<f64>,
    /// When each worker's receive NIC frees up.
    rx_free: Vec<f64>,
    /// Per-source FIFO queues contending for the switch uplink.
    flows: Vec<VecDeque<Msg<T>>>,
    /// Ids of the non-empty flows, ordered — the arbiter's index.  At
    /// megafleet scale almost every flow is idle; the round-robin pick
    /// must not scan them (`try_serve` is O(log n) against the old O(n)
    /// cyclic walk, selecting the identical flow).
    ready: BTreeSet<usize>,
    switch_busy: bool,
    /// Round-robin arbiter position: the flow served last.
    rr_cursor: usize,
    heap: BinaryHeap<FabEvent<T>>,
    seq: u64,
    stats: FabricStats,
}

impl<T> Fabric<T> {
    /// Build the fabric for a fleet of `workers` NICs.
    pub fn new(workers: usize, params: FabricParams) -> Self {
        assert!(workers >= 2, "a fabric needs at least two endpoints");
        Fabric {
            params,
            capacity: workers as f64 * params.bandwidth / params.oversub,
            nic_free: vec![0.0; workers],
            up_inorder: vec![0.0; workers],
            down_inorder: vec![0.0; workers],
            rx_free: vec![0.0; workers],
            flows: (0..workers).map(|_| VecDeque::new()).collect(),
            ready: BTreeSet::new(),
            switch_busy: false,
            rr_cursor: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            stats: FabricStats {
                nic_queue_secs: vec![0.0; workers],
                nic_busy_secs: vec![0.0; workers],
                rx_queue_secs: vec![0.0; workers],
                ..FabricStats::default()
            },
        }
    }

    fn push(&mut self, time: f64, hop: Hop<T>) {
        self.seq += 1;
        self.heap.push(FabEvent { time, seq: self.seq, hop });
    }

    /// Accept a message of `bytes` from `src` to `dst` at time `now`:
    /// serialize it through `src`'s NIC (queueing behind any transmission
    /// still in progress) and start it up the link.  Call
    /// [`Fabric::next_transition`] afterwards to learn when the fabric
    /// next needs [`Fabric::advance_into`].
    pub fn inject(
        &mut self,
        src: usize,
        dst: usize,
        bytes: usize,
        now: f64,
        rng: &mut dyn Draws,
        item: T,
    ) {
        let up_delay = self.params.sample_delay(rng);
        self.inject_delayed(src, dst, bytes, now, up_delay, item);
    }

    /// [`Fabric::inject`] with the up-link jitter already drawn.  The
    /// parallel DES samples `up_delay` from the sending worker's counter
    /// stream while its shard runs concurrently, then replays injections
    /// on the merge thread in global `(time, key)` order — this split
    /// keeps that replay bit-identical to the sequential engine, which
    /// draws the sample at the same point of the same stream.
    pub fn inject_delayed(
        &mut self,
        src: usize,
        dst: usize,
        bytes: usize,
        now: f64,
        up_delay: f64,
        item: T,
    ) {
        assert!(src < self.flows.len() && dst < self.flows.len());
        assert!(src != dst, "a worker never gossips with itself");
        assert!(bytes > 0, "messages carry at least their headers");
        let bytes = bytes as f64;
        let tx = bytes / self.params.bandwidth;
        // NIC serialization: FIFO per worker by construction (a worker's
        // injections arrive in time order).
        let start_tx = now.max(self.nic_free[src]);
        self.stats.nic_queue_secs[src] += start_tx - now;
        self.stats.nic_busy_secs[src] += tx;
        let depart = start_tx + tx;
        self.nic_free[src] = depart;
        // Up link: propagation + jitter, clamped to in-order per flow.
        let arrive = (depart + up_delay).max(self.up_inorder[src]);
        self.up_inorder[src] = arrive;
        self.stats.injected += 1;
        self.push(
            arrive,
            Hop::ArriveSwitch(Msg {
                src,
                dst,
                bytes,
                injected_at: now,
                switch_arrive: 0.0,
                item,
            }),
        );
    }

    /// Earliest pending internal transition, if any in-flight message
    /// still needs the fabric to act.  O(1): a heap peek — the engine
    /// re-arms its `FabricTick` after every inject and fire, so this
    /// sits on the hot path at fleet scale.
    pub fn next_transition(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Messages currently inside the fabric (injected, not yet delivered).
    pub fn in_flight(&self) -> usize {
        (self.stats.injected - self.stats.delivered) as usize
    }

    /// Visit every in-flight message's payload (conservation audits: each
    /// message lives in exactly one place — an internal hop event or a
    /// switch flow queue).
    pub fn for_each_in_flight<F: FnMut(&T)>(&self, mut f: F) {
        for ev in self.heap.iter() {
            match &ev.hop {
                Hop::ArriveSwitch(m) | Hop::SwitchDone(m) | Hop::Deliver(m) => f(&m.item),
            }
        }
        for q in &self.flows {
            for m in q {
                f(&m.item);
            }
        }
    }

    /// The fastest any `bytes`-sized message can possibly transit: both
    /// NIC serializations, both minimum link delays, and one uncontended
    /// pass through the switch.  Every actual delivery takes at least
    /// this long — the "ideal-latency lower bound" the invariants suite
    /// pins per preset.
    pub fn lower_bound_secs(&self, bytes: usize) -> f64 {
        let b = bytes as f64;
        2.0 * b / self.params.bandwidth + 2.0 * self.params.min_delay() + b / self.capacity
    }

    /// Fabric accounting so far.
    pub fn stats(&self) -> &FabricStats {
        &self.stats
    }

    /// If the switch is idle and any flow has a waiting message, serve
    /// the next flow in round-robin order (starting after the flow served
    /// last).  The `ready` index makes the pick O(log n) in the fleet
    /// size: the first ready flow above the cursor, or — wrapping — the
    /// smallest ready flow.  That is exactly the flow the cyclic scan
    /// `(rr_cursor + step) % n, step = 1..=n` reaches first, including
    /// the full-wrap case where the cursor's own flow is served again,
    /// so arbitration order (and every figure) is unchanged.
    fn try_serve(&mut self, now: f64) {
        if self.switch_busy {
            return;
        }
        let next = self
            .ready
            .range((Bound::Excluded(self.rr_cursor), Bound::Unbounded))
            .next()
            .or_else(|| self.ready.iter().next())
            .copied();
        let Some(flow) = next else {
            return;
        };
        let msg = self.flows[flow].pop_front().expect("ready flows are non-empty");
        if self.flows[flow].is_empty() {
            self.ready.remove(&flow);
        }
        self.rr_cursor = flow;
        self.switch_busy = true;
        self.stats.switch_queue_secs += now - msg.switch_arrive;
        let service = msg.bytes / self.capacity;
        self.stats.switch_busy_secs += service;
        self.push(now + service, Hop::SwitchDone(msg));
    }

    /// Process every internal transition due by `now`, appending
    /// completed deliveries to `out` (cleared first).  Transitions only
    /// ever spawn strictly-later transitions, so one pass drains
    /// everything due.
    pub fn advance_into(&mut self, now: f64, rng: &mut dyn Draws, out: &mut Vec<Delivery<T>>) {
        out.clear();
        while self.heap.peek().is_some_and(|e| e.time <= now) {
            let ev = self.heap.pop().expect("peeked");
            let t = ev.time;
            match ev.hop {
                Hop::ArriveSwitch(mut msg) => {
                    msg.switch_arrive = t;
                    self.ready.insert(msg.src);
                    self.flows[msg.src].push_back(msg);
                    self.try_serve(t);
                }
                Hop::SwitchDone(msg) => {
                    self.switch_busy = false;
                    // Down link: propagation + jitter, in-order per
                    // destination.
                    let ready =
                        (t + self.params.sample_delay(rng)).max(self.down_inorder[msg.dst]);
                    self.down_inorder[msg.dst] = ready;
                    // Receiver NIC: deserialization is FIFO in switch
                    // order, so per-destination delivery times are
                    // monotone and per-link FIFO holds end to end.
                    let start_rx = ready.max(self.rx_free[msg.dst]);
                    self.stats.rx_queue_secs[msg.dst] += start_rx - ready;
                    let deliver = start_rx + msg.bytes / self.params.bandwidth;
                    self.rx_free[msg.dst] = deliver;
                    self.push(deliver, Hop::Deliver(msg));
                    self.try_serve(t);
                }
                Hop::Deliver(msg) => {
                    self.stats.delivered += 1;
                    out.push(Delivery {
                        at: t,
                        src: msg.src,
                        dst: msg.dst,
                        injected_at: msg.injected_at,
                        item: msg.item,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Deterministic params: bandwidth 1000 B/s, no delay, no jitter.
    fn flat(oversub: f64) -> FabricParams {
        FabricParams { bandwidth: 1000.0, delay: 0.0, jitter: Jitter::None, oversub }
    }

    /// Drain the fabric completely, returning deliveries in time order.
    fn drain(fab: &mut Fabric<u64>, rng: &mut Rng) -> Vec<Delivery<u64>> {
        let mut all = Vec::new();
        let mut out = Vec::new();
        while let Some(t) = fab.next_transition() {
            fab.advance_into(t, rng, &mut out);
            all.append(&mut out);
        }
        assert_eq!(fab.in_flight(), 0, "drained fabric must be empty");
        all
    }

    // ---- NIC serialization ---------------------------------------------

    #[test]
    fn two_simultaneous_sends_from_one_worker_serialize() {
        // Two 1000-byte messages from worker 0 at t=0: tx = 1 s each, so
        // the second departs the NIC only after the first finishes
        // transmitting, and the deliveries land exactly one tx apart.
        let mut rng = Rng::new(1);
        let mut fab: Fabric<u64> = Fabric::new(4, flat(1.0));
        fab.inject(0, 1, 1000, 0.0, &mut rng, 10);
        fab.inject(0, 2, 1000, 0.0, &mut rng, 11);
        let got = drain(&mut fab, &mut rng);
        assert_eq!(got.len(), 2);
        // Pipeline: 1 s tx + 0 delay + 1000/4000 s switch + 0 + 1 s rx.
        assert!((got[0].at - 2.25).abs() < 1e-12, "first at {}", got[0].at);
        assert!((got[1].at - 3.25).abs() < 1e-12, "second at {}", got[1].at);
        // The second message queued exactly one tx behind the first.
        assert!((fab.stats().nic_queue_secs[0] - 1.0).abs() < 1e-12);
        assert_eq!(fab.stats().nic_queue_secs[1..], [0.0, 0.0, 0.0]);
        assert!((fab.stats().nic_busy_secs[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sends_spaced_wider_than_tx_never_queue() {
        let mut rng = Rng::new(2);
        let mut fab: Fabric<u64> = Fabric::new(4, flat(1.0));
        fab.inject(0, 1, 500, 0.0, &mut rng, 0); // tx 0.5 s
        fab.inject(0, 1, 500, 1.0, &mut rng, 1); // NIC long free again
        drain(&mut fab, &mut rng);
        assert_eq!(fab.stats().nic_queue_secs[0], 0.0);
        assert_eq!(fab.stats().queued_secs(), 0.0);
    }

    // ---- switch arbiter ------------------------------------------------

    #[test]
    fn oversubscribed_uplink_throttles_aggregate_throughput_to_the_ratio() {
        // 8 workers, each shipping one 1000-byte message at t=0.  At
        // oversub r the uplink's capacity is 8000/r B/s, so serving all
        // 8000 bytes occupies the switch for exactly r seconds — the
        // aggregate throughput is throttled to 1/r of the non-blocking
        // switch, which is the definition of the ratio.
        let serve_time = |oversub: f64| {
            let mut rng = Rng::new(3);
            let mut fab: Fabric<u64> = Fabric::new(8, flat(oversub));
            for w in 0..8 {
                fab.inject(w, (w + 1) % 8, 1000, 0.0, &mut rng, w as u64);
            }
            let got = drain(&mut fab, &mut rng);
            assert_eq!(got.len(), 8);
            fab.stats().switch_busy_secs
        };
        let non_blocking = serve_time(1.0);
        let oversubscribed = serve_time(4.0);
        assert!((non_blocking - 1.0).abs() < 1e-12, "8000 B / 8000 B/s");
        assert!((oversubscribed - 4.0).abs() < 1e-12, "8000 B / 2000 B/s");
        assert!((oversubscribed / non_blocking - 4.0).abs() < 1e-9);
    }

    #[test]
    fn oversubscription_delays_the_last_delivery() {
        let last_delivery = |oversub: f64| {
            let mut rng = Rng::new(4);
            let mut fab: Fabric<u64> = Fabric::new(8, flat(oversub));
            for w in 0..8 {
                fab.inject(w, (w + 1) % 8, 1000, 0.0, &mut rng, w as u64);
            }
            drain(&mut fab, &mut rng)
                .last()
                .map(|d| d.at)
                .expect("deliveries")
        };
        assert!(
            last_delivery(4.0) > last_delivery(1.0) + 2.0,
            "a 4:1 uplink must visibly stretch the burst"
        );
    }

    #[test]
    fn switch_serves_contending_flows_round_robin() {
        // Two workers each queue a burst that reaches the switch far
        // faster than the uplink drains it (oversub 400 → capacity
        // 10 B/s), so both flows contend for every slot.  Fair
        // round-robin must alternate flows instead of draining one
        // worker's burst first.
        let mut rng = Rng::new(5);
        let params = FabricParams {
            bandwidth: 100_000.0,
            delay: 0.0,
            jitter: Jitter::None,
            oversub: 4000.0, // capacity = 4 * 100_000 / 4000 = 100 B/s
        };
        let mut fab: Fabric<u64> = Fabric::new(4, params);
        for k in 0..3 {
            fab.inject(0, 2, 1000, 0.0, &mut rng, k); // from flow 0
            fab.inject(1, 3, 1000, 0.0, &mut rng, 10 + k); // from flow 1
        }
        let got = drain(&mut fab, &mut rng);
        let srcs: Vec<usize> = got.iter().map(|d| d.src).collect();
        assert_eq!(srcs, vec![0, 1, 0, 1, 0, 1], "round-robin over flows");
        // And within each flow, FIFO.
        let flow0: Vec<u64> = got.iter().filter(|d| d.src == 0).map(|d| d.item).collect();
        assert_eq!(flow0, vec![0, 1, 2]);
    }

    #[test]
    fn arbiter_wraps_below_the_cursor_with_exact_times() {
        // Two flows on both sides of the round-robin cursor: worker 2's
        // message is served first (first ready flow above cursor 0), and
        // the arbiter must then wrap *below* its new cursor to flow 0 —
        // the indexed pick reproducing the cyclic scan's wrap exactly.
        // All quantities are exact in binary (1 s tx, 0.5 s service), so
        // every assertion is `==`, not a tolerance.
        let mut rng = Rng::new(10);
        let mut fab: Fabric<u64> = Fabric::new(4, flat(2.0)); // capacity 2000 B/s
        fab.inject(2, 1, 1000, 0.0, &mut rng, 22);
        fab.inject(0, 1, 1000, 0.0, &mut rng, 20);
        let got = drain(&mut fab, &mut rng);
        let order: Vec<(usize, u64)> = got.iter().map(|d| (d.src, d.item)).collect();
        assert_eq!(order, vec![(2, 22), (0, 20)], "above the cursor first, then wrap");
        // Both reach the switch at t = 1 (1 s NIC tx, zero-delay links);
        // flow 2 is served 1.0..1.5, flow 0 is served 1.5..2.0, and the
        // shared receiver NIC deserializes them back to back.
        assert_eq!(got[0].at, 2.5);
        assert_eq!(got[1].at, 3.5);
        // Flow 0 waited exactly one service slot at the switch; flow 2
        // never queued.  The second delivery also queued half a second
        // behind the first at worker 1's receive NIC.
        assert_eq!(fab.stats().switch_queue_secs, 0.5);
        assert_eq!(fab.stats().switch_busy_secs, 1.0);
        assert_eq!(fab.stats().rx_queue_secs[1], 0.5);
    }

    // ---- links ---------------------------------------------------------

    #[test]
    fn jittered_links_never_reorder_a_flow() {
        // Heavy exponential jitter; messages on the same (src, dst) link
        // must still deliver in injection order (in-order transport).
        let params = FabricParams {
            bandwidth: 1.0e6,
            delay: 1.0e-3,
            jitter: Jitter::ExpTail { mean: 50.0e-3 },
            oversub: 1.0,
        };
        let mut rng = Rng::new(6);
        let mut fab: Fabric<u64> = Fabric::new(3, params);
        for k in 0..50 {
            fab.inject(0, 1, 200, k as f64 * 1.0e-4, &mut rng, k);
        }
        let got = drain(&mut fab, &mut rng);
        let order: Vec<u64> = got.iter().map(|d| d.item).collect();
        assert_eq!(order, (0..50).collect::<Vec<u64>>());
        for pair in got.windows(2) {
            assert!(pair[0].at <= pair[1].at);
        }
    }

    #[test]
    fn deliveries_respect_the_ideal_latency_lower_bound() {
        for spec in [FabricSpec::Rack, FabricSpec::Wan, FabricSpec::Edge] {
            let params = spec.params().unwrap();
            let mut rng = Rng::new(7);
            let mut fab: Fabric<u64> = Fabric::new(6, params);
            let bytes = 4096;
            for k in 0..40u64 {
                let src = (k % 6) as usize;
                fab.inject(src, (src + 1) % 6, bytes, k as f64 * 0.01, &mut rng, k);
            }
            let bound = fab.lower_bound_secs(bytes);
            for d in drain(&mut fab, &mut rng) {
                let transit = d.at - d.injected_at;
                assert!(
                    transit >= bound - 1e-12,
                    "{}: transit {transit} < lower bound {bound}",
                    spec.label()
                );
            }
        }
    }

    #[test]
    fn seeded_jitter_is_deterministic() {
        let run = || {
            let mut rng = Rng::new(8);
            let mut fab: Fabric<u64> = Fabric::new(4, FabricSpec::Edge.params().unwrap());
            for k in 0..20u64 {
                fab.inject((k % 4) as usize, ((k + 1) % 4) as usize, 1000, k as f64 * 0.02, &mut rng, k);
            }
            drain(&mut fab, &mut rng)
                .iter()
                .map(|d| (d.at.to_bits(), d.item))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    // ---- spec parsing --------------------------------------------------

    #[test]
    fn parse_accepts_presets_and_custom_forms() {
        assert_eq!(FabricSpec::parse("ideal").unwrap(), FabricSpec::Ideal);
        assert_eq!(FabricSpec::parse("rack").unwrap(), FabricSpec::Rack);
        assert_eq!(FabricSpec::parse("wan").unwrap(), FabricSpec::Wan);
        assert_eq!(FabricSpec::parse("edge").unwrap(), FabricSpec::Edge);
        let spec = FabricSpec::parse("custom:100:5:2").unwrap();
        let p = spec.params().unwrap();
        assert_eq!(p.bandwidth, 100.0e6);
        assert!((p.delay - 5.0e-3).abs() < 1e-12);
        assert_eq!(p.oversub, 2.0);
        assert_eq!(p.jitter, Jitter::None);
        let spec = FabricSpec::parse("custom:100:5:2:0.3").unwrap();
        assert_eq!(spec.params().unwrap().jitter, Jitter::Uniform { frac: 0.3 });
        // Boundary values: zero delay and a 1:1 switch are legal.
        assert!(FabricSpec::parse("custom:1:0:1").is_ok());
        // Zero jitter collapses to the deterministic link.
        let spec = FabricSpec::parse("custom:1:0:1:0").unwrap();
        assert_eq!(spec.params().unwrap().jitter, Jitter::None);
    }

    #[test]
    fn parse_rejects_nonsense_with_config_errors() {
        for bad in [
            "infiniband",
            "",
            "custom:",
            "custom:100",
            "custom:100:5",
            "custom:100:5:2:0.3:9",
            "custom:0:5:1",      // zero bandwidth
            "custom:-10:5:1",    // negative bandwidth
            "custom:inf:5:1",    // infinite bandwidth
            "custom:100:NaN:1",  // NaN delay
            "custom:100:-1:1",   // negative delay
            "custom:100:5:0.5",  // oversubscription < 1
            "custom:100:5:0",    // oversubscription < 1
            "custom:100:5:NaN",  // NaN oversubscription
            "custom:100:5:1:1.5", // jitter fraction out of range
            "custom:100:5:1:-0.1",
            "custom:abc:5:1",
        ] {
            let err = FabricSpec::parse(bad).unwrap_err();
            assert!(
                err.to_string().contains("config"),
                "{bad:?} should be a config error, got {err}"
            );
        }
    }

    #[test]
    fn labels_are_stable_series_tags() {
        assert_eq!(FabricSpec::Ideal.label(), "ideal");
        assert_eq!(FabricSpec::Edge.label(), "edge");
        let spec = FabricSpec::parse("custom:100:5:2").unwrap();
        assert_eq!(spec.label(), "custom:100:5.0:2");
    }

    #[test]
    fn stats_utilization_and_queueing_roll_up() {
        let mut rng = Rng::new(9);
        let mut fab: Fabric<u64> = Fabric::new(4, flat(1.0));
        fab.inject(0, 1, 1000, 0.0, &mut rng, 0);
        fab.inject(0, 1, 1000, 0.0, &mut rng, 1);
        drain(&mut fab, &mut rng);
        let stats = fab.stats();
        assert_eq!(stats.injected, 2);
        assert_eq!(stats.delivered, 2);
        // Worker 0 transmitted for 2 of the first 4 seconds.
        let util = stats.nic_utilization(4.0);
        assert!((util[0] - 0.5).abs() < 1e-12);
        assert_eq!(util[2], 0.0);
        // All queueing in this run is the second message's NIC wait plus
        // its rx wait behind the first delivery.
        assert!(stats.queued_secs() > 0.0);
    }
}
