//! Discrete-event simulation of the cluster's *time* behaviour.
//!
//! The paper's Fig. 2 compares GoSGD and EASGD against the **real-world
//! clock**: GoSGD wins because its exchanges never block, while EASGD
//! serializes through a master every `tau` steps.  This testbed has a
//! single CPU core, so native threads cannot honestly show that effect —
//! instead [`des::DesEngine`] simulates it exactly: per-step compute time,
//! per-message network latency, a serially-serviced master, and blocking
//! semantics per strategy, while the *gradients remain real* (any
//! [`GradSource`](crate::strategies::grad::GradSource), including the PJRT
//! model).  DESIGN.md §Substitutions documents the mapping.
//!
//! The simulated quantities per strategy:
//!
//! * **GoSGD** — send is fire-and-forget (`latency` to deliver); a worker
//!   never waits.  Wall time per worker = Σ compute.
//! * **EASGD** — every `tau` local steps the worker sends its model to the
//!   master and *blocks* until the elastic reply returns.  The master is a
//!   serial resource: concurrent syncs queue (the "critical resource"
//!   contention of paper section 2.1).
//! * **PerSyn** — a global barrier every `tau` rounds: everyone waits for
//!   the straggler, then for the master's gather+broadcast.

//! Scenario diversity: [`des::ScenarioModel`] layers *persistent*
//! heterogeneity (per-worker compute multipliers — slow machines, not
//! transient jitter) and crash/rejoin worker churn on top of the time
//! model.  Gossip shrugs both off (fire-and-forget sends, mailboxes
//! buffer through downtime); the barrier baselines pay for every
//! straggler at every sync — the `scenarios` harness quantifies it.

//! Network realism: [`fabric`] replaces the scalar per-message latency
//! with a finite-bandwidth pipeline — per-worker NIC serialization
//! queues, jittered link delays, and a fair round-robin arbiter over an
//! oversubscribed switch uplink — selected by [`fabric::FabricSpec`]
//! (`--fabric ideal|rack|wan|edge|custom:…`).  The `Ideal` spec keeps the
//! scalar model bit-identical, so prior figures stay reproducible.

//! Scale: the engine schedules through a hierarchical timing wheel
//! ([`wheel::TimingWheel`]) instead of a global binary heap — amortized
//! O(1) per event with the heap's exact pop order, so trace hashes are
//! bit-identical under either scheduler ([`des::SchedulerKind`] selects;
//! `runtime_equivalence.rs` pins the equivalence).  Combined with
//! copy-on-write worker models and sparse churn state, a million-worker
//! fleet fits laptop memory — `benches/des_scale.rs` asserts the
//! bytes-per-worker ceiling.

//! Parallel execution: [`des::ParallelKind::Sharded`] partitions the
//! fleet into contiguous lanes executed window-by-window on scoped
//! threads under a conservative lookahead bound, with cross-lane effects
//! merged at window barriers in global `(time, key)` order — the same
//! event schedule, RNG streams, and trace hashes as the sequential
//! executor, bit for bit (`runtime_equivalence.rs` pins it;
//! `benches/par_des.rs` measures the speedup).

pub mod des;
pub mod fabric;
pub mod wheel;

pub use des::{
    DesEngine, DesReport, DesStrategy, ParallelKind, ScenarioModel, SchedulerKind, TimeModel,
};
pub use fabric::{Delivery, Fabric, FabricParams, FabricSpec, FabricStats, Jitter};
pub use wheel::TimingWheel;
