//! Hierarchical timing wheel for the discrete-event simulator.
//!
//! The DES originally kept every pending event in one global
//! `BinaryHeap`, paying O(log n) per push/pop with n = fleet size (a
//! million-worker run keeps ~1M wakes pending at all times).  A calendar
//! queue exploits what a heap cannot: simulated time only moves forward,
//! and almost every event lands within a short horizon of "now".  This
//! module buckets events by time into fixed-width ticks:
//!
//! ```text
//!   level 0:  W slots, one tick each        — the current window
//!   level 1:  W slots, one W-tick chunk each — the current span
//!   overflow: unbucketed far-future events   — rare (e.g. rejoin times)
//! ```
//!
//! Push routes an event by `tick = floor(time / tick_width)` into level 0
//! (current window), level 1 (current span), or the overflow list — O(1).
//! Pop drains the slot under the cursor; when it empties the cursor scans
//! forward, pouring the next level-1 chunk into level 0 on window
//! crossings and re-routing the overflow only when both levels are dry —
//! amortized O(1) per event.
//!
//! # Determinism contract
//!
//! Pop order is **exactly** the heap's: ascending `(time, seq)`, with
//! NaN-free times compared by `partial_cmp` and ties broken by the
//! monotone sequence number.  Two facts make this exact rather than
//! approximate: equal times always map to the same slot (the tick is a
//! pure function of the time), and the slot under the cursor drains
//! through a sorted buffer — filled lazily on the first pop of each tick,
//! maintained by binary insertion for events pushed mid-drain.  The drain
//! buffer is **one persistent `Vec` reused across every per-slot sort**
//! (slot storage swaps in, recycled capacity swaps out), so a
//! steady-state pop performs zero heap allocations — asserted by the
//! counting allocator in `benches/hotpath_alloc.rs`.  `TimingWheel` draws
//! no randomness,
//! so a DES run pops the identical event sequence (and therefore produces
//! the identical trace hash) whichever scheduler backs it.

use std::cmp::Ordering;

/// Slots per level.  Two levels of 256 cover `256 * 256 = 65,536` ticks
/// (~2.3 simulated hours at the DES default tick of 1/8 the mean compute
/// time) before anything touches the overflow list.
const W: u64 = 256;

/// A scheduled event: the caller's `(time, seq)` key plus its payload.
#[derive(Debug)]
pub struct Entry<T> {
    pub time: f64,
    pub seq: u64,
    pub item: T,
}

/// Ascending `(time, seq)` — the heap's pop order.
fn key_cmp<T>(a: &Entry<T>, b: &Entry<T>) -> Ordering {
    a.time
        .partial_cmp(&b.time)
        .unwrap_or(Ordering::Equal)
        .then(a.seq.cmp(&b.seq))
}

/// Two-level calendar queue with an overflow list.  Generic over the
/// event payload so the unit tests can exercise it with plain integers.
#[derive(Debug)]
pub struct TimingWheel<T> {
    /// Seconds per tick (bucket width).
    tick: f64,
    /// Absolute tick currently being drained.  Never decreases.
    cursor: u64,
    /// Slot `s` holds exactly tick `win_base() + s` of the current window.
    lvl0: Vec<Vec<Entry<T>>>,
    /// Slot `c % W` holds chunk `c` (a run of W ticks) of the current span.
    lvl1: Vec<Vec<Entry<T>>>,
    /// Events beyond the current span, unbucketed.
    overflow: Vec<Entry<T>>,
    /// Reusable buffer for pouring a level-1 chunk into level 0.
    scratch: Vec<Entry<T>>,
    /// The cursor slot's sorted drain (descending, so the minimum pops
    /// from the back in O(1)).  One buffer reused across every lazy
    /// per-slot sort: entering a tick swaps the slot's contents in, and
    /// the slot inherits the drain's previous capacity — so steady-state
    /// pops touch only recycled storage and allocate nothing.
    drain: Vec<Entry<T>>,
    /// Whether `drain` is active for the tick under the cursor.
    cur_sorted: bool,
    lvl0_len: usize,
    lvl1_len: usize,
    len: usize,
}

impl<T> TimingWheel<T> {
    /// A wheel with the given bucket width in seconds.  Non-finite or
    /// non-positive widths fall back to 1 ms; the width only affects
    /// performance, never ordering.
    pub fn new(tick: f64) -> Self {
        let tick = if tick.is_finite() && tick > 0.0 { tick } else { 1e-3 };
        TimingWheel {
            tick,
            cursor: 0,
            lvl0: (0..W).map(|_| Vec::new()).collect(),
            lvl1: (0..W).map(|_| Vec::new()).collect(),
            overflow: Vec::new(),
            scratch: Vec::new(),
            drain: Vec::new(),
            cur_sorted: false,
            lvl0_len: 0,
            lvl1_len: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Absolute tick for a timestamp.  The `as` cast saturates, so huge
    /// times land in the last representable tick (still ordered correctly
    /// within their slot by the full f64 time).
    fn tick_of(&self, time: f64) -> u64 {
        (time / self.tick) as u64
    }

    /// First tick of the window currently mapped into level 0.
    fn win_base(&self) -> u64 {
        (self.cursor / W) * W
    }

    /// One-past-the-last chunk of the span currently mapped into level 1.
    fn span_end_chunk(&self) -> u64 {
        (self.cursor / W / W + 1) * W
    }

    pub fn push(&mut self, time: f64, seq: u64, item: T) {
        // Events in the past cannot exist mid-run (the DES never schedules
        // before "now"); clamping is a safety net that keeps such an event
        // poppable instead of stranding it behind the cursor.
        let t = self.tick_of(time).max(self.cursor);
        self.route(t, Entry { time, seq, item });
        self.len += 1;
    }

    /// Place an entry whose clamped tick is `t` into the right structure.
    fn route(&mut self, t: u64, e: Entry<T>) {
        let c = t / W;
        if c == self.cursor / W {
            let slot = (t % W) as usize;
            if t == self.cursor && self.cur_sorted {
                // Mid-drain push into the tick being popped: binary-insert
                // into the drain's descending order so the next pop still
                // returns the global minimum.
                let pos = self.drain.partition_point(|x| key_cmp(x, &e) == Ordering::Greater);
                self.drain.insert(pos, e);
            } else {
                self.lvl0[slot].push(e);
            }
            self.lvl0_len += 1;
        } else if c < self.span_end_chunk() {
            self.lvl1[(c % W) as usize].push(e);
            self.lvl1_len += 1;
        } else {
            self.overflow.push(e);
        }
    }

    /// Remove and return the minimum-`(time, seq)` entry.
    pub fn pop(&mut self) -> Option<Entry<T>> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.cur_sorted {
                if let Some(e) = self.drain.pop() {
                    self.lvl0_len -= 1;
                    self.len -= 1;
                    return Some(e);
                }
                // Tick fully drained; the empty drain buffer keeps its
                // capacity for the next slot's sort.
                self.cur_sorted = false;
            }
            let slot = (self.cursor % W) as usize;
            if !self.lvl0[slot].is_empty() {
                // Lazy per-slot sort into the one persistent drain buffer:
                // the slot's storage moves in, the drain's recycled
                // capacity moves out to the slot — no allocation per pop.
                std::mem::swap(&mut self.drain, &mut self.lvl0[slot]);
                self.drain.sort_unstable_by(|a, b| key_cmp(b, a));
                self.cur_sorted = true;
                continue;
            }
            self.advance();
        }
    }

    /// Move the cursor to the next non-empty tick.  Only called with the
    /// current slot empty and at least one entry somewhere in the wheel.
    fn advance(&mut self) {
        self.cur_sorted = false;
        if self.lvl0_len > 0 {
            // Entries never land below the cursor, so the next tick is
            // strictly ahead within the current window.
            let base = self.win_base();
            for s in (self.cursor - base + 1)..W {
                if !self.lvl0[s as usize].is_empty() {
                    self.cursor = base + s;
                    return;
                }
            }
            unreachable!("lvl0_len > 0 but no slot at or after the cursor");
        }
        if self.lvl1_len > 0 {
            // Enter the next non-empty chunk of the span: pour it into
            // level 0 and park the cursor on its first non-empty tick.
            let c0 = self.cursor / W;
            for c in (c0 + 1)..self.span_end_chunk() {
                if self.lvl1[(c % W) as usize].is_empty() {
                    continue;
                }
                self.cursor = c * W;
                self.pour_chunk(c);
                for s in 0..W {
                    if !self.lvl0[s as usize].is_empty() {
                        self.cursor = c * W + s;
                        return;
                    }
                }
                unreachable!("poured chunk was non-empty");
            }
            unreachable!("lvl1_len > 0 but no chunk inside the span");
        }
        // Both levels dry: jump the cursor to the overflow's earliest tick
        // and re-route everything relative to the new window/span.
        debug_assert!(!self.overflow.is_empty(), "advance called on an empty wheel");
        let min_tick = self
            .overflow
            .iter()
            .map(|e| self.tick_of(e.time))
            .min()
            .expect("overflow checked non-empty");
        self.cursor = min_tick.max(self.cursor);
        let pending = std::mem::take(&mut self.overflow);
        for e in pending {
            let t = self.tick_of(e.time).max(self.cursor);
            self.route(t, e);
        }
        // The minimum entry now sits in level 0 under the cursor; the pop
        // loop will find it on the next pass.
    }

    /// Move every entry of level-1 chunk `c` into level 0.  Valid only
    /// when the cursor's window is exactly chunk `c`.
    fn pour_chunk(&mut self, c: u64) {
        debug_assert_eq!(self.cursor / W, c, "pour target must be the cursor's window");
        let slot = (c % W) as usize;
        let mut scratch = std::mem::take(&mut self.scratch);
        std::mem::swap(&mut scratch, &mut self.lvl1[slot]);
        self.lvl1_len -= scratch.len();
        for e in scratch.drain(..) {
            let t = self.tick_of(e.time);
            debug_assert_eq!(t / W, c, "chunk entry outside its chunk");
            self.lvl0[(t % W) as usize].push(e);
            self.lvl0_len += 1;
        }
        self.scratch = scratch;
    }

    /// Visit every pending entry in unspecified order (used for the DES
    /// conservation audit over undelivered messages).
    pub fn for_each<F: FnMut(&Entry<T>)>(&self, mut f: F) {
        for slot in self.lvl0.iter().chain(self.lvl1.iter()) {
            for e in slot {
                f(e);
            }
        }
        for e in self.drain.iter().chain(&self.overflow) {
            f(e);
        }
    }

    /// Rough resident size of the wheel itself (slot headers + entry
    /// capacity), excluding payload heap allocations.
    pub fn approx_bytes(&self) -> usize {
        let entry = std::mem::size_of::<Entry<T>>();
        let hdr = std::mem::size_of::<Vec<Entry<T>>>();
        let mut cap = self.overflow.capacity() + self.scratch.capacity() + self.drain.capacity();
        for slot in self.lvl0.iter().chain(self.lvl1.iter()) {
            cap += slot.capacity();
        }
        2 * W as usize * hdr + cap * entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference scheduler: linear scan for the minimum `(time, seq)`.
    struct NaiveQueue {
        items: Vec<Entry<u64>>,
    }

    impl NaiveQueue {
        fn new() -> Self {
            NaiveQueue { items: Vec::new() }
        }
        fn push(&mut self, time: f64, seq: u64) {
            self.items.push(Entry { time, seq, item: seq });
        }
        fn pop(&mut self) -> Option<(f64, u64)> {
            if self.items.is_empty() {
                return None;
            }
            let mut best = 0;
            for i in 1..self.items.len() {
                if key_cmp(&self.items[i], &self.items[best]) == Ordering::Less {
                    best = i;
                }
            }
            let e = self.items.swap_remove(best);
            Some((e.time, e.seq))
        }
    }

    #[test]
    fn empty_wheel_pops_none() {
        let mut w: TimingWheel<u64> = TimingWheel::new(0.1);
        assert!(w.is_empty());
        assert!(w.pop().is_none());
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn degenerate_tick_width_falls_back() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut w: TimingWheel<u64> = TimingWheel::new(bad);
            w.push(5.0, 1, 1);
            w.push(2.0, 2, 2);
            assert_eq!(w.pop().unwrap().item, 2);
            assert_eq!(w.pop().unwrap().item, 1);
        }
    }

    #[test]
    fn randomized_pop_order_matches_reference_with_interleaved_pushes() {
        let mut rng = Rng::new(0x77EE1);
        for trial in 0..20 {
            let tick = [1e-3, 0.0125, 0.3, 10.0][trial % 4];
            let mut wheel: TimingWheel<u64> = TimingWheel::new(tick);
            let mut naive = NaiveQueue::new();
            let mut seq = 0u64;
            let mut now = 0.0f64;
            for _ in 0..600 {
                if rng.f64() < 0.6 || wheel.is_empty() {
                    // Pushes land at or after "now", as in the DES.
                    let dt = rng.f64() * rng.f64() * 40.0;
                    seq += 1;
                    wheel.push(now + dt, seq, seq);
                    naive.push(now + dt, seq);
                } else {
                    let got = wheel.pop().map(|e| (e.time, e.seq));
                    let want = naive.pop();
                    assert_eq!(got, want, "trial {trial} diverged at seq {seq}");
                    now = got.unwrap().0.max(now);
                }
            }
            loop {
                let got = wheel.pop().map(|e| (e.time, e.seq));
                let want = naive.pop();
                assert_eq!(got, want, "trial {trial} drain diverged");
                if got.is_none() {
                    break;
                }
            }
            assert_eq!(wheel.len(), 0);
        }
    }

    #[test]
    fn window_rollover_keeps_ascending_order() {
        // Times spanning many level-0 windows (tick 0.1 => window 25.6 s).
        let mut w: TimingWheel<u64> = TimingWheel::new(0.1);
        let n = 4000u64;
        for seq in 0..n {
            // Deterministic scatter over [0, 400): crosses ~15 windows.
            let time = ((seq * 2654435761) % 4_000_000) as f64 * 1e-4;
            w.push(time, seq, seq);
        }
        let mut prev: Option<(f64, u64)> = None;
        for _ in 0..n {
            let e = w.pop().expect("all pushed events must pop");
            if let Some((pt, ps)) = prev {
                assert!(
                    pt < e.time || (pt == e.time && ps < e.seq),
                    "pop order regressed: ({pt}, {ps}) before ({}, {})",
                    e.time,
                    e.seq
                );
            }
            prev = Some((e.time, e.seq));
        }
        assert!(w.pop().is_none());
    }

    #[test]
    fn far_future_events_overflow_and_pop_in_order() {
        let mut w: TimingWheel<u64> = TimingWheel::new(0.01);
        // Span covers 256 * 256 * 0.01 = 655 s; these must overflow.
        w.push(1.0e6, 1, 1);
        w.push(5.0e5, 2, 2);
        w.push(0.5, 3, 3);
        w.push(2.0e6, 4, 4);
        let order: Vec<u64> = std::iter::from_fn(|| w.pop().map(|e| e.item)).collect();
        assert_eq!(order, vec![3, 2, 1, 4]);
    }

    #[test]
    fn overflow_jump_then_new_near_events_stay_ordered() {
        let mut w: TimingWheel<u64> = TimingWheel::new(0.01);
        w.push(1.0e5, 1, 1);
        // Drain to the far-future event: cursor jumps to its tick.
        let e = w.pop().unwrap();
        assert_eq!(e.item, 1);
        // New events relative to the new "now" route into the new window.
        w.push(1.0e5 + 0.005, 2, 2);
        w.push(1.0e5 + 3.0, 3, 3);
        w.push(2.0e5, 4, 4);
        let order: Vec<u64> = std::iter::from_fn(|| w.pop().map(|e| e.item)).collect();
        assert_eq!(order, vec![2, 3, 4]);
    }

    #[test]
    fn equal_times_pop_in_seq_order_regardless_of_push_order() {
        let mut w: TimingWheel<u64> = TimingWheel::new(0.25);
        for &seq in &[7u64, 3, 9, 1, 8, 2] {
            w.push(4.2, seq, seq);
        }
        // An equal-time event pushed mid-drain still slots by seq.
        assert_eq!(w.pop().unwrap().seq, 1);
        w.push(4.2, 5, 5);
        let order: Vec<u64> = std::iter::from_fn(|| w.pop().map(|e| e.seq)).collect();
        assert_eq!(order, vec![2, 3, 5, 7, 8, 9]);
    }

    #[test]
    fn push_back_after_pop_returns_the_same_entry() {
        // The DES horizon loop pops an event past the deadline and pushes
        // it back verbatim; the wheel must return it first on resume.
        let mut w: TimingWheel<u64> = TimingWheel::new(0.5);
        w.push(3.0, 1, 10);
        w.push(9.0, 2, 20);
        let e = w.pop().unwrap();
        assert_eq!(e.item, 10);
        w.push(e.time, e.seq, e.item);
        let again = w.pop().unwrap();
        assert_eq!((again.time, again.seq, again.item), (3.0, 1, 10));
        assert_eq!(w.pop().unwrap().item, 20);
    }

    #[test]
    fn push_during_drain_lands_in_sorted_position() {
        let mut w: TimingWheel<u64> = TimingWheel::new(1.0);
        // All in one slot (tick 1.0, times in [2, 3)).
        w.push(2.1, 1, 1);
        w.push(2.9, 2, 2);
        w.push(2.5, 3, 3);
        assert_eq!(w.pop().unwrap().item, 1); // slot now sorted, partially drained
        w.push(2.3, 4, 4); // binary insert mid-drain
        w.push(2.7, 5, 5);
        let order: Vec<u64> = std::iter::from_fn(|| w.pop().map(|e| e.item)).collect();
        assert_eq!(order, vec![4, 3, 5, 2]);
    }

    #[test]
    fn for_each_visits_every_pending_entry_once() {
        let mut w: TimingWheel<u64> = TimingWheel::new(0.01);
        let times = [0.001, 0.5, 3.0, 700.0, 1.0e6];
        for (i, &t) in times.iter().enumerate() {
            w.push(t, i as u64, i as u64);
        }
        let mut seen = vec![false; times.len()];
        w.for_each(|e| {
            assert!(!seen[e.item as usize], "entry visited twice");
            seen[e.item as usize] = true;
        });
        assert!(seen.iter().all(|&s| s), "entry missed: {seen:?}");
        assert!(w.approx_bytes() > 0);
    }
}
