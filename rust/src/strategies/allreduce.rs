//! Fully synchronous SGD (paper Algorithm 1).
//!
//! Every round, all workers' post-update variables are replaced by their
//! mean.  Because all workers start each round from the same point, this
//! is *exactly* equivalent to single-node SGD with an M× bigger batch
//! (paper section 2.1, footnote 1) — the equivalence test below checks it
//! to floating-point tolerance.
//!
//! Communication cost per round: 2M messages (M gradients up, M models
//! down) and one global barrier — the inefficiency the paper sets out to
//! remove.

use crate::error::Result;
use crate::framework::generators;
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::util::rng::Rng;

/// Algorithm 1: average everything every round.
#[derive(Default)]
pub struct AllReduce;

impl Strategy for AllReduce {
    fn name(&self) -> String {
        "allreduce".into()
    }

    fn clock(&self) -> Clock {
        Clock::Synchronous
    }

    fn after_round(&mut self, _t: u64, state: &mut ClusterState, _rng: &mut Rng) -> Result<()> {
        let m = state.workers();
        let mean = state.stacked.worker_mean()?;
        let bytes = mean.len() * 4;
        for slot in 0..=m {
            *state.stacked.get_mut(slot) = mean.clone();
        }
        // 2M messages: every worker ships its model/gradient to the master
        // and receives the average back (section 2.1 phases 1 & 3).
        for _ in 0..(2 * m) {
            state.count_message(bytes);
        }
        state.count_barrier();
        state.record_matrix(generators::allreduce(m)?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::grad::{GradSource, QuadraticSource};
    use crate::tensor::FlatVec;

    #[test]
    fn all_workers_stay_identical() {
        let dim = 16;
        let src = QuadraticSource::new(dim, 0.2, 3);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(Box::new(AllReduce), src, 4, &init, 0.5, 0.0, 1);
        eng.run(50).unwrap();
        let eps = eng.state().stacked.consensus_error().unwrap();
        assert!(eps < 1e-10, "allreduce must keep exact consensus, eps={eps}");
        assert_eq!(eng.state().comm.barriers, 50);
        assert_eq!(eng.state().comm.messages, 50 * 8);
    }

    #[test]
    fn equivalent_to_m_times_bigger_batch() {
        // Distributed run: M workers, each one noisy gradient per round,
        // averaged. Single run: one worker whose gradient is the average of
        // the same M draws. Resulting trajectories must match exactly.
        let dim = 8;
        let m = 4;
        let eta = 0.3f32;
        let steps = 25u64;
        let init = FlatVec::zeros(dim);

        // --- distributed ---
        let src = QuadraticSource::new(dim, 0.25, 9);
        let mut eng = Engine::new(Box::new(AllReduce), src, m, &init, eta, 0.0, 5);
        eng.run(steps).unwrap();
        let distributed = eng.state().stacked.worker(1).clone();

        // --- single big batch, replaying the identical noise draws ---
        let mut src2 = QuadraticSource::new(dim, 0.25, 9);
        let mut x = init.clone();
        let mut g = FlatVec::zeros(dim);
        for t in 0..steps {
            let mut avg = FlatVec::zeros(dim);
            for w in 1..=m {
                src2.grad(w, &x, t, &mut g).unwrap();
                avg.axpy(1.0 / m as f32, &g).unwrap();
            }
            x.sgd_step(&avg, eta, 0.0).unwrap();
        }

        for i in 0..dim {
            let a = distributed.as_slice()[i];
            let b = x.as_slice()[i];
            assert!((a - b).abs() < 1e-4, "component {i}: {a} vs {b}");
        }
    }
}
