//! Downpour SGD (paper section 3.3, reference [10]).
//!
//! Asynchronous master-based training: each worker keeps a local replica,
//! accumulates its gradients, and at its own pace (a) pushes the
//! accumulated gradient to the master and (b) fetches the master's current
//! model.  The paper's framework expresses these as the `K^(send)` /
//! `K^(receive)` matrices; operationally:
//!
//! * every `n_push` local steps: `x̃ ← x̃ − η · acc_m`, `acc_m ← 0`
//! * every `n_fetch` local steps: `x_m ← x̃`
//!
//! The master is a communication bottleneck and single point of failure —
//! the weakness GoSGD removes (paper section 3.3, last paragraph).

use crate::error::Result;
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// Asynchronous parameter-server strategy.
pub struct Downpour {
    n_push: u64,
    n_fetch: u64,
    eta: f32,
    /// Per-worker gradient accumulators (index 0 unused).
    acc: Vec<FlatVec>,
}

impl Downpour {
    /// `n_push` / `n_fetch`: local steps between pushes / fetches.
    /// `eta` must match the engine's learning rate (the master applies the
    /// accumulated gradient with the same step size).
    pub fn new(n_push: u64, n_fetch: u64, eta: f32) -> Self {
        assert!(n_push >= 1 && n_fetch >= 1);
        Downpour { n_push, n_fetch, eta, acc: Vec::new() }
    }

    fn ensure_acc(&mut self, workers: usize, dim: usize) {
        if self.acc.len() != workers + 1 {
            self.acc = vec![FlatVec::zeros(dim); workers + 1];
        }
    }
}

impl Strategy for Downpour {
    fn name(&self) -> String {
        format!("downpour(push={},fetch={})", self.n_push, self.n_fetch)
    }

    fn clock(&self) -> Clock {
        Clock::Asynchronous
    }

    fn after_local_step(
        &mut self,
        _t: u64,
        m: usize,
        grad: &FlatVec,
        state: &mut ClusterState,
        _rng: &mut Rng,
    ) -> Result<()> {
        let workers = state.workers();
        self.ensure_acc(workers, grad.len());
        self.acc[m].add_assign(grad)?;
        let local_steps = state.steps[m];
        let bytes = grad.len() * 4;

        if local_steps % self.n_push == 0 {
            // Master applies the accumulated gradient (send phase).
            let acc = std::mem::replace(&mut self.acc[m], FlatVec::zeros(grad.len()));
            state.stacked.get_mut(0).axpy(-self.eta, &acc)?;
            state.count_message(bytes);
        }
        if local_steps % self.n_fetch == 0 {
            // Worker fetches the master model (receive phase).
            *state.stacked.worker_mut(m) = state.stacked.master().clone();
            state.count_message(bytes);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::grad::{GradSource, QuadraticSource};

    #[test]
    fn master_tracks_descent() {
        let dim = 32;
        let eta = 1.5f32;
        let src = QuadraticSource::new(dim, 0.05, 31);
        let init = FlatVec::zeros(dim);
        let l0 = {
            let s = QuadraticSource::new(dim, 0.05, 31);
            s.true_loss(&init).unwrap()
        };
        let mut eng = Engine::new(
            Box::new(Downpour::new(4, 4, eta)),
            src,
            4,
            &init,
            eta,
            0.0,
            37,
        );
        eng.run(4 * 600).unwrap();
        let master = eng.state().stacked.master().clone();
        let l1 = eng.grad_source().true_loss(&master).unwrap();
        assert!(l1 < l0 * 0.3, "{l0} -> {l1}");
    }

    #[test]
    fn push_fetch_cadence_counts_messages() {
        let dim = 8;
        let src = QuadraticSource::new(dim, 0.1, 5);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(Downpour::new(5, 10, 0.1)),
            src,
            2,
            &init,
            0.1,
            0.0,
            7,
        );
        eng.run(1000).unwrap();
        // Each worker pushes every 5 local steps and fetches every 10:
        // total messages = total_local_steps/5 + total_local_steps/10.
        let total_local: u64 = eng.state().steps[1..].iter().sum();
        assert_eq!(total_local, 1000);
        let expect = eng.state().steps[1..]
            .iter()
            .map(|s| s / 5 + s / 10)
            .sum::<u64>();
        assert_eq!(eng.state().comm.messages, expect);
    }

    #[test]
    fn fetch_resets_worker_to_master() {
        let dim = 4;
        let src = QuadraticSource::new(dim, 0.0, 2);
        let init = FlatVec::zeros(dim);
        // fetch every step: worker equals master after each tick.
        let mut eng = Engine::new(
            Box::new(Downpour::new(1, 1, 0.2)),
            src,
            2,
            &init,
            0.2,
            0.0,
            3,
        );
        eng.run(50).unwrap();
        // the most recently awake worker must equal the master exactly
        let state = eng.state();
        let any_equal = (1..=2).any(|w| {
            state.stacked.worker(w).as_slice() == state.stacked.master().as_slice()
        });
        assert!(any_equal);
    }
}
