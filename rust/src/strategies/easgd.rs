//! EASGD — Elastic Averaging SGD (paper section 3.2, reference [9]).
//!
//! Every `tau` rounds, workers and the master move *toward each other*
//! elastically instead of being replaced by the average:
//!
//! ```text
//! x̃  ← (1 − Mα) x̃ + α Σ_m x_m
//! x_m ← α x̃ + (1 − α) x_m
//! ```
//!
//! Cheaper than PerSyn in bandwidth terms per sync in the original paper's
//! asynchronous variant, but as the paper notes it still requires a global
//! synchronization: the master must combine local models that have been
//! updated the same number of times — which is what makes it *slower in
//! wall clock* than GoSGD (Fig. 2).

use crate::error::Result;
use crate::framework::generators;
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// Elastic averaging against a master every `tau` rounds.
pub struct Easgd {
    alpha: f64,
    tau: u64,
}

impl Easgd {
    pub fn new(alpha: f64, tau: u64) -> Self {
        assert!(tau >= 1);
        assert!(alpha > 0.0, "alpha must be positive");
        Easgd { alpha, tau }
    }

    /// The paper's experiments compare methods at equal exchange frequency:
    /// probability `p` per worker per step ↔ sync every `1/p` rounds.
    /// `alpha` defaults to the EASGD paper's 0.9/M-style mixing scaled to a
    /// stable value; callers can override.
    pub fn from_probability(p: f64, m: usize) -> Self {
        let tau = (1.0 / p).round().max(1.0) as u64;
        // stability requires 1 - M·alpha >= 0; use the EASGD paper's
        // beta = 0.9 split evenly: alpha = 0.9 / M.
        Easgd::new(0.9 / m as f64, tau)
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn tau(&self) -> u64 {
        self.tau
    }
}

impl Strategy for Easgd {
    fn name(&self) -> String {
        format!("easgd(alpha={:.3},tau={})", self.alpha, self.tau)
    }

    fn clock(&self) -> Clock {
        Clock::Synchronous
    }

    fn after_round(&mut self, t: u64, state: &mut ClusterState, _rng: &mut Rng) -> Result<()> {
        let m = state.workers();
        if (t + 1) % self.tau != 0 {
            if state.recorder.is_some() {
                state.record_matrix(crate::framework::CommMatrix::identity(m + 1));
            }
            return Ok(());
        }
        if 1.0 - m as f64 * self.alpha < 0.0 {
            return Err(crate::error::Error::config(format!(
                "easgd unstable: 1 - M*alpha = {} < 0",
                1.0 - m as f64 * self.alpha
            )));
        }
        let alpha = self.alpha as f32;
        let bytes = state.stacked.vec_len() * 4;

        // x̃' = (1 − Mα) x̃ + α Σ x_m
        let mut new_master: FlatVec = state.stacked.master().clone();
        new_master.scale(1.0 - m as f32 * alpha);
        for w in 1..=m {
            new_master.axpy(alpha, state.stacked.worker(w))?;
        }
        // x_m' = α x̃ + (1 − α) x_m   (uses the *old* master, as in [9])
        let old_master = state.stacked.master().clone();
        for w in 1..=m {
            let xw = state.stacked.worker_mut(w);
            xw.scale(1.0 - alpha);
            xw.axpy(alpha, &old_master)?;
        }
        *state.stacked.get_mut(0) = new_master;

        // 2M messages: each worker sends x_m and receives x̃ (section 3.2).
        for _ in 0..(2 * m) {
            state.count_message(bytes);
        }
        state.count_barrier();
        state.record_matrix(generators::easgd(0, 1, self.alpha, m)?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::grad::{NoiseSource, QuadraticSource};
    use crate::tensor::FlatVec;

    #[test]
    fn elastic_update_matches_matrix_form() {
        // One round with zero gradients: state change must equal the
        // generators::easgd matrix applied to the stacked state.
        let dim = 4;
        let m = 3;
        let alpha = 0.2;
        let mut rng = crate::util::rng::Rng::new(1);
        let init = FlatVec::randn(dim, 1.0, &mut rng);
        let src = QuadraticSource::new(dim, 0.0, 2);
        let mut eng = Engine::new(Box::new(Easgd::new(alpha, 1)), src, m, &init, 0.0, 0.0, 3);
        eng.state_mut().enable_recording();
        // Perturb workers so the elastic move is visible.
        for w in 1..=m {
            *eng.state_mut().stacked.worker_mut(w) = FlatVec::randn(dim, 1.0, &mut rng);
        }
        let before = eng.state().stacked.clone();
        eng.run(1).unwrap();
        let k = generators::easgd(0, 1, alpha, m).unwrap();
        let want = k.apply(&before).unwrap();
        for slot in 0..=m {
            for i in 0..dim {
                let a = eng.state().stacked.get(slot).as_slice()[i];
                let b = want.get(slot).as_slice()[i];
                assert!((a - b).abs() < 1e-5, "slot {slot} comp {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn keeps_workers_loosely_coupled() {
        let dim = 32;
        let src = NoiseSource::new(dim, 4);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(Easgd::new(0.9 / 8.0, 10)),
            src,
            8,
            &init,
            1.0,
            0.0,
            5,
        );
        eng.run(400).unwrap();
        let eps = eng.state().stacked.consensus_error().unwrap();
        // Elastic coupling bounds the drift (Local would exceed this by a
        // lot — see the consensus harness).
        assert!(eps.is_finite() && eps > 0.0);
        let src_local = NoiseSource::new(dim, 4);
        let mut local = Engine::new(
            Box::new(crate::strategies::local::Local),
            src_local,
            8,
            &init,
            1.0,
            0.0,
            5,
        );
        local.run(400).unwrap();
        let eps_local = local.state().stacked.consensus_error().unwrap();
        assert!(eps < eps_local * 0.5, "easgd {eps} vs local {eps_local}");
    }

    #[test]
    fn unstable_alpha_is_rejected() {
        let dim = 4;
        let src = QuadraticSource::new(dim, 0.0, 1);
        let init = FlatVec::zeros(dim);
        // M = 8, alpha = 0.2 -> 1 - 1.6 < 0.
        let mut eng = Engine::new(Box::new(Easgd::new(0.2, 1)), src, 8, &init, 0.1, 0.0, 1);
        assert!(eng.run(1).is_err());
    }

    #[test]
    fn from_probability_scales_alpha_with_m() {
        let e = Easgd::from_probability(0.02, 8);
        assert_eq!(e.tau(), 50);
        assert!((e.alpha() - 0.1125).abs() < 1e-12);
    }
}
