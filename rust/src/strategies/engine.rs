//! Sequential training engine: the paper's universal-clock execution model.
//!
//! The engine owns the section-3 recursion — local half-step then
//! communication — and drives a [`Strategy`] under its declared clock:
//!
//! * **Synchronous** (`Algorithm 1/2`, EASGD): each round every worker
//!   computes a gradient *at its current variable* and applies it; then
//!   the strategy's [`Strategy::after_round`] communicates.
//! * **Asynchronous** (Downpour, GoSGD): each tick one uniformly-random
//!   worker is awake (the paper's finest-resolution clock); the strategy
//!   sees [`Strategy::before_local_step`] / [`Strategy::after_local_step`].
//!
//! The engine is deterministic given its seed — worker wake order,
//! Bernoulli sends and peer choices all flow from one split RNG — which is
//! what makes the figure-level experiments and the matrix cross-checks
//! reproducible.

use crate::error::Result;
use crate::metrics::LossCurve;
use crate::strategies::grad::GradSource;
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// Sequential driver for one strategy over one gradient source.
pub struct Engine<'a> {
    state: ClusterState,
    strategy: Box<dyn Strategy>,
    grad_source: Box<dyn GradSource + 'a>,
    eta: f32,
    weight_decay: f32,
    rng: Rng,
    /// Universal-clock tick counter (async) / round counter (sync).
    t: u64,
    /// Loss per engine step (mean across workers for sync rounds).
    pub losses: LossCurve,
    grad_buf: FlatVec,
}

impl<'a> Engine<'a> {
    /// Build an engine with `workers` replicas initialized to `init`.
    pub fn new(
        strategy: Box<dyn Strategy>,
        grad_source: impl GradSource + 'a,
        workers: usize,
        init: &FlatVec,
        eta: f32,
        weight_decay: f32,
        seed: u64,
    ) -> Self {
        let dim = init.len();
        assert_eq!(grad_source.dim(), dim, "grad source dim mismatch");
        Engine {
            state: ClusterState::new(workers, init),
            strategy,
            grad_source: Box::new(grad_source),
            eta,
            weight_decay,
            rng: Rng::new(seed),
            t: 0,
            losses: LossCurve::new(),
            grad_buf: FlatVec::zeros(dim),
        }
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut ClusterState {
        &mut self.state
    }

    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }

    pub fn grad_source(&self) -> &dyn GradSource {
        self.grad_source.as_ref()
    }

    pub fn ticks(&self) -> u64 {
        self.t
    }

    /// Run `steps` engine steps (rounds for sync strategies, single-worker
    /// ticks for async ones).
    pub fn run(&mut self, steps: u64) -> Result<()> {
        match self.strategy.clock() {
            Clock::Synchronous => self.run_sync(steps),
            Clock::Asynchronous => self.run_async(steps),
        }
    }

    fn run_sync(&mut self, rounds: u64) -> Result<()> {
        let m = self.state.workers();
        for _ in 0..rounds {
            let mut round_loss = 0.0;
            for w in 1..=m {
                let loss = {
                    let params = self.state.stacked.worker(w);
                    self.grad_source.grad(w, params, self.t, &mut self.grad_buf)?
                };
                round_loss += loss;
                self.apply_local_update(w)?;
                self.state.steps[w] += 1;
            }
            self.strategy.after_round(self.t, &mut self.state, &mut self.rng)?;
            self.losses.push(self.t, round_loss / m as f64);
            self.t += 1;
        }
        Ok(())
    }

    fn run_async(&mut self, ticks: u64) -> Result<()> {
        let m = self.state.workers();
        for _ in 0..ticks {
            // Paper's clock model: a single uniformly-random worker awakes.
            let w = 1 + self.rng.below(m as u64) as usize;
            self.strategy
                .before_local_step(self.t, w, &mut self.state, &mut self.rng)?;
            let loss = {
                let params = self.state.stacked.worker(w);
                self.grad_source.grad(w, params, self.t, &mut self.grad_buf)?
            };
            self.apply_local_update(w)?;
            self.state.steps[w] += 1;
            self.strategy.after_local_step(
                self.t,
                w,
                &self.grad_buf,
                &mut self.state,
                &mut self.rng,
            )?;
            self.losses.push(self.t, loss);
            self.t += 1;
        }
        Ok(())
    }

    /// The local half-step `x^(t+1/2)` (records the event if enabled).
    fn apply_local_update(&mut self, w: usize) -> Result<()> {
        // Weight decay folds into the recorded gradient so the matrix
        // replay (which only models plain steps) stays exact.
        if self.weight_decay != 0.0 {
            let params = self.state.stacked.worker(w).clone();
            self.grad_buf.axpy(self.weight_decay, &params)?;
        }
        if self.state.recorder.is_some() {
            let grad = self.grad_buf.clone();
            self.state.record_step(w, &grad, self.eta);
        }
        self.state
            .stacked
            .worker_mut(w)
            .axpy(-self.eta, &self.grad_buf)
    }

    /// Mean worker variable — the model the paper reports/returns.
    pub fn consensus_model(&self) -> Result<FlatVec> {
        self.state.stacked.worker_mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::allreduce::AllReduce;
    use crate::strategies::gosgd::GoSgd;
    use crate::strategies::grad::QuadraticSource;
    use crate::strategies::replay_events;

    #[test]
    fn sync_engine_counts_rounds_and_steps() {
        let src = QuadraticSource::new(8, 0.1, 1);
        let init = FlatVec::zeros(8);
        let mut eng = Engine::new(Box::new(AllReduce), src, 3, &init, 0.1, 0.0, 2);
        eng.run(10).unwrap();
        assert_eq!(eng.ticks(), 10);
        for w in 1..=3 {
            assert_eq!(eng.state().steps[w], 10);
        }
        assert_eq!(eng.losses.len(), 10);
    }

    #[test]
    fn async_engine_wakes_one_worker_per_tick() {
        let src = QuadraticSource::new(8, 0.1, 1);
        let init = FlatVec::zeros(8);
        let mut eng = Engine::new(Box::new(GoSgd::new(0.0)), src, 4, &init, 0.1, 0.0, 3);
        eng.run(1000).unwrap();
        let total: u64 = eng.state().steps[1..].iter().sum();
        assert_eq!(total, 1000);
        // roughly uniform wake distribution
        for w in 1..=4 {
            let s = eng.state().steps[w];
            assert!((s as f64 - 250.0).abs() < 70.0, "worker {w}: {s}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let init = FlatVec::zeros(16);
        let mk = || {
            let src = QuadraticSource::new(16, 0.2, 7);
            let mut eng =
                Engine::new(Box::new(GoSgd::new(0.3)), src, 4, &init, 0.2, 1e-4, 11);
            eng.run(500).unwrap();
            eng.consensus_model().unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn losses_decrease_on_quadratic() {
        let src = QuadraticSource::new(32, 0.05, 5);
        let init = FlatVec::zeros(32);
        let mut eng = Engine::new(Box::new(AllReduce), src, 4, &init, 2.0, 0.0, 6);
        eng.run(200).unwrap();
        let first = eng.losses.window_mean(0, 10);
        let last = eng.losses.window_mean(190, 200);
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    fn recorded_events_replay_to_identical_state_sync() {
        // The matrix-framework cross-check in miniature: AllReduce engine
        // run == replay of its event log through K^(t) products.
        let dim = 8;
        let src = QuadraticSource::new(dim, 0.3, 9);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(Box::new(AllReduce), src, 3, &init, 0.4, 0.0, 10);
        eng.state_mut().enable_recording();
        eng.run(20).unwrap();
        let events = &eng.state().recorder.as_ref().unwrap().events;
        let replayed = replay_events(3, &init, events).unwrap();
        for slot in 0..=3 {
            for i in 0..dim {
                let a = eng.state().stacked.get(slot).as_slice()[i];
                let b = replayed.get(slot).as_slice()[i];
                assert!((a - b).abs() < 1e-4, "slot {slot} comp {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn weight_decay_shrinks_solution_norm() {
        let dim = 16;
        let init = FlatVec::zeros(dim);
        let mk = |wd: f32| {
            let src = QuadraticSource::new(dim, 0.05, 21);
            let mut eng = Engine::new(Box::new(AllReduce), src, 2, &init, 1.0, wd, 22);
            eng.run(500).unwrap();
            eng.consensus_model().unwrap().norm()
        };
        let plain = mk(0.0);
        let decayed = mk(0.05);
        assert!(decayed < plain, "decayed {decayed} vs plain {plain}");
    }
}
