//! GoSGD — the paper's contribution (section 4, Algorithms 3 & 4).
//!
//! Fully asynchronous, fully decentralized distributed SGD:
//!
//! * **Universal clock** (shared with Downpour's analysis): at each tick a
//!   single random worker `s` is awake.
//! * **Process messages first** (Algorithm 3, line 4): drain the own
//!   mailbox, folding each `(x, w)` in with the sum-weight blend
//!   `x_r ← w_r/(w_r+w_s)·x_r + w_s/(w_r+w_s)·x_s, w_r ← w_r + w_s`.
//! * **Local gradient step** (engine's job).
//! * **Bernoulli send** (Algorithm 3, lines 6-9): with probability `p`,
//!   pick a uniform peer `r ≠ s`, halve the own weight and push
//!   `(x_s, w_s/2)` to `q_r` — non-blocking, exactly one message.
//!
//! The whole state machine — blend coefficients, weight halving, the
//! round-robin shard cursor — lives in the runtime-agnostic
//! [`ProtocolCore`](crate::gossip::ProtocolCore); this strategy is only
//! the *driver* that wires the cores into the sequential engine's
//! universal clock: it empties the engine's mailboxes, hands each message
//! to the awake worker's core, and delivers the core's outbound messages
//! into the receivers' queues.  The OS-thread runtime
//! ([`crate::worker::ThreadedGossip`]) and the discrete-event simulator
//! ([`crate::sim::DesEngine`]) drive the very same cores under their own
//! clocks.
//!
//! The blend itself is exactly the `mix` Pallas kernel of Layer 1; the
//! sequential engine uses the host [`FlatVec::mix_from`] path and the PJRT
//! integration test asserts both produce the same numbers.

use crate::error::{Error, Result};
use crate::framework::generators;
use crate::gossip::{wire_bytes_for, CodecSpec, Message, PeerSelector, TopologySpec};
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// GoSGD configuration: the exchange policy the engine's protocol cores
/// are configured with.
pub struct GoSgd {
    /// Exchange probability per awake step (the paper's `p`).
    p: f64,
    /// Receiver selection topology (paper: uniform random) — see
    /// [`crate::gossip::topology`].
    topology: TopologySpec,
    /// Deliver exchanges instantly instead of queueing — used only by the
    /// matrix-framework cross-check, where `K^(t)` acts on current state.
    immediate: bool,
    /// Shards per exchange: 1 = the paper's whole-vector protocol; > 1
    /// ships one round-robin shard per gossip event (see
    /// [`crate::gossip::shard`]), cutting per-event bytes by `~1/shards`.
    shards: usize,
    /// Payload codec applied to every message body (see
    /// [`crate::gossip::codec`]); dense by default.
    codec: CodecSpec,
    /// Reusable drain buffer for `ProcessMessages`: refilled from the
    /// awake worker's queue each tick, so the steady-state drain never
    /// allocates (capacity persists across ticks).
    inbox: Vec<Message>,
}

impl GoSgd {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be a probability, got {p}");
        GoSgd {
            p,
            topology: TopologySpec::UniformRandom,
            immediate: false,
            shards: 1,
            codec: CodecSpec::Dense,
            inbox: Vec::new(),
        }
    }

    /// Legacy `--peer` form of [`GoSgd::with_topology`].
    pub fn with_selector(self, selector: PeerSelector) -> Self {
        self.with_topology(selector.into())
    }

    /// Receiver-selection topology: `uniform` (the paper), `ring`,
    /// `hypercube`, `rotation`, or `smallworld:Q` — see
    /// [`crate::gossip::topology`].
    pub fn with_topology(mut self, topology: TopologySpec) -> Self {
        self.topology = topology;
        self
    }

    /// Sharded exchange: each send ships one of `shards` contiguous slices
    /// of the vector (round-robin per sender) together with that shard's
    /// own sum weight.  Exact per shard — see the module docs of
    /// [`crate::gossip::shard`].
    pub fn with_shards(mut self, shards: usize) -> Self {
        assert!(shards >= 1, "shards must be >= 1, got {shards}");
        self.shards = shards;
        self
    }

    /// Compress message bodies with a payload codec (dense / top-k / u8
    /// quantization — see [`crate::gossip::codec`]).
    pub fn with_codec(mut self, codec: CodecSpec) -> Self {
        self.codec = codec;
        self
    }

    /// Immediate-delivery mode (cross-check only; the real protocol queues).
    pub fn immediate_delivery(mut self) -> Self {
        self.immediate = true;
        self
    }

    pub fn p(&self) -> f64 {
        self.p
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn codec(&self) -> CodecSpec {
        self.codec
    }

    pub fn topology(&self) -> TopologySpec {
        self.topology
    }

    /// Immediate-delivery exchange (cross-check only): the send-side core
    /// transition runs as usual, but the exchange is applied to *current*
    /// state through the recorded `K^(t)` matrix — block-diagonal for a
    /// shard — so the framework replay is float-for-float identical.
    fn exchange_immediately(
        &mut self,
        s: usize,
        r: usize,
        state: &mut ClusterState,
    ) -> Result<()> {
        let m = state.workers();
        let (shard, shipped) = state.cores[s].begin_send();
        let w_r = state.cores[r].weights()[shard.index].value();
        let k = generators::gossip_exchange(m, s, r, shipped.value(), w_r)?;
        if shard.is_full() {
            state.record_matrix(k);
            let t = state.cores[r].absorb_weight(shard.index, shipped);
            let snapshot = state.stacked.worker(s).clone();
            state.stacked.worker_mut(r).mix_from(&snapshot, 1.0 - t, t)?;
            state.count_message(wire_bytes_for(shard.len, false));
        } else {
            state.record_matrix_block(k.clone(), shard.offset, shard.len);
            state.stacked = k.apply_block(&state.stacked, shard.offset, shard.len)?;
            state.cores[r].absorb_weight(shard.index, shipped);
            state.count_message(wire_bytes_for(shard.len, true));
        }
        Ok(())
    }
}

impl Strategy for GoSgd {
    fn name(&self) -> String {
        let mut name = format!("gosgd(p={}", self.p);
        if self.shards > 1 {
            name.push_str(&format!(",shards={}", self.shards));
        }
        if self.codec != CodecSpec::Dense {
            name.push_str(&format!(",codec={}", self.codec.label()));
        }
        if self.topology != TopologySpec::UniformRandom {
            name.push_str(&format!(",topo={}", self.topology.label()));
        }
        name.push(')');
        name
    }

    fn clock(&self) -> Clock {
        Clock::Asynchronous
    }

    fn before_local_step(
        &mut self,
        _t: u64,
        m: usize,
        state: &mut ClusterState,
        _rng: &mut Rng,
    ) -> Result<()> {
        state.configure_gossip(self.p, self.topology, self.shards, self.codec)?;
        // ProcessMessages (Algorithm 4): drain the mailbox into the
        // reusable inbox, fold each message in through the worker's
        // protocol core.  Dropping each absorbed message retires its
        // pooled payload storage for the next emit.
        debug_assert!(self.inbox.is_empty());
        state.queues[m].drain_into(&mut self.inbox);
        let (cores, stacked) = (&mut state.cores, &mut state.stacked);
        for msg in self.inbox.drain(..) {
            cores[m].absorb_message(stacked.worker_mut(m), &msg)?;
        }
        Ok(())
    }

    fn after_local_step(
        &mut self,
        _t: u64,
        s: usize,
        _grad: &FlatVec,
        state: &mut ClusterState,
        rng: &mut Rng,
    ) -> Result<()> {
        let m = state.workers();
        if self.immediate {
            // Cross-check path: same gate and peer pick as the core's
            // emit, applied through the exchange matrix right now.  The
            // matrix replay has no notion of encoded payloads, so the
            // cross-check only speaks dense.
            if self.codec != CodecSpec::Dense {
                return Err(Error::config(
                    "immediate-delivery cross-check supports only the dense codec",
                ));
            }
            if m < 2 || !rng.bernoulli(self.p) {
                return Ok(());
            }
            // The core's topology schedule picks the receiver (slots are
            // 1-based), so the cross-check and the queued path walk the
            // identical schedule cursor.
            let r = state.cores[s].pick_peer(m, rng) + 1;
            debug_assert_ne!(r, s);
            return self.exchange_immediately(s, r, state);
        }
        // PushMessage: the core runs the whole send-side transition
        // (Bernoulli gate, peer pick, cursor advance, weight halving,
        // payload snapshot); the driver only delivers.
        let out = {
            let (cores, stacked) = (&mut state.cores, &state.stacked);
            cores[s].emit(stacked.worker(s), m, rng)?
        };
        if let Some(out) = out {
            let r = out.to + 1; // cores are 0-based, slots 1-based
            let msg = out.into_message(s, state.steps[s]);
            state.count_message_encoded(msg.wire_bytes(), msg.raw_wire_bytes());
            state.queues[r].push(msg);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::grad::{GradSource, NoiseSource, QuadraticSource};
    use crate::util::proptest::check;

    fn run_gosgd(p: f64, steps: u64, seed: u64) -> Engine<'static> {
        let dim = 32;
        let src = NoiseSource::new(dim, seed);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(Box::new(GoSgd::new(p)), src, 8, &init, 1.0, 0.0, seed);
        eng.run(steps).unwrap();
        eng
    }

    #[test]
    fn message_rate_matches_p() {
        let steps = 40_000;
        let eng = run_gosgd(0.1, steps, 3);
        let rate = eng.state().comm.messages as f64 / steps as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate = {rate}");
        // Decentralized: never a barrier.
        assert_eq!(eng.state().comm.barriers, 0);
    }

    #[test]
    fn p_zero_sends_nothing() {
        let eng = run_gosgd(0.0, 1000, 4);
        assert_eq!(eng.state().comm.messages, 0);
    }

    #[test]
    fn weight_mass_is_conserved_including_in_flight() {
        let eng = run_gosgd(0.5, 5000, 5);
        let state = eng.state();
        let m = state.workers();
        let mut total: f64 = (1..=m).map(|w| state.cores[w].weights()[0].value()).sum();
        for q in &state.queues {
            for msg in q.drain() {
                total += msg.weight.value();
            }
        }
        assert!((total - 1.0).abs() < 1e-9, "total weight {total}");
    }

    #[test]
    fn gossip_bounds_consensus_error_vs_local() {
        let dim = 64;
        let steps = 4000;
        let init = FlatVec::zeros(dim);
        let mk = |strategy: Box<dyn crate::strategies::Strategy>| {
            let src = NoiseSource::new(dim, 11);
            let mut eng = Engine::new(strategy, src, 8, &init, 1.0, 0.0, 13);
            eng.run(steps).unwrap();
            eng.state().stacked.consensus_error().unwrap()
        };
        let eps_gossip = mk(Box::new(GoSgd::new(0.1)));
        let eps_local = mk(Box::new(crate::strategies::local::Local));
        assert!(
            eps_gossip < eps_local * 0.2,
            "gossip {eps_gossip} vs local {eps_local}"
        );
    }

    #[test]
    fn converges_on_quadratic() {
        let dim = 32;
        let init = FlatVec::zeros(dim);
        let src = QuadraticSource::new(dim, 0.1, 17);
        let target_loss = {
            let s = QuadraticSource::new(dim, 0.1, 17);
            s.true_loss(&init).unwrap()
        };
        let mut eng = Engine::new(Box::new(GoSgd::new(0.05)), src, 8, &init, 2.0, 0.0, 19);
        eng.run(8 * 500).unwrap();
        let mean = eng.state().stacked.worker_mean().unwrap();
        let final_loss = eng.grad_source().true_loss(&mean).unwrap();
        assert!(
            final_loss < target_loss * 0.2,
            "{target_loss} -> {final_loss}"
        );
    }

    #[test]
    fn immediate_mode_equals_queued_mode_when_messages_processed_next_tick() {
        // Not an exact equality in general (queued delivery is delayed),
        // but with p=1 and M=2 every message is processed at the receiver's
        // next awake tick; statistically both modes must keep workers close.
        check("immediate vs queued stay consistent", 5, |rng| {
            let dim = 8;
            let seed = rng.next_u64();
            let init = FlatVec::zeros(dim);
            let mk = |imm: bool| {
                let strategy = if imm {
                    GoSgd::new(1.0).immediate_delivery()
                } else {
                    GoSgd::new(1.0)
                };
                let src = NoiseSource::new(dim, seed);
                let mut eng =
                    Engine::new(Box::new(strategy), src, 2, &init, 0.1, 0.0, seed ^ 1);
                eng.run(500).unwrap();
                eng.state().stacked.consensus_error().unwrap()
            };
            let eps_imm = mk(true);
            let eps_queue = mk(false);
            assert!(eps_imm < 1.0, "immediate eps {eps_imm}");
            assert!(eps_queue < 2.0, "queued eps {eps_queue}");
        });
    }

    #[test]
    fn sharded_weight_mass_is_conserved_per_shard() {
        // Each shard carries its own unit of mass: workers + in-flight
        // shard-k messages must sum to exactly 1 for every k.
        let dim = 64;
        let shards = 4;
        let src = NoiseSource::new(dim, 29);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(0.5).with_shards(shards)),
            src,
            8,
            &init,
            1.0,
            0.0,
            31,
        );
        eng.run(5000).unwrap();
        let state = eng.state();
        let m = state.workers();
        let mut totals = vec![0.0f64; shards];
        for w in 1..=m {
            for (k, wgt) in state.cores[w].weights().iter().enumerate() {
                totals[k] += wgt.value();
            }
        }
        for q in &state.queues {
            for msg in q.drain() {
                assert!(!msg.shard.is_full(), "sharded run must send shard messages");
                totals[msg.shard.index] += msg.weight.value();
            }
        }
        for (k, total) in totals.iter().enumerate() {
            assert!((total - 1.0).abs() < 1e-9, "shard {k} mass {total}");
        }
    }

    #[test]
    fn sharding_cuts_bytes_per_message_by_shard_count() {
        // Acceptance: bytes per gossip event drop by ~1/shards.
        let dim = 256;
        let run = |shards: usize| {
            let src = NoiseSource::new(dim, 7);
            let init = FlatVec::zeros(dim);
            let mut eng = Engine::new(
                Box::new(GoSgd::new(0.2).with_shards(shards)),
                src,
                8,
                &init,
                1.0,
                0.0,
                9,
            );
            eng.run(4000).unwrap();
            let comm = eng.state().comm;
            assert!(comm.messages > 0);
            comm.bytes as f64 / comm.messages as f64
        };
        let full = run(1);
        let quarter = run(4);
        let ratio = quarter / full;
        // dim 256, 4 shards: (64*4 + 32) / (256*4 + 24) = 0.274…
        assert!(
            (0.2..0.32).contains(&ratio),
            "bytes/msg ratio {ratio} should be ~1/4 (full {full}, sharded {quarter})"
        );
    }

    #[test]
    fn sharded_consensus_matches_unsharded_at_equal_coordinate_budget() {
        // Acceptance: at the same per-coordinate exchange rate (p, shards)
        // = (0.4, 4) vs (0.1, 1), sharded GoSGD reaches a consensus
        // residual of the same order, and both are far below silence.
        let dim = 64;
        let steps = 8000;
        let init = FlatVec::zeros(dim);
        let mk = |strategy: Box<dyn crate::strategies::Strategy>| {
            let src = NoiseSource::new(dim, 11);
            let mut eng = Engine::new(strategy, src, 8, &init, 1.0, 0.0, 13);
            eng.run(steps).unwrap();
            eng.state().stacked.consensus_error().unwrap()
        };
        let eps_full = mk(Box::new(GoSgd::new(0.1)));
        let eps_sharded = mk(Box::new(GoSgd::new(0.4).with_shards(4)));
        let eps_local = mk(Box::new(crate::strategies::local::Local));
        assert!(
            eps_sharded < eps_local * 0.2,
            "sharded gossip {eps_sharded} vs local {eps_local}"
        );
        let ratio = eps_sharded / eps_full;
        assert!(
            (0.1..10.0).contains(&ratio),
            "sharded {eps_sharded} vs full {eps_full}: same order expected"
        );
    }

    #[test]
    fn sharded_round_robin_covers_every_shard() {
        let dim = 60;
        let shards = 5;
        let src = NoiseSource::new(dim, 3);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(1.0).with_shards(shards)),
            src,
            4,
            &init,
            1.0,
            0.0,
            5,
        );
        eng.run(400).unwrap();
        let state = eng.state();
        let mut seen = vec![0u64; shards];
        for q in &state.queues {
            for msg in q.drain() {
                seen[msg.shard.index] += 1;
            }
        }
        // In-flight alone won't cover all shards, but the absorbed weights
        // witness traffic: any shard never sent would still hold 1/M at
        // every worker AND have zero queued messages.  With p = 1 and 400
        // ticks the round-robin cursor laps many times, so every shard must
        // have moved some mass somewhere.
        let m = state.workers();
        for k in 0..shards {
            let untouched = (1..=m).all(|w| {
                (state.cores[w].weights()[k].value() - 1.0 / m as f64).abs() < 1e-15
            });
            assert!(
                !untouched || seen[k] > 0,
                "shard {k} saw no traffic in 400 p=1 ticks"
            );
        }
    }

    #[test]
    fn oversized_shard_count_is_a_config_error_not_a_panic() {
        let dim = 8;
        let src = NoiseSource::new(dim, 1);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(1.0).with_shards(1000)),
            src,
            2,
            &init,
            0.1,
            0.0,
            2,
        );
        let err = eng.run(10).unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
    }

    #[test]
    fn queues_are_fully_drained_at_wake() {
        // After a long run, total pushed == total drained + still queued:
        // no message is ever lost (asymmetric protocol, no drops).
        let eng = run_gosgd(0.5, 10_000, 23);
        let state = eng.state();
        let mut pushed = 0;
        let mut drained = 0;
        let mut depth = 0;
        for q in &state.queues {
            let s = q.stats();
            pushed += s.pushed;
            drained += s.drained;
            depth += q.len() as u64;
        }
        assert_eq!(pushed, state.comm.messages);
        assert_eq!(pushed, drained + depth);
    }

    // ---- payload codecs through the engine driver ------------------------

    fn run_codec(codec: CodecSpec, dim: usize, shards: usize, steps: u64) -> Engine<'static> {
        let src = NoiseSource::new(dim, 41);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(0.5).with_shards(shards).with_codec(codec)),
            src,
            8,
            &init,
            1.0,
            0.0,
            43,
        );
        eng.run(steps).unwrap();
        eng
    }

    #[test]
    fn q8_codec_cuts_encoded_bytes_at_least_3x_at_equal_shard_count() {
        // The acceptance ratio: same shard count, q8 vs dense, >= 3x fewer
        // encoded wire bytes per message (raw accounting identical).
        let (dim, shards, steps) = (2048, 4, 2000);
        let dense = run_codec(CodecSpec::Dense, dim, shards, steps);
        let q8 = run_codec(CodecSpec::QuantizeU8, dim, shards, steps);
        let dense_per_msg =
            dense.state().comm.bytes as f64 / dense.state().comm.messages as f64;
        let q8_per_msg = q8.state().comm.bytes as f64 / q8.state().comm.messages as f64;
        assert!(
            dense_per_msg >= 3.0 * q8_per_msg,
            "dense {dense_per_msg} vs q8 {q8_per_msg} bytes/msg"
        );
        // Raw accounting is codec-independent and matches dense's wire.
        assert_eq!(
            q8.state().comm.raw_bytes / q8.state().comm.messages,
            dense.state().comm.bytes / dense.state().comm.messages,
        );
        assert_eq!(dense.state().comm.bytes, dense.state().comm.raw_bytes);
    }

    #[test]
    fn codec_runs_conserve_mass_per_shard_in_the_engine() {
        for codec in [CodecSpec::QuantizeU8, CodecSpec::TopK { k: 8 }] {
            let shards = 4;
            let eng = run_codec(codec, 64, shards, 3000);
            let state = eng.state();
            let m = state.workers();
            let mut totals = vec![0.0f64; shards];
            for w in 1..=m {
                for (k, wgt) in state.cores[w].weights().iter().enumerate() {
                    totals[k] += wgt.value();
                }
            }
            for q in &state.queues {
                for msg in q.drain() {
                    totals[msg.shard.index] += msg.weight.value();
                }
            }
            for (k, total) in totals.iter().enumerate() {
                assert!(
                    (total - 1.0).abs() < 1e-9,
                    "codec {codec:?}: shard {k} mass {total}"
                );
            }
        }
    }

    #[test]
    fn codec_runs_still_bound_consensus_error() {
        // Compressed exchange must still couple the workers far below the
        // no-communication baseline.
        let dim = 64;
        let steps = 6000;
        let init = FlatVec::zeros(dim);
        let mk = |strategy: Box<dyn crate::strategies::Strategy>| {
            let src = NoiseSource::new(dim, 47);
            let mut eng = Engine::new(strategy, src, 8, &init, 1.0, 0.0, 53);
            eng.run(steps).unwrap();
            eng.state().stacked.consensus_error().unwrap()
        };
        let eps_local = mk(Box::new(crate::strategies::local::Local));
        for codec in [CodecSpec::QuantizeU8, CodecSpec::TopK { k: 8 }] {
            let eps = mk(Box::new(GoSgd::new(0.5).with_shards(4).with_codec(codec)));
            assert!(
                eps < eps_local * 0.3,
                "codec {codec:?}: eps {eps} vs local {eps_local}"
            );
        }
    }

    #[test]
    fn immediate_mode_rejects_compressed_codecs() {
        let dim = 8;
        let src = NoiseSource::new(dim, 3);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(1.0).immediate_delivery().with_codec(CodecSpec::QuantizeU8)),
            src,
            2,
            &init,
            0.1,
            0.0,
            5,
        );
        let err = eng.run(10).unwrap_err();
        assert!(err.to_string().contains("dense codec"), "{err}");
    }

    #[test]
    fn codec_name_reports_the_codec() {
        let s = GoSgd::new(0.02).with_shards(8).with_codec(CodecSpec::QuantizeU8);
        assert_eq!(s.name(), "gosgd(p=0.02,shards=8,codec=q8)");
        assert_eq!(GoSgd::new(0.02).name(), "gosgd(p=0.02)");
        let s = GoSgd::new(0.02).with_topology(TopologySpec::PartnerRotation);
        assert_eq!(s.name(), "gosgd(p=0.02,topo=rotation)");
    }

    // ---- gossip topologies through the engine driver ---------------------

    #[test]
    fn every_topology_trains_and_bounds_consensus_error() {
        let dim = 64;
        let steps = 6000;
        let init = FlatVec::zeros(dim);
        let mk = |strategy: Box<dyn crate::strategies::Strategy>| {
            let src = NoiseSource::new(dim, 59);
            let mut eng = Engine::new(strategy, src, 8, &init, 1.0, 0.0, 61);
            eng.run(steps).unwrap();
            eng.state().stacked.consensus_error().unwrap()
        };
        let eps_local = mk(Box::new(crate::strategies::local::Local));
        for topo in [
            TopologySpec::Ring,
            TopologySpec::Hypercube, // 8 workers: a 3-cube
            TopologySpec::PartnerRotation,
        ] {
            let eps = mk(Box::new(GoSgd::new(0.5).with_topology(topo)));
            assert!(
                eps < eps_local * 0.3,
                "topology {topo:?}: eps {eps} vs local {eps_local}"
            );
        }
    }

    #[test]
    fn topology_runs_conserve_mass_per_shard_in_the_engine() {
        for topo in [
            TopologySpec::Ring,
            TopologySpec::Hypercube,
            TopologySpec::PartnerRotation,
        ] {
            let dim = 64;
            let shards = 4;
            let src = NoiseSource::new(dim, 67);
            let init = FlatVec::zeros(dim);
            let mut eng = Engine::new(
                Box::new(GoSgd::new(0.5).with_shards(shards).with_topology(topo)),
                src,
                8,
                &init,
                1.0,
                0.0,
                71,
            );
            eng.run(3000).unwrap();
            let state = eng.state();
            let m = state.workers();
            let mut totals = vec![0.0f64; shards];
            for w in 1..=m {
                for (k, wgt) in state.cores[w].weights().iter().enumerate() {
                    totals[k] += wgt.value();
                }
            }
            for q in &state.queues {
                for msg in q.drain() {
                    totals[msg.shard.index] += msg.weight.value();
                }
            }
            for (k, total) in totals.iter().enumerate() {
                assert!(
                    (total - 1.0).abs() < 1e-9,
                    "topology {topo:?}: shard {k} mass {total}"
                );
            }
        }
    }

    #[test]
    fn hypercube_with_wrong_fleet_size_is_a_config_error_not_a_panic() {
        let dim = 16;
        let src = NoiseSource::new(dim, 3);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(
            Box::new(GoSgd::new(0.5).with_topology(TopologySpec::Hypercube)),
            src,
            6, // not a power of two
            &init,
            0.1,
            0.0,
            5,
        );
        let err = eng.run(10).unwrap_err();
        assert!(err.to_string().contains("hypercube"), "{err}");
    }
}
