//! Gradient sources for the sequential engine.
//!
//! The engine is generic over where gradients come from:
//!
//! * [`QuadraticSource`] — a noisy quadratic bowl.  Convex, with a known
//!   optimum and controllable gradient noise: ideal for convergence and
//!   equivalence tests (Algorithm 1 ≡ bigger batches, Appendix A variance
//!   scaling).
//! * [`NoiseSource`] — pure i.i.d. `N(0, 1)` "gradients", the worst-case
//!   protocol of the paper's consensus experiment (section 5.2, Fig. 4).
//! * `PjrtSource` (in [`crate::runtime`]) — the real Layer-2 CNN through
//!   the AOT artifacts.

use crate::error::Result;
use crate::tensor::FlatVec;
use crate::util::rng::Rng;

/// Produces per-worker stochastic gradients.
///
/// Deliberately NOT `Send`: the PJRT-backed implementation wraps raw
/// client pointers.  The sequential/DES engines are single-threaded; the
/// threaded runtime gives each worker thread its own source instance.
pub trait GradSource {
    /// Write the gradient of worker `m`'s loss at `params` into `out`;
    /// return the (stochastic) loss value.
    fn grad(&mut self, m: usize, params: &FlatVec, step: u64, out: &mut FlatVec) -> Result<f64>;

    /// Dimension of the parameter space.
    fn dim(&self) -> usize;

    /// Deterministic full-batch loss (for reporting), if the source has one.
    fn true_loss(&self, _params: &FlatVec) -> Option<f64> {
        None
    }

    /// A clone of this source for a parallel DES shard thread, if the
    /// implementation supports one.  A fork must produce bit-identical
    /// gradients to the original for every `(m, step)` pair — the
    /// parallel executor's determinism contract leans on per-call purity
    /// (both shipped sources key an RNG stream by `(m, step)` and never
    /// advance shared state), not on sharing.  The default `None` makes
    /// the engine reject `Sharded(T)` runs with a config error instead
    /// of silently diverging; PJRT-backed sources stay sequential-only.
    fn fork(&self) -> Option<Box<dyn GradSource + Send>> {
        None
    }
}

/// Noisy quadratic: `L(x) = 0.5‖x − x*‖²/d`, gradient `(x − x*)/d + σ z`,
/// `z ~ N(0, I)`.  The `1/d` scaling keeps losses O(1) across dimensions.
///
/// Mimics the mini-batch setting of Appendix A: the gradient estimator is
/// unbiased with covariance `σ² I`, and averaging `N` draws divides the
/// error variance by `N` — which the `variance_scaling` bench reproduces.
pub struct QuadraticSource {
    target: FlatVec,
    sigma: f32,
    rng: Rng,
    scratch: Vec<f32>,
}

impl QuadraticSource {
    pub fn new(dim: usize, sigma: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let target = FlatVec::randn(dim, 1.0, &mut rng);
        QuadraticSource { target, sigma, rng: rng.split(0xC0FFEE), scratch: vec![0.0; dim] }
    }

    /// The optimum `x*`.
    pub fn target(&self) -> &FlatVec {
        &self.target
    }
}

impl GradSource for QuadraticSource {
    fn grad(&mut self, m: usize, params: &FlatVec, step: u64, out: &mut FlatVec) -> Result<f64> {
        let d = self.target.len() as f32;
        // Per-(worker, step) noise stream: deterministic and independent.
        let mut noise_rng = self.rng.split((m as u64) << 32 | step);
        noise_rng.fill_normal(&mut self.scratch, self.sigma);
        let mut loss = 0.0f64;
        let inv_d = 1.0 / d;
        for i in 0..params.len() {
            let diff = params.as_slice()[i] - self.target.as_slice()[i];
            loss += 0.5 * (diff * diff) as f64;
            out.as_mut_slice()[i] = diff * inv_d + self.scratch[i];
        }
        Ok(loss / d as f64)
    }

    fn dim(&self) -> usize {
        self.target.len()
    }

    fn true_loss(&self, params: &FlatVec) -> Option<f64> {
        let d = self.target.len() as f64;
        Some(params.dist_sq(&self.target).ok()? * 0.5 / d)
    }

    fn fork(&self) -> Option<Box<dyn GradSource + Send>> {
        Some(Box::new(QuadraticSource {
            target: self.target.clone(),
            sigma: self.sigma,
            rng: self.rng.clone(),
            scratch: self.scratch.clone(),
        }))
    }
}

/// Worst-case consensus workload (paper section 5.2): the "gradient" is
/// i.i.d. `N(0, 1)` on every worker, fully uncorrelated across workers —
/// local models drift apart as fast as possible and only communication
/// holds them together.
pub struct NoiseSource {
    dim: usize,
    rng: Rng,
}

impl NoiseSource {
    pub fn new(dim: usize, seed: u64) -> Self {
        NoiseSource { dim, rng: Rng::new(seed) }
    }
}

impl GradSource for NoiseSource {
    fn grad(&mut self, m: usize, _params: &FlatVec, step: u64, out: &mut FlatVec) -> Result<f64> {
        let mut r = self.rng.split((m as u64) << 32 | step);
        r.fill_normal(out.as_mut_slice(), 1.0);
        Ok(0.0)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn fork(&self) -> Option<Box<dyn GradSource + Send>> {
        Some(Box::new(NoiseSource { dim: self.dim, rng: self.rng.clone() }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_gradient_points_at_target() {
        let mut src = QuadraticSource::new(64, 0.0, 7);
        let params = FlatVec::zeros(64);
        let mut g = FlatVec::zeros(64);
        let loss = src.grad(1, &params, 0, &mut g).unwrap();
        assert!(loss > 0.0);
        // With zero noise: g = (0 - x*)/d, so x - η·d·g == x* after one step.
        let d = 64.0f32;
        let mut x = params.clone();
        x.axpy(-d, &g).unwrap();
        for i in 0..64 {
            assert!((x.as_slice()[i] - src.target().as_slice()[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn quadratic_noise_is_unbiased() {
        let mut src = QuadraticSource::new(16, 0.5, 3);
        let params = FlatVec::zeros(16);
        let mut g = FlatVec::zeros(16);
        let mut mean = vec![0.0f64; 16];
        let trials = 4000;
        for s in 0..trials {
            src.grad(1, &params, s, &mut g).unwrap();
            for (mu, &v) in mean.iter_mut().zip(g.as_slice()) {
                *mu += v as f64;
            }
        }
        let d = 16.0f64;
        for (i, mu) in mean.iter().enumerate() {
            let want = -(src.target().as_slice()[i] as f64) / d;
            let got = mu / trials as f64;
            // stderr = sigma/sqrt(trials) ≈ 0.008
            assert!((got - want).abs() < 0.05, "i={i}: {got} vs {want}");
        }
    }

    #[test]
    fn quadratic_descends_under_sgd() {
        let mut src = QuadraticSource::new(32, 0.05, 11);
        let mut x = FlatVec::zeros(32);
        let mut g = FlatVec::zeros(32);
        let l0 = src.true_loss(&x).unwrap();
        for s in 0..300 {
            src.grad(1, &x, s, &mut g).unwrap();
            x.sgd_step(&g, 1.0, 0.0).unwrap();
        }
        let l1 = src.true_loss(&x).unwrap();
        assert!(l1 < l0 * 0.5, "{l0} -> {l1}");
    }

    #[test]
    fn noise_source_is_deterministic_per_worker_step() {
        let mut a = NoiseSource::new(8, 5);
        let mut b = NoiseSource::new(8, 5);
        let p = FlatVec::zeros(8);
        let mut ga = FlatVec::zeros(8);
        let mut gb = FlatVec::zeros(8);
        a.grad(2, &p, 7, &mut ga).unwrap();
        b.grad(2, &p, 7, &mut gb).unwrap();
        assert_eq!(ga.as_slice(), gb.as_slice());
        b.grad(3, &p, 7, &mut gb).unwrap();
        assert_ne!(ga.as_slice(), gb.as_slice());
    }

    #[test]
    fn noise_source_unit_variance() {
        let mut src = NoiseSource::new(1000, 9);
        let p = FlatVec::zeros(1000);
        let mut g = FlatVec::zeros(1000);
        src.grad(1, &p, 0, &mut g).unwrap();
        let var = g.as_slice().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / 1000.0;
        assert!((var - 1.0).abs() < 0.15, "{var}");
    }
}
