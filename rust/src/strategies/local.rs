//! No-communication baseline: M independent SGD runs.
//!
//! The paper (section 2.1) uses this as the degenerate end of the
//! communication/consensus trade-off: with `K = I` forever, the M models
//! "are likely to be very different and almost impossible to combine".
//! The consensus experiment shows its ε(t) growing without bound.

use crate::error::Result;
use crate::strategies::{Clock, ClusterState, Strategy};
use crate::util::rng::Rng;

/// `K^(t) = I` for all t.
#[derive(Default)]
pub struct Local;

impl Strategy for Local {
    fn name(&self) -> String {
        "local".into()
    }

    fn clock(&self) -> Clock {
        Clock::Synchronous
    }

    fn after_round(&mut self, _t: u64, state: &mut ClusterState, _rng: &mut Rng) -> Result<()> {
        // Record the identity so matrix replays stay aligned per round.
        if state.recorder.is_some() {
            let m = state.workers();
            state.record_matrix(crate::framework::CommMatrix::identity(m + 1));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::engine::Engine;
    use crate::strategies::grad::QuadraticSource;
    use crate::tensor::FlatVec;

    #[test]
    fn workers_drift_apart_without_communication() {
        let dim = 32;
        let src = QuadraticSource::new(dim, 0.3, 1);
        let init = FlatVec::zeros(dim);
        let mut eng = Engine::new(Box::new(Local), src, 4, &init, 0.5, 0.0, 42);
        eng.run(200).unwrap();
        // Different noise streams => nonzero consensus error.
        let eps = eng.state().stacked.consensus_error().unwrap();
        assert!(eps > 1e-4, "eps = {eps}");
        assert_eq!(eng.state().comm.messages, 0);
    }
}
